"""Paper Fig. 2 — cost: on-demand (no ckpt) vs spot + checkpoint protection.

Claims validated: checkpoint-protected spot runs cut ~77% of cost from the
price difference alone, and up to ~86% with transparent checkpointing
(faster completion under evictions -> fewer spot hours)."""

from __future__ import annotations

from .common import CSV_HEADER, run_row

MIN = 60.0
SCALE = 1.0 / 6.0


def main():
    e60 = 60 * MIN * SCALE
    p30 = 30 * MIN * SCALE
    ondemand = run_row("ondemand_nockpt", mode="off", eviction_s=None,
                       instance_kind="ondemand")
    spot_app = run_row("spot_app_evict60", mode="application", eviction_s=e60)
    spot_tr = run_row("spot_transp_evict60", mode="transparent",
                      eviction_s=e60, periodic_s=p30)
    rows = [ondemand, spot_app, spot_tr]
    print(CSV_HEADER)
    for r in rows:
        print(r.csv())
    od = ondemand.cost["total_usd"]
    save_app = 1.0 - spot_app.cost["total_usd"] / od
    save_tr = 1.0 - spot_tr.cost["total_usd"] / od
    print(f"# cost_saving_spot_app_vs_ondemand_pct: {100*save_app:.1f} (paper: ~77)")
    print(f"# cost_saving_spot_transparent_vs_ondemand_pct: {100*save_tr:.1f} (paper: up to 86)")
    return rows


if __name__ == "__main__":
    main()
