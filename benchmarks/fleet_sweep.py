"""Heterogeneous-fleet sweep — Fig. 2/3's scenario replayed **per provider**
plus one mixed multi-cloud fleet.

Per-provider rows run the real trainer (jitted steps, real checkpoints) under
the same virtual-time eviction schedule on each backend — same workload, same
schedule, three clouds — so the cost/runtime comparison isolates what the
provider changes: notice length (30 s / 120 s / 30 s), rebalance hints (AWS)
and prices. The mixed-fleet scenario runs a 3-member azure+aws+gcp fleet
against one shared store with staggered evictions and reports per-provider
cost, eviction counts and elastic-rescale activity.

    PYTHONPATH=src python -m benchmarks.fleet_sweep
"""

from __future__ import annotations

import tempfile

from repro.checkpoint import CheckpointStore
from repro.core import (CheckpointPolicy, FleetCoordinator, FleetSpec,
                        PeriodicEviction, TimeModel, VirtualClock)

from .common import CSV_HEADER, STEP_TIME_S, run_row

MIN = 60.0
SCALE = 1.0 / 6.0


def per_provider_rows():
    e60 = 60 * MIN * SCALE
    p15 = 15 * MIN * SCALE
    rows = []
    for prov in ("azure", "aws", "gcp"):
        rows.append(run_row(f"{prov}_transp_evict60", mode="transparent",
                            eviction_s=e60, periodic_s=p15, provider=prov))
    return rows


def mixed_fleet():
    clock = VirtualClock()
    store = CheckpointStore(tempfile.mkdtemp(prefix="spoton_fleet_"),
                            time_fn=clock.now, retention=10,
                            tags={"fleet": "mixed-3"})
    spec = FleetSpec(
        providers=("azure", "aws", "gcp"),
        schedules=(PeriodicEviction(60 * MIN * SCALE),
                   PeriodicEviction(75 * MIN * SCALE),
                   PeriodicEviction(90 * MIN * SCALE)),
        provisioning_delay_s=120.0)
    fleet = FleetCoordinator(store, CheckpointPolicy.transparent(15 * MIN * SCALE),
                             clock, spec, time_model=TimeModel())
    report = fleet.run(total_steps=185, step_time_s=STEP_TIME_S)
    return report


def main():
    rows = per_provider_rows()
    print(CSV_HEADER)
    for r in rows:
        print(r.csv())
    base = rows[0]
    for r in rows[1:]:
        dt = r.report.total_time_s / base.report.total_time_s - 1.0
        print(f"# {r.provider}: runtime {dt:+.1%} vs azure "
              f"(notice {int({'aws': 120, 'gcp': 30}[r.provider])}s), "
              f"cost ${r.cost['total_usd']:.4f} vs ${base.cost['total_usd']:.4f}")

    print("\n# mixed fleet: azure+aws+gcp, one shared checkpoint store")
    rep = mixed_fleet()
    print(f"# completed={rep.completed} total_s={rep.total_time_s:.0f} "
          f"lost_steps={rep.lost_steps} restores={rep.restores} "
          f"full_outages={rep.full_outages} "
          f"rescales={len(rep.rescale_events)} total_usd={rep.total_usd:.4f}")
    print("provider,evictions,instances,rebalance_recs,term_ckpts,spot_hours,total_usd")
    for name, p in rep.per_provider.items():
        bp = rep.checkpoints["by_provider"][name]
        print(f"{name},{p['evictions']},{p['instances']},"
              f"{p['rebalance_recommendations']},{bp['termination']},"
              f"{p['spot_hours']:.3f},{p['total_usd']:.4f}")
    return rows, rep


if __name__ == "__main__":
    main()
