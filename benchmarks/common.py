"""Shared benchmark machinery: run one Spot-on job under a configured cloud
(virtual time) and report Table-I-style rows."""

from __future__ import annotations

import tempfile
from dataclasses import dataclass

from repro.checkpoint import CheckpointStore
from repro.configs import get_smoke_config
from repro.core import (CheckpointPolicy, CostAccountant, NoEviction,
                        PeriodicEviction, SpotOnCoordinator, TimeModel,
                        VirtualClock, get_provider)
from repro.optim import AdamWConfig
from repro.train import SpotTrainer, TrainJob

# The paper's workload scaled into virtual time: metaSPAdes ran 5 k-mer stages
# in ~3h03m (~37 min/stage) against 60/90-min eviction intervals — stages FIT
# between evictions, which is what lets application-stage checkpointing make
# progress at all. At 1:6 time scale: 5 stages x 37 steps x 10 s = 1850 s of
# pure compute, evictions every 600/900 s, checkpoint/restore costs from the
# TimeModel. Headline RATIOS (overhead %, transparent-vs-application savings,
# cost cuts) are scale-free.
STEP_TIME_S = 10.0
TOTAL_STEPS = 185
N_STAGES = 5


@dataclass
class Row:
    label: str
    mode: str
    eviction_s: float | None
    periodic_s: float | None
    report: object
    cost: dict
    instance_kind: str = "spot"
    provider: str = "azure"

    def csv(self) -> str:
        r = self.report
        stage = ",".join(f"{t:.0f}" for t in r.stage_times_s)
        return (f"{self.label},{self.provider},{self.mode},"
                f"{self.eviction_s or 0:.0f},"
                f"{r.completed},{r.total_time_s:.0f},{stage},"
                f"{r.lost_steps},{r.restores},"
                f"{r.coordinator['termination_ckpts']},"
                f"{self.cost['total_usd']:.4f}")


def run_row(label: str, *, mode: str, eviction_s: float | None,
            periodic_s: float = 900.0, instance_kind: str = "spot",
            provider: str = "azure",
            arch: str = "phi3_mini_3p8b", total_steps: int = TOTAL_STEPS,
            step_time_s: float = STEP_TIME_S, seed: int = 0,
            time_model: TimeModel | None = None,
            quantize_moments: bool = False) -> Row:
    clock = VirtualClock()
    prov = get_provider(provider)
    acct = CostAccountant(prov.prices)
    sched = PeriodicEviction(eviction_s) if eviction_s else NoEviction()
    pool = prov.make_pool(clock, sched, acct, provisioning_delay_s=120.0,
                          kind=instance_kind)
    td = tempfile.mkdtemp(prefix="spoton_bench_")
    store = CheckpointStore(td, time_fn=clock.now,
                            quantize_moments=quantize_moments)
    policy = {"off": CheckpointPolicy.off(),
              "application": CheckpointPolicy.application(),
              "transparent": CheckpointPolicy.transparent(periodic_s)}[mode]
    coord = SpotOnCoordinator(store, policy, clock, provider=prov,
                              time_model=time_model or TimeModel())
    cfg = get_smoke_config(arch)
    job = TrainJob(cfg=cfg, opt=AdamWConfig(total_steps=total_steps),
                   total_steps=total_steps, n_stages=N_STAGES, batch=2,
                   seq_len=16, seed=seed)
    trainer = SpotTrainer(job, coord, pool, clock, step_time_s=step_time_s,
                          max_sessions=100)
    report = trainer.run()
    coord.close()
    # NFS provisioned for the checkpoint volume while the job ran
    acct.provision_storage(max(store.total_bytes(), 1) / 2**30, clock.now())
    return Row(label=label, mode=mode, eviction_s=eviction_s,
               periodic_s=periodic_s, report=report,
               cost=acct.summary(clock.now()), instance_kind=instance_kind,
               provider=prov.name)


CSV_HEADER = ("label,provider,mode,eviction_s,completed,total_s,"
              + ",".join(f"stage{i}_s" for i in range(N_STAGES))
              + ",lost_steps,restores,termination_ckpts,total_usd")
