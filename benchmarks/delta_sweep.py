"""Delta vs. full checkpointing sweep — bytes written and save latency as a
function of parameter churn.

The paper's core economics: checkpoint cost bounds how often you can afford
to checkpoint, and how much an eviction can destroy. Incremental saves cut
the written bytes to the churn since the last committed step, so this sweep
reports, per churn rate, the physical bytes and wall latency of full (v1
shard files) vs delta (content-addressed chunk pool) saves over a short run
of steps.

    PYTHONPATH=src python -m benchmarks.delta_sweep
"""

from __future__ import annotations

import shutil
import tempfile
import time

import numpy as np

from repro.checkpoint import CheckpointStore

CHURN_RATES = (0.01, 0.10, 0.50, 1.00)
N_TENSORS = 16
ROWS, COLS = 256, 1024          # 16 x 1 MB = 16 MB of f32 state
STEPS = 4                       # step 0 is the cold (full) write


def make_state(step: int, churn: float) -> dict:
    """Deterministic state where `churn` of each tensor's rows move per step."""
    rng = np.random.default_rng(1234)
    base = {f"w{i}": rng.standard_normal((ROWS, COLS)).astype(np.float32)
            for i in range(N_TENSORS)}
    dirty_rows = max(1, int(ROWS * churn))
    for i, w in enumerate(base.values()):
        w[:dirty_rows] += float(step * (i + 1))
    base["step"] = step
    return base


def run_store(store: CheckpointStore, churn: float) -> tuple[float, float, float]:
    """Returns (mean bytes written, mean save s, mean restore s) over warm
    steps — restore exercises the mmap/parallel-decode read path."""
    t_bytes, t_lat, t_res = [], [], []
    template = {k: np.zeros_like(v) if isinstance(v, np.ndarray) else 0
                for k, v in make_state(0, churn).items()}
    for step in range(STEPS):
        state = make_state(step, churn)
        t0 = time.perf_counter()
        info = store.save(step, state)
        lat = time.perf_counter() - t0
        t0 = time.perf_counter()
        store.restore(template)
        res = time.perf_counter() - t0
        if step > 0:            # step 0 is the cold full write for both modes
            t_bytes.append(info.new_bytes)
            t_lat.append(lat)
            t_res.append(res)
    return float(np.mean(t_bytes)), float(np.mean(t_lat)), float(np.mean(t_res))


def main() -> None:
    print("churn,mode,bytes_written,save_ms,restore_ms,bytes_vs_full")
    for churn in CHURN_RATES:
        results = {}
        for mode in ("full", "delta"):
            td = tempfile.mkdtemp(prefix=f"spoton_delta_{mode}_")
            try:
                store = CheckpointStore(td, mode=mode, retention=2,
                                        chunk_size=64 * 1024)
                results[mode] = run_store(store, churn)
            finally:
                shutil.rmtree(td, ignore_errors=True)
        full_bytes = results["full"][0]
        for mode in ("full", "delta"):
            b, lat, res = results[mode]
            rel = b / full_bytes if full_bytes else float("nan")
            print(f"{churn:.2f},{mode},{b:.0f},{lat * 1e3:.1f},{res * 1e3:.1f},{rel:.3f}")


if __name__ == "__main__":
    main()
