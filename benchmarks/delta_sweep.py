"""Delta vs. full checkpointing sweep — bytes moved and save latency as a
function of parameter churn.

The paper's core economics: checkpoint cost bounds how often you can afford
to checkpoint, and how much an eviction can destroy. Two costs are swept per
churn rate:

* **bytes written** to the shared store (full v1 shard files vs the
  content-addressed chunk pool), and
* **device→host bytes** of the save's extract leg — with the device-resident
  fingerprint tracker, unchanged blocks never cross the link, so ``d2h_bytes``
  tracks the churn instead of the state size. ``save_stall_ms`` is the wall
  time the trainer is blocked inside extract.

Latencies are **best-of-N per leg** (this box's 9p filesystem has
multi-hundred-ms fsync stalls from noisy neighbours; the bench measures the
code, not the weather). Results land in ``BENCH_ckpt.json`` under a
``delta`` section next to a frozen pre-change ``baseline`` — reruns never
overwrite it, so the D2H/latency ratios are always against the real before.

    PYTHONPATH=src python -m benchmarks.delta_sweep            # full sweep
    PYTHONPATH=src python -m benchmarks.delta_sweep --smoke    # CI guard
"""

from __future__ import annotations

import argparse
import json
import os
import shutil
import sys
import tempfile
import time

import numpy as np

BENCH_JSON = "BENCH_ckpt.json"
CHURN_RATES = (0.01, 0.05, 0.25, 1.00)
N_TENSORS = 16
ROWS, COLS = 256, 1024          # 16 x 1 MiB = 16 MiB of f32 state
CHUNK = 64 * 1024
WARM = 7                        # best-of-7 warm saves; step 0 is the cold write
# CI guard: at 5% churn the dirty-block save must move no more than this
# fraction of the full state over the device→host link
SMOKE_CHURN = 0.05
SMOKE_MAX_D2H_FRAC = 0.35


def base_arrays() -> list[np.ndarray]:
    rng = np.random.default_rng(1234)
    return [rng.standard_normal((ROWS, COLS)).astype(np.float32)
            for _ in range(N_TENSORS)]


def make_state(base, step: int, churn: float) -> dict:
    """Deterministic device state where `churn` of each tensor's rows move
    per step (jnp arrays: the fingerprint path is device-resident)."""
    import jax.numpy as jnp

    dirty_rows = max(1, int(ROWS * churn))
    out = {f"w{i}": jnp.asarray(b).at[:dirty_rows].add(float(step * (i + 1)))
           for i, b in enumerate(base)}
    out["step"] = step
    return out


def run_churn(churn: float, modes=("full", "delta")) -> dict:
    """One churn rate, all modes. The modes' save legs are interleaved step
    by step (not run one whole leg after another) so this box's drifting fs
    weather — multi-hundred-ms 9p fsync stalls arrive in waves — hits every
    mode equally; best-of-N per leg then discards the waves."""
    import jax

    from repro.checkpoint import (CheckpointStore, DeviceDeltaTracker,
                                  extract_snapshot)

    base = base_arrays()
    template = {f"w{i}": np.zeros((ROWS, COLS), np.float32)
                for i in range(N_TENSORS)}
    template["step"] = 0
    stores, trackers, dirs = {}, {}, {}
    acc = {m: {"saves": [], "stalls": [], "restores": [], "info": None}
           for m in modes}
    try:
        for mode in modes:
            dirs[mode] = tempfile.mkdtemp(prefix=f"spoton_delta_{mode}_")
            # "delta_pre" is the pre-change delta save path: same store,
            # same chunk pool + raw-digest memo, no device fingerprints —
            # measured in the same interleaved run for an equal-weather
            # before/after on a box whose fs speed drifts by the minute
            stores[mode] = CheckpointStore(
                dirs[mode], mode="full" if mode == "full" else "delta",
                retention=2, chunk_size=CHUNK)
            trackers[mode] = (DeviceDeltaTracker(
                stores[mode].pool, chunk_size=CHUNK,
                compress=stores[mode].compress) if mode == "delta" else None)
        for step in range(WARM + 1):
            state = make_state(base, step, churn)
            jax.block_until_ready([v for v in state.values()
                                   if hasattr(v, "block_until_ready")])
            # rotate the order each step: a save inherits the previous
            # save's fsync backlog on this box's 9p queue, so a fixed order
            # would systematically tax whichever mode runs last
            order = [modes[(i + step) % len(modes)] for i in range(len(modes))]
            for mode in order:
                t0 = time.perf_counter()
                snap = extract_snapshot(state, step=step,
                                        tracker=trackers[mode])
                info = stores[mode].save_snapshot(snap)
                lat = time.perf_counter() - t0
                if step > 0:    # step 0 is the cold full write for all modes
                    acc[mode]["saves"].append(lat)
                    acc[mode]["stalls"].append(snap.stall_s)
                acc[mode]["info"] = info
        # restore leg after the saves: interleaving reads into the save loop
        # would leak the restore's page-cache/9p traffic into save timings
        for rep in range(WARM):
            for mode in [modes[(i + rep) % len(modes)]
                         for i in range(len(modes))]:
                t0 = time.perf_counter()
                stores[mode].restore(template)
                acc[mode]["restores"].append(time.perf_counter() - t0)
    finally:
        for d in dirs.values():
            shutil.rmtree(d, ignore_errors=True)
    results = {}
    for mode in modes:
        a = acc[mode]
        results[mode] = {
            "d2h_bytes": int(a["info"].d2h_bytes),      # steady state
            "d2h_bytes_skipped": int(a["info"].d2h_bytes_skipped),
            "bytes_written": int(a["info"].new_bytes),
            "save_ms": round(min(a["saves"]) * 1e3, 2),
            "save_stall_ms": round(min(a["stalls"]) * 1e3, 2),
            "restore_ms": round(min(a["restores"]) * 1e3, 2),
        }
    return results


def _repo_json_path() -> str:
    return os.path.join(os.path.dirname(os.path.abspath(__file__)), "..",
                        BENCH_JSON)


def record(results: dict) -> None:
    """Merge this run under BENCH_ckpt.json's ``delta`` section. The
    ``baseline`` subsection is frozen pre-change numbers and is only seeded
    (with a disclaimer) when absent."""
    path = _repo_json_path()
    doc = {}
    if os.path.exists(path):
        try:
            with open(path) as f:
                doc = json.load(f)
        except (OSError, ValueError):
            doc = {}
    delta = doc.setdefault("delta", {})
    delta.setdefault("fixture", f"{N_TENSORS}x{ROWS}x{COLS} float32 "
                     f"(16.8 MB), {CHUNK // 1024} KiB chunks, CPU")
    delta.setdefault("method", f"best of {WARM} warm saves per leg; "
                     "d2h/bytes_written from the steady-state save")
    delta.setdefault("baseline", {
        "recorded": "seeded from the first delta sweep on this machine "
                    "(no frozen pre-change baseline found)",
        **{churn: {"d2h_bytes": leg["delta"]["d2h_bytes"],
                   "save_ms": leg["delta"]["save_ms"]}
           for churn, leg in results.items()}})
    delta["current"] = results
    base = delta["baseline"]
    for churn, leg in results.items():
        b = base.get(churn) or base.get(f"{float(churn):.2f}")
        if not b:
            continue
        cur = leg["delta"]
        if cur.get("d2h_bytes"):
            cur["d2h_reduction_vs_baseline"] = round(
                b["d2h_bytes"] / cur["d2h_bytes"], 2)
        if cur.get("save_ms"):
            cur["save_speedup_vs_baseline"] = round(
                b["save_ms"] / cur["save_ms"], 2)
    try:
        with open(path, "w") as f:
            json.dump(doc, f, indent=2)
            f.write("\n")
        print(f"(recorded to {os.path.relpath(path)})")
    except OSError:
        pass  # read-only checkout still gets its numbers on stdout


def smoke() -> int:
    """CI guard: one low-churn delta leg; fails the build when the dirty-
    block save moves more than SMOKE_MAX_D2H_FRAC of the full state D2H."""
    full_bytes = N_TENSORS * ROWS * COLS * 4
    leg = run_churn(SMOKE_CHURN, modes=("delta",))["delta"]
    frac = leg["d2h_bytes"] / full_bytes
    print(f"smoke: churn={SMOKE_CHURN} d2h_bytes={leg['d2h_bytes']} "
          f"({frac:.1%} of {full_bytes}) save_ms={leg['save_ms']} "
          f"save_stall_ms={leg['save_stall_ms']}")
    if frac > SMOKE_MAX_D2H_FRAC:
        print(f"FAIL: d2h fraction {frac:.1%} exceeds the "
              f"{SMOKE_MAX_D2H_FRAC:.0%} budget at {SMOKE_CHURN:.0%} churn")
        return 1
    print("OK")
    return 0


def main() -> dict:
    print("churn,mode,d2h_bytes,bytes_written,save_ms,save_stall_ms,"
          "restore_ms,bytes_vs_full,d2h_vs_full")
    results: dict[str, dict] = {}
    modes = ("full", "delta_pre", "delta")
    for churn in CHURN_RATES:
        legs = run_churn(churn, modes=modes)
        full_bytes = legs["full"]["bytes_written"]
        full_d2h = legs["full"]["d2h_bytes"]
        for mode in modes:
            leg = legs[mode]
            rel = leg["bytes_written"] / full_bytes if full_bytes else float("nan")
            rel_d2h = leg["d2h_bytes"] / full_d2h if full_d2h else float("nan")
            print(f"{churn:.2f},{mode},{leg['d2h_bytes']},{leg['bytes_written']}"
                  f",{leg['save_ms']:.1f},{leg['save_stall_ms']:.2f}"
                  f",{leg['restore_ms']:.1f},{rel:.3f},{rel_d2h:.3f}")
        if legs["delta"]["save_ms"]:
            legs["delta"]["save_speedup_vs_pre_same_weather"] = round(
                legs["delta_pre"]["save_ms"] / legs["delta"]["save_ms"], 2)
        results[f"{churn:.2f}"] = legs
    record(results)
    return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="single low-churn delta leg with a d2h budget "
                         "assertion (CI)")
    args = ap.parse_args()
    if args.smoke:
        sys.exit(smoke())
    main()
