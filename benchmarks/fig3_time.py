"""Paper Fig. 3 — execution time: application-native vs transparent
checkpointing on spot instances, across eviction intervals."""

from __future__ import annotations

from .common import CSV_HEADER, run_row

MIN = 60.0
SCALE = 1.0 / 6.0


def main():
    rows = []
    for evict_min in (90, 60, 45, 30):
        e = evict_min * MIN * SCALE
        app = run_row(f"app_evict{evict_min}", mode="application", eviction_s=e)
        tr = run_row(f"transp_evict{evict_min}", mode="transparent",
                     eviction_s=e, periodic_s=15 * MIN * SCALE)
        rows += [app, tr]
        save = 1.0 - tr.report.total_time_s / app.report.total_time_s
        print(f"# evict={evict_min}min: transparent saves {100*save:.1f}% time "
              f"(paper band: 15-40%, wider at shorter intervals)")
    print(CSV_HEADER)
    for r in rows:
        print(r.csv())
    return rows


if __name__ == "__main__":
    main()
