"""Beyond-paper E5 — termination-checkpoint feasibility vs notice window.

The paper's termination checkpoints are "opportunistic": they fail if the
write misses the eviction notice (>=30 s on Azure). For a training state of
10 bytes/param (bf16 + fp32 Adam moments), per-host shard bytes determine the
window needed at a given NFS bandwidth. This benchmark sweeps the assigned
architectures and reports (a) whether a termination ckpt fits a 30 s window
at 0.5/2/8 GB/s per-host write bandwidth on 256 hosts, and (b) the effect of
the int8-quantized-moment codec (measured compressed bytes on real tensors,
scaled analytically)."""

from __future__ import annotations

import numpy as np

from repro.checkpoint import serialize as ser
from repro.configs import ARCH_IDS, get_config

HOSTS = 256
NOTICE_S = 30.0
BYTES_PER_PARAM_RAW = 10.0          # bf16 param + fp32 mu + fp32 nu


def measured_int8_ratio() -> float:
    """Measured on-representative moment tensors (zstd over int8+scale)."""
    rng = np.random.default_rng(0)
    m = (rng.standard_normal((1 << 20,)) * 1e-3).astype(np.float32)
    raw = ser.encode_tensor("nu", m, codec="raw").record.nbytes
    q = ser.encode_tensor("nu", m, codec="int8+zstd").record.nbytes
    return q / raw


def main():
    ratio = measured_int8_ratio()
    # params stay bf16-raw; only mu+nu (8 of 10 bytes) take the int8 path
    eff_bpp = 2.0 + 8.0 * ratio
    print("arch,params_B,shard_GiB_raw,shard_GiB_int8,"
          "fits30s@0.5GBps_raw,fits30s@0.5GBps_int8,min_bw_raw_GBps,min_bw_int8_GBps")
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        n = cfg.param_count()
        shard_raw = n * BYTES_PER_PARAM_RAW / HOSTS
        shard_q = n * eff_bpp / HOSTS
        fit_raw = shard_raw / 0.5e9 <= NOTICE_S
        fit_q = shard_q / 0.5e9 <= NOTICE_S
        print(f"{arch},{n/1e9:.1f},{shard_raw/2**30:.2f},{shard_q/2**30:.2f},"
              f"{fit_raw},{fit_q},"
              f"{shard_raw/NOTICE_S/1e9:.2f},{shard_q/NOTICE_S/1e9:.2f}")
    print(f"# int8+zstd moment bytes ratio (measured): {ratio:.3f}")
    print(f"# effective bytes/param: raw={BYTES_PER_PARAM_RAW} -> int8={eff_bpp:.2f}")


if __name__ == "__main__":
    main()
