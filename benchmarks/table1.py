"""Paper Table I — execution time of the staged workload under Spot-on.

Rows (mirroring the paper):
  1. Spot-on OFF, no evictions            (baseline)
  2. Spot-on ON (transparent), no evictions   -> overhead ~ 0
  3. application ckpt, evictions every 90 "min"
  4. application ckpt, evictions every 60 "min"
  5. transparent 30-min periodic, evictions every 90 min
  6. transparent 15-min periodic, evictions every 90 min
  7. transparent 30-min periodic, evictions every 60 min
  8. transparent 15-min periodic, evictions every 60 min

Virtual-time replay: the tiny training workload really executes (state and
checkpoint bytes are real); the clock advances by modeled step/checkpoint/
restore costs. Paper "minutes" are scaled 1:6 (a 90-min interval becomes
900 s of virtual workload time) so relative structure is preserved while the
total virtual span stays comparable to the paper's 3-hour run.
"""

from __future__ import annotations

from .common import CSV_HEADER, Row, run_row

SCALE = 1.0 / 6.0
MIN = 60.0


def rows() -> list[Row]:
    e90 = 90 * MIN * SCALE
    e60 = 60 * MIN * SCALE
    p30 = 30 * MIN * SCALE
    p15 = 15 * MIN * SCALE
    out = [
        run_row("off_noevict", mode="off", eviction_s=None),
        run_row("spoton_noevict", mode="transparent", eviction_s=None,
                periodic_s=p30),
        run_row("app_evict90", mode="application", eviction_s=e90),
        run_row("app_evict60", mode="application", eviction_s=e60),
        run_row("transp30_evict90", mode="transparent", eviction_s=e90,
                periodic_s=p30),
        run_row("transp15_evict90", mode="transparent", eviction_s=e90,
                periodic_s=p15),
        run_row("transp30_evict60", mode="transparent", eviction_s=e60,
                periodic_s=p30),
        run_row("transp15_evict60", mode="transparent", eviction_s=e60,
                periodic_s=p15),
    ]
    return out


def derived_claims(rs: list[Row]) -> dict:
    by = {r.label: r for r in rs}
    base = by["off_noevict"].report.total_time_s
    overhead = by["spoton_noevict"].report.total_time_s / base - 1.0
    save90 = 1.0 - (by["transp30_evict90"].report.total_time_s
                    / by["app_evict90"].report.total_time_s)
    save60 = 1.0 - (by["transp30_evict60"].report.total_time_s
                    / by["app_evict60"].report.total_time_s)
    return {
        "spoton_overhead_pct": 100 * overhead,
        "transparent_vs_app_time_saving_evict90_pct": 100 * save90,
        "transparent_vs_app_time_saving_evict60_pct": 100 * save60,
        "paper_claim": "overhead ~1%; transparent saves 15-40% vs application",
    }


def main():
    rs = rows()
    print(CSV_HEADER)
    for r in rs:
        print(r.csv())
    for k, v in derived_claims(rs).items():
        print(f"# {k}: {v if isinstance(v, str) else round(v, 2)}")
    return rs


if __name__ == "__main__":
    main()
