"""Benchmark driver — one section per paper table/figure + framework extras.

    PYTHONPATH=src python -m benchmarks.run            # everything
    PYTHONPATH=src python -m benchmarks.run table1     # one section

Sections:
  table1   — paper Table I (8 rows, virtual-time replay)
  fig2     — paper Fig. 2 cost comparison
  fig3     — paper Fig. 3 app vs transparent time
  fleet    — beyond-paper: per-provider (azure/aws/gcp) + mixed-fleet sweep
  term     — beyond-paper: termination-ckpt window feasibility (+int8 moments)
  delta    — beyond-paper: delta vs full checkpoint bytes/latency by churn
  micro    — microbenchmarks: checkpoint save/restore/extract throughput
  resume   — fast-resume: restore-to-device throughput + simulated MTTR
  roofline — roofline table from the dry-run JSONs (if present)

Every section that records numbers also appends one line (git sha,
timestamp, numbers) to ``BENCH_trajectory.jsonl`` at the repo root, so the
perf history across PRs stays recoverable even though the per-section JSONs
only keep {baseline, current}.
"""

from __future__ import annotations

import sys
import time


def section(name):
    print(f"\n===== {name} =====", flush=True)


BENCH_JSON = "BENCH_ckpt.json"
TRAJECTORY_JSONL = "BENCH_trajectory.jsonl"


def _repo_path(name: str) -> str:
    import os
    return os.path.join(os.path.dirname(os.path.abspath(__file__)), "..", name)


def record_trajectory(section_name: str, results: dict) -> None:
    """Append one observation to the bench trajectory (never overwrites).

    The per-section BENCH_*.json files hold only {baseline, current}, so a
    rerun loses the point in between; the jsonl is the full time series —
    one line per (sha, section) run, grep/jq-able across the repo history.
    """
    import json
    import os
    import subprocess
    entry = {"ts": round(time.time(), 1), "section": section_name,
             "results": results}
    try:
        entry["git_sha"] = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"], capture_output=True,
            text=True, cwd=os.path.dirname(os.path.abspath(__file__)),
            timeout=10).stdout.strip()
    except (OSError, subprocess.SubprocessError):
        entry["git_sha"] = ""
    try:
        with open(_repo_path(TRAJECTORY_JSONL), "a") as f:
            f.write(json.dumps(entry) + "\n")
    except OSError:
        pass  # a read-only checkout still gets its numbers on stdout


def micro():
    """Checkpoint-path microbenchmarks (real wall time, CPU).

    The 8x512x512 float32 fixture (8.4 MB) runs each hot-path leg 7x and
    records the **best** per-rep GB/s into BENCH_ckpt.json at the repo root,
    next to a frozen ``baseline`` section (the pre-zero-copy hot path,
    measured with this same best-of-7 harness) — the bench trajectory the
    ROADMAP asks for. Best-of-N, not mean: the CI/container filesystem (9p)
    has multi-hundred-ms fsync stalls from noisy neighbours, and the bench
    measures the code, not the weather. Numbers print as CSV either way.
    """
    import json
    import os
    import tempfile

    import numpy as np

    from repro.checkpoint import CheckpointStore, extract_snapshot

    state = {"params": {f"w{i}": np.random.default_rng(i).standard_normal(
        (512, 512)).astype(np.float32) for i in range(8)},
        "step": 7}
    nbytes = sum(a.nbytes for a in state["params"].values())
    results: dict[str, float] = {}
    print("name,best_us_per_call,derived")

    def report(name: str, dts: list) -> None:
        dt = min(dts)
        gbps = nbytes / dt / 1e9
        results[f"{name}_GBps"] = round(gbps, 3)
        print(f"{name},{dt*1e6:.0f},{gbps:.2f}_GBps")

    def timed(fn, *args) -> float:
        t0 = time.perf_counter()
        fn(*args)
        return time.perf_counter() - t0

    reps = 7
    report("extract_snapshot",
           [timed(lambda: extract_snapshot(state, step=7))
            for _ in range(reps)])
    with tempfile.TemporaryDirectory() as td:
        store = CheckpointStore(td, compress=False)
        report("store_save_raw",
               [timed(store.save, i, state) for i in range(reps)])
        store_z = CheckpointStore(td + "_z", compress=True)
        report("store_save_compressed",
               [timed(store_z.save, i, state) for i in range(reps)])
        tpl = {"params": {k: np.zeros_like(v) for k, v in state["params"].items()},
               "step": 0}
        report("store_restore",
               [timed(store.restore, tpl) for _ in range(reps)])

    record_trajectory("micro", results)
    path = _repo_path(BENCH_JSON)
    doc = {}
    if os.path.exists(path):
        try:
            with open(path) as f:
                doc = json.load(f)
        except (OSError, ValueError):
            doc = {}
    doc.setdefault("fixture", "8x512x512 float32 (8.39 MB), CPU")
    doc.setdefault("method", "best of 7 reps per leg")
    # a missing baseline is seeded from this run — and says so, so a wiped
    # file can never masquerade as a meaningful before/after comparison
    doc.setdefault("baseline", {
        "recorded": "seeded from the first micro run on this machine "
                    "(no prior baseline found)", **results})
    doc["current"] = dict(results)
    with open(path, "w") as f:
        json.dump(doc, f, indent=2)
        f.write("\n")
    print(f"(recorded to {os.path.relpath(path)})")


def main() -> None:
    want = set(sys.argv[1:]) or {"table1", "fig2", "fig3", "fleet", "term",
                                 "delta", "micro", "resume", "roofline"}
    if "table1" in want:
        section("Table I: execution time under Spot-on (virtual-time replay)")
        from . import table1
        table1.main()
    if "fig2" in want:
        section("Fig 2: cost, on-demand vs checkpoint-protected spot")
        from . import fig2_cost
        fig2_cost.main()
    if "fig3" in want:
        section("Fig 3: app-native vs transparent checkpointing time")
        from . import fig3_time
        fig3_time.main()
    if "fleet" in want:
        section("fleet: per-provider + heterogeneous multi-cloud fleet")
        from . import fleet_sweep
        fleet_sweep.main()
    if "term" in want:
        section("E5: termination-checkpoint window feasibility")
        from . import term_ckpt_window
        term_ckpt_window.main()
    if "delta" in want:
        section("delta: incremental vs full checkpoint sweep by churn rate")
        from . import delta_sweep
        record_trajectory("delta", delta_sweep.main())
    if "micro" in want:
        section("micro: checkpoint path throughput")
        micro()
    if "resume" in want:
        section("resume: restore-to-device throughput + simulated MTTR")
        from . import resume_bench
        record_trajectory("resume", resume_bench.main())
    if "roofline" in want:
        section("roofline table (from dry-run artifacts)")
        try:
            from . import roofline
            roofline.main()
        except Exception as e:  # dry-run artifacts may not exist yet
            print(f"(skipped: {e})")


if __name__ == "__main__":
    main()
