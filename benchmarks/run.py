"""Benchmark driver — one section per paper table/figure + framework extras.

    PYTHONPATH=src python -m benchmarks.run            # everything
    PYTHONPATH=src python -m benchmarks.run table1     # one section

Sections:
  table1   — paper Table I (8 rows, virtual-time replay)
  fig2     — paper Fig. 2 cost comparison
  fig3     — paper Fig. 3 app vs transparent time
  fleet    — beyond-paper: per-provider (azure/aws/gcp) + mixed-fleet sweep
  term     — beyond-paper: termination-ckpt window feasibility (+int8 moments)
  delta    — beyond-paper: delta vs full checkpoint bytes/latency by churn
  micro    — microbenchmarks: checkpoint save/restore/extract throughput
  roofline — roofline table from the dry-run JSONs (if present)
"""

from __future__ import annotations

import sys
import time


def section(name):
    print(f"\n===== {name} =====", flush=True)


def micro():
    """Checkpoint-path microbenchmarks (real wall time, CPU)."""
    import tempfile

    import numpy as np

    from repro.checkpoint import CheckpointStore, extract_snapshot

    state = {"params": {f"w{i}": np.random.default_rng(i).standard_normal(
        (512, 512)).astype(np.float32) for i in range(8)},
        "step": 7}
    nbytes = sum(a.nbytes for a in state["params"].values())
    print("name,us_per_call,derived")
    t0 = time.perf_counter()
    reps = 5
    for _ in range(reps):
        snap = extract_snapshot(state, step=7)
    dt = (time.perf_counter() - t0) / reps
    print(f"extract_snapshot,{dt*1e6:.0f},{nbytes/dt/1e9:.2f}_GBps")
    with tempfile.TemporaryDirectory() as td:
        store = CheckpointStore(td, compress=False)
        t0 = time.perf_counter()
        for i in range(reps):
            store.save(i, state)
        dt = (time.perf_counter() - t0) / reps
        print(f"store_save_raw,{dt*1e6:.0f},{nbytes/dt/1e9:.2f}_GBps")
        store_z = CheckpointStore(td + "_z", compress=True)
        t0 = time.perf_counter()
        for i in range(reps):
            store_z.save(i, state)
        dt = (time.perf_counter() - t0) / reps
        print(f"store_save_zstd,{dt*1e6:.0f},{nbytes/dt/1e9:.2f}_GBps")
        tpl = {"params": {k: np.zeros_like(v) for k, v in state["params"].items()},
               "step": 0}
        t0 = time.perf_counter()
        for _ in range(reps):
            store.restore(tpl)
        dt = (time.perf_counter() - t0) / reps
        print(f"store_restore,{dt*1e6:.0f},{nbytes/dt/1e9:.2f}_GBps")


def main() -> None:
    want = set(sys.argv[1:]) or {"table1", "fig2", "fig3", "fleet", "term",
                                 "delta", "micro", "roofline"}
    if "table1" in want:
        section("Table I: execution time under Spot-on (virtual-time replay)")
        from . import table1
        table1.main()
    if "fig2" in want:
        section("Fig 2: cost, on-demand vs checkpoint-protected spot")
        from . import fig2_cost
        fig2_cost.main()
    if "fig3" in want:
        section("Fig 3: app-native vs transparent checkpointing time")
        from . import fig3_time
        fig3_time.main()
    if "fleet" in want:
        section("fleet: per-provider + heterogeneous multi-cloud fleet")
        from . import fleet_sweep
        fleet_sweep.main()
    if "term" in want:
        section("E5: termination-checkpoint window feasibility")
        from . import term_ckpt_window
        term_ckpt_window.main()
    if "delta" in want:
        section("delta: incremental vs full checkpoint sweep by churn rate")
        from . import delta_sweep
        delta_sweep.main()
    if "micro" in want:
        section("micro: checkpoint path throughput")
        micro()
    if "roofline" in want:
        section("roofline table (from dry-run artifacts)")
        try:
            from . import roofline
            roofline.main()
        except Exception as e:  # dry-run artifacts may not exist yet
            print(f"(skipped: {e})")


if __name__ == "__main__":
    main()
