"""Deliverable (g): roofline table from the dry-run JSONs.

Reads results/dryrun/<tag>/<mesh>/ and emits the per-(arch x shape x mesh)
three-term roofline with dominant bottleneck, MODEL_FLOPS/HLO_FLOPS ratio,
and a one-line "what would move the dominant term" note."""

from __future__ import annotations

import glob
import json
import os

NOTES = {
    ("compute",): "raise MXU utilization: fewer remat recomputes, larger microbatches",
    ("memory", "train"): "cut HBM traffic: fuse CE/unembed, bf16 activations end-to-end, larger microbatch reuse",
    ("memory", "prefill"): "KV/activation traffic: flash-attention kernel residency, wider q-chunks",
    ("memory", "decode"): "weight/cache streaming bound (expected for decode): batch more requests per step",
    ("collective", "train"): "overlap grad reduce-scatter with bwd; shard weights so all-gathers amortize across microbatches",
    ("collective", "prefill"): "reorder TP collectives; all-gather KV once per layer",
    ("collective", "decode"): "shrink per-token all-gathers: keep weights TP-resident",
}


def note_for(dominant: str, kind: str) -> str:
    return NOTES.get((dominant, kind)) or NOTES.get((dominant,)) or ""


def load(tag: str = "baseline", root: str = "results/dryrun"):
    rows = []
    for path in sorted(glob.glob(os.path.join(root, tag, "*", "*.json"))):
        d = json.load(open(path))
        rows.append(d)
    return rows


def main(tag: str = "baseline"):
    rows = load(tag)
    print("mesh,arch,shape,status,dominant,compute_s,memory_s,collective_s,"
          "bound_s,model_flops,hlo_flops_global,useful_frac,live_GiB_per_dev,note")
    kinds = {"train_4k": "train", "prefill_32k": "prefill",
             "decode_32k": "decode", "long_500k": "decode"}
    for r in sorted(rows, key=lambda r: (r["mesh"], r["arch"], r["shape"])):
        if r["status"] != "ok":
            print(f"{r['mesh']},{r['arch']},{r['shape']},{r['status']},,,,,,,,,,"
                  f"{r.get('reason', '')[:60]}")
            continue
        t = r["roofline"]
        kind = kinds[r["shape"]]
        print(f"{r['mesh']},{r['arch']},{r['shape']},ok,{t['dominant']},"
              f"{t['compute_s']:.3e},{t['memory_s']:.3e},{t['collective_s']:.3e},"
              f"{t['bound_s']:.3e},{r['model_flops']:.3e},"
              f"{r['hlo_flops_global']:.3e},"
              f"{(r['useful_flops_frac'] or 0):.3f},"
              f"{r['memory']['live_bytes']/2**30:.2f},"
              f"\"{note_for(t['dominant'], kind)}\"")


if __name__ == "__main__":
    import sys
    main(sys.argv[1] if len(sys.argv) > 1 else "baseline")
