"""Fast-resume microbenchmark — the eviction→first-step-back window.

Legs:

* **restore-to-device** (wall time, CPU): the same committed checkpoint
  (float32 params + int8-quantized mu/nu optimizer moments, the urgent-save
  shape) restored two ways — the pre-change path (serial public restore to
  host numpy, then ``jax.device_put`` per leaf) vs the streaming pipeline
  (``store.restore(..., streaming=True)`` into a device-sharded template:
  read→decode→H2D overlapped, int8 payloads widened on device). Best-of-7
  per leg — the bench box's 9p filesystem has multi-hundred-ms fsync/IO
  stalls from noisy neighbours, and the bench measures the code, not the
  weather. GB/s is logical (dequantized) bytes over wall time.

* **contended restore** (wall time): the same streaming restore while 1/2/4
  concurrent writers save into the same pool — restore QoS under load. The
  1-writer figure gates CI against the frozen pre-scheduler collapse
  (0.269 GB/s, a ~7x drop from idle under fair-share executors).

* **restore storm** (hybrid): N replacement instances restore from one pool
  simultaneously after a capacity outage while a survivor keeps saving;
  per-member MTTR-under-storm = spot_sim-derived provisioning gap (virtual)
  + measured concurrent restore wall time (physical).

* **pod restore** (hybrid): an N-member fleet with a peer chunk exchange;
  one member's eviction notice seeds the survivors' local pools, then the
  replacement restores warm through peer read-through vs cold off a
  bandwidth-modeled shared store (reads serialize at 0.05 GB/s — the
  contended multi-tenant figure after an outage). Reports ``pod_restore_GBps``
  (warm),
  ``pod_restore_cold_GBps``, ``peer_hit_rate`` and ``mttr_replacement_s``
  (spot_sim provisioning gap + measured warm restore wall). The warm figure
  gates CI at ≥1.5× the frozen cold baseline.

* **object store** (wall time): the same committed checkpoint with its
  chunks in an in-process S3-style store behind a modeled link (2 ms
  per-op latency, reads/writes serialize at 1 GB/s — a same-region object
  store). Cold: the replacement's read-through cache is wiped each rep, so
  every chunk is a verified ranged GET across the link; warm: the cache
  holds every chunk and restore is the untouched local mmap path. The warm
  figure gates CI at ≥1.5× the frozen cold baseline — the read-through
  cache earning its disk.

* **simulated MTTR** (virtual time): a transparent-mode spot run with
  periodic evictions; reports the coordinator's measured
  eviction→first-step-back windows (provisioning + restore + recompile +
  data seek). Restore decode wall time is charged onto the virtual clock
  (``TimeLedger.charge_measured``), so samples are wall-clock-coupled and
  distinct — a real measurement, not the model's constant.

Results land in ``BENCH_resume.json`` next to a ``baseline`` section frozen
from the **pre-change** code — reruns never overwrite it, so the ≥1.5×
acceptance ratio is always against the real before.
"""

from __future__ import annotations

import json
import os
import tempfile
import time

import numpy as np

BENCH_JSON = "BENCH_resume.json"
N_TENSORS = 8
SHAPE = (512, 512)
REPS = 7


def fixture_state():
    """float32 params + optimizer moments; moments int8-quantize on save."""
    rng = np.random.default_rng(0)
    params = {f"w{i}": rng.standard_normal(SHAPE).astype(np.float32)
              for i in range(N_TENSORS)}
    mu = {f"w{i}": rng.standard_normal(SHAPE).astype(np.float32) * 1e-2
          for i in range(N_TENSORS)}
    nu = {f"w{i}": np.abs(rng.standard_normal(SHAPE)).astype(np.float32) * 1e-4
          for i in range(N_TENSORS)}
    return {"params": params, "opt": {"mu": mu, "nu": nu}, "step": 7}


def bench_restore_to_device() -> dict:
    import jax

    from repro.checkpoint import CheckpointStore
    from repro.train import state_template, state_template_on_device

    state = fixture_state()
    nbytes = sum(a.nbytes for a in jax.tree.leaves(state)
                 if hasattr(a, "nbytes"))
    # the same template builders the trainer's resume path uses, so the
    # bench measures the production restore path, not a hand-rolled twin
    host_tpl = state_template(state)
    dev_tpl = state_template_on_device(state)
    results: dict = {}
    with tempfile.TemporaryDirectory() as td:
        store = CheckpointStore(td, compress=False, quantize_moments=True)
        store.save(7, state)

        def serial_leg():
            got, _ = store.restore(host_tpl)
            dev = jax.tree.map(jax.device_put, got)
            jax.block_until_ready(dev)
            return dev

        def streaming_leg():
            got, _ = store.restore(dev_tpl, streaming=True)
            jax.block_until_ready(got)
            return got

        # parity first (also warms caches): streaming must be bit-identical
        a, b = serial_leg(), streaming_leg()
        for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
        results["parity"] = True

        for name, leg in (("serial_restore_then_put", serial_leg),
                          ("streaming_restore_to_device", streaming_leg)):
            dts = []
            for _ in range(REPS):
                t0 = time.perf_counter()
                leg()
                dts.append(time.perf_counter() - t0)
            best = min(dts)
            results[f"{name}_GBps"] = round(nbytes / best / 1e9, 3)
            results[f"{name}_best_us"] = round(best * 1e6)
            print(f"{name},{best*1e6:.0f}us,{nbytes/best/1e9:.2f}_GBps")
    return results


def bench_contended_restore(n_writers: int = 1) -> dict:
    """Contended MTTR leg: restore throughput while ``n_writers`` concurrent
    writers save against the *same* store (ROADMAP "Restore QoS") — after an
    outage the surviving fleet members keep checkpointing into the shared
    volume, so the replacement's restore competes for the codec workers.
    With the priority scheduler the restore jumps every queued periodic
    encode and running encodes yield between chunks, so the figure should
    track the idle number instead of collapsing ~7x (the frozen 0.27 GB/s
    pre-scheduler baseline). Reports best-of-N restore GB/s under load next
    to the idle figure the main leg measures."""
    import threading

    import jax
    import numpy as np

    from repro.checkpoint import CheckpointStore, DeviceDeltaTracker
    from repro.train import state_template_on_device

    state = fixture_state()
    nbytes = sum(a.nbytes for a in jax.tree.leaves(state)
                 if hasattr(a, "nbytes"))
    dev_tpl = state_template_on_device(state)
    results: dict = {}
    with tempfile.TemporaryDirectory() as td:
        # retention high enough that the writer's steps never gc the
        # restored step out from under the bench
        store = CheckpointStore(td, compress=False, quantize_moments=True,
                                retention=400)
        store.save(7, state)

        # writers: periodic low-churn delta saves through the device-delta
        # tracker — the steady-state save shape the fleet actually runs.
        # Each writer owns a disjoint step range and its own tracker (one
        # tracker per training process, as in production).
        stop = threading.Event()
        saved = [0] * n_writers

        def writer(wi: int):
            import jax.numpy as jnp
            tracker = DeviceDeltaTracker(store.pool,
                                         chunk_size=store.chunk_size,
                                         compress=store.compress)
            base = {k: jnp.asarray(np.asarray(v) + 1.0 + wi)
                    for k, v in state["params"].items()}
            step = 100 + 10_000 * wi
            while not stop.is_set():
                step += 1
                st = {"params": {k: v.at[:8].add(float(step))
                                 for k, v in base.items()}, "step": step}
                try:
                    store.save(step, st, tracker=tracker)
                    saved[wi] += 1
                except OSError:
                    break

        threads = [threading.Thread(target=writer, args=(wi,), daemon=True)
                   for wi in range(n_writers)]
        for t in threads:
            t.start()
        try:
            dts = []
            for _ in range(REPS):
                t0 = time.perf_counter()
                got, _ = store.restore(dev_tpl, step=7, streaming=True)
                jax.block_until_ready(got)
                dts.append(time.perf_counter() - t0)
        finally:
            stop.set()
            for t in threads:
                t.join(timeout=30)
        best = min(dts)
        suffix = "" if n_writers == 1 else f"_{n_writers}w"
        results[f"contended_streaming_restore{suffix}_GBps"] = round(
            nbytes / best / 1e9, 3)
        results[f"contended_writer_saves{suffix}"] = sum(saved)
        print(f"contended_streaming_restore[{n_writers}w],{best*1e6:.0f}us,"
              f"{nbytes/best/1e9:.2f}_GBps,writer_saves={sum(saved)}")
    return results


def bench_restore_storm(n_instances: int = 4) -> dict:
    """Fleet-wide restore storm: a capacity outage ends and ``n_instances``
    replacements restore from one shared pool *simultaneously*, while a
    surviving member keeps saving into it. The spot simulator supplies each
    member's provisioning gap (TraceEviction → replacement pays the
    provider's provisioning delay on a virtual clock); the restores
    themselves physically execute concurrently on wall clock. MTTR-under-
    storm per member = simulated provisioning gap + its measured restore
    wall time — the post-outage number reliability-aware provisioners treat
    as SLA-binding, and exactly the window the RESTORE lane protects."""
    import threading

    import jax
    import numpy as np

    from repro.checkpoint import CheckpointStore, DeviceDeltaTracker
    from repro.core import TraceEviction, VirtualClock, get_provider
    from repro.train import state_template_on_device

    state = fixture_state()
    nbytes = sum(a.nbytes for a in jax.tree.leaves(state)
                 if hasattr(a, "nbytes"))
    providers = ["azure", "aws", "gcp"]
    # simulated leg: one pool per member, eviction at t=10 s, replacement
    # pays the 120 s provisioning delay — wait_for_instance walks the
    # virtual clock through death + gap, giving each member a real
    # spot_sim-derived provisioning window
    gaps = []
    for i in range(n_instances):
        clock = VirtualClock()
        prov = get_provider(providers[i % len(providers)])
        pool = prov.make_pool(clock, TraceEviction((10.0,)), None,
                              provisioning_delay_s=120.0)
        pool.start()
        inst = pool.wait_for_instance()
        clock.advance(10.0 + (pool.notice_s or 0.0) + 1.0)
        while pool.tick() is not None:      # ride the notice out
            clock.sleep(1.0)
        died_at = clock.now()
        pool.wait_for_instance()
        gaps.append(clock.now() - died_at)
        pool.shutdown()

    results: dict = {}
    with tempfile.TemporaryDirectory() as td:
        store = CheckpointStore(td, compress=False, quantize_moments=True,
                                retention=400)
        store.save(7, state)
        stop = threading.Event()

        def survivor():
            import jax.numpy as jnp
            tracker = DeviceDeltaTracker(store.pool,
                                         chunk_size=store.chunk_size,
                                         compress=store.compress)
            base = {k: jnp.asarray(np.asarray(v) + 1.0)
                    for k, v in state["params"].items()}
            step = 100
            while not stop.is_set():
                step += 1
                st = {"params": {k: v.at[:8].add(float(step))
                                 for k, v in base.items()}, "step": step}
                try:
                    store.save(step, st, tracker=tracker)
                except OSError:
                    break

        # each member restores to its own device template (concurrently)
        tpls = [state_template_on_device(state) for _ in range(n_instances)]
        walls = [0.0] * n_instances
        errs = []
        barrier = threading.Barrier(n_instances)

        def member(i: int):
            try:
                barrier.wait(timeout=60)     # everyone restores at once
                t0 = time.perf_counter()
                got, _ = store.restore(tpls[i], step=7, streaming=True)
                jax.block_until_ready(got)
                walls[i] = time.perf_counter() - t0
            except BaseException as e:
                errs.append(e)

        wt = threading.Thread(target=survivor, daemon=True)
        wt.start()
        try:
            t0_all = time.perf_counter()
            threads = [threading.Thread(target=member, args=(i,), daemon=True)
                       for i in range(n_instances)]
            for t in threads:
                t.start()
            for t in threads:
                t.join(timeout=120)
            span = time.perf_counter() - t0_all
        finally:
            stop.set()
            wt.join(timeout=30)
        if errs:
            raise errs[0]
        mttrs = [g + w for g, w in zip(gaps, walls)]
        results["storm_instances"] = n_instances
        results["storm_aggregate_GBps"] = round(
            n_instances * nbytes / span / 1e9, 3)
        results["storm_restore_walls_s"] = [round(w, 3) for w in walls]
        results["mttr_under_storm_samples_s"] = [round(m, 2) for m in mttrs]
        results["mttr_under_storm_mean_s"] = round(sum(mttrs) / len(mttrs), 2)
        results["mttr_under_storm_max_s"] = round(max(mttrs), 2)
        print(f"restore_storm,n={n_instances},"
              f"aggregate={results['storm_aggregate_GBps']}_GBps,"
              f"mttr_mean={results['mttr_under_storm_mean_s']}s,"
              f"mttr_max={results['mttr_under_storm_max_s']}s")
    return results


def bench_pod_restore(n_members: int = 3) -> dict:
    """Pod-restore leg: replacement warm-from-peers vs cold-from-store.

    Models the pod economics the peer exchange exists for: the shared store
    sits behind a contended link (reads serialize at ``SHARED_GBPS`` — the
    multi-tenant object-store/NFS figure, orders below NIC speed), while
    surviving members' local pools answer at loopback speed. One member gets
    the eviction notice and seeds the survivors (``seed_from``); the
    replacement then restores twice per rep from the same committed
    checkpoint — cold straight off the modeled store vs warm through its
    peer read-through pool (local pool wiped each rep: a replacement starts
    empty). Reports ``pod_restore_GBps`` (warm) / ``pod_restore_cold_GBps``,
    ``peer_hit_rate``, and ``mttr_replacement_s`` = spot_sim provisioning
    gap + measured warm restore wall."""
    import shutil
    import threading

    import jax

    from repro.checkpoint import CheckpointStore, chunkstore, peer_exchange
    from repro.core import TraceEviction, VirtualClock, get_provider
    from repro.train import state_template_on_device

    # contended multi-tenant shared-storage read bandwidth: every evicted
    # pod's replacements hammer the same volume after an outage, so the
    # per-reader share sits far below the idle figure
    SHARED_GBPS = 0.05

    class _ModeledSharedPool(chunkstore.ChunkPool):
        """The shared store behind a saturated link: every chunk read pays
        nbytes/bandwidth on a single serializing 'link' lock. Bench-only
        model — the sleep-under-lock is the contention being modeled."""

        def __init__(self, root: str, gbps: float):
            super().__init__(root)
            self._gbps = gbps
            self._link = threading.Lock()

        def chunk_path(self, ref):
            with self._link:
                time.sleep(ref.nbytes / (self._gbps * 1e9))
            return self.path(ref.hash)

    # spot_sim-derived provisioning gap for the replacement (virtual time)
    clock = VirtualClock()
    pool = get_provider("aws").make_pool(clock, TraceEviction((10.0,)), None,
                                         provisioning_delay_s=120.0)
    pool.start()
    pool.wait_for_instance()
    clock.advance(10.0 + (pool.notice_s or 0.0) + 1.0)
    while pool.tick() is not None:
        clock.sleep(1.0)
    died_at = clock.now()
    pool.wait_for_instance()
    provisioning_gap_s = clock.now() - died_at
    pool.shutdown()

    state = fixture_state()
    nbytes = sum(a.nbytes for a in jax.tree.leaves(state)
                 if hasattr(a, "nbytes"))
    dev_tpl = state_template_on_device(state)
    results: dict = {}
    with tempfile.TemporaryDirectory() as td:
        store = CheckpointStore(os.path.join(td, "store"), compress=False,
                                quantize_moments=True)
        store.save(7, state)
        man, reader = store.latest_valid()
        reader.close()
        slow_shared = _ModeledSharedPool(store.pool.root, SHARED_GBPS)
        exchange = peer_exchange.FleetPeerExchange(
            os.path.join(td, "fabric"), n_members)
        try:
            # member 0 takes the eviction notice and seeds the survivors
            # (from its committed chunks — here the store pool stands in
            # for its instance-local copy of the last save)
            seed = exchange.seed_from(0, store.pool,
                                      sorted(man.chunk_hashes()))
            results["pod_seeded_chunks"] = seed["chunks"]
            results["pod_seeded_MB"] = round(seed["bytes"] / 1e6, 2)

            cold_walls, warm_walls, hit_rates = [], [], []
            local_pool = exchange.members[0][0]
            for _ in range(REPS):
                # cold: straight off the contended shared store
                t0 = time.perf_counter()
                got, _ = store.restore(dev_tpl, streaming=True,
                                       chunk_pool=slow_shared)
                jax.block_until_ready(got)
                cold_walls.append(time.perf_counter() - t0)

                # warm: the replacement reuses member 0's slot with an
                # EMPTY local pool and read-through to the seeded peers
                shutil.rmtree(local_pool.root, ignore_errors=True)
                rt = exchange.read_through(0, slow_shared)
                t0 = time.perf_counter()
                got, _ = store.restore(dev_tpl, streaming=True,
                                       chunk_pool=rt)
                jax.block_until_ready(got)
                warm_walls.append(time.perf_counter() - t0)
                cs = rt.client.stats
                if cs["hits"] + cs["misses"]:
                    hit_rates.append(cs["hits"]
                                     / (cs["hits"] + cs["misses"]))
        finally:
            exchange.close()

    cold, warm = min(cold_walls), min(warm_walls)
    results["pod_members"] = n_members
    results["pod_restore_cold_GBps"] = round(nbytes / cold / 1e9, 3)
    results["pod_restore_GBps"] = round(nbytes / warm / 1e9, 3)
    results["pod_warm_vs_cold_x"] = round(cold / warm, 2)
    results["peer_hit_rate"] = round(
        sum(hit_rates) / len(hit_rates), 4) if hit_rates else 0.0
    results["mttr_replacement_s"] = round(
        provisioning_gap_s + sum(warm_walls) / len(warm_walls), 2)
    results["mttr_replacement_cold_s"] = round(
        provisioning_gap_s + sum(cold_walls) / len(cold_walls), 2)
    print(f"pod_restore,n={n_members},"
          f"warm={results['pod_restore_GBps']}_GBps,"
          f"cold={results['pod_restore_cold_GBps']}_GBps,"
          f"x={results['pod_warm_vs_cold_x']},"
          f"hit_rate={results['peer_hit_rate']},"
          f"mttr={results['mttr_replacement_s']}s")
    return results


def bench_object_store() -> dict:
    """Object-store leg: cold ranged-GET restore vs warm local-cache restore.

    The committed checkpoint's chunks live in an in-process S3-style store
    behind a modeled link (2 ms per-op latency, reads serialize at 1 GB/s —
    a same-region object store). Cold: the replacement's read-through cache
    is wiped each rep, so every chunk crosses the link as a verified ranged
    GET and lands in the cache on the way through. Warm: the cache already
    holds every chunk, so restore is the untouched local mmap path and the
    server sees zero additional GETs. The warm/cold ratio is the
    read-through cache earning its disk; CI gates warm ≥ ``OBJSTORE_GATE_X``
    × the frozen cold figure."""
    import shutil

    import jax

    from repro.checkpoint import CheckpointStore
    from repro.checkpoint import backend as chunk_backend
    from repro.train import state_template_on_device

    state = fixture_state()
    nbytes = sum(a.nbytes for a in jax.tree.leaves(state)
                 if hasattr(a, "nbytes"))
    dev_tpl = state_template_on_device(state)
    results: dict = {}
    server = chunk_backend.InProcessObjectStore(
        network=chunk_backend.NetworkModel(latency_s=0.002, gbps=1.0))
    with tempfile.TemporaryDirectory() as td:
        store = CheckpointStore(os.path.join(td, "store"), compress=False,
                                quantize_moments=True,
                                backend=chunk_backend.ObjectStoreBackend(
                                    server))
        store.save(7, state)
        cache_root = store.pool.root
        cold_walls, warm_walls = [], []
        gets_before_warm = 0
        for _ in range(REPS):
            # cold: empty cache, every chunk is a ranged GET over the link
            shutil.rmtree(cache_root, ignore_errors=True)
            t0 = time.perf_counter()
            got, _ = store.restore(dev_tpl, streaming=True)
            jax.block_until_ready(got)
            cold_walls.append(time.perf_counter() - t0)

            # warm: the cold pass populated the cache; the link goes quiet
            gets_before_warm = server.stats["gets"]
            t0 = time.perf_counter()
            got, _ = store.restore(dev_tpl, streaming=True)
            jax.block_until_ready(got)
            warm_walls.append(time.perf_counter() - t0)
        warm_gets = server.stats["gets"] - gets_before_warm
        pool_stats = dict(store.pool.stats)

    cold, warm = min(cold_walls), min(warm_walls)
    results["objstore_cold_restore_GBps"] = round(nbytes / cold / 1e9, 3)
    results["objstore_restore_GBps"] = round(nbytes / warm / 1e9, 3)
    results["objstore_warm_vs_cold_x"] = round(cold / warm, 2)
    results["objstore_warm_gets"] = warm_gets
    results["objstore_cache_hits"] = pool_stats.get("cache_hits", 0)
    results["objstore_backend_reads"] = pool_stats.get("backend_reads", 0)
    print(f"objstore_restore,"
          f"warm={results['objstore_restore_GBps']}_GBps,"
          f"cold={results['objstore_cold_restore_GBps']}_GBps,"
          f"x={results['objstore_warm_vs_cold_x']},"
          f"warm_gets={warm_gets}")
    return results


def bench_mttr() -> dict:
    from .common import run_row

    # short virtual-time run: evictions every 250 s against 10 s steps, so
    # the MTTR windows (120 s provisioning + modeled restore + notice tail)
    # are exercised a handful of times without CI-hostile wall cost
    row = run_row("resume_mttr", mode="transparent", eviction_s=250.0,
                  periodic_s=100.0, total_steps=60)
    coord = row.report.coordinator
    samples = coord.get("mttr_samples", [])
    out = {
        "mttr_mean_s": round(coord.get("mttr_mean_s", 0.0), 3),
        # 3 decimals: the samples are wall-clock-coupled now (measured
        # restore time charged onto the virtual clock), and the rounding
        # must not collapse them back into one constant
        "mttr_samples_s": [round(s, 3) for s in samples],
        "evictions": row.report.evictions_seen,
        "restores": row.report.restores,
    }
    print(f"simulated_mttr_mean_s,{out['mttr_mean_s']}"
          f",n={len(samples)},restores={out['restores']}")
    return out


# restore-under-one-writer must stay at least this multiple of the frozen
# pre-scheduler collapse (0.269 GB/s) — the CI smoke gate for restore QoS
CONTENDED_GATE_X = 3.0

# replacement warm-from-peers must beat cold-from-store by at least this on
# the same box — the CI smoke gate for the peer exchange
POD_GATE_X = 1.5

# warm (read-through-cached) restore must beat the frozen cold object-store
# figure by at least this — the CI smoke gate for the backend cache
OBJSTORE_GATE_X = 1.5


def main() -> dict:
    results = bench_restore_to_device()
    for n_writers in (1, 2, 4):
        results.update(bench_contended_restore(n_writers))
    results.update(bench_restore_storm())
    results.update(bench_pod_restore())
    results.update(bench_object_store())
    results.update(bench_mttr())
    from repro.checkpoint import codec_sched
    sched = codec_sched.snapshot_stats()
    results["scheduler_yields"] = sched["yields"]
    results["scheduler_restore_queue_wait_s"] = round(
        sched["restore"]["queue_wait_s"], 4)
    path = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..",
                        BENCH_JSON)
    doc = {}
    if os.path.exists(path):
        try:
            with open(path) as f:
                doc = json.load(f)
        except (OSError, ValueError):
            doc = {}
    doc.setdefault("fixture", f"{N_TENSORS}x{SHAPE[0]}x{SHAPE[1]} float32 "
                   "params + int8-quantized mu/nu moments (25.2 MB logical), "
                   "CPU")
    doc.setdefault("method", f"best of {REPS} reps per leg; GB/s over "
                   "logical bytes")
    # a missing baseline is seeded from this run — and says so, so a wiped
    # file can never masquerade as a meaningful before/after comparison
    doc.setdefault("baseline", {
        "recorded": "seeded from the first resume bench on this machine "
                    "(no frozen pre-change baseline found)",
        "restore_to_device_GBps": results.get(
            "serial_restore_then_put_GBps", 0.0)})
    # the pre-scheduler contended collapse, frozen the same way: first run
    # on a file without the key seeds it (the checked-in file carries the
    # real pre-change 0.269), later runs never overwrite it
    doc["baseline"].setdefault(
        "contended_restore_GBps",
        results.get("contended_streaming_restore_GBps", 0.0))
    # the pre-peer-exchange cold pod restore, frozen the same way: the
    # checked-in file carries the real pre-change figure, reruns keep it
    doc["baseline"].setdefault(
        "pod_cold_restore_GBps",
        results.get("pod_restore_cold_GBps", 0.0))
    # the cold object-store restore over the modeled link, frozen the same
    # way: first run seeds it, reruns never overwrite it
    doc["baseline"].setdefault(
        "objstore_cold_restore_GBps",
        results.get("objstore_cold_restore_GBps", 0.0))
    base = doc["baseline"].get("restore_to_device_GBps", 0.0)
    cur = results.get("streaming_restore_to_device_GBps", 0.0)
    if base:
        results["speedup_vs_frozen_baseline"] = round(cur / base, 2)
        print(f"speedup_vs_frozen_baseline,{results['speedup_vs_frozen_baseline']}x")
    cbase = doc["baseline"].get("contended_restore_GBps", 0.0)
    ccur = results.get("contended_streaming_restore_GBps", 0.0)
    if cbase:
        results["contended_speedup_vs_frozen_baseline"] = round(ccur / cbase, 2)
        print("contended_speedup_vs_frozen_baseline,"
              f"{results['contended_speedup_vs_frozen_baseline']}x")
    pbase = doc["baseline"].get("pod_cold_restore_GBps", 0.0)
    pcur = results.get("pod_restore_GBps", 0.0)
    if pbase:
        results["pod_speedup_vs_frozen_cold"] = round(pcur / pbase, 2)
        print(f"pod_speedup_vs_frozen_cold,"
              f"{results['pod_speedup_vs_frozen_cold']}x")
    obase = doc["baseline"].get("objstore_cold_restore_GBps", 0.0)
    ocur = results.get("objstore_restore_GBps", 0.0)
    if obase:
        results["objstore_speedup_vs_frozen_cold"] = round(ocur / obase, 2)
        print(f"objstore_speedup_vs_frozen_cold,"
              f"{results['objstore_speedup_vs_frozen_cold']}x")
    doc["current"] = results
    with open(path, "w") as f:
        json.dump(doc, f, indent=2)
        f.write("\n")
    print(f"(recorded to {os.path.relpath(path)})")
    # restore-QoS smoke gate: restore under one concurrent writer must not
    # collapse back toward the pre-scheduler fair-share behaviour
    if cbase and ccur < CONTENDED_GATE_X * cbase:
        raise SystemExit(
            f"restore QoS regression: contended restore {ccur} GB/s < "
            f"{CONTENDED_GATE_X}x frozen baseline {cbase} GB/s")
    # pod-restore smoke gate: warm-from-peers must clearly beat the frozen
    # cold-from-store figure on the same box, or the exchange isn't earning
    # its sockets
    if pbase and pcur < POD_GATE_X * pbase:
        raise SystemExit(
            f"peer exchange regression: pod warm restore {pcur} GB/s < "
            f"{POD_GATE_X}x frozen cold baseline {pbase} GB/s")
    # object-store smoke gate: the read-through cache must keep warm
    # restores clearly above the modeled-link cold figure, or the backend
    # pool is re-fetching what it already holds
    if obase and ocur < OBJSTORE_GATE_X * obase:
        raise SystemExit(
            f"backend cache regression: warm objstore restore {ocur} GB/s < "
            f"{OBJSTORE_GATE_X}x frozen cold baseline {obase} GB/s")
    return results


if __name__ == "__main__":
    main()
