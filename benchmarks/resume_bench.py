"""Fast-resume microbenchmark — the eviction→first-step-back window.

Two legs:

* **restore-to-device** (wall time, CPU): the same committed checkpoint
  (float32 params + int8-quantized mu/nu optimizer moments, the urgent-save
  shape) restored two ways — the pre-change path (serial public restore to
  host numpy, then ``jax.device_put`` per leaf) vs the streaming pipeline
  (``store.restore(..., streaming=True)`` into a device-sharded template:
  read→decode→H2D overlapped, int8 payloads widened on device). Best-of-7
  per leg — the bench box's 9p filesystem has multi-hundred-ms fsync/IO
  stalls from noisy neighbours, and the bench measures the code, not the
  weather. GB/s is logical (dequantized) bytes over wall time.

* **simulated MTTR** (virtual time): a transparent-mode spot run with
  periodic evictions; reports the coordinator's measured
  eviction→first-step-back windows (provisioning + restore + recompile +
  data seek, as charged/observed on the virtual clock).

Results land in ``BENCH_resume.json`` next to a ``baseline`` section frozen
from the **pre-change** code — reruns never overwrite it, so the ≥1.5×
acceptance ratio is always against the real before.
"""

from __future__ import annotations

import json
import os
import tempfile
import time

import numpy as np

BENCH_JSON = "BENCH_resume.json"
N_TENSORS = 8
SHAPE = (512, 512)
REPS = 7


def fixture_state():
    """float32 params + optimizer moments; moments int8-quantize on save."""
    rng = np.random.default_rng(0)
    params = {f"w{i}": rng.standard_normal(SHAPE).astype(np.float32)
              for i in range(N_TENSORS)}
    mu = {f"w{i}": rng.standard_normal(SHAPE).astype(np.float32) * 1e-2
          for i in range(N_TENSORS)}
    nu = {f"w{i}": np.abs(rng.standard_normal(SHAPE)).astype(np.float32) * 1e-4
          for i in range(N_TENSORS)}
    return {"params": params, "opt": {"mu": mu, "nu": nu}, "step": 7}


def bench_restore_to_device() -> dict:
    import jax

    from repro.checkpoint import CheckpointStore
    from repro.train import state_template, state_template_on_device

    state = fixture_state()
    nbytes = sum(a.nbytes for a in jax.tree.leaves(state)
                 if hasattr(a, "nbytes"))
    # the same template builders the trainer's resume path uses, so the
    # bench measures the production restore path, not a hand-rolled twin
    host_tpl = state_template(state)
    dev_tpl = state_template_on_device(state)
    results: dict = {}
    with tempfile.TemporaryDirectory() as td:
        store = CheckpointStore(td, compress=False, quantize_moments=True)
        store.save(7, state)

        def serial_leg():
            got, _ = store.restore(host_tpl)
            dev = jax.tree.map(jax.device_put, got)
            jax.block_until_ready(dev)
            return dev

        def streaming_leg():
            got, _ = store.restore(dev_tpl, streaming=True)
            jax.block_until_ready(got)
            return got

        # parity first (also warms caches): streaming must be bit-identical
        a, b = serial_leg(), streaming_leg()
        for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
        results["parity"] = True

        for name, leg in (("serial_restore_then_put", serial_leg),
                          ("streaming_restore_to_device", streaming_leg)):
            dts = []
            for _ in range(REPS):
                t0 = time.perf_counter()
                leg()
                dts.append(time.perf_counter() - t0)
            best = min(dts)
            results[f"{name}_GBps"] = round(nbytes / best / 1e9, 3)
            results[f"{name}_best_us"] = round(best * 1e6)
            print(f"{name},{best*1e6:.0f}us,{nbytes/best/1e9:.2f}_GBps")
    return results


def bench_contended_restore() -> dict:
    """Contended MTTR leg: restore throughput while a concurrent writer
    saves against the *same* store (ROADMAP "MTTR under load") — after an
    eviction the surviving fleet members keep checkpointing into the shared
    volume, so the replacement's restore competes for the 9p/NFS executor.
    Reports best-of-N restore GB/s under load next to the idle figure the
    main leg measures; the gap is the contention tax."""
    import threading

    import jax
    import numpy as np

    from repro.checkpoint import CheckpointStore, DeviceDeltaTracker
    from repro.train import state_template_on_device

    state = fixture_state()
    nbytes = sum(a.nbytes for a in jax.tree.leaves(state)
                 if hasattr(a, "nbytes"))
    dev_tpl = state_template_on_device(state)
    results: dict = {}
    with tempfile.TemporaryDirectory() as td:
        # retention high enough that the writer's steps never gc the
        # restored step out from under the bench
        store = CheckpointStore(td, compress=False, quantize_moments=True,
                                retention=100)
        store.save(7, state)

        # writer: periodic low-churn delta saves through the device-delta
        # tracker — the steady-state save shape the fleet actually runs
        writer_state = {
            "params": {k: np.asarray(v) + 1.0
                       for k, v in state["params"].items()},
            "step": 100}
        tracker = DeviceDeltaTracker(store.pool, chunk_size=store.chunk_size,
                                     compress=store.compress)
        stop = threading.Event()
        saved = [0]

        def writer():
            step = 100
            import jax.numpy as jnp
            base = {k: jnp.asarray(v)
                    for k, v in writer_state["params"].items()}
            while not stop.is_set():
                step += 1
                st = {"params": {k: v.at[:8].add(float(step))
                                 for k, v in base.items()}, "step": step}
                try:
                    store.save(step, st, tracker=tracker)
                    saved[0] += 1
                except OSError:
                    break

        t = threading.Thread(target=writer, daemon=True)
        t.start()
        try:
            dts = []
            for _ in range(REPS):
                t0 = time.perf_counter()
                got, _ = store.restore(dev_tpl, step=7, streaming=True)
                jax.block_until_ready(got)
                dts.append(time.perf_counter() - t0)
        finally:
            stop.set()
            t.join(timeout=30)
        best = min(dts)
        results["contended_streaming_restore_GBps"] = round(
            nbytes / best / 1e9, 3)
        results["contended_writer_saves"] = saved[0]
        print(f"contended_streaming_restore,{best*1e6:.0f}us,"
              f"{nbytes/best/1e9:.2f}_GBps,writer_saves={saved[0]}")
    return results


def bench_mttr() -> dict:
    from .common import run_row

    # short virtual-time run: evictions every 250 s against 10 s steps, so
    # the MTTR windows (120 s provisioning + modeled restore + notice tail)
    # are exercised a handful of times without CI-hostile wall cost
    row = run_row("resume_mttr", mode="transparent", eviction_s=250.0,
                  periodic_s=100.0, total_steps=60)
    coord = row.report.coordinator
    samples = coord.get("mttr_samples", [])
    out = {
        "mttr_mean_s": round(coord.get("mttr_mean_s", 0.0), 2),
        "mttr_samples_s": [round(s, 2) for s in samples],
        "evictions": row.report.evictions_seen,
        "restores": row.report.restores,
    }
    print(f"simulated_mttr_mean_s,{out['mttr_mean_s']}"
          f",n={len(samples)},restores={out['restores']}")
    return out


def main() -> dict:
    results = bench_restore_to_device()
    results.update(bench_contended_restore())
    results.update(bench_mttr())
    path = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..",
                        BENCH_JSON)
    doc = {}
    if os.path.exists(path):
        try:
            with open(path) as f:
                doc = json.load(f)
        except (OSError, ValueError):
            doc = {}
    doc.setdefault("fixture", f"{N_TENSORS}x{SHAPE[0]}x{SHAPE[1]} float32 "
                   "params + int8-quantized mu/nu moments (25.2 MB logical), "
                   "CPU")
    doc.setdefault("method", f"best of {REPS} reps per leg; GB/s over "
                   "logical bytes")
    # a missing baseline is seeded from this run — and says so, so a wiped
    # file can never masquerade as a meaningful before/after comparison
    doc.setdefault("baseline", {
        "recorded": "seeded from the first resume bench on this machine "
                    "(no frozen pre-change baseline found)",
        "restore_to_device_GBps": results.get(
            "serial_restore_then_put_GBps", 0.0)})
    base = doc["baseline"].get("restore_to_device_GBps", 0.0)
    cur = results.get("streaming_restore_to_device_GBps", 0.0)
    if base:
        results["speedup_vs_frozen_baseline"] = round(cur / base, 2)
        print(f"speedup_vs_frozen_baseline,{results['speedup_vs_frozen_baseline']}x")
    doc["current"] = results
    with open(path, "w") as f:
        json.dump(doc, f, indent=2)
        f.write("\n")
    print(f"(recorded to {os.path.relpath(path)})")
    return results


if __name__ == "__main__":
    main()
