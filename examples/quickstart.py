"""Quickstart: train a small model with Spot-on protection, survive a
simulated eviction, and verify the restored run continues bit-exactly.

    PYTHONPATH=src python examples/quickstart.py
"""

import tempfile

from repro.checkpoint import CheckpointStore
from repro.configs import get_smoke_config
from repro.core import (AZURE_D8S_V3, CheckpointPolicy, CostAccountant,
                        PeriodicEviction, ScaleSet, SpotOnCoordinator,
                        TimeModel, VirtualClock)
from repro.optim import AdamWConfig
from repro.train import SpotTrainer, TrainJob


def main():
    clock = VirtualClock()
    accountant = CostAccountant(AZURE_D8S_V3)
    # a spot pool that preempts us every 20 virtual minutes
    pool = ScaleSet(clock=clock, schedule=PeriodicEviction(1200.0),
                    accountant=accountant, provisioning_delay_s=120.0)
    store = CheckpointStore(tempfile.mkdtemp(prefix="spoton_quickstart_"))
    coordinator = SpotOnCoordinator(
        store, CheckpointPolicy.transparent(periodic_interval_s=300.0),
        clock, time_model=TimeModel())

    cfg = get_smoke_config("gemma3-1b")     # reduced same-family config
    job = TrainJob(cfg=cfg, opt=AdamWConfig(total_steps=240),
                   total_steps=240, n_stages=4, batch=4, seq_len=32)
    trainer = SpotTrainer(job, coordinator, pool, clock, step_time_s=10.0)

    report = trainer.run()
    coordinator.close()

    print(f"completed:            {report.completed}")
    print(f"virtual time:         {report.total_time_s:,.0f} s")
    print(f"final loss:           {report.final_loss:.4f}")
    print(f"evictions survived:   {report.evictions_seen}")
    print(f"restores:             {report.restores}")
    print(f"lost steps:           {report.lost_steps} (0 = termination ckpts caught the frontier)")
    print(f"periodic ckpts:       {report.coordinator['periodic_ckpts']}")
    print(f"termination ckpts:    {report.coordinator['termination_ckpts']}")
    print(f"cost:                 ${accountant.summary(clock.now())['total_usd']:.4f}")
    assert report.completed


if __name__ == "__main__":
    main()
