"""Elastic restore: checkpoint a sharded training state on an 8-device mesh,
then resume on 4 devices (half the capacity evicted) with identical values —
the paper's "restart on a new instance" generalized to a new topology.

    PYTHONPATH=src python examples/elastic_restore.py
(re-executes itself with XLA_FLAGS for 8 host devices)
"""

import os
import subprocess
import sys

INNER = "SPOTON_ELASTIC_INNER"


def inner():
    import numpy as np
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.checkpoint import CheckpointStore
    from repro.core.elastic import plan_mesh_for

    import tempfile
    td = tempfile.mkdtemp(prefix="spoton_elastic_")

    # "pod" of 8 devices: (4 data, 2 model)
    mesh8 = jax.make_mesh((4, 2), ("data", "model"),
                          axis_types=(jax.sharding.AxisType.Auto,) * 2)
    w = jax.device_put(
        jnp.arange(64 * 128, dtype=jnp.bfloat16).reshape(64, 128),
        NamedSharding(mesh8, P("data", "model")))
    state = {"params": {"w": w}, "step": 42}
    store = CheckpointStore(td)
    info = store.save(42, state, mesh_info={"shape": [4, 2]})
    print(f"saved on 8 devices: {info.nbytes} bytes, step {info.step}")

    # half the capacity disappears: rebuild a 4-device mesh and restore
    plan = plan_mesh_for(4, model_parallel=2)
    mesh4 = plan.build(jax.devices()[:4])
    tpl = {"params": {"w": jax.ShapeDtypeStruct(
        (64, 128), jnp.bfloat16,
        sharding=NamedSharding(mesh4, P("data", "model")))},
        "step": 0}
    restored, man = store.restore(tpl)
    assert restored["step"] == 42
    assert np.array_equal(np.asarray(restored["params"]["w"]), np.asarray(w))
    print(f"restored on 4 devices ({plan.shape}): bit-exact ✓")


def main():
    if os.environ.get(INNER):
        inner()
        return
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env[INNER] = "1"
    env.setdefault("PYTHONPATH", "src")
    raise SystemExit(subprocess.run([sys.executable, __file__], env=env).returncode)


if __name__ == "__main__":
    main()
