"""End-to-end driver (deliverable b): train a ~100M-param model for a few
hundred steps under Spot-on, in REAL time on CPU, with a real mid-run
eviction triggered through the chosen cloud's metadata API (Azure Scheduled
Events by default; ``--provider aws|gcp`` exercises the IMDS / GCE-metadata
backends) — then verify the run completes and the loss went down.

    PYTHONPATH=src python examples/spot_training.py [--steps 120] [--provider azure]
"""

import argparse
import tempfile
import threading
import time

from repro.checkpoint import CheckpointStore
from repro.configs import get_smoke_config
from repro.core import (CheckpointPolicy, NoEviction, SpotOnCoordinator,
                        WallClock, get_provider)
from repro.models.config import ModelConfig
from repro.optim import AdamWConfig
from repro.train import SpotTrainer, TrainJob


def hundred_m_config() -> ModelConfig:
    """~100M-param dense decoder (real weights, CPU-trainable)."""
    base = get_smoke_config("phi3-mini-3.8b")
    return base.scaled(n_layers=10, d_model=640, n_heads=10, n_kv_heads=10,
                       head_dim=64, d_ff=2560, vocab_size=32064)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--provider", default="azure",
                    choices=("azure", "aws", "gcp"))
    args = ap.parse_args()

    clock = WallClock()
    prov = get_provider(args.provider)
    pool = prov.make_pool(clock, NoEviction(), provisioning_delay_s=1.0,
                          notice_s=30.0)
    store = CheckpointStore(tempfile.mkdtemp(prefix="spoton_e2e_"))
    coord = SpotOnCoordinator(store, CheckpointPolicy.transparent(20.0), clock,
                              provider=prov)

    cfg = hundred_m_config()
    n_params = cfg.param_count()
    print(f"model: {cfg.name}-derived, {n_params/1e6:.0f}M params")
    job = TrainJob(cfg=cfg, opt=AdamWConfig(total_steps=args.steps,
                                            warmup_steps=10, peak_lr=1e-3),
                   total_steps=args.steps, n_stages=3, batch=4, seq_len=128)
    trainer = SpotTrainer(job, coord, pool, clock)

    # mid-run, simulate a real spot eviction through the metadata service
    def evict_later():
        time.sleep(30.0)
        inst = pool.current
        if inst is not None and inst.alive:
            print(f">>> simulate-eviction issued ({prov.name}, "
                  f"{prov.notice_s:.0f}s notice)")
            inst.announce_preemption(notice_s=30.0)

    threading.Thread(target=evict_later, daemon=True).start()
    t0 = time.time()
    report = trainer.run()
    coord.close()

    print(f"completed:          {report.completed}")
    print(f"wall time:          {time.time()-t0:.1f}s")
    print(f"steps executed:     {report.steps_executed}")
    print(f"evictions survived: {report.evictions_seen}")
    print(f"restores:           {report.restores}")
    print(f"final loss:         {report.final_loss:.4f}")
    assert report.completed
    assert report.final_loss < 10.2, "loss should drop from ~ln(32064)=10.4"


if __name__ == "__main__":
    main()
