"""Serving example: batched prefill + greedy decode with checkpointable
serving state — Spot-on protects long-running batch-inference jobs the same
way it protects training (the serving caches + cursor are just a pytree).

    PYTHONPATH=src python examples/serve_demo.py
"""

import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointStore
from repro.configs import get_smoke_config
from repro.models import init_params
from repro.serve.serve_step import make_decode_step, make_prefill

BATCH, PROMPT_LEN, NEW_TOKENS = 4, 16, 24


def main():
    cfg = get_smoke_config("recurrentgemma-2b")   # hybrid: RG-LRU + local attn
    params = init_params(cfg, jax.random.key(0))
    prompts = jax.random.randint(jax.random.key(1), (BATCH, PROMPT_LEN),
                                 0, cfg.vocab_size)

    prefill = jax.jit(make_prefill(cfg, cache_len=PROMPT_LEN + NEW_TOKENS))
    step = jax.jit(make_decode_step(cfg))

    tok, caches, pos = prefill(params, prompts)
    generated = [tok]
    store = CheckpointStore(tempfile.mkdtemp(prefix="spoton_serve_"))
    for i in range(NEW_TOKENS - 1):
        tok, _, caches = step(params, generated[-1][:, None], caches, pos + i)
        generated.append(tok)
        if i == NEW_TOKENS // 2:
            # Spot-on can snapshot mid-generation: caches are a pytree
            serving_state = {"caches": caches, "cursor": pos + i,
                             "generated": jnp.stack(generated, 1)}
            info = store.save(i, serving_state, kind="transparent")
            print(f"mid-generation checkpoint: {info.nbytes} bytes at token {i}")

    out = np.asarray(jnp.stack(generated, axis=1))
    print(f"generated {out.shape[1]} tokens for {out.shape[0]} sequences")
    print("first sequence:", out[0].tolist())
    assert out.shape == (BATCH, NEW_TOKENS)
    assert (out >= 0).all() and (out < cfg.vocab_size).all()
    print("OK")


if __name__ == "__main__":
    main()
