"""Zero-copy/pipelined hot path: mmap fallback, parallel-vs-serial restore
bit-identity, on-device Pallas quantize parity, legacy pool addresses,
commit durability (directory fsyncs)."""

import os
import zlib

import numpy as np
import pytest

import jax.numpy as jnp
import ml_dtypes

from repro.checkpoint import CheckpointStore, ChunkRef, extract_snapshot
from repro.checkpoint import chunkstore, ioutil
from repro.checkpoint import manifest as mf
from repro.checkpoint import serialize as ser
from repro.kernels.quantize import quantize_int8, quantize_int8_ref


def mixed_state(step=3):
    rng = np.random.default_rng(step)
    return {
        "params": {"big": rng.standard_normal((256, 1024)).astype(np.float32),
                   "bf16": rng.standard_normal((64, 32)).astype(ml_dtypes.bfloat16),
                   "ints": np.arange(4000, dtype=np.int32)},
        "opt": {"mu": {"big": rng.standard_normal((256, 1024)).astype(np.float32)}},
        "step": step,
    }


def template():
    s = mixed_state()
    return {"params": {k: np.zeros_like(v) for k, v in s["params"].items()},
            "opt": {"mu": {"big": np.zeros((256, 1024), np.float32)}},
            "step": 0}


def assert_tree_equal(a, b):
    import jax
    for x, y in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


class TestMmapFallback:
    def test_shard_reader_falls_back_without_mmap(self, tmp_path, monkeypatch):
        """v1 containers read identically when mmap is unavailable."""
        arrays = {"a": np.arange(512, dtype=np.float32).reshape(8, 64),
                  "b": np.arange(100, dtype=np.int32)}
        pend = [ser.encode_tensor(k, v) for k, v in arrays.items()]
        path = str(tmp_path / "x.spot")
        ser.write_shard_file(path, pend)
        import mmap as mmap_mod

        def broken_mmap(*a, **k):
            raise OSError("mmap unsupported on this filesystem")
        monkeypatch.setattr(mmap_mod, "mmap", broken_mmap)
        r = ser.ShardFileReader(path)
        for k, v in arrays.items():
            np.testing.assert_array_equal(r.read(k), v)
        dst = np.empty((8, 64), np.float32)
        assert r.read_into("a", dst)
        np.testing.assert_array_equal(dst, arrays["a"])
        r.close()

    def test_pool_read_view_falls_back_without_mmap(self, tmp_path, monkeypatch):
        pool = chunkstore.ChunkPool(str(tmp_path / "chunks"))
        data = b"q" * 4096
        h = chunkstore.chunk_digest(data)
        pool.write(h, data)
        import mmap as mmap_mod
        monkeypatch.setattr(mmap_mod, "mmap",
                            lambda *a, **k: (_ for _ in ()).throw(OSError()))
        ref = ChunkRef(hash=h, nbytes=4096, raw_len=4096,
                       crc32=zlib.crc32(data), comp="raw")
        assert pool.read(ref) == data


class TestParallelRestoreBitIdentical:
    @pytest.mark.parametrize("mode", ["delta", "full"])
    def test_parallel_matches_serial(self, tmp_path, mode):
        """read_many (parallel decode) and per-leaf serial read_slice produce
        byte-identical tensors for both manifest formats."""
        store = CheckpointStore(str(tmp_path), mode=mode, chunk_size=64 * 1024)
        s = mixed_state(5)
        store.save(5, s)
        _man, reader = store.latest_valid()
        names = reader.names()
        par = reader.read_many(names)
        for n in names:
            serial = reader.read_slice(n, parallel=False)
            assert serial.dtype == par[n].dtype
            np.testing.assert_array_equal(serial, par[n])

    def test_restore_matches_saved_state(self, tmp_path):
        store = CheckpointStore(str(tmp_path), chunk_size=64 * 1024)
        s = mixed_state(9)
        store.save(9, s)
        got, man = store.restore(template())
        assert man.step == 9
        assert_tree_equal(got, s)


class TestPallasQuantize:
    @pytest.mark.parametrize("shape,dtype", [
        ((257, 33), np.float32), ((512,), np.float32),
        ((16, 8, 4), "bfloat16"), ((1,), np.float32)])
    def test_kernel_matches_serialize_quantize(self, shape, dtype):
        """Interpret-mode Pallas kernel is bit-identical to the host path —
        the dedup contract between device- and host-quantized chunks."""
        if dtype == "bfloat16":
            dtype = ml_dtypes.bfloat16
        x = np.random.default_rng(0).standard_normal(shape).astype(dtype)
        q, s = quantize_int8(jnp.asarray(x), interpret=True)
        qr, sr = quantize_int8_ref(jnp.asarray(x))
        raw, scale = ser.quantize(x, "int8")
        assert float(s) == scale == float(sr)
        np.testing.assert_array_equal(np.asarray(q), raw)
        np.testing.assert_array_equal(np.asarray(qr), raw)

    def test_all_zero_tensor_scale_one(self):
        q, s = quantize_int8(jnp.zeros((64, 64)), interpret=True)
        assert float(s) == 1.0 and not np.asarray(q).any()

    def test_prequant_extract_and_roundtrip(self, tmp_path):
        """Urgent-style extract quantizes moments on device; the record is a
        normal int8 record (logical dtype + scale) and restores within the
        int8 error bound."""
        s = mixed_state(4)
        s["opt"]["mu"]["big"] = jnp.asarray(s["opt"]["mu"]["big"])  # on device
        snap = extract_snapshot(s, step=4, on_device_quantize=ser.is_moment_name)
        lp = snap.leaves["opt/mu/big"]
        assert lp.prequant == "int8" and lp.pieces[0][1].dtype == np.int8
        assert lp.dtype == "float32"
        # moments crossed at 1/4 width: snapshot accounts the staged bytes
        full = extract_snapshot(s, step=4)
        assert snap.nbytes < full.nbytes
        store = CheckpointStore(str(tmp_path), quantize_moments=True)
        store.save_snapshot(snap, kind="termination")
        got, man = store.restore(template())
        rec = next(r for r in man.tensors if r["name"].startswith("opt/mu/big"))
        assert rec["codec"].startswith("int8") and rec["dtype"] == "float32"
        absmax = np.abs(s["opt"]["mu"]["big"]).max()
        np.testing.assert_allclose(got["opt"]["mu"]["big"], s["opt"]["mu"]["big"],
                                   atol=absmax / 127.0)

    def test_device_quantize_dedups_against_host_quantize(self, tmp_path):
        """Same state quantized on device (urgent) and on host (periodic)
        produces identical chunks — the second save writes ~nothing."""
        s = mixed_state(4)
        s["opt"]["mu"]["big"] = jnp.asarray(s["opt"]["mu"]["big"])  # on device
        store = CheckpointStore(str(tmp_path), quantize_moments=True,
                                retention=10)
        host_snap = extract_snapshot(s, step=1)
        store.save_snapshot(host_snap)
        dev_snap = extract_snapshot(s, step=2,
                                    on_device_quantize=ser.is_moment_name)
        assert dev_snap.leaves["opt/mu/big"].prequant == "int8"
        info = store.save_snapshot(dev_snap)
        assert info.new_bytes < 0.01 * info.nbytes, (info.new_bytes, info.nbytes)


class TestLegacyPoolAddresses:
    def test_blake2b_addressed_chunk_still_restores(self, tmp_path):
        """Chunks written under the old blake2b addressing stay readable:
        the manifest carries the address, readers never recompute it."""
        import hashlib
        pool = chunkstore.ChunkPool(str(tmp_path / "chunks"))
        payload = np.arange(1000, dtype=np.float32).tobytes()
        h = hashlib.blake2b(payload, digest_size=20).hexdigest()  # old scheme
        assert pool.write(h, payload) == len(payload)
        refs = [ChunkRef(hash=h, nbytes=len(payload), raw_len=len(payload),
                         crc32=zlib.crc32(payload), comp="raw").to_json()]
        dst = np.empty(1000, np.float32)
        chunkstore.read_payload_into(pool, refs, dst)
        np.testing.assert_array_equal(dst, np.arange(1000, dtype=np.float32))


class TestCommitDurability:
    def test_commit_fsyncs_directories(self, tmp_path, monkeypatch):
        """The commit protocol syncs every directory whose entries it relies
        on: the pool fan-out dirs (chunk renames), the step dir (manifest
        rename + COMMITTED), and the store root (stage->final rename)."""
        synced: list[str] = []
        real = ioutil.fsync_dir

        def spy(path):
            synced.append(os.path.abspath(path))
            real(path)
        monkeypatch.setattr(ioutil, "fsync_dir", spy)
        monkeypatch.setattr(chunkstore, "fsync_dir", spy)
        monkeypatch.setattr(mf, "fsync_dir", spy)
        import repro.checkpoint.sharded as sharded_mod
        import repro.checkpoint.store as store_mod
        monkeypatch.setattr(sharded_mod, "fsync_dir", spy)
        monkeypatch.setattr(store_mod, "fsync_dir", spy)
        store = CheckpointStore(str(tmp_path))
        store.save(1, mixed_state(1))
        root = os.path.abspath(str(tmp_path))
        final = os.path.join(root, mf.step_dirname(1))
        assert root in synced                      # rename durable
        assert final in synced                     # COMMITTED durable
        assert any(chunkstore.CHUNKS_DIRNAME in p for p in synced)  # chunks

    def test_corrupt_chunk_detected_and_healed_via_into_path(self, tmp_path):
        store = CheckpointStore(str(tmp_path), chunk_size=64 * 1024)
        store.save(1, mixed_state(1))
        man = mf.read_manifest(os.path.join(str(tmp_path), mf.step_dirname(1)))
        victim = sorted(man.chunk_hashes())[0]
        path = store.pool.path(victim)
        raw = bytearray(open(path, "rb").read())
        raw[len(raw) // 2] ^= 0xFF
        open(path, "wb").write(bytes(raw))
        _man2, reader = store.latest_valid()
        with pytest.raises(IOError):
            reader.validate()
        assert not os.path.exists(path)            # self-heal removed it
