"""Pod-scale sharded restore: range-addressed region reads (shard-span map
and prefix-sum chunk selection, boundary chunks, fallbacks), rescale-stable
fingerprint keys through elastic 2→3→2 topology changes, and multi-device
per-shard streaming bit-identity."""

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointStore, DeviceDeltaTracker
from repro.checkpoint import manifest as mf
from repro.checkpoint.device_delta import stable_piece_key
from repro.core.elastic import MeshPlan, fleet_mesh_plan, member_addressable
from repro.distributed import addressable_shard_spans

CHUNK = 2048                     # 8 rows of a (*, 64) float32 leaf per chunk


def _state(rng, *, rows=256, cols=64):
    # one compressible leaf (zlib chunks) + one incompressible (raw chunks):
    # both chunk codecs cross the boundary-decode path
    ramp = np.tile(np.arange(cols, dtype=np.float32), (rows, 1))
    return {"ramp": ramp,
            "noise": rng.normal(size=(rows, cols)).astype(np.float32)}


@pytest.fixture
def saved(tmp_path, rng):
    store = CheckpointStore(str(tmp_path), chunk_size=CHUNK)
    state = _state(rng)
    store.save(3, state)
    man, reader = store.latest_valid()
    yield store, state, reader
    reader.close()


class TestRegionReads:
    def test_manifest_carries_shard_span_map(self, saved):
        _store, _state_, reader = saved
        for name in ("ramp", "noise"):
            rec = reader.single_piece_record(name)
            assert rec is not None and "chunks" in rec
            assert "shard_spans" in rec
            spans = mf.record_shard_spans(rec)
            assert spans is not None
            assert len(spans) == len(rec["chunks"])
            # spans tile the row axis: start at 0, end at the last row
            assert spans[0][0] == 0
            assert spans[-1][1] == rec["shape"][0]

    @pytest.mark.parametrize("region_rows", [(0, 256), (8, 16), (3, 29),
                                             (248, 256), (0, 1)])
    def test_region_read_bit_identical(self, saved, region_rows):
        _store, state, reader = saved
        a, b = region_rows
        for name in ("ramp", "noise"):
            region = ((a, b), (0, 64))
            got = reader.read_region_streaming(name, region)
            assert got is not None
            np.testing.assert_array_equal(got, state[name][a:b])
            np.testing.assert_array_equal(
                got, np.asarray(reader.read_slice(name, region)))

    def test_small_region_skips_chunks(self, saved):
        _store, state, reader = saved
        got = reader.read_region_streaming("noise", ((8, 16), (0, 64)))
        np.testing.assert_array_equal(got, state["noise"][8:16])
        st = reader.region_stats
        assert st["region_reads"] == 1
        # 64 KiB payload in 2 KiB chunks: an 8-row (one-chunk) region must
        # decode O(region), not O(tensor)
        assert st["chunks_decoded"] <= 2
        assert st["chunks_skipped"] >= 30

    def test_prefix_sum_path_matches_span_map(self, saved):
        # strip the optional shard-span map: chunk selection falls back to
        # raw_len prefix sums and must pick the same bytes
        _store, state, reader = saved
        rec = reader.single_piece_record("noise")
        assert rec.pop("shard_spans", None) is not None
        got = reader.read_region_streaming("noise", ((3, 29), (0, 64)))
        np.testing.assert_array_equal(got, state["noise"][3:29])
        assert reader.region_stats["chunks_skipped"] > 0

    def test_corrupt_span_map_is_rejected_not_trusted(self, saved):
        _store, state, reader = saved
        rec = reader.single_piece_record("noise")
        # truncated map: wrong length must invalidate the whole map
        rec["shard_spans"] = rec["shard_spans"][:-1]
        assert mf.record_shard_spans(rec) is None
        # a read through the corrupt record still comes back bit-identical
        # (prefix sums take over)
        got = reader.read_region_streaming("noise", ((8, 16), (0, 64)))
        np.testing.assert_array_equal(got, state["noise"][8:16])
        # non-monotonic map: a gap in the tiling could skip needed chunks
        n = len(rec["chunks"])
        rec["shard_spans"] = [[i * 100 + 50, i * 100] for i in range(n)]
        assert mf.record_shard_spans(rec) is None

    def test_trailing_axis_slice_falls_back(self, saved):
        _store, state, reader = saved
        region = ((0, 8), (0, 32))   # not flat-contiguous in C order
        assert reader.read_region_streaming("noise", region) is None
        got = reader.read_region_for_restore("noise", region)
        np.testing.assert_array_equal(np.asarray(got),
                                      state["noise"][0:8, 0:32])
        assert reader.region_stats["fallback_reads"] == 1

    def test_v1_records_fall_back(self, tmp_path, rng):
        store = CheckpointStore(str(tmp_path / "v1"), mode="full")
        state = _state(rng, rows=32)
        store.save(1, state)
        _man, reader = store.latest_valid()
        try:
            assert reader.read_region_streaming("noise", ((0, 8), (0, 64))) \
                is None
            got = reader.read_region_for_restore("noise", ((0, 8), (0, 64)))
            np.testing.assert_array_equal(np.asarray(got), state["noise"][:8])
            assert reader.region_stats["fallback_reads"] == 1
        finally:
            reader.close()

    def test_chunk_byte_offsets_and_span_map_helpers(self):
        rec = {"chunks": [{"r": 100}, {"r": 100}, {"r": 56}]}
        assert mf.chunk_byte_offsets(rec) == [0, 100, 200, 256]
        # 256 payload bytes, 16 bytes/row -> 16 rows tiled by ceil division
        spans = mf.shard_span_map((16, 4), 16, [100, 100, 56])
        assert spans == [[0, 7], [6, 13], [12, 16]]
        assert mf.shard_span_map((), 16, [100]) is None
        assert mf.shard_span_map((16, 4), 0, [100]) is None


class TestAddressableShardSpans:
    def test_single_device_whole_leaf(self):
        x = jax.device_put(np.arange(12, dtype=np.float32).reshape(3, 4))
        spans = addressable_shard_spans(x.sharding, (3, 4))
        assert spans == [((0, 3), (0, 4))]


class TestStablePieceKeys:
    def test_offset_is_global_and_row_major(self):
        # piece at global rows [2, 4) of an (8, 8) float32 leaf
        assert stable_piece_key("w", ((2, 4), (0, 8)), (8, 8), "float32") == \
            ("w", 2 * 8 * 4)
        # replicated / whole-tensor pieces sit at offset 0
        assert stable_piece_key("w", ((0, 8), (0, 8)), (8, 8), "float32") == \
            ("w", 0)
        assert stable_piece_key("w", None, None, "float32") == ("w", 0)
        # column offset scales by the innermost stride
        assert stable_piece_key("w", ((0, 8), (4, 8)), (8, 8), "bfloat16") == \
            ("w", 4 * 2)

    def test_topology_independent(self):
        # the same global piece gets the same key no matter how many other
        # pieces the saving topology had — that is the rescale-remap property
        k4 = stable_piece_key("w", ((6, 8), (0, 8)), (8, 8), "float32")
        k2 = stable_piece_key("w", ((4, 8), (0, 8)), (8, 8), "float32")
        assert k4 == ("w", 192) and k2 == ("w", 128)


class TestMemberAddressable:
    def test_dp_only_owns_everything(self):
        plan = fleet_mesh_plan(3, model_parallel=1)
        owns = member_addressable(plan, 1)
        assert owns("w", 0, 10_000, 10_000)
        assert owns("w", 123, 456, 10_000)

    def test_model_parallel_partitions_byte_spans(self):
        plan = MeshPlan((1, 2), ("data", "model"))
        m0 = member_addressable(plan, 0)
        m1 = member_addressable(plan, 1)
        assert m0("w", 0, 50, 100) and not m0("w", 50, 100, 100)
        assert m1("w", 50, 100, 100) and not m1("w", 0, 50, 100)
        # straddling spans belong to nobody: they must re-seed
        assert not m0("w", 25, 75, 100) and not m1("w", 25, 75, 100)
        # members fill the model axis fastest
        m2 = member_addressable(plan, 2)
        assert m2("w", 0, 50, 100)


def _tracker_for(store):
    return DeviceDeltaTracker(store.pool, chunk_size=store.chunk_size,
                              compress=store.compress,
                              quantize_moments=store.quantize_moments)


class TestRescaleStableFingerprints:
    def test_2_3_2_rescale_keeps_fingerprints_and_delta_win(self, tmp_path,
                                                            rng):
        store = CheckpointStore(str(tmp_path), chunk_size=CHUNK)
        tracker = _tracker_for(store)
        state = {
            "w": jnp.asarray(rng.normal(size=(512, 64)).astype(np.float32)),
            "b": jnp.asarray(
                rng.normal(size=(64 * 1024,)).astype(np.float32)),
        }
        store.save(0, state, tracker=tracker)
        state["w"] = state["w"].at[0, 0].add(1.0)
        info1 = store.save(1, state, tracker=tracker)
        assert info1.d2h_bytes_skipped > 0          # tracker warm + engaged

        # elastic 2 -> 3 -> 2: data-parallel fleet (model degree 1) keeps
        # every surviving-shard fingerprint at every step of the sequence
        kept_total = 0
        for n_alive in (3, 2):
            plan = fleet_mesh_plan(n_alive, model_parallel=1)
            res = tracker.rescale(member_addressable(plan, 0))
            assert res["dropped"] == 0
            assert res["kept"] >= 2                 # both tracked leaves
            kept_total = res["kept"]
        assert tracker.stats["rescale_events"] == 2
        assert tracker.stats["fp_kept"] >= 2 * kept_total
        assert tracker.stats["fp_dropped"] == 0

        # the next delta save still skips clean blocks: the D2H win
        # survived the topology changes instead of re-transferring the world
        state["w"] = state["w"].at[1, 0].add(1.0)
        info2 = store.save(2, state, tracker=tracker)
        full = sum(np.asarray(v).nbytes for v in state.values())
        assert info2.d2h_bytes_skipped > 0
        assert info2.d2h_bytes < full / 2

        # restores from post-rescale delta saves stay bit-identical
        tpl = {k: np.zeros_like(np.asarray(v)) for k, v in state.items()}
        got, man = store.restore(tpl)
        assert man.step == 2
        for k, v in state.items():
            np.testing.assert_array_equal(np.asarray(got[k]), np.asarray(v))

    def test_rescale_drops_only_nonaddressable_spans(self, tmp_path, rng):
        # a model-parallel re-plan drops exactly the spans the member no
        # longer owns; whole-leaf pieces at offset 0 survive for member 0
        store = CheckpointStore(str(tmp_path), chunk_size=CHUNK)
        tracker = _tracker_for(store)
        state = {"w": jnp.asarray(
            rng.normal(size=(512, 64)).astype(np.float32))}
        store.save(0, state, tracker=tracker)
        state["w"] = state["w"].at[0, 0].add(1.0)
        store.save(1, state, tracker=tracker)

        # member 1 under model=2 owns the upper half of each leaf's bytes:
        # a single whole-leaf piece spanning [0, total) is not addressable
        plan = MeshPlan((1, 2), ("data", "model"))
        res = tracker.rescale(member_addressable(plan, 1))
        assert res["kept"] == 0 and res["dropped"] >= 1
        # dropped entries mean the next save re-seeds (full path), never
        # a wrong skip
        info = store.save(2, state, tracker=tracker)
        assert info.d2h_bytes >= np.asarray(state["w"]).nbytes
        got, _ = store.restore({"w": np.zeros((512, 64), np.float32)})
        np.testing.assert_array_equal(np.asarray(got["w"]),
                                      np.asarray(state["w"]))

    def test_rescale_keeps_addressable_synthetic_spans(self, tmp_path, rng):
        # surviving-shard fraction: with per-shard entries, a member keeps
        # exactly the fraction of fingerprints whose spans it still owns
        store = CheckpointStore(str(tmp_path), chunk_size=CHUNK)
        tracker = _tracker_for(store)
        state = {
            "lo": jnp.asarray(rng.normal(size=(256, 64)).astype(np.float32)),
            "hi": jnp.asarray(rng.normal(size=(256, 64)).astype(np.float32)),
        }
        store.save(0, state, tracker=tracker)
        state["lo"] = state["lo"].at[0, 0].add(1.0)
        store.save(1, state, tracker=tracker)

        # predicate that keeps "lo" (owned) and rejects "hi" (moved away):
        # stands in for a mixed-ownership re-plan without needing devices
        res = tracker.rescale(lambda name, lo, hi, total: name == "lo")
        assert res["kept"] == 1 and res["dropped"] == 1
        assert tracker.stats["fp_kept"] == 1
        assert tracker.stats["fp_dropped"] == 1


MULTIDEV_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    import numpy as np
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.checkpoint import CheckpointStore
    from repro.distributed import addressable_shard_spans
    from repro.launch.mesh import make_mesh

    td = sys.argv[1]
    mesh = make_mesh((4, 2), ("data", "model"))
    sh = NamedSharding(mesh, P("data", None))
    w = jax.device_put(
        jnp.arange(64 * 32, dtype=jnp.float32).reshape(64, 32), sh)
    state = {"w": w, "b": jnp.ones((64,), jnp.float32)}
    store = CheckpointStore(td, chunk_size=2048)
    store.save(5, state)

    # per-shard enqueue plans one region per distinct addressable shard:
    # P("data", None) over a (4, 2) mesh -> 4 distinct row bands
    spans = addressable_shard_spans(sh, (64, 32))
    assert len(spans) == 4, spans
    assert sorted(spans) == [(((16 * i, 16 * (i + 1))), (0, 32))
                             for i in range(4)], spans

    # streaming restore onto a *different* mesh: per-shard region reads +
    # restore barrier, bit-identical to the serial path
    mesh2 = make_mesh((2, 4), ("data", "model"))
    sh2 = NamedSharding(mesh2, P("data", "model"))
    tpl = {"w": jax.ShapeDtypeStruct((64, 32), jnp.float32, sharding=sh2),
           "b": jnp.zeros((64,), jnp.float32)}
    got, man = store.restore(tpl, streaming=True)
    got_serial, _ = store.restore(tpl, streaming=False)
    assert np.array_equal(np.asarray(got["w"]), np.asarray(w))
    assert np.array_equal(np.asarray(got["w"]), np.asarray(got_serial["w"]))
    assert np.array_equal(np.asarray(got["b"]), np.ones((64,), np.float32))

    # a multi-piece record (one piece per saved shard) cannot be
    # range-addressed as one byte run -> read_region_for_restore must fall
    # back to the always-correct assembly path, bit-identically
    _man, reader = store.latest_valid()
    assert reader.read_region_streaming("w", ((16, 32), (0, 32))) is None
    a = reader.read_region_for_restore("w", ((16, 32), (0, 32)))
    assert np.array_equal(np.asarray(a), np.asarray(w)[16:32])
    assert reader.region_stats["fallback_reads"] >= 1
    reader.close()
    print("POD_STREAM_OK")
""")


def test_multidevice_streaming_restore_bit_identical(tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run([sys.executable, "-c", MULTIDEV_SCRIPT,
                           str(tmp_path)],
                          capture_output=True, text=True, env=env, timeout=300)
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "POD_STREAM_OK" in proc.stdout
