"""FleetCoordinator: heterogeneous multi-provider fleet against one shared
store — elastic rescale, provider-tagged checkpoints, full-outage restore,
and the store's atomic-commit invariant under concurrent fleet writers."""

import os

import numpy as np
import pytest

from repro.checkpoint import CheckpointStore
from repro.checkpoint import manifest as mf
from repro.core import (CheckpointPolicy, FleetCoordinator, FleetReport,
                        FleetSpec, NoEviction, PeriodicEviction, TimeModel,
                        TraceEviction, VirtualClock)


def run_fleet(tmp_path, *, providers=("azure", "aws", "gcp"),
              schedules=None, total_steps=50, step_time_s=10.0,
              periodic_s=100.0, retention=50, fault_injector=None,
              provisioning_delay_s=60.0):
    clock = VirtualClock()
    store = CheckpointStore(str(tmp_path), time_fn=clock.now,
                            retention=retention,
                            fault_injector=fault_injector)
    spec = FleetSpec(providers=providers, schedules=schedules,
                     provisioning_delay_s=provisioning_delay_s)
    fleet = FleetCoordinator(store, CheckpointPolicy.transparent(periodic_s),
                             clock, spec, time_model=TimeModel())
    report = fleet.run(total_steps=total_steps, step_time_s=step_time_s)
    return report, store, fleet


class TestMixedFleet:
    def test_completes_under_staggered_evictions(self, tmp_path):
        rep, store, fleet = run_fleet(
            tmp_path, schedules=(PeriodicEviction(150.0),
                                 PeriodicEviction(200.0),
                                 PeriodicEviction(250.0)))
        assert rep.completed
        assert rep.final_state_consistent
        # each provider saw at least one eviction and wrote a termination ckpt
        for name in ("azure", "aws", "gcp"):
            assert rep.per_provider[name]["evictions"] >= 1
            assert rep.checkpoints["by_provider"][name]["termination"] >= 1
        # cost accounted at each provider's own prices
        assert all(p["spot_usd"] > 0 for p in rep.per_provider.values())

    def test_provider_tags_on_shared_store(self, tmp_path):
        rep, store, fleet = run_fleet(
            tmp_path, schedules=(PeriodicEviction(150.0),
                                 PeriodicEviction(200.0),
                                 PeriodicEviction(250.0)))
        tagged = set()
        for step in store.committed_steps():
            man = mf.read_manifest(os.path.join(store.root,
                                                mf.step_dirname(step)))
            if "provider" in man.extra:
                tagged.add(man.extra["provider"])
        assert tagged  # manifests on the shared volume carry provenance
        assert tagged <= {"azure", "aws", "gcp"}

    def test_rescale_events_track_alive_capacity(self, tmp_path):
        rep, _, fleet = run_fleet(
            tmp_path, schedules=(PeriodicEviction(150.0),
                                 PeriodicEviction(200.0),
                                 PeriodicEviction(250.0)))
        assert len(rep.rescale_events) >= 3   # initial + at least one down/up
        first = rep.rescale_events[0]
        assert first["alive"] == 3 and first["mesh_shape"] == (3, 1)
        assert any(e["alive"] < 3 for e in rep.rescale_events[1:])

    def test_single_eviction_costs_capacity_not_progress(self, tmp_path):
        # only one member is ever evicted; the survivors carry the state, so
        # nothing is lost and no restore happens
        rep, _, _ = run_fleet(
            tmp_path, schedules=(TraceEviction((200.0,)), NoEviction(),
                                 NoEviction()), total_steps=40)
        assert rep.completed
        assert rep.full_outages == 0 and rep.restores == 0
        assert rep.lost_steps == 0
        assert rep.per_provider["azure"]["evictions"] == 1

    def test_full_outage_restores_latest_valid(self, tmp_path):
        # all three members die at once (same provider -> same 30 s notice,
        # so no survivor bridges the gap) -> in-memory replicas gone -> the
        # fleet must come back from the shared store's latest valid ckpt
        rep, _, _ = run_fleet(
            tmp_path, providers=("azure", "azure", "azure"),
            schedules=(TraceEviction((200.0,)),
                       TraceEviction((200.0,)),
                       TraceEviction((200.0,))),
            total_steps=40, periodic_s=50.0)
        assert rep.completed
        assert rep.full_outages >= 1
        assert rep.restores >= 1
        assert rep.final_state_consistent
        # termination ckpts caught the frontier: at most the steps the last
        # survivor ran past its final checkpoint were recomputed — not the
        # 20+ steps a cold restart would cost
        assert rep.lost_steps <= 4

    def test_homogeneous_fleet(self, tmp_path):
        rep, _, _ = run_fleet(tmp_path, providers=("azure", "azure"),
                              schedules=(PeriodicEviction(150.0), NoEviction()),
                              total_steps=30)
        assert rep.completed
        assert set(rep.per_provider) == {"azure"}
        assert rep.per_provider["azure"]["instances"] >= 3  # 2 + replacements

    def test_schedule_count_mismatch_rejected(self, tmp_path):
        clock = VirtualClock()
        store = CheckpointStore(str(tmp_path), time_fn=clock.now)
        with pytest.raises(ValueError):
            FleetCoordinator(store, CheckpointPolicy.transparent(100.0), clock,
                             FleetSpec(providers=("azure", "aws"),
                                       schedules=(NoEviction(),)))


class TestAtomicityUnderFleet:
    def test_failed_write_stays_invisible_run_completes(self, tmp_path):
        # kill one checkpoint write mid-commit: the staged ckpt must stay
        # invisible, the failure is counted, and the fleet still finishes
        boom = {"armed": True}

        def injector(phase):
            if phase == "manifest_written" and boom["armed"]:
                boom["armed"] = False
                raise IOError("nfs died mid-eviction")

        rep, store, _ = run_fleet(
            tmp_path, schedules=(TraceEviction((200.0,)),
                                 TraceEviction((200.0,)),
                                 TraceEviction((200.0,))),
            total_steps=40, periodic_s=50.0, fault_injector=injector)
        assert rep.completed
        assert (rep.checkpoints["termination_failures"]
                + rep.checkpoints["periodic_failures"]) >= 1
        # no half-written checkpoint became visible
        for step in store.committed_steps():
            path = os.path.join(store.root, mf.step_dirname(step))
            assert mf.is_committed(path)
            mf.read_manifest(path)  # parses

    def test_concurrent_writers_do_not_corrupt(self, tmp_path):
        # aggressive periodic cadence + evictions => many concurrent async
        # writers against one store; every committed ckpt must stay valid
        rep, store, _ = run_fleet(
            tmp_path, schedules=(PeriodicEviction(120.0),
                                 PeriodicEviction(170.0),
                                 PeriodicEviction(220.0)),
            total_steps=60, periodic_s=30.0)
        assert rep.completed
        opened = store.latest_valid()
        assert opened is not None
        man, reader = opened
        reader.validate()                   # full crc check of newest ckpt
