"""Device-resident delta detection: fingerprint kernel parity, dirty-block
save bit-identity, collision/shape guards, urgent-save bypass."""

import os
import time

import numpy as np
import pytest

import jax
import jax.numpy as jnp
import ml_dtypes

from repro.checkpoint import (AsyncCheckpointer, CheckpointStore,
                              DeviceDeltaTracker, extract_snapshot, prestage)
from repro.checkpoint.device_delta import DeltaBlocks
from repro.kernels.fingerprint import (fingerprint_blocks,
                                       fingerprint_blocks_ref,
                                       fingerprint_diff, n_blocks_of)

CHUNK = 64 * 1024


# ---------------------------------------------------------------------------
# kernel parity
# ---------------------------------------------------------------------------

FP_CASES = [
    # dtype, n elements (odd sizes exercise the zero-padded partial block)
    (np.float32, 3 * CHUNK // 4 + 17),
    (ml_dtypes.bfloat16, 2 * CHUNK + 1),
    (np.int8, 5 * CHUNK + 333),
    (np.float32, 7),                     # single partial block
]


def _payload(dtype, n):
    rng = np.random.default_rng(n)
    if np.dtype(dtype) == np.dtype(np.int8):
        return rng.integers(-100, 100, n).astype(dtype)
    return (rng.standard_normal(n) * 3).astype(dtype)


@pytest.mark.parametrize("dtype,n", FP_CASES)
def test_fingerprint_ref_vs_jnp(dtype, n):
    a = _payload(dtype, n)
    ref = fingerprint_blocks_ref(a, CHUNK)
    got = np.asarray(fingerprint_blocks(jnp.asarray(a), block_bytes=CHUNK))
    assert ref.dtype == np.uint32 and got.shape == ref.shape
    np.testing.assert_array_equal(ref, got)


@pytest.mark.parametrize("dtype,n", FP_CASES)
def test_fingerprint_pallas_interpret_parity(dtype, n):
    a = _payload(dtype, n)
    ref = fingerprint_blocks_ref(a, CHUNK)
    got = np.asarray(fingerprint_blocks(jnp.asarray(a), block_bytes=CHUNK,
                                        interpret=True))
    np.testing.assert_array_equal(ref, got)


def test_fingerprint_diff_matches_separate_compare():
    a = _payload(np.float32, 4 * CHUNK // 4)
    b = a.copy()
    b[CHUNK // 4 + 5] += 1.0            # dirty exactly block 1
    old = fingerprint_blocks(jnp.asarray(a), block_bytes=CHUNK)
    fp, diff = fingerprint_diff(jnp.asarray(b), old, block_bytes=CHUNK)
    np.testing.assert_array_equal(np.asarray(fp),
                                  fingerprint_blocks_ref(b, CHUNK))
    assert np.asarray(diff).tolist() == [False, True, False, False]


def test_fingerprint_block_sensitivity_and_position():
    a = _payload(np.float32, CHUNK)     # 4 blocks of 64 KiB
    base = fingerprint_blocks_ref(a, CHUNK)
    flipped = a.copy()
    flipped[0], flipped[1] = a[1], a[0]     # swap two words in block 0
    swapped = fingerprint_blocks_ref(flipped, CHUNK)
    assert swapped[0] != base[0]            # position is part of the digest
    np.testing.assert_array_equal(swapped[1:], base[1:])


# ---------------------------------------------------------------------------
# dirty-block saves
# ---------------------------------------------------------------------------

def _state(step, churn_rows=8, n=4, rows=64, cols=1024):
    """~1 MiB of f32 per tensor; `churn_rows` leading rows move per step."""
    rng = np.random.default_rng(42)
    out = {}
    for i in range(n):
        base = jnp.asarray(rng.standard_normal((rows, cols)).astype(np.float32))
        out[f"w{i}"] = base.at[:churn_rows].add(float(step * (i + 1)))
    out["step"] = step
    return out


def _template(state):
    return {k: (np.zeros_like(np.asarray(v)) if hasattr(v, "shape") else 0)
            for k, v in state.items()}


def _tracker_for(store, **kw):
    return DeviceDeltaTracker(store.pool, chunk_size=store.chunk_size,
                              compress=store.compress,
                              quantize_moments=store.quantize_moments, **kw)


def test_dirty_block_save_bit_identical_to_full_v1_and_v2(tmp_path):
    """Restores from fingerprint-delta saves must match, byte for byte,
    restores from v1 (full shard files) and v2-dense (no tracker) saves of
    the same states."""
    stores = {
        "v1": CheckpointStore(str(tmp_path / "v1"), mode="full"),
        "v2": CheckpointStore(str(tmp_path / "v2"), mode="delta",
                              chunk_size=CHUNK),
        "fp": CheckpointStore(str(tmp_path / "fp"), mode="delta",
                              chunk_size=CHUNK),
    }
    tracker = _tracker_for(stores["fp"])
    infos = []
    for step in range(3):
        state = _state(step)
        stores["v1"].save(step, state)
        i_dense = stores["v2"].save(step, state)
        i_fp = stores["fp"].save(step, state, tracker=tracker)
        infos.append((i_dense, i_fp))
        tpl = _template(state)
        restored = {k: s.restore(tpl, step=step)[0] for k, s in stores.items()}
        for k in tpl:
            a = np.asarray(restored["fp"][k])
            np.testing.assert_array_equal(a, np.asarray(restored["v1"][k]))
            np.testing.assert_array_equal(a, np.asarray(restored["v2"][k]))
            np.testing.assert_array_equal(
                a, np.asarray(state[k]) if hasattr(state[k], "shape")
                else state[k])
    # warm fingerprint saves write the same dirty chunks as the dense delta
    for i_dense, i_fp in infos[1:]:
        assert i_fp.new_bytes == i_dense.new_bytes
        # ... while moving far fewer bytes device→host
        assert i_fp.d2h_bytes < i_dense.d2h_bytes / 2
        assert i_fp.d2h_bytes_skipped > 0


def test_unchanged_state_skips_everything(tmp_path):
    store = CheckpointStore(str(tmp_path), mode="delta", chunk_size=CHUNK)
    tracker = _tracker_for(store)
    state = _state(0)
    store.save(0, state, tracker=tracker)
    info = store.save(1, {**state, "step": 1}, tracker=tracker)
    assert info.new_bytes <= 64                     # only the step scalar...
    # ...and (almost) nothing crossed the link: the step scalar plus the
    # per-leaf diff vectors
    assert info.d2h_bytes < 4096
    assert info.d2h_bytes_skipped == sum(
        np.asarray(v).nbytes for k, v in state.items() if k != "step")
    got, _ = store.restore(_template(state), step=1)
    for k, v in state.items():
        if hasattr(v, "shape"):
            np.testing.assert_array_equal(np.asarray(got[k]), np.asarray(v))


def test_forced_collision_shape_dtype_mismatch_never_skips(tmp_path):
    """A fingerprint match may only suppress transfers when shape, dtype,
    chunk size and codec also match. Forge a matching fingerprint under a
    changed shape/dtype: the save must take the dense path, not trust it."""
    store = CheckpointStore(str(tmp_path), mode="delta", chunk_size=CHUNK)
    tracker = _tracker_for(store)
    state = _state(0)
    store.save(0, state, tracker=tracker)

    # same total bytes, different shape; and a dtype change at equal shape
    w0 = np.asarray(state["w0"])
    reshaped = {**state, "w0": jnp.asarray(w0.reshape(128, 512)),
                "step": 1}
    with tracker._lock:
        ent = tracker._entries[("w0", 0)]
        # forge: make the stored fingerprints exactly what the reshaped
        # leaf will digest to (bytes unchanged -> digests identical anyway)
        assert ent.shape == (64, 1024)
    info = store.save(1, reshaped, tracker=tracker)
    # shape mismatch -> dense path: the full leaf crossed the link
    assert info.d2h_bytes >= w0.nbytes
    got, _ = store.restore({**_template(state),
                            "w0": np.zeros((128, 512), np.float32)}, step=1)
    np.testing.assert_array_equal(np.asarray(got["w0"]),
                                  w0.reshape(128, 512))
    assert tracker.stats["fallbacks"] >= 1

    recast = {**state, "w0": jnp.asarray(w0.view(np.int32)), "step": 2}
    info2 = store.save(2, recast, tracker=tracker)
    assert info2.d2h_bytes >= w0.nbytes             # dtype mismatch -> dense
    got2, _ = store.restore({**_template(state),
                             "w0": np.zeros((64, 1024), np.int32)}, step=2)
    np.testing.assert_array_equal(np.asarray(got2["w0"]), w0.view(np.int32))


def test_missing_pool_chunk_turns_block_dirty(tmp_path):
    """A clean-by-fingerprint block whose pool chunk vanished (swept by
    another writer) must be re-transferred, not dangled."""
    store = CheckpointStore(str(tmp_path), mode="delta", chunk_size=CHUNK)
    tracker = _tracker_for(store, touch_interval_s=0.0)  # verify every save
    state = _state(0)
    store.save(0, state, tracker=tracker)
    with tracker._lock:
        ent = tracker._entries[("w1", 0)]
        victim = ent.refs[2]
    os.remove(store.pool.path(victim.hash))
    info = store.save(1, {**state, "step": 1}, tracker=tracker)
    assert info.new_bytes >= victim.nbytes          # block re-written
    got, _ = store.restore(_template(state), step=1)
    np.testing.assert_array_equal(np.asarray(got["w1"]),
                                  np.asarray(state["w1"]))


def test_urgent_save_bypasses_fingerprints(tmp_path):
    """Termination saves take the full prestage path: fingerprints never
    gate them, and the tracker stays consistent for later periodic saves."""
    store = CheckpointStore(str(tmp_path), mode="delta", chunk_size=CHUNK)
    tracker = _tracker_for(store)
    ckpt = AsyncCheckpointer(store)
    try:
        state = _state(0)
        snap0 = ckpt.save_async(0, state, tracker=tracker)
        ckpt.wait_until_finished()
        nbytes = snap0.nbytes
        urgent_state = _state(1)
        info = ckpt.save_urgent(1, urgent_state, timeout_s=120.0)
        # bypass: the full state crossed the link, nothing was skipped
        assert info.d2h_bytes >= nbytes
        assert info.d2h_bytes_skipped == 0
        got, _ = store.restore(_template(urgent_state), step=1)
        for k, v in urgent_state.items():
            if hasattr(v, "shape"):
                np.testing.assert_array_equal(np.asarray(got[k]),
                                              np.asarray(v))
        # periodic save after the urgent one still restores bit-exactly
        state2 = _state(2)
        snap2 = ckpt.save_async(2, state2, tracker=tracker)
        ckpt.wait_until_finished()
        assert snap2.d2h_bytes < snap2.nbytes       # delta path engaged
        got2, _ = store.restore(_template(state2), step=2)
        for k, v in state2.items():
            if hasattr(v, "shape"):
                np.testing.assert_array_equal(np.asarray(got2[k]),
                                              np.asarray(v))
    finally:
        ckpt.close()


def test_high_churn_falls_back_dense(tmp_path):
    """When most blocks are dirty the gather cannot pay; the leaf takes the
    dense path while fingerprints still refresh for the next save."""
    store = CheckpointStore(str(tmp_path), mode="delta", chunk_size=CHUNK)
    tracker = _tracker_for(store)
    state = _state(0, churn_rows=64)                # 100% churn
    store.save(0, state, tracker=tracker)
    info = store.save(1, _state(1, churn_rows=64), tracker=tracker)
    assert info.d2h_bytes >= info.nbytes            # dense fallback
    # fingerprints still refreshed through the fallback: the next save
    # restores bit-exactly off refs recorded by the dense path
    store.save(2, _state(2, churn_rows=64), tracker=tracker)
    got, _ = store.restore(_template(state), step=2)
    for k, v in _state(2, churn_rows=64).items():
        if hasattr(v, "shape"):
            np.testing.assert_array_equal(np.asarray(got[k]), np.asarray(v))


def test_prestage_with_tracker_feeds_extract(tmp_path):
    """The trainer supplier path: prestage dispatches fingerprint+diff, the
    subsequent extract consumes the pending work and produces DeltaBlocks."""
    store = CheckpointStore(str(tmp_path), mode="delta", chunk_size=CHUNK)
    tracker = _tracker_for(store)
    state = _state(0)
    store.save(0, state, tracker=tracker)
    state1 = _state(1)
    prestage(state1, tracker=tracker)
    assert tracker._pending                          # work is in flight
    snap = extract_snapshot(state1, step=1, tracker=tracker)
    assert not tracker._pending                      # consumed, not leaked
    assert any(isinstance(p, DeltaBlocks)
               for lp in snap.leaves.values() for _i, p in lp.pieces)
    info = store.save_snapshot(snap)
    got, _ = store.restore(_template(state1), step=1)
    np.testing.assert_array_equal(np.asarray(got["w2"]),
                                  np.asarray(state1["w2"]))


def test_prestaged_diff_discarded_when_entry_swaps(tmp_path):
    """Async-writer race: a diff prestaged against save N-2's fingerprints
    must be discarded when save N-1 commits in between — pairing the old
    diff with the new refs would reuse a stale chunk for any block that
    reverted to its N-2 value."""
    store = CheckpointStore(str(tmp_path), mode="delta", chunk_size=CHUNK)
    t1 = _tracker_for(store)
    state_a = _state(0, churn_rows=0)               # block content X
    store.save(0, state_a, tracker=t1)

    # save B (content Y for the leading rows) through a second tracker on
    # the same pool — its entries stand in for the async writer's commit
    t2 = _tracker_for(store)
    state_b = _state(5)                             # rows 0..7 differ
    store.save(1, state_b, tracker=t2)

    # state C reverts to A's bytes; prestage diffs it against t1's entry
    # (vs A: everything clean), then the "async commit" swaps the entries
    state_c = {**{k: v for k, v in state_a.items()}, "step": 2}
    prestage(state_c, tracker=t1)
    with t1._lock, t2._lock:
        for key, ent in t2._entries.items():
            t1._entries[key] = ent
    info = store.save(2, state_c, tracker=t1)
    got, _ = store.restore(_template(state_c), step=2)
    for k, v in state_c.items():
        if hasattr(v, "shape"):
            np.testing.assert_array_equal(np.asarray(got[k]), np.asarray(v))
    # the reverted blocks had to cross again (they differ from B)
    assert info.d2h_bytes > 0


def test_coordinator_accounts_d2h(tmp_path):
    """Periodic saves through the coordinator surface d2h/skip/stall in
    CoordinatorStats and the TimeLedger counters."""
    import dataclasses

    from repro.core import CheckpointPolicy, SpotOnCoordinator, WallClock

    store = CheckpointStore(str(tmp_path), mode="delta", chunk_size=CHUNK)
    policy = dataclasses.replace(CheckpointPolicy.transparent(1e9),
                                 async_writes=False)
    coord = SpotOnCoordinator(store, policy, WallClock())
    assert coord.delta_tracker is not None
    state = _state(0)
    assert coord.save_periodic_now(0, state)
    assert coord.save_periodic_now(1, _state(1))
    st = coord.stats
    assert st.d2h_bytes > 0
    assert st.d2h_bytes_skipped > 0                 # second save skipped blocks
    assert st.save_stall_s > 0
    assert coord.ledger.counted_total("d2h_bytes") == st.d2h_bytes
    assert coord.ledger.counted_total("d2h_bytes_skipped") == st.d2h_bytes_skipped
    assert len(coord.ledger.observed.get("save_stall", [])) == 2


# ---------------------------------------------------------------------------
# compile-cache gc + post-commit hooks
# ---------------------------------------------------------------------------

def test_sweep_compilation_cache_age_and_size(tmp_path):
    from repro.launch.train import sweep_compilation_cache

    cache = tmp_path / "xla_cache"
    cache.mkdir()
    now = time.time()
    old = cache / "jit_old"
    old.write_bytes(b"x" * 1000)
    os.utime(old, (now - 30 * 86400, now - 30 * 86400))   # past the age gate
    entries = []
    for i in range(4):
        p = cache / f"jit_{i}"
        p.write_bytes(b"y" * 1000)
        os.utime(p, (now - i * 60, now - i * 60))
        entries.append(p)
    removed = sweep_compilation_cache(str(cache), max_bytes=2500,
                                      max_age_s=14 * 86400, min_interval_s=0)
    assert not old.exists()                         # age-gated
    live = sorted(p.name for p in cache.iterdir())
    assert len(live) == 2                           # size budget: keep newest 2
    assert "jit_0" in live and "jit_1" in live
    assert removed == 3000

    # rate limit: immediate rerun is a no-op even with garbage present
    junk = cache / "jit_junk"
    junk.write_bytes(b"z" * 5000)
    os.utime(junk, (now - 30 * 86400, now - 30 * 86400))
    assert sweep_compilation_cache(str(cache), max_bytes=2500,
                                   max_age_s=14 * 86400,
                                   min_interval_s=3600) == 0
    assert junk.exists()


def test_store_post_commit_hook_runs_and_never_fails_save(tmp_path):
    store = CheckpointStore(str(tmp_path), mode="delta", chunk_size=CHUNK)
    calls = []
    store.post_commit.append(lambda: calls.append(1))
    def boom():
        raise RuntimeError("janitor exploded")
    store.post_commit.append(boom)
    info = store.save(0, _state(0))
    assert calls == [1]
    assert info.step == 0                           # save survived the hook
