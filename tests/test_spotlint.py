"""spotlint + lock witness: the analyzer itself is under test.

Seeded-violation fixtures in tests/spotlint_fixtures/ carry
``# SPOTLINT-EXPECT: CODE`` markers. Each fixture test asserts the analyzer
reports *exactly* the marked (code, line) set — so the seeded violations must
fire and the clean twins in the same file must stay silent.
"""

import os
import re
import shutil
import subprocess
import sys
import threading
from pathlib import Path

import pytest

from repro.analysis import lock_witness
from repro.analysis.spotlint import analyze
from repro.checkpoint import CheckpointStore, codec_sched
from repro.checkpoint.codec_sched import PERIODIC, CodecScheduler

import numpy as np

REPO = Path(__file__).resolve().parent.parent
FIXTURES = Path(__file__).resolve().parent / "spotlint_fixtures"

EXPECT_RE = re.compile(r"#\s*SPOTLINT-EXPECT:\s*([A-Z0-9,\s]+)")

FINDING_RE = re.compile(r"^(.*?):(\d+):(\d+): (SPOT\d+) ")

FIXTURE_FILES = [
    "rename_without_fsync.py",
    "same_lane_result.py",
    "lane_misuse.py",
    "escaping_view.py",
    "abba_locks.py",
    "unbounded_retry.py",
    "peer_under_lock.py",
    "bare_ranged_get.py",
    "put_in_loop.py",
    "backend_under_lock.py",
]


def expected_findings(path: Path) -> set:
    exp = set()
    for lineno, line in enumerate(path.read_text().splitlines(), start=1):
        m = EXPECT_RE.search(line)
        if m:
            exp |= {(code.strip(), lineno)
                    for code in m.group(1).split(",") if code.strip()}
    return exp


def spotlint_env() -> dict:
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    return env


class TestFixtures:
    @pytest.mark.parametrize("fname", FIXTURE_FILES)
    def test_fixture_flagged_exactly(self, fname):
        path = FIXTURES / fname
        exp = expected_findings(path)
        assert exp, f"{fname} carries no SPOTLINT-EXPECT markers"
        got = {(f.code, f.line) for f in analyze([str(path)])}
        assert got == exp

    def test_noncopied_leaf_scoped_to_checkpoint(self, tmp_path):
        # SPOT021 only applies inside repro.checkpoint.*, so the fixture is
        # analyzed from a scratch tree rooted at src/repro/checkpoint/.
        target = tmp_path / "src" / "repro" / "checkpoint" / "noncopied_leaf.py"
        target.parent.mkdir(parents=True)
        shutil.copy(FIXTURES / "noncopied_leaf.py", target)
        res = subprocess.run(
            [sys.executable, "-m", "repro.analysis.spotlint",
             "--no-baseline", "src"],
            cwd=tmp_path, env=spotlint_env(), capture_output=True, text=True)
        assert res.returncode == 1, res.stdout + res.stderr
        got = set()
        for line in res.stdout.splitlines():
            m = FINDING_RE.match(line)
            if m:
                got.add((m.group(4), int(m.group(2))))
        assert got == expected_findings(FIXTURES / "noncopied_leaf.py")

    def test_noncopied_leaf_silent_outside_checkpoint(self):
        # Same code outside the checkpoint layer: np.asarray on a jax leaf is
        # a D2H copy there, not an alias — must not be flagged.
        assert analyze([str(FIXTURES / "noncopied_leaf.py")]) == []


class TestCli:
    def test_repo_is_clean(self):
        res = subprocess.run(
            [sys.executable, "-m", "repro.analysis.spotlint", "src"],
            cwd=REPO, env=spotlint_env(), capture_output=True, text=True)
        assert res.returncode == 0, res.stdout + res.stderr
        assert "spotlint: clean" in res.stdout

    @pytest.mark.parametrize("fname", FIXTURE_FILES)
    def test_nonzero_on_seeded_fixture(self, fname):
        res = subprocess.run(
            [sys.executable, "-m", "repro.analysis.spotlint",
             "--no-baseline", str(FIXTURES / fname)],
            cwd=REPO, env=spotlint_env(), capture_output=True, text=True)
        assert res.returncode == 1, res.stdout + res.stderr
        codes = {c for c, _ in expected_findings(FIXTURES / fname)}
        for code in codes:
            assert code in res.stdout

    def test_baseline_suppresses_then_goes_stale(self, tmp_path):
        code_dir = tmp_path / "code"
        code_dir.mkdir()
        mod = code_dir / "mod.py"
        mod.write_text(
            "import os\n\n\ndef commit(tmp, path):\n"
            "    os.replace(tmp, path)\n")
        baseline = tmp_path / "lint.baseline"
        baseline.write_text(
            "code/mod.py\tSPOT001\t5\tos.replace(tmp, path)\n"
            "code/mod.py\tSPOT002\t5\tos.replace(tmp, path)\n")
        cmd = [sys.executable, "-m", "repro.analysis.spotlint",
               "--baseline", str(baseline), "code"]

        res = subprocess.run(cmd, cwd=tmp_path, env=spotlint_env(),
                             capture_output=True, text=True)
        assert res.returncode == 0, res.stdout + res.stderr

        # Edit the suppressed line: the baseline entry no longer matches the
        # file content, so it is stale and the run must fail.
        mod.write_text(
            "import os\n\n\ndef commit(tmp, path):\n"
            "    os.replace(tmp, path + '.new')\n")
        res = subprocess.run(cmd, cwd=tmp_path, env=spotlint_env(),
                             capture_output=True, text=True)
        assert res.returncode == 1, res.stdout + res.stderr
        assert "stale-baseline" in res.stdout + res.stderr


class TestLockWitness:
    def _local(self):
        # Scope to locks created from this file so the witness's verdict is
        # unaffected by whatever the rest of the test session does.
        return lock_witness.LockWitness(
            path_filter=lambda fn: fn == __file__)

    def test_abba_inversion_detected(self):
        w = self._local()
        w.install()
        try:
            a = threading.Lock()
            b = threading.Lock()
            with a:
                with b:
                    pass
            with b:  # opposite order — a latent deadlock, no actual block
                with a:
                    pass
        finally:
            w.uninstall()
        inv = w.inversions()
        assert len(inv) == 1
        assert "inversion" in inv[0]

    def test_consistent_order_is_clean(self):
        w = self._local()
        w.install()
        try:
            a = threading.Lock()
            b = threading.Lock()
            for _ in range(3):
                with a:
                    with b:
                        pass
        finally:
            w.uninstall()
        assert w.inversions() == []

    def test_condition_wait_releases_held_state(self):
        # Condition.wait releases the underlying lock; the witness must model
        # that, or everything acquired by other threads during a wait would
        # look like a nested acquisition.
        w = self._local()
        w.install()
        try:
            cond = threading.Condition()
            other = threading.Lock()
            with cond:
                cond.wait(timeout=0.01)
                with other:
                    pass
            with other:
                pass
        finally:
            w.uninstall()
        assert w.inversions() == []

    def test_checkpoint_save_restore_clean_under_witness(self, tmp_path, rng):
        # End-to-end: a fresh scheduler + store created *after* install get
        # witnessed locks; a real delta save/restore must show no inversions.
        w = lock_witness.LockWitness()
        w.install()
        try:
            codec_sched._reset_for_tests()
            store = CheckpointStore(str(tmp_path / "ckpt"), mode="delta")
            state = {"w": rng.normal(size=(64, 64)).astype(np.float32)}
            store.save(1, state)
            got, man = store.restore(
                {"w": np.zeros((64, 64), np.float32)})
        finally:
            w.uninstall()
            codec_sched._reset_for_tests()
        assert man.step == 1
        np.testing.assert_array_equal(np.asarray(got["w"]), state["w"])
        assert w.inversions() == []

    def test_scheduler_shutdown_clean_under_witness(self):
        w = lock_witness.LockWitness()
        w.install()
        try:
            s = CodecScheduler(max_workers=2)
            futs = [s.submit(PERIODIC, lambda i=i: i * i) for i in range(8)]
            assert [f.result(timeout=10) for f in futs] == \
                [i * i for i in range(8)]
            s.shutdown(wait=True, timeout=10.0)
        finally:
            w.uninstall()
        assert w.inversions() == []
