"""Fault-injection torture tests for the checkpoint stack.

The crash-point matrix aborts a save at every enumerated point of the
commit protocol (``faults.COMMIT_CRASH_POINTS``) and asserts the recovery
invariant: a fresh store always finds a bit-identical committed checkpoint
(the prior one for every point before the COMMITTED marker), and the next
save commits cleanly over the debris. The remaining classes cover the
bounded-retry layer, storage degradation (skip-and-alert on ENOSPC while
urgent saves still commit), metadata-poll degradation (assume-evictable),
gc of aborted-commit staging debris, and a fleet eviction storm with
transient IO faults live.

``TestSeededTorture`` is the randomized storm behind the CI torture step;
it only runs with ``SPOTON_FAULTS=1`` (seed via ``SPOTON_FAULTS_SEED``).
"""

import errno
import logging
import os
import random

import numpy as np
import pytest

from repro import faults
from repro.checkpoint import CheckpointStore, ioutil
from repro.core import (CheckpointPolicy, FleetCoordinator, FleetSpec,
                        PeriodicEviction, Signal, SimulatedMetadataService,
                        SpotOnCoordinator, TimeModel, VirtualClock, retry)


def make_state(seed: int) -> dict:
    rng = np.random.default_rng(seed)
    return {
        "w": rng.standard_normal((64, 33)).astype(np.float32),
        "m": (rng.standard_normal(4096) * 8).astype(np.int32),
        "step": seed,
    }


def template(state: dict) -> dict:
    return {k: (np.zeros_like(v) if isinstance(v, np.ndarray) else 0)
            for k, v in state.items()}


def assert_state_equal(got: dict, want: dict) -> None:
    assert set(got) == set(want)
    for k, v in want.items():
        if isinstance(v, np.ndarray):
            np.testing.assert_array_equal(np.asarray(got[k]), v)
        else:
            assert got[k] == v


def make_store(root, **kw) -> CheckpointStore:
    # small chunks so every save exercises multiple chunk.{write,fsync,
    # replace} sites, not just one
    kw.setdefault("chunk_size", 4096)
    kw.setdefault("retention", 5)
    return CheckpointStore(str(root), **kw)


def tmp_debris(root) -> list:
    return [d for d in os.listdir(root) if ".tmp-" in d]


# -- the FaultPlan itself -----------------------------------------------------


class TestFaultPlan:
    def test_nth_and_count_window(self):
        plan = faults.FaultPlan().add("chunk.write", nth=2, count=2, error="eio")
        hits = [plan.check("chunk.write") is not None for _ in range(5)]
        assert hits == [False, True, True, False, False]

    def test_persistent_rule_matches_wildcard(self):
        plan = faults.FaultPlan().add("chunk.*", nth=2, count=-1)
        assert plan.check("chunk.fsync") is None        # arming call
        assert plan.check("chunk.replace").action == "crash"
        assert plan.check("chunk.write").action == "crash"
        assert plan.check("manifest.write") is None     # pattern miss
        assert plan.fired() == 2

    def test_path_substr_filter(self):
        plan = faults.FaultPlan().add("chunk.write", path_substr="deadbeef",
                                      error="eio")
        assert plan.check("chunk.write", "/pool/ab/abcd1234") is None
        inj = plan.check("chunk.write", "/pool/de/deadbeef01")
        assert inj is not None and inj.err == errno.EIO

    def test_unknown_error_kind_rejected(self):
        plan = faults.FaultPlan().add("chunk.write", error="ekaboom")
        with pytest.raises(ValueError):
            plan.check("chunk.write")


# -- bounded retry / backoff --------------------------------------------------


class TestRetry:
    def test_transient_retried_with_exponential_backoff(self):
        sleeps = []
        calls = {"n": 0}

        def flaky():
            calls["n"] += 1
            if calls["n"] < 3:
                raise OSError(errno.EIO, "flaky disk")
            return "ok"

        policy = retry.RetryPolicy(max_attempts=4, base_delay_s=1.0,
                                   multiplier=2.0, jitter=0.0)
        assert retry.call_with_retry(flaky, policy=policy,
                                     sleep=sleeps.append) == "ok"
        assert calls["n"] == 3
        assert sleeps == [1.0, 2.0]

    def test_persistent_errno_fails_immediately(self):
        calls = {"n": 0}

        def full_disk():
            calls["n"] += 1
            raise OSError(errno.ENOSPC, "disk full")

        with pytest.raises(OSError) as ei:
            retry.call_with_retry(full_disk, sleep=lambda d: None)
        assert ei.value.errno == errno.ENOSPC
        assert calls["n"] == 1  # retrying a full disk just burns the window

    def test_exhaustion_reraises_after_bound(self):
        calls = {"n": 0}

        def dead_disk():
            calls["n"] += 1
            raise OSError(errno.EIO, "dead disk")

        policy = retry.RetryPolicy(max_attempts=3, base_delay_s=0.0)
        with pytest.raises(OSError):
            retry.call_with_retry(dead_disk, policy=policy,
                                  sleep=lambda d: None)
        assert calls["n"] == 3

    def test_jitter_deterministic_with_seeded_rng(self):
        policy = retry.RetryPolicy(base_delay_s=1.0, jitter=0.5)
        a = [policy.delay_s(k, random.Random(7)) for k in (1, 2, 3)]
        b = [policy.delay_s(k, random.Random(7)) for k in (1, 2, 3)]
        assert a == b
        assert all(0.5 <= d / min(policy.max_delay_s, 2.0 ** (k - 1)) <= 1.5
                   for k, d in enumerate(a, start=1))

    def test_simulated_crash_passes_straight_through(self):
        calls = {"n": 0}

        def killed():
            calls["n"] += 1
            raise faults.SimulatedCrash("kill -9")

        with pytest.raises(faults.SimulatedCrash):
            retry.call_with_retry(killed, sleep=lambda d: None)
        assert calls["n"] == 1  # a dead process does not retry


# -- the crash-point matrix ---------------------------------------------------


#: Points at or after the COMMITTED marker hits disk: the aborted save's own
#: step is legitimately recoverable (the marker file exists even when its
#: write was torn or the crash landed mid-write — existence is the commit
#: bit). Every earlier point must recover the *prior* checkpoint.
NEW_STEP_POINTS = {
    ("marker.write", "torn"),
    ("marker.write", "crash"),
    ("commit.committed", "crash"),
}


class TestCrashPointMatrix:
    @pytest.mark.parametrize(
        "op,error", faults.COMMIT_CRASH_POINTS,
        ids=[f"{op}-{error}" for op, error in faults.COMMIT_CRASH_POINTS])
    def test_abort_recover_selfheal(self, tmp_path, op, error):
        store = make_store(tmp_path)
        s1, s2, s3 = make_state(1), make_state(2), make_state(3)
        store.save(1, s1)

        # errno faults must be persistent so the bounded retry layer cannot
        # absorb them; crash-type faults kill the save on the first hit
        count = -1 if error not in ("crash", "torn", "rollback") else 1
        plan = faults.FaultPlan().add(op, error=error, count=count)
        with faults.active(plan):
            with pytest.raises((faults.SimulatedCrash, OSError)):
                store.save(2, s2)
        assert plan.fired() >= 1, f"crash point {op}/{error} never hit"

        # a fresh store (the restarted process) must find a bit-identical
        # committed checkpoint — the new step only when the marker landed
        reopened = make_store(tmp_path)
        opened = reopened.latest_valid()
        assert opened is not None, "recovery lost every checkpoint"
        expect_step = 2 if (op, error) in NEW_STEP_POINTS else 1
        assert opened[0].step == expect_step
        expect = {1: s1, 2: s2}[expect_step]
        got, man = reopened.restore(template(expect))
        assert man.step == expect_step
        assert_state_equal(got, expect)

        # the surviving writer's next save commits over the debris and its
        # gc reclaims the aborted attempt's staging dir (same stage token)
        store.save(3, s3)
        got3, man3 = store.restore(template(s3))
        assert man3.step == 3
        assert_state_equal(got3, s3)
        assert tmp_debris(tmp_path) == []


# -- gc: staging debris vs. live saves ----------------------------------------


class TestGcStagingDebris:
    def test_sweep_during_inflight_save_spares_stage_and_pins(self, tmp_path):
        store = make_store(tmp_path)
        store.save(1, make_state(11))

        # leave debris: a commit aborted after the manifest was staged
        plan = faults.FaultPlan().add("commit.manifest_written", error="crash")
        with faults.active(plan):
            with pytest.raises(faults.SimulatedCrash):
                store.save(2, make_state(12))
        debris = tmp_debris(tmp_path)
        assert len(debris) == 1

        # re-run the save; mid-commit (pins held, stage in flight) fire the
        # most aggressive sweep possible — zero age gates, full pool walk
        seen = {}

        def hook(phase):
            if phase == "manifest_written":
                store.gc(stale_staging_age_s=0.0, stale_chunk_age_s=0.0,
                         sweep_chunks=True)
                seen["tmp"] = tmp_debris(tmp_path)

        store.fault_injector = hook
        s2 = make_state(13)
        store.save(2, s2)
        # the sweep reclaimed the aborted attempt's stage but not the one a
        # writer was inside
        assert len(seen["tmp"]) == 1
        assert debris[0] not in seen["tmp"]
        # and the pinned chunks survived the pool walk: a fresh store
        # reassembles the committed step bit-identically
        got, man = make_store(tmp_path).restore(template(s2))
        assert man.step == 2
        assert_state_equal(got, s2)

    def test_foreign_debris_is_age_gated(self, tmp_path):
        store = make_store(tmp_path)
        store.save(1, make_state(21))
        foreign = os.path.join(str(tmp_path),
                               "step_00000002.tmp-ffffff-deadbeef")
        os.makedirs(foreign)
        store.gc(stale_staging_age_s=3600.0)
        assert os.path.isdir(foreign)       # young foreign stage: protected
        store.gc(stale_staging_age_s=0.0)
        assert not os.path.exists(foreign)  # past the gate: reclaimed


# -- fsync_dir degradation ----------------------------------------------------


class TestFsyncDirDegradation:
    def test_unsupported_fs_warns_once_and_continues(self, tmp_path,
                                                     monkeypatch, caplog):
        monkeypatch.setattr(ioutil, "_fsync_warned", False)
        monkeypatch.setattr(ioutil.os, "fsync", lambda fd: (_ for _ in ()).throw(
            OSError(errno.EINVAL, "Invalid argument")))
        with caplog.at_level(logging.WARNING, logger="repro.checkpoint.ioutil"):
            ioutil.fsync_dir(str(tmp_path))   # no raise: degrade
            ioutil.fsync_dir(str(tmp_path))   # second call: silent
        warned = [r for r in caplog.records if "fsync unsupported" in r.message]
        assert len(warned) == 1

    def test_real_io_error_propagates(self, tmp_path, monkeypatch):
        monkeypatch.setattr(ioutil.os, "fsync", lambda fd: (_ for _ in ()).throw(
            OSError(errno.EIO, "Input/output error")))
        with pytest.raises(OSError) as ei:
            ioutil.fsync_dir(str(tmp_path))
        assert ei.value.errno == errno.EIO  # lost durability must not be hidden


# -- storage degradation: skip-and-alert --------------------------------------


def make_coord(tmp_path, periodic_s=100.0, async_writes=False):
    clock = VirtualClock()
    store = make_store(tmp_path, time_fn=clock.now)
    policy = CheckpointPolicy(periodic_interval_s=periodic_s,
                              async_writes=async_writes)
    coord = SpotOnCoordinator(store, policy, clock, time_model=TimeModel())
    md = SimulatedMetadataService(clock, "vm-0")
    coord.attach_instance(md, "vm-0")
    return coord, md, clock, store


class TestStorageDegradation:
    def test_enospc_periodic_degrades_urgent_still_commits(self, tmp_path):
        coord, md, clock, store = make_coord(tmp_path)
        clock.advance(100.0)
        coord.on_step_end(1, lambda: make_state(1))
        assert coord.stats.periodic_ckpts == 1

        plan = faults.FaultPlan().add("chunk.write", error="enospc", count=-1)
        with faults.active(plan):
            # full disk at the next cadence: the save fails, training does
            # not, and the coordinator enters the skip-and-alert window
            clock.advance(100.0)
            assert coord.on_step_end(2, lambda: make_state(2)) is Signal.CONTINUE
            assert coord.stats.periodic_failures == 1
            assert coord.stats.saves_degraded == 1
            # next cadence lands inside the window: skipped outright, no
            # second ENOSPC round-trip
            clock.advance(100.0)
            coord.on_step_end(3, lambda: make_state(3))
            assert coord.stats.periodic_ckpts == 1
            assert coord.stats.periodic_failures == 1   # skip, not a failure
            assert coord.stats.saves_degraded == 2
            # an eviction notice mid-degradation: the urgent save must still
            # attempt — this state dedups against step 1's chunks, so it
            # commits even on the full disk
            md.simulate_eviction()
            clock.advance(1.0)
            assert coord.on_step_end(4, lambda: make_state(1)) is Signal.PREEMPTING
            assert coord.stats.termination_ckpts == 1

        # cooldown over (2x the cadence): the next periodic probes storage
        # again and succeeds now the fault cleared
        clock.advance(300.0)
        coord.on_step_end(5, lambda: make_state(5))
        assert coord.stats.periodic_ckpts == 2
        assert store.committed_steps() == [1, 4, 5]
        # counters surfaced for run reports
        clock.advance(1.0)
        coord.on_step_end(6, lambda: make_state(5))
        assert coord.stats.faults_injected >= 1


# -- metadata-poll retry and assume-evictable ---------------------------------


class TestPollDegradation:
    def test_transient_poll_fault_absorbed_by_backoff(self, tmp_path):
        coord, md, clock, store = make_coord(tmp_path, periodic_s=1e9)
        plan = faults.FaultPlan().add("provider.poll", error="etimedout",
                                      count=2)
        with faults.active(plan):
            clock.advance(10.0)
            before = clock.now()
            assert coord.on_step_end(1, lambda: make_state(1)) is Signal.CONTINUE
        assert plan.fired() == 2
        assert coord.stats.poll_failures == 0      # the retry layer ate it
        # backoff slept on the injected clock, not the wall clock
        assert clock.now() > before
        clock.advance(10.0)
        coord.on_step_end(2, lambda: make_state(1))  # fold retry counters
        assert coord.stats.io_retries >= 2

    def test_persistent_poll_failure_assumes_evictable(self, tmp_path):
        coord, md, clock, store = make_coord(tmp_path, periodic_s=1e9)
        plan = faults.FaultPlan().add("provider.poll", error="eio", count=-1)
        with faults.active(plan):
            for step in range(1, 7):
                clock.advance(10.0)
                coord.on_step_end(step, lambda: make_state(1))
        # six consecutive dead polls, each already retried with backoff
        assert coord.stats.poll_failures == 6
        # every assume_evictable_after-th failure degrades to "assume
        # evictable": a synthetic rebalance drives a proactive checkpoint
        assert coord.stats.rebalance_ckpts == 2
        assert store.committed_steps()
        # endpoint back: one clean poll resets the streak
        clock.advance(10.0)
        coord.on_step_end(7, lambda: make_state(1))
        assert coord._poll_fail_streak == 0


# -- fleet eviction storm with live faults ------------------------------------


class TestFleetStormUnderFaults:
    def test_storm_completes_and_surfaces_fault_counters(self, tmp_path):
        plan = (faults.FaultPlan()
                .add("chunk.write", error="eio", count=1)
                .add("chunk.fsync", error="eio", count=1)
                .add("provider.poll", error="etimedout", count=1))
        with faults.active(plan):
            clock = VirtualClock()
            store = CheckpointStore(str(tmp_path), time_fn=clock.now,
                                    retention=50)
            spec = FleetSpec(providers=("azure", "aws", "gcp"),
                             schedules=(PeriodicEviction(150.0),
                                        PeriodicEviction(200.0),
                                        PeriodicEviction(250.0)),
                             provisioning_delay_s=60.0)
            fleet = FleetCoordinator(store, CheckpointPolicy.transparent(100.0),
                                     clock, spec, time_model=TimeModel())
            rep = fleet.run(total_steps=50, step_time_s=10.0)
        # transient faults on the save path and the metadata endpoint are
        # absorbed: the run completes and the state stays consistent
        assert rep.completed
        assert rep.final_state_consistent
        assert plan.fired() == 3
        assert rep.checkpoints["io_retries"] >= 2
        assert rep.checkpoints["faults_injected"] >= 3
        assert rep.checkpoints["saves_degraded"] == 0


# -- randomized seeded torture (CI: SPOTON_FAULTS=1) --------------------------


torture = pytest.mark.skipif(
    not os.environ.get("SPOTON_FAULTS"),
    reason="seeded torture storm: set SPOTON_FAULTS=1 (CI torture step)")


@torture
class TestSeededTorture:
    def test_random_crash_storm_never_loses_committed_state(self, tmp_path):
        seed = int(os.environ.get("SPOTON_FAULTS_SEED", "0"))
        rng = random.Random(seed)
        store = make_store(tmp_path, retention=4)
        committed = {}
        step = 1
        store.save(step, make_state(100 + step))
        committed[step] = make_state(100 + step)

        ops = [op for op, _ in faults.COMMIT_CRASH_POINTS]
        errors = ["crash", "torn", "eio", "enospc"]
        for _trial in range(12):
            step += 1
            s = make_state(100 + step)
            op = rng.choice(ops)
            error = rng.choice(errors)
            count = -1 if error in ("eio", "enospc") else 1
            plan = faults.FaultPlan().add(op, nth=rng.randint(1, 3),
                                          error=error, count=count)
            try:
                with faults.active(plan):
                    store.save(step, s)
                committed[step] = s
            except (faults.SimulatedCrash, OSError):
                pass
            # invariant after every trial: a fresh store finds a committed
            # checkpoint whose payload is bit-identical to what was saved
            fresh = make_store(tmp_path, retention=4)
            opened = fresh.latest_valid()
            assert opened is not None
            got_step = opened[0].step
            expect = committed.get(got_step)
            if expect is None:
                # the abort landed at/after the marker: the "failed" save
                # actually committed — legal, as long as it reads back whole
                assert got_step == step
                committed[step] = expect = s
            got, _ = fresh.restore(template(expect))
            assert_state_equal(got, expect)

        # the survivor self-heals: one clean save, then a zero-age sweep
        # leaves no staging debris behind
        step += 1
        s = make_state(100 + step)
        store.save(step, s)
        got, man = store.restore(template(s))
        assert man.step == step
        assert_state_equal(got, s)
        store.gc(stale_staging_age_s=0.0, stale_chunk_age_s=0.0,
                 sweep_chunks=True)
        assert tmp_debris(tmp_path) == []
