"""Fast-resume pipeline: streaming-vs-serial restore bit-identity (v1+v2
manifests, host+device templates), Pallas-vs-host dequant parity, data
fast-forward determinism, MTTR ledger accounting, warm-start trainer resume."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp
import ml_dtypes

from repro.checkpoint import CheckpointStore
from repro.checkpoint import serialize as ser
from repro.core import (CheckpointPolicy, SpotOnCoordinator, TimeModel,
                        VirtualClock)
from repro.data import PipelineState, TokenPipeline
from repro.kernels.quantize import (dequantize_int8, dequantize_int8_many,
                                    dequantize_int8_ref)


def mixed_state(step=3):
    rng = np.random.default_rng(step)
    return {
        "params": {"big": rng.standard_normal((128, 1024)).astype(np.float32),
                   "bf16": rng.standard_normal((64, 32)).astype(ml_dtypes.bfloat16),
                   "ints": np.arange(4000, dtype=np.int32),
                   "tiny": np.float32(2.5)},
        "opt": {"mu": {"big": rng.standard_normal((128, 1024)).astype(np.float32)},
                "nu": {"big": np.abs(rng.standard_normal((128, 1024))).astype(np.float32)}},
        "step": step,
    }


def host_template(state):
    return jax.tree.map(
        lambda x: np.zeros(np.shape(x), x.dtype) if hasattr(x, "dtype") else x,
        state)


def device_template(state):
    sharding = jax.sharding.SingleDeviceSharding(jax.devices()[0])
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(np.shape(x), x.dtype, sharding=sharding)
        if hasattr(x, "dtype") else x, state)


def assert_tree_equal(a, b):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        x, y = np.asarray(x), np.asarray(y)
        assert x.dtype == y.dtype
        np.testing.assert_array_equal(x, y)


class TestStreamingRestoreBitIdentity:
    @pytest.mark.parametrize("mode", ["delta", "full"])
    @pytest.mark.parametrize("compress", [False, True])
    def test_streaming_matches_serial(self, tmp_path, mode, compress):
        """Streaming restore (host and device templates) is bit-identical to
        the serial path for both manifest formats, quantized moments and
        compressed integer payloads included."""
        store = CheckpointStore(str(tmp_path), mode=mode, compress=compress,
                                quantize_moments=True, chunk_size=64 * 1024)
        s = mixed_state(5)
        store.save(5, s)
        serial, man = store.restore(host_template(s))
        assert man.step == 5
        stream_host, _ = store.restore(host_template(s), streaming=True)
        stream_dev, _ = store.restore(device_template(s), streaming=True)
        assert_tree_equal(serial, stream_host)
        assert_tree_equal(serial, stream_dev)
        # device template actually landed arrays on device
        assert isinstance(stream_dev["params"]["big"], jax.Array)
        assert isinstance(stream_dev["opt"]["mu"]["big"], jax.Array)

    def test_streaming_many_tiny_leaves_batch(self, tmp_path):
        """Sub-4KiB leaves (batched into one decode task) restore exactly."""
        s = {"scalars": {f"s{i:02d}": np.float32(i) * np.ones(3, np.float32)
                         for i in range(32)},
             "big": np.random.default_rng(0).standard_normal((256, 256))
             .astype(np.float32)}
        store = CheckpointStore(str(tmp_path))
        store.save(1, s)
        serial, _ = store.restore(host_template(s))
        stream, _ = store.restore(device_template(s), streaming=True)
        assert_tree_equal(serial, stream)

    def test_streaming_zero_copy_payload_is_immutable_safe(self, tmp_path):
        """Zero-copy mmap payloads must not alias restored *host* results in
        a way that lets one restore see another's buffers: two restores of
        the same checkpoint return independent-valued trees."""
        s = mixed_state(2)
        store = CheckpointStore(str(tmp_path))
        store.save(2, s)
        a, _ = store.restore(device_template(s), streaming=True)
        b, _ = store.restore(device_template(s), streaming=True)
        assert_tree_equal(a, b)


class TestLegacyPoolValidation:
    def test_legacy_chunk_flips_pool_to_crc_first_and_modern_still_reads(
            self, tmp_path):
        """First blake2b-era chunk flips the pool to crc-first validation
        (one double-digest total, not per chunk); modern sha1 chunks keep
        validating after the flip, and corruption is still caught."""
        import hashlib
        import zlib
        from repro.checkpoint import ChunkRef
        from repro.checkpoint import chunkstore

        pool = chunkstore.ChunkPool(str(tmp_path / "chunks"))
        legacy = np.arange(500, dtype=np.float32).tobytes()
        lh = hashlib.blake2b(legacy, digest_size=20).hexdigest()
        pool.write(lh, legacy)
        modern = np.arange(300, dtype=np.float32).tobytes()
        mh = chunkstore.chunk_digest(modern)
        pool.write(mh, modern)
        lref = ChunkRef(hash=lh, nbytes=len(legacy), raw_len=len(legacy),
                        crc32=zlib.crc32(legacy), comp="raw")
        mref = ChunkRef(hash=mh, nbytes=len(modern), raw_len=len(modern),
                        crc32=zlib.crc32(modern), comp="raw")
        assert not pool.legacy_validate
        assert pool.read(lref) == legacy
        assert pool.legacy_validate          # flipped on the fallback hit
        assert pool.read(mref) == modern     # modern chunks unaffected
        bad = ChunkRef(hash=mh, nbytes=len(modern), raw_len=len(modern),
                       crc32=mref.crc32 ^ 0xFF, comp="raw")
        with pytest.raises(IOError):
            pool.read(bad)                   # corruption still caught


class TestDequantKernelParity:
    @pytest.mark.parametrize("shape,dtype", [
        ((257, 33), np.float32), ((512,), np.float32),
        ((16, 8, 4), "bfloat16"), ((1,), np.float32)])
    def test_kernel_matches_host_dequant(self, shape, dtype):
        """Interpret-mode Pallas dequant == host finish_payload == jnp ref —
        the streaming restore's bit-identity contract."""
        if dtype == "bfloat16":
            dtype = ml_dtypes.bfloat16
        x = np.random.default_rng(1).standard_normal(shape).astype(dtype)
        q, scale = ser.quantize(x, "int8")
        host = ser.finish_payload(q.copy(), dtype_name=np.dtype(dtype).name,
                                  quant="int8", scale=float(scale))
        dev = dequantize_int8(jnp.asarray(q), scale, dtype=dtype,
                              interpret=True)
        ref = dequantize_int8_ref(jnp.asarray(q), scale, dtype=dtype)
        assert host.dtype == np.asarray(dev).dtype == np.asarray(ref).dtype
        np.testing.assert_array_equal(host, np.asarray(dev))
        np.testing.assert_array_equal(host, np.asarray(ref))

    def test_batched_dequant_matches_per_tensor(self):
        rng = np.random.default_rng(2)
        xs = [rng.standard_normal((64, 64)).astype(np.float32),
              rng.standard_normal((33,)).astype(ml_dtypes.bfloat16)]
        qs, scales, dtypes = [], [], []
        for x in xs:
            q, s = ser.quantize(x, "int8")
            qs.append(q); scales.append(s); dtypes.append(np.dtype(x.dtype).name)
        outs = dequantize_int8_many(qs, scales, dtypes)
        for x, q, s, d, o in zip(xs, qs, scales, dtypes, outs):
            host = ser.finish_payload(q.copy(), dtype_name=d, quant="int8",
                                      scale=s)
            assert np.asarray(o).dtype == host.dtype
            np.testing.assert_array_equal(np.asarray(o), host)

    def test_host_dequant_float32_single_allocation_path(self):
        """The float32 fast path (multiply straight into the target dtype)
        is exact vs the generic two-step sequence."""
        q = np.random.default_rng(3).integers(-127, 128, 4096).astype(np.int8)
        scale = 0.0123
        fast = ser.finish_payload(q.copy(), dtype_name="float32",
                                  quant="int8", scale=scale)
        slow = (q.astype(np.float32) * np.float32(scale)).astype(np.float32)
        assert fast.dtype == np.float32
        np.testing.assert_array_equal(fast, slow)


class TestDataFastForward:
    def test_fast_forward_matches_uninterrupted_run(self):
        pipe = TokenPipeline(vocab_size=128, batch=2, seq_len=8, seed=3)
        st = PipelineState()
        batches = []
        for _ in range(10):
            b, st = pipe.next(st)
            batches.append(b)
        st2 = pipe.fast_forward(4)
        assert st2.next_batch_index == 4
        for i in range(4, 10):
            b2, st2 = pipe.next(st2)
            np.testing.assert_array_equal(b2["inputs"], batches[i]["inputs"])
            np.testing.assert_array_equal(b2["labels"], batches[i]["labels"])

    def test_fast_forward_rejects_negative(self):
        pipe = TokenPipeline(vocab_size=16, batch=1, seq_len=4)
        with pytest.raises(ValueError):
            pipe.fast_forward(-1)


class TestMttrAccounting:
    def _coord(self, tmp_path, clock):
        store = CheckpointStore(str(tmp_path), time_fn=clock.now)
        policy = CheckpointPolicy.transparent(1e9)  # no periodic noise
        return SpotOnCoordinator(store, policy, clock,
                                 time_model=TimeModel()), store

    def test_mttr_window_measured_from_detach_to_first_step(self, tmp_path):
        clock = VirtualClock()
        coord, store = self._coord(tmp_path, clock)
        s = mixed_state(3)
        store.save(3, s)
        coord.detach()                       # eviction at t0
        t0 = clock.now()
        clock.advance(50.0)                  # provisioning delay
        restored = coord.restore_latest(host_template(s))
        assert restored is not None
        _state, man = restored
        nbytes = sum(t["nbytes"] for t in man.tensors)
        # the measured decode wall time is charged too (restore_wall): it
        # couples the virtual-mode MTTR sample to the physically-executed
        # restore, so samples differ run to run instead of being a constant
        wall = coord.ledger.charged["restore_wall"]
        assert wall > 0.0
        assert clock.now() == pytest.approx(
            t0 + 50.0 + coord.ledger.read_s(nbytes) + wall)
        clock.advance(2.0)                   # the first step back
        coord.on_step_end(4, lambda: s)
        expected = 50.0 + coord.ledger.read_s(nbytes) + wall + 2.0
        assert coord.stats.mttr_samples == [pytest.approx(expected)]
        assert coord.stats.mttr_mean_s == pytest.approx(expected)
        assert coord.ledger.observed["mttr"] == [pytest.approx(expected)]
        assert coord.ledger.observed_total("mttr") == pytest.approx(expected)
        # the window is consumed: the next step adds no sample
        coord.on_step_end(5, lambda: s)
        assert len(coord.stats.mttr_samples) == 1

    def test_no_mttr_sample_without_eviction(self, tmp_path):
        clock = VirtualClock()
        coord, store = self._coord(tmp_path, clock)
        s = mixed_state(1)
        store.save(1, s)
        coord.restore_latest(host_template(s))
        coord.on_step_end(2, lambda: s)
        assert coord.stats.mttr_samples == []
        assert coord.stats.mttr_mean_s == 0.0


class TestTrainerResume:
    def test_resume_overlaps_compile_and_restores_state(self, tmp_path):
        """SpotTrainer.resume: restores the latest checkpoint, fast-forwards
        the pipeline cursor, and leaves a warm compiled step behind."""
        from repro.configs import get_smoke_config
        from repro.core import (CheckpointPolicy, CostAccountant, AZURE_D8S_V3,
                                NoEviction, ScaleSet, SpotOnCoordinator,
                                WallClock)
        from repro.optim import AdamWConfig
        from repro.train import SpotTrainer, TrainJob
        from repro.train.train_step import state_template

        clock = WallClock()
        pool = ScaleSet(clock=clock, schedule=NoEviction(),
                        accountant=CostAccountant(AZURE_D8S_V3),
                        provisioning_delay_s=0.0)
        store = CheckpointStore(str(tmp_path))
        coord = SpotOnCoordinator(store, CheckpointPolicy.transparent(1e9),
                                  clock)
        cfg = get_smoke_config("gemma3-1b")
        job = TrainJob(cfg=cfg, opt=AdamWConfig(total_steps=4), total_steps=4,
                       n_stages=1, batch=2, seq_len=8)
        trainer = SpotTrainer(job, coord, pool, clock)
        state0 = trainer._fresh_state()
        template = state_template(state0)
        assert trainer.resume(template) is None          # no checkpoint yet
        assert trainer._compiled_step is not None        # compile still warm
        # run one real step with the compiled fn, checkpoint it, resume
        batch = trainer.pipeline.batch_at(0)
        state1, _metrics = trainer._compiled_step(state0, batch)
        store.save(1, state1)
        resumed = trainer.resume(template)
        assert resumed is not None
        state, _man, step, pstate = resumed
        assert step == 1 and pstate.next_batch_index == 1
        assert_tree_equal(state, state1)
