"""Train-step math: fused CE equivalence, microbatch-grad equivalence, AdamW
reference math, serving generate loop."""

import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.data import TokenPipeline
from repro.optim import AdamWConfig, adamw_update, global_norm, init_opt_state
from repro.train.train_step import (cross_entropy, fused_unembed_xent,
                                    init_train_state, make_train_step)


@pytest.fixture(scope="module")
def setup():
    cfg = dataclasses.replace(get_smoke_config("phi3_mini_3p8b"), dtype="float32")
    opt = AdamWConfig(total_steps=100)
    pipe = TokenPipeline(vocab_size=cfg.vocab_size, batch=4, seq_len=32)
    return cfg, opt, pipe.batch_at(0)


def test_fused_ce_matches_plain(setup):
    cfg, opt, batch = setup
    s1 = init_train_state(cfg, opt, 0)
    s2 = init_train_state(cfg, opt, 0)
    f1 = jax.jit(make_train_step(cfg, opt, fused_ce=True))
    f2 = jax.jit(make_train_step(cfg, opt, fused_ce=False))
    s1, m1 = f1(s1, batch)
    s2, m2 = f2(s2, batch)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), abs=1e-4)
    for a, b in zip(jax.tree.leaves(s1["params"]), jax.tree.leaves(s2["params"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-4)


def test_microbatch_grads_match_full(setup):
    cfg, opt, batch = setup
    s1 = init_train_state(cfg, opt, 0)
    s2 = init_train_state(cfg, opt, 0)
    f1 = jax.jit(make_train_step(cfg, opt, microbatches=1))
    f4 = jax.jit(make_train_step(cfg, opt, microbatches=4))
    s1, m1 = f1(s1, batch)
    s2, m2 = f4(s2, batch)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-5)
    for a, b in zip(jax.tree.leaves(s1["params"]), jax.tree.leaves(s2["params"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=2e-4)


def test_remat_matches_no_remat(setup):
    cfg, opt, batch = setup
    s1 = init_train_state(cfg, opt, 0)
    s2 = init_train_state(cfg, opt, 0)
    f1 = jax.jit(make_train_step(cfg, opt, remat="none"))
    f2 = jax.jit(make_train_step(cfg, opt, remat="full"))
    _, m1 = f1(s1, batch)
    _, m2 = f2(s2, batch)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-5)


def test_adamw_reference_step():
    params = {"w": jnp.asarray([1.0, -2.0], jnp.float32)}
    grads = {"w": jnp.asarray([0.5, 0.5], jnp.float32)}
    cfg = AdamWConfig(peak_lr=0.1, warmup_steps=0, total_steps=10,
                      b1=0.9, b2=0.99, eps=1e-8, weight_decay=0.0,
                      clip_norm=1e9)
    st = init_opt_state(params)
    new_p, new_st, metrics = adamw_update(grads, st, params, cfg)
    # closed form at t=1: mhat = g, vhat = g^2, step = g/(|g|+eps) = sign(g)
    lr0 = 0.1  # cosine at t=1/10 ~ peak; warmup 0
    expect = np.asarray([1.0, -2.0]) - float(metrics["lr"]) * np.sign([0.5, 0.5])
    np.testing.assert_allclose(np.asarray(new_p["w"]), expect, atol=1e-4)
    assert float(metrics["grad_norm"]) == pytest.approx(np.sqrt(0.5), rel=1e-6)


def test_clip_norm_applies():
    params = {"w": jnp.zeros((3,), jnp.float32)}
    grads = {"w": jnp.asarray([30.0, 40.0, 0.0], jnp.float32)}   # norm 50
    cfg = AdamWConfig(clip_norm=1.0, warmup_steps=0, weight_decay=0.0)
    st = init_opt_state(params)
    _, new_st, _ = adamw_update(grads, st, params, cfg)
    mu = np.asarray(new_st["mu"]["w"])
    np.testing.assert_allclose(mu, 0.1 * np.asarray([0.6, 0.8, 0.0]), rtol=1e-5)


def test_cross_entropy_uniform_logits():
    V = 64
    logits = jnp.zeros((2, 8, V), jnp.float32)
    labels = jnp.zeros((2, 8), jnp.int32)
    assert float(cross_entropy(logits, labels)) == pytest.approx(np.log(V), rel=1e-6)


def test_generate_greedy_runs(setup):
    from repro.serve.serve_step import generate
    cfg, opt, _ = setup
    state = init_train_state(cfg, opt, 0)
    prompt = jnp.ones((2, 8), jnp.int32)
    toks = generate(state["params"], cfg, prompt, 4)
    assert toks.shape == (2, 4)
    assert (np.asarray(toks) >= 0).all() and (np.asarray(toks) < cfg.vocab_size).all()


def test_factored_second_moment_trains(setup):
    """Adafactor-style nu halves optimizer state and still reduces loss."""
    import dataclasses
    cfg, opt, batch = setup
    fopt = dataclasses.replace(opt, factored_second_moment=True)
    s = init_train_state(cfg, fopt, 0)
    # matrix params get {row, col} factors
    nu_leaves = jax.tree.leaves(s["opt"]["nu"])
    full = init_train_state(cfg, opt, 0)
    full_bytes = sum(x.size * 4 for x in jax.tree.leaves(full["opt"]["nu"]))
    fact_bytes = sum(x.size * 4 for x in nu_leaves)
    assert fact_bytes < 0.35 * full_bytes, (fact_bytes, full_bytes)
    step = jax.jit(make_train_step(cfg, fopt))
    losses = []
    for i in range(8):
        from repro.data import TokenPipeline
        pipe = TokenPipeline(vocab_size=cfg.vocab_size, batch=4, seq_len=32)
        s, m = step(s, pipe.batch_at(i))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0], losses
    assert np.isfinite(losses).all()
