"""Spot-cloud simulator: metadata-service schema fidelity, instance lifecycle,
scale-set replacement, eviction schedules, cost model (paper prices)."""

import itertools

import numpy as np
import pytest

from repro.core import (AZURE_D8S_V3, CostAccountant, NoEviction,
                        PeriodicEviction, PoissonEviction, ScaleSet,
                        SimulatedMetadataService, SpotInstance,
                        StragglerDetector, TraceEviction, VirtualClock,
                        first_preempt)
from repro.core.spot_sim import InstanceState


class TestMetadataService:
    def test_document_shape_matches_azure(self):
        clock = VirtualClock()
        md = SimulatedMetadataService(clock, "vm-0001")
        doc = md.get_scheduled_events()
        assert set(doc) == {"DocumentIncarnation", "Events"}
        assert doc["Events"] == []
        ev = md.simulate_eviction()
        doc = md.get_scheduled_events()
        e = doc["Events"][0]
        assert set(e) == {"EventId", "EventType", "ResourceType", "Resources",
                          "EventStatus", "NotBefore", "EventSource",
                          "Description"}
        assert e["EventType"] == "Preempt"
        assert e["ResourceType"] == "VirtualMachine"
        assert e["Resources"] == ["vm-0001"]
        assert e["EventStatus"] == "Scheduled"

    def test_minimum_30s_notice(self):
        clock = VirtualClock(start=100.0)
        md = SimulatedMetadataService(clock, "vm")
        ev = md.schedule_preempt(notice_s=1.0)  # below Azure's floor
        assert ev.not_before - clock.now() >= 30.0

    def test_incarnation_increments(self):
        md = SimulatedMetadataService(VirtualClock(), "vm")
        inc0 = md.get_scheduled_events()["DocumentIncarnation"]
        md.simulate_eviction()
        assert md.get_scheduled_events()["DocumentIncarnation"] == inc0 + 1

    def test_first_preempt_filters_by_resource(self):
        md = SimulatedMetadataService(VirtualClock(), "vm-a")
        md.simulate_eviction()
        doc = md.get_scheduled_events()
        assert first_preempt(doc, "vm-a") is not None
        assert first_preempt(doc, "vm-b") is None


class TestInstanceLifecycle:
    def test_preempt_then_terminate_at_notbefore(self):
        clock = VirtualClock()
        inst = SpotInstance(name="vm", clock=clock)
        inst.boot()
        inst.announce_preemption(notice_s=30.0)
        assert inst.state is InstanceState.EVICTING and inst.alive
        clock.advance(29.0)
        inst.tick()
        assert inst.alive
        clock.advance(2.0)
        inst.tick()
        assert inst.state is InstanceState.TERMINATED
        assert inst.lifetime_s() == pytest.approx(31.0)


class TestScaleSet:
    def test_replacement_after_eviction(self):
        clock = VirtualClock()
        pool = ScaleSet(clock=clock, schedule=PeriodicEviction(100.0),
                        provisioning_delay_s=20.0, notice_s=30.0)
        pool.start()
        first = pool.wait_for_instance()
        clock.advance(101.0)
        pool.tick()             # preemption announced
        assert first.state is InstanceState.EVICTING
        clock.advance(31.0)
        assert pool.tick() is None   # dead, replacement provisioning
        second = pool.wait_for_instance()
        assert second.name != first.name
        assert pool.instances_created == 2
        assert pool.evictions_announced == 1

    def test_ondemand_never_evicted(self):
        clock = VirtualClock()
        pool = ScaleSet(clock=clock, schedule=PeriodicEviction(50.0),
                        kind="ondemand")
        pool.start()
        inst = pool.wait_for_instance()
        clock.advance(1000.0)
        assert pool.tick() is inst

    def test_accounting(self):
        clock = VirtualClock()
        acct = CostAccountant(AZURE_D8S_V3)
        pool = ScaleSet(clock=clock, schedule=NoEviction(), accountant=acct)
        pool.start()
        pool.wait_for_instance()
        clock.advance(3600.0)
        pool.tick()
        pool.shutdown()
        assert acct.summary(clock.now())["spot_usd"] == pytest.approx(0.076)


class TestSchedules:
    def test_periodic(self):
        times = list(itertools.islice(PeriodicEviction(60.0).eviction_times(10.0), 3))
        assert times == [70.0, 130.0, 190.0]

    def test_poisson_mean(self):
        it = PoissonEviction(100.0, seed=1).eviction_times(0.0)
        times = list(itertools.islice(it, 500))
        gaps = np.diff([0.0] + times)
        assert abs(np.mean(gaps) - 100.0) / 100.0 < 0.15

    def test_trace(self):
        it = TraceEviction((5.0, 9.0)).eviction_times(100.0)
        assert list(it) == [105.0, 109.0]


class TestCostModel:
    def test_paper_discount(self):
        # the paper's headline: spot price cut alone saves ~77-80%
        assert AZURE_D8S_V3.spot_discount == pytest.approx(0.8, abs=0.01)

    def test_storage_pricing(self):
        acct = CostAccountant(AZURE_D8S_V3)
        acct.provision_storage(100.0, now=0.0)      # 100 GiB
        month = 30 * 24 * 3600.0
        assert acct.storage_cost(month) == pytest.approx(16.0, rel=1e-6)


class TestStraggler:
    def test_fires_only_on_persistent_slowness(self):
        det = StragglerDetector(factor=2.0, min_samples=10, patience=3)
        fired = [det.observe(1.0) for _ in range(20)]
        assert not any(fired)
        assert not det.observe(5.0)
        assert not det.observe(5.0)
        assert det.observe(5.0)   # third consecutive slow step

    def test_single_blip_tolerated(self):
        det = StragglerDetector(factor=2.0, min_samples=5, patience=3)
        for _ in range(10):
            det.observe(1.0)
        assert not det.observe(9.0)
        for _ in range(3):
            assert not det.observe(1.0)
