"""Shared fixtures. NOTE: no XLA_FLAGS device-count override here — smoke
tests and benches must see the real single CPU device. Multi-device sharding
tests spawn subprocesses with their own XLA_FLAGS (test_sharded_elastic.py)."""

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)
