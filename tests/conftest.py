"""Shared fixtures. NOTE: no XLA_FLAGS device-count override here — smoke
tests and benches must see the real single CPU device. Multi-device sharding
tests spawn subprocesses with their own XLA_FLAGS (test_sharded_elastic.py)."""

import os

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def pytest_configure(config):
    # Opt-in runtime lock-order witness (SPOTON_LOCK_WITNESS=1): instruments
    # threading.Lock/RLock/Condition created from repro code for the whole
    # session. Installed here, before test modules import repro, so even
    # module-level locks (codec_sched._sched_lock, ...) are witnessed.
    if os.environ.get("SPOTON_LOCK_WITNESS"):
        from repro.analysis.lock_witness import install_from_env

        install_from_env()


def pytest_sessionfinish(session, exitstatus):
    if not os.environ.get("SPOTON_LOCK_WITNESS"):
        return
    from repro.analysis.lock_witness import active, uninstall

    if not active():
        return
    inversions = uninstall()
    if inversions:
        print(f"\nlock-order witness: {len(inversions)} inversion(s) "
              f"observed during this run:\n")
        for inv in inversions:
            print(inv + "\n")
        session.exitstatus = 1
