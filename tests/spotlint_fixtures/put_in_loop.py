"""SPOT042 seeded fixture: blind chunk-key PUT loops, plus clean twins.

Violations: an object-store ``put`` inside a for/while loop with no
existence consult anywhere in the loop — re-driving the loop (an outage
reconcile, a retried save) re-uploads every chunk blind instead of
treating an already-committed address as a verified no-op. Clean twins:
the HEAD-guarded shape ``backend.upload_chunk`` uses, a queue handoff
(``.put`` on a non-backend receiver), and a single commit-time PUT outside
any loop. Never imported; the rule is lexical (see README in this
directory).
"""


def object_key(h):
    return "chunks/%s/%s" % (h[:2], h)


def upload_all_blind(backend, chunks):
    # re-driving this loop after a partial failure re-sends every byte
    for h, data in chunks:
        backend.put(object_key(h), data)  # SPOTLINT-EXPECT: SPOT042


def drain_spool_blind(objstore, spool):
    # the outage-reconcile path of all places must be idempotent: it runs
    # precisely when the previous attempt died partway through
    while spool:
        h, data = spool.pop()
        objstore.put(object_key(h), data)  # SPOTLINT-EXPECT: SPOT042


def upload_all_guarded_twin(backend, chunks):
    # clean: HEAD first — an already-committed address whose size matches
    # is a verified no-op, and a size mismatch (torn upload) is rewritten
    sent = 0
    for h, data in chunks:
        key = object_key(h)
        if backend.head(key) == len(data):
            continue
        backend.put(key, data)
        sent += len(data)
    return sent


def queue_dispatch_twin(work_queue, jobs):
    # clean: a queue handoff, not an object-store upload — the receiver
    # does not look like a backend client
    for job in jobs:
        work_queue.put(job)


def single_put_twin(backend, key, data):
    # clean: one commit-time PUT outside any loop; the caller's retry
    # discipline owns re-drive semantics
    backend.put(key, data)
