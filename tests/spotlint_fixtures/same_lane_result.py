"""Seeded violations: lane discipline, same-lane blocking (SPOT010)."""


def encode_chunk(b):
    return b


def encode_piece_deadlock(data):
    """Runs on the PERIODIC lane (submitted below) and blocks on a future
    it submitted to its own lane — classic lane self-deadlock."""
    ex = codec_executor()  # noqa: F821 — lexical fixture
    fut = ex.submit(encode_chunk, data)
    return fut.result()  # SPOTLINT-EXPECT: SPOT010


def encode_piece_batch_deadlock(pieces):
    """Same via the futures-list + wait idiom."""
    ex = codec_executor()  # noqa: F821
    futs = []
    for p in pieces:
        futs.append(ex.submit(encode_chunk, p))
    futures_wait(futs)  # noqa: F821  # SPOTLINT-EXPECT: SPOT010
    return futs


def kick(data, pieces):
    codec_executor().submit(encode_piece_deadlock, data)  # noqa: F821
    codec_executor().submit(encode_piece_batch_deadlock, pieces)  # noqa: F821


def encode_piece_ok(data):
    """Clean twin: submitted to PERIODIC but blocks only on strictly
    higher-priority (URGENT) work, which can always run."""
    fut = urgent_executor().submit(encode_chunk, data)  # noqa: F821
    return fut.result()


def kick_ok(data):
    codec_executor().submit(encode_piece_ok, data)  # noqa: F821


def toplevel_waiter(data):
    """Clean twin: never submitted as a lane job itself, so blocking on a
    lane future is fine (this is what the trainer thread does)."""
    fut = codec_executor().submit(encode_chunk, data)  # noqa: F821
    return fut.result()
