"""SPOT031 seeded fixture addendum: ChunkBackend client calls under a lock.

Violations: object-store network methods (``head``/``put``/``get_range``/
``complete_multipart``) while holding a tracker lock — each may burn a
full bounded-retry cycle against a flaky endpoint, serializing every
writer behind it. The ``get_range`` case also draws SPOT041 (it is a bare
one-shot GET on top of being under the lock): one defect, two distinct
failure modes. Clean twin: decide under the lock, ride the network
outside it, re-acquire to record — the shape BackendChunkPool uses.
Never imported; the rule is lexical (see README in this directory).
"""

import threading


class UploadTracker:
    def __init__(self, backend):
        self._lock = threading.Lock()
        self.backend = backend
        self.durable = {}

    def confirm_holding_lock(self, key):
        # a flaky endpoint's full retry cycle now serializes every writer
        with self._lock:
            size = self.backend.head(key)  # SPOTLINT-EXPECT: SPOT031
            self.durable[key] = size
        return size

    def upload_holding_lock(self, key, data):
        with self._lock:
            if key not in self.durable:
                self.backend.put(key, data)  # SPOTLINT-EXPECT: SPOT031
                self.durable[key] = len(data)

    def finish_holding_lock(self, key, upload_id, etags):
        with self._lock:
            self.backend.complete_multipart(key, upload_id, etags)  # SPOTLINT-EXPECT: SPOT031

    def read_holding_lock(self, key, nbytes):
        # under the lock AND a bare one-shot GET: both rules fire
        with self._lock:
            return self.backend.get_range(key, 0, nbytes)  # SPOTLINT-EXPECT: SPOT031, SPOT041

    def snapshot_then_upload_twin(self, key, data):
        # clean: decide under the lock, upload outside it, record after
        with self._lock:
            if key in self.durable:
                return 0
        self.backend.put(key, data)
        with self._lock:
            self.durable[key] = len(data)
        return len(data)

    def bookkeeping_twin(self, key, size):
        # clean: pure in-memory accounting is what the lock is for
        with self._lock:
            self.durable[key] = size
            return len(self.durable)
