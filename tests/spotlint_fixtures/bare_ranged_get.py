"""SPOT041 seeded fixture: unguarded object-store ranged GETs, plus twins.

Violations: a ``get_range`` call outside the bounded-retry substrate (a
torn response wedges the restore — the content address makes the fetch
repeatable, but only if somebody repeats it), and a retried fetch whose
closure never re-digests the payload (a corrupt response is accepted on
attempt 1; the retries protect nothing). Clean twins: the verified-and-
retried shape ``backend.fetch_chunk_verified`` uses, and a backend
implementation delegating to its transport (the interface seam the retry
contract sits above). Never imported; the rule is lexical (see README in
this directory).
"""

from repro.checkpoint.chunkstore import chunk_content_ok
from repro.core.retry import IO_RETRY, call_with_retry


def fetch_once_bare(backend, key, nbytes):
    # one torn response and this restore path is wedged for good
    return backend.get_range(key, 0, nbytes)  # SPOTLINT-EXPECT: SPOT041


def fetch_retried_unverified(backend, key, nbytes):
    # bounded attempts, but nothing re-digests the payload — a corrupt
    # response is accepted on the first try and no retry ever triggers
    return call_with_retry(
        lambda: backend.get_range(key, 0, nbytes),  # SPOTLINT-EXPECT: SPOT041
        policy=IO_RETRY)


def _fetch_verified_once(backend, ref):
    data = backend.get_range("chunks/%s/%s" % (ref.hash[:2], ref.hash),
                             0, ref.nbytes)
    if not chunk_content_ok(ref, data):
        raise OSError(5, "content-address mismatch: " + ref.hash)
    return data


def fetch_verified_twin(backend, ref):
    # clean: the retried closure re-digests against the content address
    # before accepting a byte, so a short/torn/corrupt response becomes
    # a transient failure the bounded retry absorbs
    return call_with_retry(lambda: _fetch_verified_once(backend, ref),
                           policy=IO_RETRY)


class MirrorBackend:
    def __init__(self, inner):
        self.inner = inner

    def get_range(self, key, start, length):
        # clean: interface delegation — a backend implementation handing
        # the range to its transport is the seam the retry contract sits
        # above; the consumer owns retry and verification
        return self.inner.get_range(key, start, length)
