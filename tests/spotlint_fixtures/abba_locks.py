"""Seeded violations: lock discipline — ABBA cycle (SPOT030) and blocking
IO under a lock (SPOT031)."""

import os
import threading

LOCK_A = threading.Lock()
LOCK_B = threading.Lock()
LOCK_C = threading.Lock()
LOCK_D = threading.Lock()


def path_one():
    with LOCK_A:
        with LOCK_B:  # SPOTLINT-EXPECT: SPOT030
            pass


def path_two():
    with LOCK_B:
        with LOCK_A:
            pass


def ordered_one():
    """Clean twin: both paths take C before D — no cycle."""
    with LOCK_C:
        with LOCK_D:
            pass


def ordered_two():
    with LOCK_C:
        with LOCK_D:
            pass


def fsync_under_lock(fd):
    with LOCK_C:
        os.fsync(fd)  # SPOTLINT-EXPECT: SPOT031


def fsync_outside_lock(state, fd):
    """Clean twin: snapshot under the lock, do the IO outside it."""
    with LOCK_C:
        pending = list(state)
    os.fsync(fd)
    return pending
