"""Seeded violations: non-copied numpy snapshot leaves (SPOT021).

SPOT021 is scoped to repro.checkpoint.* — the test copies this file into a
scratch src/repro/checkpoint/ tree before analyzing it.
"""

import numpy as np


def extract_aliasing(leaf):
    return np.asarray(leaf)  # SPOTLINT-EXPECT: SPOT021


def extract_frozen(leaf):
    """Clean twin: the to_host idiom — numpy leaves are copied, asarray is
    only the jax/sequence branch."""
    if isinstance(leaf, np.ndarray):
        return leaf.copy()
    return np.asarray(leaf)


def scale_scalar(dev_scale):
    """Clean twin: float() conversion keeps no buffer, nothing aliases."""
    return float(np.asarray(dev_scale))
