"""Seeded violations: restore-lane routing (SPOT011) and missing
chunk-loop yields (SPOT012)."""


def decode_chunk(c):
    return c


def restore_blocks_wrong_lane(chunks):
    ex = codec_executor()  # noqa: F821 — lexical fixture
    return [ex.submit(decode_chunk, c) for c in chunks]  # SPOTLINT-EXPECT: SPOT011


def restore_blocks_ok(chunks):
    """Clean twin: MTTR-window work on the RESTORE lane."""
    ex = restore_executor()  # noqa: F821
    return [ex.submit(decode_chunk, c) for c in chunks]


def encode_loop_no_yield(pool, chunks):
    refs = []
    for c in chunks:  # SPOTLINT-EXPECT: SPOT012
        refs.append(store_chunk(pool, c))  # noqa: F821
    return refs


def encode_loop_ok(pool, chunks):
    """Clean twin: yields its worker to queued restore/urgent jobs
    between chunks."""
    refs = []
    for c in chunks:
        maybe_yield()  # noqa: F821
        refs.append(store_chunk(pool, c))  # noqa: F821
    return refs
