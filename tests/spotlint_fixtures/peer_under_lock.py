"""SPOT031 seeded fixture: peer-exchange network calls under a lock.

Violations: socket/peer-client calls (``fetch``/``push``/``sendall``/
``recv``/``accept``/``socket.create_connection``) while holding a tracker
or pool lock — every thread queued on that lock then waits out a dead
peer's network timeout. Clean twins: snapshot the decision under the lock,
do the network round-trip outside it, re-acquire to record the result
(the decide-under-lock / dispatch-outside pattern the tracker uses).
Never imported; the rule is lexical (see README in this directory).
"""

import socket
import threading


class ChunkCache:
    def __init__(self, client, sock):
        self._lock = threading.Lock()
        self.client = client
        self.sock = sock
        self.entries = {}

    def fetch_holding_lock(self, ref):
        # a dead peer's timeout now serializes every tracker thread
        with self._lock:
            data = self.client.fetch(ref)  # SPOTLINT-EXPECT: SPOT031
            self.entries[ref.hash] = data
        return data

    def push_holding_lock(self, addr, h, data):
        with self._lock:
            if h in self.entries:
                return self.client.push(addr, h, data)  # SPOTLINT-EXPECT: SPOT031
        return False

    def serve_holding_lock(self, header, payload):
        with self._lock:
            self.sock.sendall(header)  # SPOTLINT-EXPECT: SPOT031
            self.sock.sendall(payload)  # SPOTLINT-EXPECT: SPOT031

    def dial_holding_lock(self, addr):
        with self._lock:
            conn = socket.create_connection(addr, timeout=1.0)  # SPOTLINT-EXPECT: SPOT031
        return conn

    def fetch_then_record_twin(self, ref):
        # clean: decide under the lock, fetch outside it, record after
        with self._lock:
            if ref.hash in self.entries:
                return self.entries[ref.hash]
        data = self.client.fetch(ref)
        with self._lock:
            self.entries[ref.hash] = data
        return data

    def snapshot_then_push_twin(self, addr):
        # clean: snapshot the work list under the lock, push outside
        with self._lock:
            todo = list(self.entries.items())
        pushed = 0
        for h, data in todo:
            if self.client.push(addr, h, data):
                pushed += 1
        return pushed

    def stats_only_twin(self, n):
        # clean: pure bookkeeping under the lock is what locks are for
        with self._lock:
            self.entries["served"] = self.entries.get("served", 0) + n
