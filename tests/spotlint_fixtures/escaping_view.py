"""Seeded violations: zero-copy view lifetimes (SPOT020)."""

GLOBAL_VIEW = mmap_view("/tmp/pool-chunk")  # noqa: F821  # SPOTLINT-EXPECT: SPOT020


class LeakyHolder:
    """Stores a view on self with no close() — escapes every release
    scope."""

    def __init__(self, path):
        self.buf = mmap_view(path)  # noqa: F821  # SPOTLINT-EXPECT: SPOT020


class OwnedHolder:
    """Clean twin: the class owns the mapping's lifetime via close()."""

    def __init__(self, path):
        self.buf = mmap_view(path)  # noqa: F821

    def close(self):
        release_view(self.buf)  # noqa: F821


def leak_local(pool, ref):
    view = pool.read_view(ref)  # SPOTLINT-EXPECT: SPOT020
    n = len(view)
    return n


def read_released(pool, ref):
    """Clean twin: release in a finally block."""
    view = pool.read_view(ref)
    try:
        return bytes(view)
    finally:
        release_view(view)  # noqa: F821


def read_transfer_ownership(pool, ref):
    """Clean twin: returning the view transfers the release obligation."""
    view = pool.read_view(ref)
    return view
