"""SPOT040 seeded fixture: unbounded IO retry loops, plus clean twins.

Violations: a `while True` (or `while 1`) whose try body performs primitive
IO and whose handler swallows the failure — no raise/break/return, no
backoff — retries a dead disk or endpoint forever. Clean twins: attempt
bounds, backoff pacing, re-raising handlers, and worker dispatch loops.
Never imported; the rule is lexical (see README in this directory).
"""

import os
import time
import urllib.request


def keep_polling_forever(url):
    # a flaky metadata endpoint spins this loop for the process lifetime
    while True:  # SPOTLINT-EXPECT: SPOT040
        try:
            return urllib.request.urlopen(url).read()
        except OSError:
            pass


def spin_on_stat(path):
    # persistent EPERM re-attempts with zero pacing until the heat death
    while 1:  # SPOTLINT-EXPECT: SPOT040
        try:
            os.stat(path)
            break
        except (IOError, PermissionError):
            continue


def bounded_twin(path):
    # clean: counter-bounded attempts with a terminal raise
    for _ in range(5):
        try:
            os.stat(path)
            return True
        except OSError:
            time.sleep(0.05)
    raise IOError(f"gave up on {path}")


def backoff_poll_twin(url):
    # clean: an infinite but *paced* poll loop is a deliberate design
    delay = 0.5
    while True:
        try:
            return urllib.request.urlopen(url).read()
        except OSError:
            time.sleep(delay)
            delay = min(delay * 2.0, 30.0)


def reraise_twin(path):
    # clean: the handler surfaces the failure instead of swallowing it
    while True:
        try:
            os.stat(path)
            return True
        except OSError:
            raise


def worker_dispatch_twin(q):
    # clean: a job-dispatch loop, not a retry loop — the try wraps a
    # high-level call, not primitive IO, and each iteration is new work
    while True:
        job = q.get()
        try:
            job.run()
        except Exception as exc:
            job.error = exc
