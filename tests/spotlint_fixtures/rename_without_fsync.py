"""Seeded violations: crash-consistency family (SPOT001/SPOT002)."""

import os


def commit_no_fsync_at_all(tmp, final):
    with open(tmp, "w") as f:
        f.write("data")
    os.replace(tmp, final)  # SPOTLINT-EXPECT: SPOT001,SPOT002


def commit_fsync_but_no_dirsync(tmp, final):
    with open(tmp, "w") as f:
        f.write("data")
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, final)  # SPOTLINT-EXPECT: SPOT002


def commit_durably(tmp, final):
    """Clean twin: full fsync -> rename -> dir-fsync protocol."""
    with open(tmp, "w") as f:
        f.write("data")
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, final)
    fsync_dir(os.path.dirname(final))  # noqa: F821 — lexical fixture


def commit_via_blessed_helper(stage, final, manifest):
    """Clean twin: write_manifest fsyncs the data, mark_committed fsyncs
    file + dir — the store's real commit shape."""
    write_manifest(stage, manifest)  # noqa: F821
    os.replace(stage, final)
    mark_committed(final)  # noqa: F821
