"""Multi-cloud provider backends: metadata schema fidelity, notice semantics,
pool behavior, coordinator integration, and the trainer completing under
eviction on every backend with identical checkpoint/restore invariants."""

import numpy as np
import pytest

from repro.checkpoint import CheckpointStore
from repro.checkpoint import manifest as mf
from repro.core import (CheckpointPolicy, CostAccountant, PeriodicEviction,
                        Signal, SpotOnCoordinator, TimeModel, VirtualClock,
                        get_provider)
from repro.core.providers import (AwsProvider, AzureProvider, GcpProvider,
                                  PREEMPT_KIND, REBALANCE_KIND)
from repro.core.providers.aws import iso_to_ts, ts_to_iso


def state(step):
    return {"w": np.full((16,), float(step), np.float32), "step": step}


def make_coord(tmp_path, provider, policy=None, tm=TimeModel()):
    clock = VirtualClock()
    store = CheckpointStore(str(tmp_path), time_fn=clock.now)
    coord = SpotOnCoordinator(store, policy or CheckpointPolicy.transparent(1e9),
                              clock, provider=provider, time_model=tm)
    md = provider.make_metadata(clock, "inst-0")
    coord.attach_instance(md, "inst-0")
    return coord, md, clock, store


class TestRegistry:
    def test_get_provider_by_name(self):
        assert get_provider("azure").name == "azure"
        assert get_provider("aws").name == "aws"
        assert get_provider("gcp").name == "gcp"

    def test_passthrough_and_unknown(self):
        p = AwsProvider()
        assert get_provider(p) is p
        with pytest.raises(ValueError):
            get_provider("ibm")

    def test_notice_floors(self):
        assert get_provider("azure").notice_s == 30.0
        assert get_provider("aws").notice_s == 120.0
        assert get_provider("gcp").notice_s == 30.0


class TestAwsSchema:
    def test_instance_action_document_shape(self):
        clock = VirtualClock(start=1000.0)
        md = AwsProvider().make_metadata(clock, "i-0001")
        assert md.get_instance_action() is None          # the 404 case
        ev = md.schedule_preempt(notice_s=120.0)
        doc = md.get_instance_action()
        assert set(doc) == {"action", "time"}
        assert doc["action"] == "terminate"
        # ISO-8601 UTC wire format round-trips to the clock deadline
        assert doc["time"].endswith("Z")
        assert iso_to_ts(doc["time"]) == pytest.approx(ev.not_before, abs=1e-3)
        assert ev.not_before == pytest.approx(1120.0, abs=1e-3)

    def test_two_minute_floor(self):
        md = AwsProvider().make_metadata(VirtualClock(), "i-0")
        ev = md.schedule_preempt(notice_s=10.0)          # below the floor
        assert ev.not_before >= 120.0

    def test_poll_orders_preempt_before_rebalance(self):
        prov = AwsProvider()
        clock = VirtualClock()
        md = prov.make_metadata(clock, "i-0")
        md.announce_rebalance()
        md.schedule_preempt()
        notices = prov.poll(md, "i-0", clock.now())
        assert [n.kind for n in notices] == [PREEMPT_KIND, REBALANCE_KIND]

    def test_iso_roundtrip(self):
        assert iso_to_ts(ts_to_iso(1234567.25)) == pytest.approx(1234567.25)


class TestGcpSchema:
    def test_preempted_flag(self):
        md = GcpProvider().make_metadata(VirtualClock(), "gce-0")
        assert md.get_preempted() == "FALSE"
        md.schedule_preempt()
        assert md.get_preempted() == "TRUE"

    def test_poll_synthesizes_stable_deadline(self):
        prov = GcpProvider()
        clock = VirtualClock(start=50.0)
        md = prov.make_metadata(clock, "gce-0")
        assert prov.poll(md, "gce-0", clock.now()) == []
        md.schedule_preempt()                # platform kill at 50 + 30 = 80
        clock.advance(1.0)
        (n1,) = prov.poll(md, "gce-0", clock.now())
        assert n1.kind == PREEMPT_KIND
        # observation+notice clamped to the platform's actual kill time
        assert n1.deadline == pytest.approx(80.0)
        clock.advance(5.0)
        (n2,) = prov.poll(md, "gce-0", clock.now())
        # repeated polls of one preemption: same event, same deadline
        assert n2.event_id == n1.event_id and n2.deadline == n1.deadline

    def test_poll_after_kill_time_has_no_budget(self):
        prov = GcpProvider()
        clock = VirtualClock()
        md = prov.make_metadata(clock, "gce-0")
        md.schedule_preempt()                # kill at t=30
        clock.advance(45.0)                  # a long step ran past the kill
        (n,) = prov.poll(md, "gce-0", clock.now())
        assert n.deadline <= 30.0 < clock.now()   # zero/negative budget


class TestPools:
    @pytest.mark.parametrize("name,prefix", [("azure", "vm-"), ("aws", "i-"),
                                             ("gcp", "gce-")])
    def test_replacement_and_naming(self, name, prefix):
        prov = get_provider(name)
        clock = VirtualClock()
        pool = prov.make_pool(clock, PeriodicEviction(200.0),
                              provisioning_delay_s=20.0)
        pool.start()
        first = pool.wait_for_instance()
        assert first.name.startswith(prefix)
        clock.advance(201.0)
        pool.tick()                                   # eviction announced
        clock.advance(prov.notice_s + 1.0)
        pool.tick()                                   # dead
        second = pool.wait_for_instance()
        assert second.name != first.name
        assert pool.instances_created == 2

    def test_aws_rebalance_precedes_eviction(self):
        prov = AwsProvider()
        clock = VirtualClock()
        pool = prov.make_pool(clock, PeriodicEviction(1000.0))
        pool.start()
        inst = pool.wait_for_instance()
        clock.advance(750.0)                          # lead is 300 s
        pool.tick()
        assert inst.metadata.get_rebalance_recommendation() is not None
        assert inst.metadata.get_instance_action() is None
        assert pool.rebalance_recommendations == 1


class TestCoordinatorIntegration:
    @pytest.mark.parametrize("name", ["azure", "aws", "gcp"])
    def test_termination_checkpoint_on_preempt(self, tmp_path, name):
        prov = get_provider(name)
        coord, md, clock, store = make_coord(tmp_path / name, prov)
        prov.simulate_eviction(md)
        clock.advance(2.0)
        sig = coord.on_step_end(7, lambda: state(7))
        assert sig is Signal.PREEMPTING
        assert coord.stats.termination_ckpts == 1
        got, man = store.restore(state(0))
        assert man.kind == "termination" and got["step"] == 7
        # provider tags recorded in the manifest
        assert man.extra["provider"] == name
        assert man.extra["instance"] == "inst-0"

    def test_aws_rebalance_triggers_proactive_ckpt(self, tmp_path):
        prov = AwsProvider()
        coord, md, clock, store = make_coord(tmp_path, prov)
        md.announce_rebalance()
        clock.advance(2.0)
        sig = coord.on_step_end(3, lambda: state(3))
        assert sig is Signal.CONTINUE                 # keep training
        coord.flush()
        assert coord.stats.rebalance_ckpts == 1
        assert store.committed_steps() == [3]
        # the recommendation is handled once
        clock.advance(2.0)
        coord.on_step_end(4, lambda: state(4))
        coord.flush()
        assert coord.stats.rebalance_ckpts == 1

    def test_rebalance_opt_out(self, tmp_path):
        prov = AwsProvider()
        policy = CheckpointPolicy(periodic_interval_s=1e9,
                                  checkpoint_on_rebalance=False)
        coord, md, clock, store = make_coord(tmp_path, prov, policy=policy)
        md.announce_rebalance()
        clock.advance(2.0)
        assert coord.on_step_end(1, lambda: state(1)) is Signal.CONTINUE
        coord.flush()
        assert store.committed_steps() == []


class TestTrainerAcrossProviders:
    """Acceptance: the trainer completes under eviction on every backend with
    identical checkpoint/restore invariants (latest-valid restore, atomic
    commit via the shared store machinery)."""

    @pytest.mark.parametrize("name", ["azure", "aws", "gcp"])
    def test_completes_under_eviction(self, tmp_path, name):
        from repro.configs import get_smoke_config
        from repro.optim import AdamWConfig
        from repro.train import SpotTrainer, TrainJob

        prov = get_provider(name)
        clock = VirtualClock()
        acct = CostAccountant(prov.prices)
        pool = prov.make_pool(clock, PeriodicEviction(250.0), acct,
                              provisioning_delay_s=60.0)
        store = CheckpointStore(str(tmp_path / name), time_fn=clock.now)
        coord = SpotOnCoordinator(store, CheckpointPolicy.transparent(100.0),
                                  clock, provider=prov, time_model=TimeModel())
        cfg = get_smoke_config("phi3_mini_3p8b")
        job = TrainJob(cfg=cfg, opt=AdamWConfig(total_steps=40), total_steps=40,
                       n_stages=2, batch=2, seq_len=16)
        rep = SpotTrainer(job, coord, pool, clock, step_time_s=10.0,
                          max_sessions=40).run()
        coord.close()
        assert rep.completed
        assert rep.evictions_seen >= 1 and rep.restores >= 1
        assert rep.lost_steps == 0          # termination ckpt caught the frontier
        assert rep.extra["provider"] == name
        # every committed checkpoint remains valid + restorable (atomicity)
        latest = store.latest_valid()
        assert latest is not None
        assert acct.summary(clock.now())["spot_usd"] > 0


class TestStragglerRearm:
    def test_rearms_after_fire(self):
        from repro.core import StragglerDetector
        det = StragglerDetector(factor=2.0, min_samples=5, patience=2)
        for _ in range(10):
            det.observe(1.0)
        assert not det.observe(9.0)
        assert det.observe(9.0)            # fires after `patience` slow steps
        # window reset: the replacement's steps cannot be condemned by stale
        # samples — even persistent slowness needs min_samples fresh data
        for _ in range(4):
            assert not det.observe(9.0)
        assert not det.observe(9.0)        # still below min_samples
