"""Priority codec scheduler: lane ordering, cooperative yields, shutdown,
contended restore correctness (bit-identity under a concurrent writer),
and the queue-wait vs decode accounting split."""

import threading
import time

import numpy as np
import pytest

import jax

from repro.checkpoint import AsyncCheckpointer, CheckpointStore, codec_sched
from repro.checkpoint.codec_sched import (PERIODIC, RESTORE, URGENT,
                                          CodecLane, CodecScheduler)
from repro.core.clock import VirtualClock
from repro.core.coordinator import SpotOnCoordinator, TimeModel
from repro.core.policy import CheckpointPolicy


def sched1():
    """Private 1-worker scheduler: execution order == pop order, so lane
    ordering is observable deterministically."""
    return CodecScheduler(max_workers=1)


class TestPriorityOrder:
    def test_strict_priority_pop_order(self):
        s = sched1()
        order = []
        gate = threading.Event()
        # first job blocks the only worker while we queue the rest
        futs = [s.submit(PERIODIC, lambda: (gate.wait(5), order.append("gate")))]
        time.sleep(0.05)     # let the worker take the gate job
        futs.append(s.submit(PERIODIC, lambda: order.append("p1")))
        futs.append(s.submit(RESTORE, lambda: order.append("r1")))
        futs.append(s.submit(URGENT, lambda: order.append("u1")))
        futs.append(s.submit(RESTORE, lambda: order.append("r2")))
        gate.set()
        for f in futs:
            f.result(timeout=5)
        assert order == ["gate", "u1", "r1", "r2", "p1"]
        s.shutdown(wait=True, timeout=5)

    def test_fifo_within_lane(self):
        s = sched1()
        order = []
        gate = threading.Event()
        futs = [s.submit(PERIODIC, gate.wait, 5)]
        time.sleep(0.05)
        futs += [s.submit(RESTORE, lambda i=i: order.append(i))
                 for i in range(5)]
        gate.set()
        for f in futs:
            f.result(timeout=5)
        assert order == list(range(5))
        s.shutdown(wait=True, timeout=5)

    def test_errors_propagate_through_future(self):
        s = sched1()

        def boom():
            raise IOError("disk gone")

        with pytest.raises(IOError):
            s.submit(RESTORE, boom).result(timeout=5)
        s.shutdown(wait=True, timeout=5)

    def test_rejects_unknown_priority(self):
        s = sched1()
        with pytest.raises(ValueError):
            s.submit(7, lambda: None)
        s.shutdown(wait=True, timeout=5)


class TestMaybeYield:
    def test_periodic_job_runs_queued_restore_inline(self):
        s = sched1()
        order = []
        started = threading.Event()
        queued = threading.Event()

        def periodic():
            started.set()
            assert queued.wait(5)
            helped = s.maybe_yield()
            order.append("periodic")
            return helped

        fut = s.submit(PERIODIC, periodic)
        assert started.wait(5)
        # the only worker is inside `periodic`; these can only run if it
        # yields
        r = s.submit(RESTORE, lambda: order.append("restore"))
        u = s.submit(URGENT, lambda: order.append("urgent"))
        queued.set()
        assert fut.result(timeout=5) == 2
        r.result(timeout=5)
        u.result(timeout=5)
        assert order == ["urgent", "restore", "periodic"]
        assert s.snapshot_stats()["yields"] == 2
        s.shutdown(wait=True, timeout=5)

    def test_restore_job_never_yields(self):
        s = sched1()
        ran = []

        def restore_job():
            # an URGENT job is queued, but RESTORE must not self-preempt
            s.submit(URGENT, lambda: ran.append("urgent"))
            assert s.maybe_yield() == 0
            ran.append("restore")

        s.submit(RESTORE, restore_job).result(timeout=5)
        s.shutdown(wait=True, timeout=5)
        assert ran[0] == "restore"

    def test_noop_off_worker_threads(self):
        s = sched1()
        assert s.maybe_yield() == 0          # instance, foreign thread
        assert codec_sched.maybe_yield() == 0  # module level, foreign thread
        s.shutdown(wait=True, timeout=5)

    def test_module_level_yield_reaches_private_scheduler(self):
        """Encode loops call codec_sched.maybe_yield() without a scheduler
        handle; the thread-local active-scheduler registry must route it to
        whichever instance is executing the job — including private ones."""
        s = sched1()
        order = []
        started = threading.Event()
        queued = threading.Event()

        def periodic():
            started.set()
            assert queued.wait(5)
            codec_sched.maybe_yield()
            order.append("periodic")

        fut = s.submit(PERIODIC, periodic)
        assert started.wait(5)
        r = s.submit(RESTORE, lambda: order.append("restore"))
        queued.set()
        fut.result(timeout=5)
        r.result(timeout=5)
        assert order == ["restore", "periodic"]
        s.shutdown(wait=True, timeout=5)

    def test_helped_time_excluded_from_periodic_exec(self):
        s = sched1()
        started = threading.Event()
        queued = threading.Event()

        def periodic():
            started.set()
            assert queued.wait(5)
            s.maybe_yield()

        fut = s.submit(PERIODIC, periodic)
        assert started.wait(5)
        r = s.submit(RESTORE, lambda: time.sleep(0.2))
        queued.set()
        fut.result(timeout=5)
        r.result(timeout=5)
        st = s.snapshot_stats()
        assert st["restore"]["exec_s"] >= 0.2
        # the periodic job's exec excludes the 0.2 s it spent helping
        assert st["periodic"]["exec_s"] < 0.2
        s.shutdown(wait=True, timeout=5)


class TestLifecycle:
    def test_shutdown_cancels_pending_and_joins(self):
        s = sched1()
        gate = threading.Event()
        running = s.submit(PERIODIC, gate.wait, 5)
        time.sleep(0.05)
        queued = s.submit(PERIODIC, lambda: None)
        gate.set()
        running.result(timeout=5)
        s.shutdown(wait=True, timeout=5, cancel_pending=True)
        assert queued.cancelled() or queued.done()
        with pytest.raises(RuntimeError):
            s.submit(RESTORE, lambda: None)

    def test_shutdown_drains_queued_urgent_jobs(self):
        # cancel_pending must never cancel URGENT work: a termination save
        # queued behind a running encode has to reach its COMMITTED rename.
        s = sched1()
        gate = threading.Event()
        running = s.submit(PERIODIC, gate.wait, 5)
        time.sleep(0.05)
        urgent = s.submit(URGENT, lambda: "committed")
        periodic = s.submit(PERIODIC, lambda: None)
        s.shutdown(wait=False, cancel_pending=True)
        assert urgent.result(timeout=1) == "committed"
        assert periodic.cancelled()
        gate.set()
        running.result(timeout=5)
        s.shutdown(wait=True, timeout=5)

    def test_urgent_submit_after_shutdown_runs_inline(self):
        # the atexit race: a transient save_urgent thread that loses the
        # race with interpreter-shutdown must still get its job executed
        # (inline, on the submitting thread) instead of a RuntimeError.
        s = sched1()
        s.shutdown(wait=True, timeout=5, cancel_pending=True)
        fut = s.submit(URGENT, lambda: 7)
        assert fut.done() and fut.result() == 7
        with pytest.raises(RuntimeError):
            s.submit(PERIODIC, lambda: None)

    def test_urgent_save_after_global_shutdown_commits(self, tmp_path):
        # End-to-end regression for the same race: simulate atexit having
        # already shut the global scheduler down, then drive a termination
        # save through AsyncCheckpointer — it must commit a manifest that
        # restores bit-identically.
        codec_sched._reset_for_tests()
        try:
            codec_sched.scheduler().shutdown(
                wait=True, timeout=5.0, cancel_pending=True)
            store = CheckpointStore(str(tmp_path), mode="delta")
            ckpt = AsyncCheckpointer(store)
            state = _state(3)
            info = ckpt.save_urgent(3, state, timeout_s=60)
            assert info is not None and info.step == 3
            assert store.committed_steps() == [3]
        finally:
            codec_sched._reset_for_tests()
        # verify on a fresh scheduler: the checkpoint written during
        # teardown must restore bit-identically
        got, man = store.restore(_template(state))
        assert man.step == 3
        for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(state)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_global_scheduler_is_singleton_and_lanes_share_it(self):
        a = codec_sched.scheduler()
        b = codec_sched.scheduler()
        assert a is b
        lane = codec_sched.lane(RESTORE)
        assert isinstance(lane, CodecLane)
        assert lane.scheduler is a and lane.priority == RESTORE

    def test_global_shutdown_registered_atexit(self):
        # the leak fix: the process-wide scheduler must be atexit-registered
        import atexit
        codec_sched.scheduler()
        # py>=3.12 private introspection varies; assert via re-register
        # being idempotent instead: unregister succeeds only if registered
        n = atexit.unregister(codec_sched._sched.shutdown)
        assert n is None          # unregister never raises; re-register now
        atexit.register(codec_sched._sched.shutdown, wait=True,
                        timeout=10.0, cancel_pending=True)


def _state(step, seed=0):
    rng = np.random.default_rng(seed)
    return {
        "params": {"w": rng.standard_normal((256, 256)).astype(np.float32),
                   "b": rng.standard_normal((256,)).astype(np.float32)},
        "opt": {"mu": {"w": rng.standard_normal((256, 256)).astype(np.float32)}},
        "step": step,
    }


def _template(s):
    return jax.tree.map(
        lambda x: np.zeros(x.shape, x.dtype) if hasattr(x, "shape") else x, s)


class TestContendedCorrectness:
    """Satellite: restore under an active writer into the same pool must be
    bit-identical, and a yielded periodic save must still commit."""

    @pytest.mark.timeout(120)
    @pytest.mark.parametrize("mode", ["delta", "full"])
    def test_restore_bit_identical_under_concurrent_writer(self, tmp_path, mode):
        store = CheckpointStore(str(tmp_path / "a"), mode=mode, retention=100)
        expect = _state(1, seed=1)
        store.save(1, expect)
        stop = threading.Event()
        errs = []

        def writer():
            # hammers the same process-wide scheduler with PERIODIC encodes
            wstore = CheckpointStore(str(tmp_path / "b"), mode=mode,
                                     retention=4)
            i = 0
            try:
                while not stop.is_set():
                    i += 1
                    wstore.save(i, _state(i, seed=i))
            except BaseException as e:
                errs.append(e)

        t = threading.Thread(target=writer, daemon=True)
        t.start()
        try:
            for _ in range(5):
                got, man = store.restore(_template(expect))
                assert man.step == 1
                for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(expect)):
                    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        finally:
            stop.set()
            t.join(timeout=30)
        assert not errs

    @pytest.mark.timeout(120)
    def test_yielded_periodic_save_commits_valid_manifest(self, tmp_path):
        """A periodic save whose encode workers yield to interleaved restores
        must still produce a COMMITTED manifest that restores exactly."""
        store = CheckpointStore(str(tmp_path), mode="delta", retention=100)
        base = _state(1, seed=1)
        store.save(1, base)
        stop = threading.Event()
        errs = []

        def restorer():
            try:
                while not stop.is_set():
                    got, man = store.restore(_template(base))
                    assert man.step >= 1
            except BaseException as e:
                errs.append(e)

        t = threading.Thread(target=restorer, daemon=True)
        t.start()
        try:
            for i in range(2, 6):
                s = _state(i, seed=i)
                store.save(i, s)
        finally:
            stop.set()
            t.join(timeout=30)
        assert not errs
        assert store.committed_steps() == [1, 2, 3, 4, 5]
        got, man = store.restore(_template(base))
        assert man.step == 5
        expect = _state(5, seed=5)
        for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(expect)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class TestLedgerSplit:
    """Satellite: queue-wait charged separately from decode time."""

    def test_restore_latest_splits_queue_wait_from_decode(self, tmp_path):
        clock = VirtualClock()
        store = CheckpointStore(str(tmp_path), time_fn=clock.now)
        policy = CheckpointPolicy.transparent(1e9)
        coord = SpotOnCoordinator(store, policy, clock,
                                  time_model=TimeModel())
        s = _state(3)
        store.save(3, s)
        restored = coord.restore_latest(_template(s))
        assert restored is not None
        # both observation categories exist and were recorded once
        assert len(coord.ledger.observed["restore_queue_wait"]) == 1
        assert len(coord.ledger.observed["restore_decode"]) == 1
        assert coord.stats.restore_decode_s > 0.0
        assert coord.stats.restore_queue_wait_s >= 0.0
        # measured wall restore time advanced the virtual clock (the MTTR
        # de-quantization fix): restore_wall is charged, distinct from the
        # modeled `restore` read cost
        assert coord.ledger.charged["restore_wall"] > 0.0
        assert coord.ledger.charged["restore"] > 0.0
