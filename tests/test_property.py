"""Property-based tests (hypothesis) on system invariants."""

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

import jax
import jax.numpy as jnp

from repro.checkpoint import serialize as ser
from repro.core.cost import AZURE_D8S_V3, CostAccountant
from repro.models.moe import router_capacity, top_k_routing
from repro.models.config import MoEConfig
from repro.data import TokenPipeline
from repro.optim import AdamWConfig, lr_at

DTYPES = st.sampled_from(["float32", "int32", "uint8", "bfloat16"])
SHAPES = st.lists(st.integers(1, 7), min_size=0, max_size=3).map(tuple)


@settings(max_examples=40, deadline=None)
@given(shape=SHAPES, dtype=DTYPES, codec=st.sampled_from(["raw", "zstd"]),
       seed=st.integers(0, 2**31 - 1))
def test_serialize_roundtrip(tmp_path_factory, shape, dtype, codec, seed):
    rng = np.random.default_rng(seed)
    np_dtype = ser.name_to_dtype(dtype)
    if dtype in ("float32", "bfloat16"):
        arr = rng.standard_normal(shape).astype(np_dtype)
    else:
        arr = rng.integers(0, 100, size=shape).astype(np_dtype)
    p = ser.encode_tensor("x", arr, codec=codec)
    dec = ser._decode(p.payload, p.record)
    assert dec.dtype == arr.dtype and dec.shape == arr.shape
    np.testing.assert_array_equal(dec, arr)


@settings(max_examples=25, deadline=None)
@given(n_experts=st.sampled_from([4, 8, 16]), top_k=st.integers(1, 4),
       tokens=st.integers(1, 64), seed=st.integers(0, 1000))
def test_moe_routing_invariants(n_experts, top_k, tokens, seed):
    top_k = min(top_k, n_experts)
    cfg = MoEConfig(n_experts=n_experts, top_k=top_k, d_expert=8,
                    capacity_factor=1.25)
    logits = jax.random.normal(jax.random.key(seed), (tokens, n_experts))
    dispatch, combine, aux = top_k_routing(logits, cfg)
    C = router_capacity(tokens, cfg)
    d = np.asarray(dispatch)
    c = np.asarray(combine)
    # no expert queue overflows its capacity
    load = d.sum(axis=(0, 2))
    assert (d.sum(axis=0).max(initial=0.0) <= C + 1e-6)
    # each (token, k) occupies at most one slot; combine weights <= 1 per token
    assert (d.reshape(tokens, -1).sum(axis=1) <= cfg.top_k + 1e-6).all()
    assert (c.reshape(tokens, -1).sum(axis=1) <= 1.0 + 1e-5).all()
    # combine weight only where dispatched
    assert (c[d == 0.0] == 0.0).all()
    assert np.isfinite(float(aux))


@settings(max_examples=20, deadline=None)
@given(seconds=st.lists(st.floats(0.0, 1e5), min_size=1, max_size=10))
def test_cost_accountant_additivity(seconds):
    a = CostAccountant(AZURE_D8S_V3)
    for s in seconds:
        a.record_instance("spot", s)
    b = CostAccountant(AZURE_D8S_V3)
    b.record_instance("spot", sum(seconds))
    assert a.compute_cost()["spot_usd"] == pytest.approx(
        b.compute_cost()["spot_usd"], rel=1e-9, abs=1e-12)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 100), idx=st.integers(0, 500),
       vocab=st.sampled_from([16, 1000, 65536]))
def test_pipeline_pure_function_of_index(seed, idx, vocab):
    p1 = TokenPipeline(vocab_size=vocab, batch=2, seq_len=8, seed=seed)
    p2 = TokenPipeline(vocab_size=vocab, batch=2, seq_len=8, seed=seed)
    a, b = p1.batch_at(idx), p2.batch_at(idx)
    np.testing.assert_array_equal(a["inputs"], b["inputs"])
    assert a["inputs"].max() < vocab and a["inputs"].min() >= 0


@settings(max_examples=20, deadline=None)
@given(step=st.integers(0, 20000))
def test_lr_schedule_bounds(step):
    cfg = AdamWConfig(peak_lr=1e-3, warmup_steps=100, total_steps=10000,
                      min_lr_frac=0.1)
    lr = float(lr_at(cfg, step))
    assert 0.0 <= lr <= cfg.peak_lr * (1 + 1e-6)
    if step >= cfg.total_steps:
        assert lr == pytest.approx(cfg.peak_lr * cfg.min_lr_frac, rel=1e-3)


@settings(max_examples=15, deadline=None)
@given(n=st.integers(1, 30), retention=st.integers(1, 5))
def test_store_retention_invariant(tmp_path_factory, n, retention):
    from repro.checkpoint import CheckpointStore
    td = tmp_path_factory.mktemp("ret")
    store = CheckpointStore(str(td), retention=retention)
    for i in range(n):
        store.save(i, {"x": np.full((4,), i, np.float32)})
    steps = store.committed_steps()
    assert len(steps) == min(n, retention)
    assert steps == sorted(range(n))[-retention:]
