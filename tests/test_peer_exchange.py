"""Peer-to-peer chunk exchange: wire protocol, read-through restore,
mid-transfer peer death (store fallback stays bit-identical), notice-window
seeding through the fleet, RESTORE-lane discipline of the new submit sites,
and the simulated multihost restore barrier."""

import os
import shutil
import socket
import threading
from pathlib import Path

import numpy as np
import pytest

from repro import faults
from repro.analysis.spotlint import analyze
from repro.checkpoint import CheckpointStore, chunkstore, codec_sched
from repro.checkpoint import manifest as mf
from repro.checkpoint import peer_exchange as px
from repro.checkpoint.chunkstore import ChunkPool, ChunkRef, store_chunk
from repro.core import (CheckpointPolicy, FleetCoordinator, FleetSpec,
                        NoEviction, PeriodicEviction, TimeModel, VirtualClock)
from repro.distributed import multihost

REPO = Path(__file__).resolve().parent.parent


def seed_chunks(pool: ChunkPool, rng, n=4, size=4096) -> list[ChunkRef]:
    refs = []
    for _ in range(n):
        raw = rng.integers(0, 256, size=size, dtype=np.uint8).tobytes()
        ref, _n, _rd = store_chunk(pool, raw, comp="zlib")
        refs.append(ref)
    return refs


@pytest.fixture
def server_pool(tmp_path):
    pool = ChunkPool(str(tmp_path / "peer" / "chunks"))
    srv = px.PeerChunkServer(pool).start()
    yield pool, srv
    srv.close()


class TestProtocol:
    def test_get_round_trip(self, server_pool, rng):
        pool, srv = server_pool
        refs = seed_chunks(pool, rng)
        client = px.PeerChunkClient([srv.address])
        for ref in refs:
            data = client.fetch(ref)
            assert data is not None
            assert chunkstore.chunk_content_ok(ref, data)
            assert data == pool.read(ref)
        assert client.stats["hits"] == len(refs)
        assert srv.stats["get_hits"] == len(refs)
        assert srv.stats["bytes_served"] == sum(r.nbytes for r in refs)

    def test_get_miss(self, server_pool):
        _pool, srv = server_pool
        client = px.PeerChunkClient([srv.address])
        ghost = ChunkRef(hash="ab" * 20, nbytes=64, raw_len=64,
                         crc32=0, comp="raw")
        assert client.fetch(ghost) is None
        assert client.stats["misses"] == 1
        assert srv.stats["get_misses"] == 1

    def test_put_lands_and_bad_digest_rejected(self, server_pool):
        pool, srv = server_pool
        client = px.PeerChunkClient([srv.address])
        data = b"peer-seeded chunk payload" * 64
        h = chunkstore.chunk_digest(data)
        assert client.push(srv.address, h, data)
        assert pool.check(h, len(data))
        # a push may not plant bytes under an address they don't hash to
        assert not client.push(srv.address, "00" * 20, data)
        assert not pool.check("00" * 20, len(data))
        assert client.stats["pushes"] == 1
        assert client.stats["push_failures"] == 1

    def test_dead_peer_is_a_miss_not_an_error(self, rng):
        # grab a port that nothing listens on
        s = socket.socket()
        s.bind(("127.0.0.1", 0))
        dead = s.getsockname()
        s.close()
        client = px.PeerChunkClient([dead], timeout_s=0.2)
        ghost = ChunkRef(hash="cd" * 20, nbytes=64, raw_len=64,
                         crc32=0, comp="raw")
        assert client.fetch(ghost) is None
        assert client.stats["misses"] == 1

    def test_fetch_falls_through_to_second_peer(self, tmp_path, rng):
        # peer A is empty, peer B holds the chunk: the client must find it
        empty = ChunkPool(str(tmp_path / "a" / "chunks"))
        full = ChunkPool(str(tmp_path / "b" / "chunks"))
        refs = seed_chunks(full, rng, n=3)
        sa = px.PeerChunkServer(empty).start()
        sb = px.PeerChunkServer(full).start()
        try:
            client = px.PeerChunkClient([sa.address, sb.address])
            for ref in refs:
                assert client.fetch(ref) == full.read(ref)
        finally:
            sa.close()
            sb.close()


def make_store(tmp_path, rng, *, elems=8192):
    store = CheckpointStore(str(tmp_path / "store"))
    state = {"w": rng.normal(size=(elems,)).astype(np.float32),
             "b": rng.normal(size=(257,)).astype(np.float32)}
    store.save(1, state)
    return store, state


def manifest_refs(store: CheckpointStore) -> list[ChunkRef]:
    man, reader = store.latest_valid()
    reader.close()
    refs: dict[str, ChunkRef] = {}
    for rec in man.tensors:
        for c in rec.get("chunks", ()):
            refs.setdefault(c["h"], ChunkRef.from_json(c))
    return list(refs.values())


class TestReadThrough:
    def _fabric(self, tmp_path, store, *, seed_peer=True):
        local = ChunkPool(str(tmp_path / "local" / "chunks"))
        peer = ChunkPool(str(tmp_path / "peer" / "chunks"))
        if seed_peer:
            for h, path in store.pool.all_chunks():
                with open(path, "rb") as f:
                    peer.write(h, f.read(), sync_dir=False)
        srv = px.PeerChunkServer(peer).start()
        client = px.PeerChunkClient([srv.address])
        return px.ReadThroughPool(local, client, store.pool), srv

    def test_restore_warm_from_peer_bit_identical(self, tmp_path, rng):
        store, state = make_store(tmp_path, rng)
        rt, srv = self._fabric(tmp_path, store)
        try:
            template = {k: np.zeros_like(v) for k, v in state.items()}
            got, man = store.restore(template, chunk_pool=rt)
            for k in state:
                np.testing.assert_array_equal(np.asarray(got[k]), state[k])
            assert rt.stats["peer_hits"] > 0
            assert rt.stats["store_reads"] == 0
            # peer hits landed in the local cache: a second restore is local
            got2, _ = store.restore(template, chunk_pool=rt)
            assert rt.stats["local_hits"] > 0
            for k in state:
                np.testing.assert_array_equal(np.asarray(got2[k]), state[k])
        finally:
            srv.close()

    def test_restore_streaming_through_peers(self, tmp_path, rng):
        store, state = make_store(tmp_path, rng)
        rt, srv = self._fabric(tmp_path, store)
        try:
            template = {k: np.zeros_like(v) for k, v in state.items()}
            got, _ = store.restore(template, streaming=True, chunk_pool=rt)
            for k in state:
                np.testing.assert_array_equal(np.asarray(got[k]), state[k])
            assert rt.stats["peer_hits"] + rt.stats["local_hits"] > 0
        finally:
            srv.close()

    def test_empty_peer_falls_back_to_store(self, tmp_path, rng):
        store, state = make_store(tmp_path, rng)
        rt, srv = self._fabric(tmp_path, store, seed_peer=False)
        try:
            template = {k: np.zeros_like(v) for k, v in state.items()}
            got, _ = store.restore(template, chunk_pool=rt)
            for k in state:
                np.testing.assert_array_equal(np.asarray(got[k]), state[k])
            assert rt.stats["store_reads"] > 0
            assert rt.stats["peer_hits"] == 0
        finally:
            srv.close()


class TestWarmPrefetch:
    def test_warm_restore_from_peers(self, tmp_path, rng):
        store, state = make_store(tmp_path, rng)
        local = ChunkPool(str(tmp_path / "local" / "chunks"))
        peer = ChunkPool(str(tmp_path / "peer" / "chunks"))
        for h, path in store.pool.all_chunks():
            with open(path, "rb") as f:
                peer.write(h, f.read(), sync_dir=False)
        srv = px.PeerChunkServer(peer).start()
        try:
            rt = px.ReadThroughPool(local, px.PeerChunkClient([srv.address]),
                                    store.pool)
            refs = manifest_refs(store)
            assert refs
            res = px.warm_restore_from_peers(rt, refs, batch=2)
            assert res["warmed"] == len(refs)
            assert res["missed"] == 0
            # everything is local now: the restore never leaves the box
            res2 = px.warm_restore_from_peers(rt, refs)
            assert res2["already_local"] == len(refs)
            template = {k: np.zeros_like(v) for k, v in state.items()}
            got, _ = store.restore(template, chunk_pool=rt)
            for k in state:
                np.testing.assert_array_equal(np.asarray(got[k]), state[k])
            assert rt.stats["store_reads"] == 0
        finally:
            srv.close()


class TestPeerFaults:
    def test_peer_dies_mid_transfer_store_fallback_bit_identical(
            self, tmp_path, rng):
        # the serving peer announces the full frame, ships half, drops the
        # connection — the client must treat it as a miss (short read), and
        # the read-through restore must come back bit-identical via the store
        store, state = make_store(tmp_path, rng)
        local = ChunkPool(str(tmp_path / "local" / "chunks"))
        peer = ChunkPool(str(tmp_path / "peer" / "chunks"))
        for h, path in store.pool.all_chunks():
            with open(path, "rb") as f:
                peer.write(h, f.read(), sync_dir=False)
        srv = px.PeerChunkServer(peer).start()
        try:
            client = px.PeerChunkClient([srv.address], timeout_s=0.5)
            rt = px.ReadThroughPool(local, client, store.pool)
            template = {k: np.zeros_like(v) for k, v in state.items()}
            plan = faults.FaultPlan().add("peer.send", nth=1, count=-1,
                                          error="crash")
            with faults.active(plan):
                got, _ = store.restore(template, chunk_pool=rt)
            assert plan.fired()
            for k in state:
                np.testing.assert_array_equal(np.asarray(got[k]), state[k])
            # every chunk came off the durable store, none off the dying peer
            assert rt.stats["store_reads"] > 0
            assert rt.stats["peer_hits"] == 0
        finally:
            srv.close()

    def test_unreachable_peer_fault_store_fallback(self, tmp_path, rng):
        store, state = make_store(tmp_path, rng)
        local = ChunkPool(str(tmp_path / "local" / "chunks"))
        peer = ChunkPool(str(tmp_path / "peer" / "chunks"))
        srv = px.PeerChunkServer(peer).start()
        try:
            rt = px.ReadThroughPool(local, px.PeerChunkClient([srv.address]),
                                    store.pool)
            plan = faults.FaultPlan().add("peer.fetch", nth=1, count=-1,
                                          error="etimedout")
            template = {k: np.zeros_like(v) for k, v in state.items()}
            with faults.active(plan):
                got, _ = store.restore(template, chunk_pool=rt)
            assert plan.fired()
            for k in state:
                np.testing.assert_array_equal(np.asarray(got[k]), state[k])
            assert rt.stats["store_reads"] > 0
        finally:
            srv.close()

    def test_partial_peer_loss_still_warms_from_survivor(self, tmp_path, rng):
        # two peers hold the chunks; the first dies mid-transfer every time,
        # the second answers — fetch must land without touching the store
        store, state = make_store(tmp_path, rng)
        pools, servers = [], []
        for name in ("a", "b"):
            p = ChunkPool(str(tmp_path / name / "chunks"))
            for h, path in store.pool.all_chunks():
                with open(path, "rb") as f:
                    p.write(h, f.read(), sync_dir=False)
            pools.append(p)
            servers.append(px.PeerChunkServer(p).start())
        try:
            dying = servers[0].pool.root
            plan = faults.FaultPlan().add("peer.send", nth=1, count=-1,
                                          error="crash", path_substr=dying)
            client = px.PeerChunkClient([s.address for s in servers],
                                        timeout_s=0.5)
            refs = manifest_refs(store)
            with faults.active(plan):
                for ref in refs:
                    data = client.fetch(ref)
                    assert data is not None
                    assert chunkstore.chunk_content_ok(ref, data)
            assert client.stats["hits"] == len(refs)
        finally:
            for s in servers:
                s.close()


class TestFleetSeeding:
    def test_notice_window_seeds_survivors(self, tmp_path):
        clock = VirtualClock()
        store = CheckpointStore(str(tmp_path / "store"), time_fn=clock.now)
        exchange = px.FleetPeerExchange(str(tmp_path / "fabric"), 3)
        try:
            spec = FleetSpec(providers=("aws", "gcp", "azure"),
                             schedules=(PeriodicEviction(150.0),
                                        NoEviction(), NoEviction()),
                             provisioning_delay_s=60.0)
            fleet = FleetCoordinator(store, CheckpointPolicy.transparent(100.0),
                                     clock, spec, time_model=TimeModel(),
                                     peer_exchange=exchange)
            rep = fleet.run(total_steps=40, step_time_s=10.0)
            assert rep.completed
            assert fleet.peer_seed_events, "eviction notice never seeded peers"
            ev = fleet.peer_seed_events[0]
            assert ev["survivors"] == 2
            assert ev["chunks"] > 0
            assert rep.checkpoints["peer_seed_events"] == \
                len(fleet.peer_seed_events)
            assert rep.checkpoints["peer_seeded_chunks"] > 0
            assert rep.checkpoints["peer_seeded_bytes"] > 0
            # the pushed chunks really landed in the survivors' local pools
            seeded = [sum(1 for _ in pool.all_chunks())
                      for i, (pool, _srv) in enumerate(exchange.members)
                      if i != ev["member"]]
            assert all(n > 0 for n in seeded)
        finally:
            exchange.close()

    def test_rescale_events_carry_fingerprint_counts(self, tmp_path):
        clock = VirtualClock()
        store = CheckpointStore(str(tmp_path / "store"), time_fn=clock.now)
        spec = FleetSpec(providers=("aws", "gcp"),
                         schedules=(PeriodicEviction(200.0), NoEviction()),
                         provisioning_delay_s=60.0)
        fleet = FleetCoordinator(store, CheckpointPolicy.transparent(100.0),
                                 clock, spec, time_model=TimeModel())
        rep = fleet.run(total_steps=40, step_time_s=10.0)
        assert rep.completed
        assert fleet.rescale_events
        planned = [ev for ev in fleet.rescale_events if "mesh_shape" in ev]
        assert planned
        for ev in planned:
            assert "fingerprints_kept" in ev
            assert "fingerprints_dropped" in ev


class TestLaneDiscipline:
    """SPOT011 mutation coverage: the new restore-window submit sites are
    lane-correct, and the rule would catch them drifting to the encode lane."""

    REAL = ["src/repro/checkpoint/peer_exchange.py",
            "src/repro/checkpoint/sharded.py"]

    def _mirror(self, tmp_path, relpath: str) -> Path:
        target = tmp_path / relpath
        target.parent.mkdir(parents=True, exist_ok=True)
        shutil.copy(REPO / relpath, target)
        return target

    @pytest.mark.parametrize("relpath", REAL)
    def test_restore_submit_sites_clean(self, tmp_path, relpath):
        target = self._mirror(tmp_path, relpath)
        codes = {f.code for f in analyze([str(target)])}
        assert "SPOT011" not in codes

    @pytest.mark.parametrize("relpath", REAL)
    def test_lane_drift_is_caught(self, tmp_path, relpath):
        # mutate restore_executor() -> codec_executor(): every restore-path
        # submit site must light up SPOT011, proving the rule covers them
        target = self._mirror(tmp_path, relpath)
        src = target.read_text()
        assert "restore_executor()" in src
        target.write_text(src.replace("restore_executor()",
                                      "codec_executor()"))
        codes = {f.code for f in analyze([str(target)])}
        assert "SPOT011" in codes


class TestRestoreBarrier:
    def test_streaming_restores_rendezvous(self, tmp_path, rng):
        # three "processes" (threads) restore the same checkpoint; with the
        # simulated barrier installed, none returns before all reach the
        # spoton:restore_streaming sync point
        store, state = make_store(tmp_path, rng)
        template = {k: np.zeros_like(v) for k, v in state.items()}
        results, errors = [None] * 3, []
        barrier = multihost.SimulatedBarrier(3, timeout_s=30.0)

        def restore(i):
            try:
                got, _ = store.restore(template, streaming=True)
                results[i] = got
            except Exception as e:            # pragma: no cover - diagnostics
                errors.append(e)

        with multihost.use_simulated_barrier(barrier):
            threads = [threading.Thread(target=restore, args=(i,))
                       for i in range(3)]
            for t in threads:
                t.start()
            for t in threads:
                t.join(timeout=60.0)
        assert not errors
        for got in results:
            assert got is not None
            for k in state:
                np.testing.assert_array_equal(np.asarray(got[k]), state[k])

    def test_lost_participant_breaks_loudly(self):
        barrier = multihost.SimulatedBarrier(2, timeout_s=0.2)
        with pytest.raises(RuntimeError, match="broken"):
            barrier.wait("spoton:restore_streaming")

    def test_no_barrier_installed_is_a_noop(self):
        multihost.sync_global_devices("spoton:restore_streaming")
