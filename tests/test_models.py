"""Per-arch smoke tests (reduced configs): one forward + one train step on
CPU asserting shapes and finiteness; decode-vs-teacher-forcing consistency for
one representative of each cache family (ring / KV / SSM / LRU / MoE)."""

import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, SHAPES, cell_is_runnable, get_config, get_smoke_config
from repro.models import decode_step, forward, init_cache, init_params, prefill
from repro.optim import AdamWConfig
from repro.train.train_step import init_train_state, make_train_step
from repro.data import TokenPipeline


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_smoke_config(arch)
    B, S = 2, 32
    pipe = TokenPipeline(vocab_size=cfg.vocab_size, batch=B, seq_len=S,
                         embed_dim=None if cfg.embed_inputs else cfg.d_model)
    batch = pipe.batch_at(0)
    state = init_train_state(cfg, AdamWConfig(total_steps=10))
    logits, aux, _ = jax.jit(lambda p, x: forward(p, cfg, x))(
        state["params"], batch["inputs"])
    assert logits.shape == (B, S, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    step = jax.jit(make_train_step(cfg, AdamWConfig(total_steps=10)))
    state2, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert int(state2["step"]) == 1
    # params actually moved
    moved = any(
        not np.array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))
        for a, b in zip(jax.tree.leaves(state["params"]),
                        jax.tree.leaves(state2["params"])))
    assert moved


@pytest.mark.parametrize("arch", [
    "gemma3_1b",          # ring + full caches, 5:1 local:global
    "deepseek_moe_16b",   # MoE routed+shared, dense prelude
    "falcon_mamba_7b",    # SSM state cache
    "recurrentgemma_2b",  # RG-LRU + local attn hybrid
])
def test_decode_matches_teacher_forcing(arch):
    cfg = dataclasses.replace(get_smoke_config(arch), dtype="float32")
    params = init_params(cfg, jax.random.key(0))
    B, S = 2, 24
    if cfg.embed_inputs:
        inputs = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size)
    else:
        inputs = jax.random.normal(jax.random.key(1), (B, S, cfg.d_model))
    logits_tf, _, _ = jax.jit(lambda p, x: forward(p, cfg, x))(params, inputs)
    dec = jax.jit(lambda p, x, c, pos: decode_step(p, cfg, x, c, pos))
    caches = init_cache(cfg, B, S)
    errs = []
    for t in range(S):
        lg, caches = dec(params, inputs[:, t:t + 1], caches, t)
        errs.append(float(np.max(np.abs(np.asarray(lg) - np.asarray(logits_tf[:, t])))))
    assert max(errs) < 5e-4, max(errs)
    # prefill handoff
    half = S // 2
    last, caches_p, _ = jax.jit(
        lambda p, x: prefill(p, cfg, x, cache_len=S))(params, inputs[:, :half])
    lg, _ = dec(params, inputs[:, half:half + 1], caches_p, half)
    assert float(np.max(np.abs(np.asarray(lg) - np.asarray(logits_tf[:, half])))) < 5e-4


def test_param_count_within_spec():
    """Analytic parameter counts stay near the published sizes."""
    expected = {
        "command_r_plus_104b": (104e9, 0.10),
        "grok1_314b": (314e9, 0.10),
        "falcon_mamba_7b": (7.3e9, 0.15),
        "phi3_mini_3p8b": (3.8e9, 0.10),
        "deepseek_moe_16b": (16.4e9, 0.10),
        "minitron_8b": (8e9, 0.25),
        "gemma3_1b": (1.0e9, 0.35),
        "recurrentgemma_2b": (2.7e9, 0.25),
        "llava_next_34b": (34e9, 0.15),
        "musicgen_medium": (1.5e9, 0.35),
    }
    for arch, (target, tol) in expected.items():
        n = get_config(arch).param_count()
        assert abs(n - target) / target < tol, (arch, n, target)


def test_long_context_eligibility():
    eligible = {a for a in ARCH_IDS
                if cell_is_runnable(get_config(a), "long_500k")[0]}
    assert eligible == {"falcon_mamba_7b", "recurrentgemma_2b", "gemma3_1b"}


def test_pipeline_deterministic_and_stateless():
    pipe = TokenPipeline(vocab_size=100, batch=2, seq_len=8, seed=3)
    a = pipe.batch_at(5)
    b = pipe.batch_at(5)
    np.testing.assert_array_equal(a["inputs"], b["inputs"])
    c = pipe.batch_at(6)
    assert not np.array_equal(a["inputs"], c["inputs"])
    # labels are next-token shifted
    full = TokenPipeline(vocab_size=100, batch=2, seq_len=8, seed=3)
    d = full.batch_at(0)
    assert d["labels"].shape == d["inputs"].shape
