"""Checkpoint substrate: serialization, atomic commit, corruption fallback,
retention, async writer, termination-checkpoint semantics."""

import os
import threading

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint import (AsyncCheckpointer, CheckpointStore,
                              extract_snapshot)
from repro.checkpoint import serialize as ser
from repro.checkpoint import manifest as mf


def small_state(step=3):
    return {
        "params": {"w": jnp.arange(32, dtype=jnp.bfloat16).reshape(4, 8),
                   "b": jnp.ones((8,), jnp.float32)},
        "opt": {"mu": {"w": jnp.full((4, 8), 0.25, jnp.float32)},
                "count": jnp.asarray(step, jnp.int32)},
        "step": step,
        "rng": np.array([7, 9], np.uint32),
    }


def template():
    s = small_state()
    return jax.tree.map(lambda x: np.zeros(x.shape, x.dtype)
                        if hasattr(x, "shape") else x, s)


class TestSerialize:
    def test_roundtrip_dtypes(self, tmp_path):
        arrays = {
            "bf16": np.arange(24, dtype=np.float32).reshape(2, 3, 4),
            "f32": np.random.default_rng(0).standard_normal((5, 7)).astype(np.float32),
            "i32": np.arange(-5, 5, dtype=np.int32),
            "u8": np.arange(16, dtype=np.uint8),
        }
        import ml_dtypes
        arrays["bf16"] = arrays["bf16"].astype(ml_dtypes.bfloat16)
        pend = [ser.encode_tensor(k, v, codec="zstd") for k, v in arrays.items()]
        path = tmp_path / "x.spot"
        ser.write_shard_file(path, pend)
        r = ser.ShardFileReader(path)
        for k, v in arrays.items():
            got = r.read(k)
            assert got.dtype == v.dtype
            np.testing.assert_array_equal(got, v)

    def test_int8_codec_bounded_error(self):
        x = np.linspace(-3, 3, 1000, dtype=np.float32)
        p = ser.encode_tensor("m", x, codec="int8")
        buf = p.payload
        dec = ser._decode(buf, p.record)
        assert np.max(np.abs(dec - x)) <= (3.0 / 127.0) * 0.5 + 1e-6

    def test_crc_detects_corruption(self, tmp_path):
        p = ser.encode_tensor("t", np.ones((64,), np.float32))
        path = tmp_path / "c.spot"
        ser.write_shard_file(path, [p])
        raw = bytearray(open(path, "rb").read())
        raw[-5] ^= 0xFF  # flip a payload byte
        open(path, "wb").write(bytes(raw))
        r = ser.ShardFileReader(path)
        with pytest.raises(IOError):
            r.read("t")


class TestAtomicCommit:
    @pytest.mark.parametrize("phase", ["shards_written", "manifest_written"])
    def test_crash_before_rename_invisible(self, tmp_path, phase):
        def injector(p):
            if p == phase:
                raise RuntimeError("killed mid-eviction")
        store = CheckpointStore(str(tmp_path), fault_injector=injector)
        with pytest.raises(RuntimeError):
            store.save(1, small_state())
        assert store.committed_steps() == []
        clean = CheckpointStore(str(tmp_path))
        assert clean.latest_valid() is None

    def test_crash_after_rename_before_marker_invisible(self, tmp_path):
        def injector(p):
            if p == "renamed":
                raise RuntimeError("killed")
        store = CheckpointStore(str(tmp_path), fault_injector=injector)
        with pytest.raises(RuntimeError):
            store.save(1, small_state())
        # dir exists but no COMMITTED marker -> not restorable
        assert CheckpointStore(str(tmp_path)).committed_steps() == []

    def test_fallback_to_older_on_corruption(self, tmp_path):
        store = CheckpointStore(str(tmp_path), validate_on_restore=True,
                                retention=10)
        store.save(1, small_state(1))
        store.save(2, small_state(2))
        # corrupt a pool chunk referenced by step 2 only (shared chunks must
        # stay intact or step 1 would be damaged too)
        man1 = mf.read_manifest(os.path.join(str(tmp_path), mf.step_dirname(1)))
        man2 = mf.read_manifest(os.path.join(str(tmp_path), mf.step_dirname(2)))
        only2 = man2.chunk_hashes() - man1.chunk_hashes()
        assert only2, "steps 1 and 2 differ, so step 2 must own dirty chunks"
        chunk = store.pool.path(sorted(only2)[0])
        raw = bytearray(open(chunk, "rb").read())
        raw[-1] ^= 0xFF
        open(chunk, "wb").write(bytes(raw))
        state, man = store.restore(template())
        assert man.step == 1
        assert state["step"] == 1

    def test_fallback_to_older_on_corruption_v1(self, tmp_path):
        store = CheckpointStore(str(tmp_path), validate_on_restore=True,
                                retention=10, mode="full")
        store.save(1, small_state(1))
        store.save(2, small_state(2))
        # corrupt newest shard payload
        d2 = os.path.join(str(tmp_path), mf.step_dirname(2))
        shard = os.path.join(d2, "shard_p000.spot")
        raw = bytearray(open(shard, "rb").read())
        raw[-3] ^= 0xFF
        open(shard, "wb").write(bytes(raw))
        state, man = store.restore(template())
        assert man.step == 1
        assert state["step"] == 1

    def test_restore_roundtrip_exact(self, tmp_path):
        store = CheckpointStore(str(tmp_path))
        s = small_state(9)
        store.save(9, s, extra={"stage": 2})
        got, man = store.restore(template())
        assert man.extra["stage"] == 2
        for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(s)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_retention_gc(self, tmp_path):
        store = CheckpointStore(str(tmp_path), retention=2)
        for i in range(5):
            store.save(i, small_state(i))
        assert store.committed_steps() == [3, 4]


class TestAsync:
    def test_async_then_restore(self, tmp_path):
        store = CheckpointStore(str(tmp_path))
        ac = AsyncCheckpointer(store)
        ac.save_async(5, small_state(5))
        ac.wait_until_finished()
        state, man = store.restore(template())
        assert man.step == 5 and man.kind == "transparent"
        ac.close()

    def test_urgent_supersedes_queued(self, tmp_path):
        store = CheckpointStore(str(tmp_path))
        ac = AsyncCheckpointer(store, max_pending=4)
        ac.save_async(1, small_state(1))
        info = ac.save_urgent(2, small_state(2))
        assert info.kind == "termination" and info.step == 2
        ac.close()
        assert 2 in store.committed_steps()

    def test_error_surfaced(self, tmp_path):
        store = CheckpointStore(str(tmp_path))
        boom = {"n": 0}

        def injector(phase):
            if phase == "shards_written" and boom["n"] == 0:
                boom["n"] = 1
                raise IOError("nfs died")
        store.fault_injector = injector
        ac = AsyncCheckpointer(store)
        ac.save_async(1, small_state(1))
        with pytest.raises(RuntimeError):
            ac.wait_until_finished()
        ac.close()


class TestSnapshot:
    def test_extract_is_host_copy(self):
        s = small_state()
        snap = extract_snapshot(s, step=3)
        assert snap.nbytes > 0
        assert set(snap.leaves) == {
            "params/w", "params/b", "opt/mu/w", "opt/count", "step", "rng"}
        lp = snap.leaves["step"]
        assert lp.is_scalar_py and lp.py_type == "int"
