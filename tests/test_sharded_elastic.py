"""Sharded checkpoint + elastic restore across meshes. Needs >1 device, so it
runs in a subprocess with XLA_FLAGS=--xla_force_host_platform_device_count=8
(never set globally — see conftest)."""

import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    import numpy as np
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.checkpoint import CheckpointStore
    from repro.launch.mesh import make_mesh

    td = sys.argv[1]
    mesh = make_mesh((4, 2), ("data", "model"))
    sh = NamedSharding(mesh, P("data", "model"))
    w = jax.device_put(jnp.arange(256, dtype=jnp.bfloat16).reshape(16, 16), sh)
    state = {"params": {"w": w, "b": jnp.ones((16,), jnp.float32)},
             "opt": {"mu": {"w": w.astype(jnp.float32) * 0.5}},
             "step": 11}
    store = CheckpointStore(td, quantize_moments=False)
    info = store.save(11, state, mesh_info={"shape": [4, 2]})
    assert info.nbytes > 0

    # 1. restore onto a DIFFERENT mesh shape (2x4) with different specs
    mesh2 = make_mesh((2, 4), ("data", "model"))
    sh2 = NamedSharding(mesh2, P(None, "model"))
    tpl = {"params": {"w": jax.ShapeDtypeStruct((16, 16), jnp.bfloat16, sharding=sh2),
                      "b": jnp.zeros((16,), jnp.float32)},
           "opt": {"mu": {"w": jnp.zeros((16, 16), jnp.float32)}},
           "step": 0}
    got, man = store.restore(tpl)
    assert np.array_equal(np.asarray(got["params"]["w"]), np.asarray(w)), "remesh w"
    assert got["step"] == 11

    # 2. restore onto FEWER devices (half the 'pod' lost)
    mesh3 = make_mesh((2, 2), ("data", "model"), devices=jax.devices()[:4])
    sh3 = NamedSharding(mesh3, P("data", "model"))
    tpl3 = dict(tpl)
    tpl3 = {"params": {"w": jax.ShapeDtypeStruct((16, 16), jnp.bfloat16, sharding=sh3),
                       "b": jnp.zeros((16,), jnp.float32)},
            "opt": {"mu": {"w": jnp.zeros((16, 16), jnp.float32)}},
            "step": 0}
    got3, _ = store.restore(tpl3)
    assert np.array_equal(np.asarray(got3["params"]["w"]), np.asarray(w)), "elastic w"
    assert np.allclose(np.asarray(got3["opt"]["mu"]["w"]),
                       np.asarray(w, np.float32) * 0.5)
    print("ELASTIC_OK")
""")


def test_elastic_restore_across_meshes(tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run([sys.executable, "-c", SCRIPT, str(tmp_path)],
                          capture_output=True, text=True, env=env, timeout=300)
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "ELASTIC_OK" in proc.stdout
