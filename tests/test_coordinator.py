"""SpotOnCoordinator policy semantics — the paper's §III-A contract."""

import numpy as np
import pytest

from repro.checkpoint import CheckpointStore
from repro.core import (CheckpointPolicy, Mode, Signal, SimulatedMetadataService,
                        SpotOnCoordinator, TimeModel, VirtualClock)


def state(step):
    return {"w": np.full((16,), float(step), np.float32), "step": step}


def make(tmp_path, policy, clock=None, tm=TimeModel()):
    clock = clock or VirtualClock()
    store = CheckpointStore(str(tmp_path), time_fn=clock.now)
    coord = SpotOnCoordinator(store, policy, clock, time_model=tm)
    md = SimulatedMetadataService(clock, "vm-0")
    coord.attach_instance(md, "vm-0")
    return coord, md, clock, store


class TestTransparent:
    def test_periodic_cadence(self, tmp_path):
        coord, md, clock, store = make(tmp_path, CheckpointPolicy.transparent(100.0))
        for step in range(1, 31):
            clock.advance(10.0)
            coord.on_step_end(step, lambda s=step: state(s))
        coord.flush()
        assert coord.stats.periodic_ckpts == pytest.approx(3, abs=1)

    def test_termination_checkpoint_on_preempt(self, tmp_path):
        coord, md, clock, store = make(tmp_path, CheckpointPolicy.transparent(1e9))
        md.simulate_eviction()
        clock.advance(2.0)
        sig = coord.on_step_end(7, lambda: state(7))
        assert sig is Signal.PREEMPTING
        assert coord.stats.termination_ckpts == 1
        got, man = store.restore(state(0))
        assert man.kind == "termination" and got["step"] == 7

    def test_termination_missing_window_fails_gracefully(self, tmp_path):
        # write cost exceeds the notice -> opportunistic failure, not crash
        tm = TimeModel(write_bw=1.0, latency_s=1000.0)   # absurdly slow NFS
        coord, md, clock, store = make(tmp_path, CheckpointPolicy.transparent(1e9),
                                       tm=tm)
        md.simulate_eviction()
        clock.advance(1.0)
        sig = coord.on_step_end(3, lambda: state(3))
        assert sig is Signal.PREEMPTING
        assert coord.stats.termination_failures == 1

    def test_same_event_handled_once(self, tmp_path):
        coord, md, clock, store = make(tmp_path, CheckpointPolicy.transparent(1e9))
        md.simulate_eviction()
        clock.advance(2.0)
        assert coord.on_step_end(1, lambda: state(1)) is Signal.PREEMPTING
        clock.advance(2.0)
        assert coord.on_step_end(2, lambda: state(2)) is Signal.CONTINUE


class TestDeadlineEdges:
    """Termination-checkpoint deadline edges: zero/negative budget, virtual
    cost exceeding the notice window, duplicate-event suppression."""

    def test_zero_budget_fails_without_write(self, tmp_path):
        coord, md, clock, store = make(tmp_path, CheckpointPolicy.transparent(1e9))
        ev = md.schedule_preempt(notice_s=30.0)
        clock.advance(ev.not_before - clock.now())     # poll lands AT NotBefore
        sig = coord.on_step_end(5, lambda: state(5))
        assert sig is Signal.PREEMPTING
        assert coord.stats.termination_failures == 1
        assert coord.stats.termination_ckpts == 0
        assert store.committed_steps() == []

    def test_negative_budget_fails_without_write(self, tmp_path):
        coord, md, clock, store = make(tmp_path, CheckpointPolicy.transparent(1e9))
        md.schedule_preempt(notice_s=30.0)
        clock.advance(90.0)                            # way past the deadline
        sig = coord.on_step_end(5, lambda: state(5))
        assert sig is Signal.PREEMPTING
        assert coord.stats.termination_failures == 1
        assert store.committed_steps() == []

    def test_virtual_cost_exceeding_window_charges_only_budget(self, tmp_path):
        # write cost exceeds the remaining notice: the failure must consume
        # exactly the budget (the VM was writing until the platform killed it)
        tm = TimeModel(write_bw=1.0, latency_s=500.0)  # cost >> 30 s window
        coord, md, clock, store = make(tmp_path, CheckpointPolicy.transparent(1e9),
                                       tm=tm)
        ev = md.simulate_eviction()
        clock.advance(2.0)
        t_before = clock.now()
        budget = ev.not_before - t_before
        sig = coord.on_step_end(3, lambda: state(3))
        assert sig is Signal.PREEMPTING
        assert coord.stats.termination_failures == 1
        assert clock.now() - t_before == pytest.approx(budget)

    def test_duplicate_event_id_suppressed(self, tmp_path):
        coord, md, clock, store = make(tmp_path, CheckpointPolicy.transparent(1e9))
        md.simulate_eviction()
        clock.advance(2.0)
        assert coord.on_step_end(1, lambda: state(1)) is Signal.PREEMPTING
        assert coord.stats.termination_ckpts == 1
        # same event still in the document: must not be handled twice
        for step in (2, 3, 4):
            clock.advance(2.0)
            assert coord.on_step_end(step, lambda s=step: state(s)) is Signal.CONTINUE
        assert coord.stats.termination_ckpts == 1

    def test_distinct_event_handled_separately(self, tmp_path):
        coord, md, clock, store = make(tmp_path, CheckpointPolicy.transparent(1e9))
        md.simulate_eviction()
        clock.advance(2.0)
        assert coord.on_step_end(1, lambda: state(1)) is Signal.PREEMPTING
        md.clear()
        md.simulate_eviction()                         # a NEW event id
        clock.advance(2.0)
        assert coord.on_step_end(2, lambda: state(2)) is Signal.PREEMPTING
        assert coord.stats.termination_ckpts == 2


class TestApplication:
    def test_cannot_checkpoint_on_demand(self, tmp_path):
        """Paper: 'application-specific checkpointing cannot be taken on
        demand' — a preempt produces NO termination checkpoint."""
        coord, md, clock, store = make(tmp_path, CheckpointPolicy.application())
        md.simulate_eviction()
        clock.advance(2.0)
        sig = coord.on_step_end(9, lambda: state(9))
        assert sig is Signal.PREEMPTING
        assert coord.stats.termination_ckpts == 0
        assert store.committed_steps() == []

    def test_stage_boundary_checkpoints(self, tmp_path):
        coord, md, clock, store = make(tmp_path, CheckpointPolicy.application())
        coord.on_stage_end(0, 100, state(100))
        assert coord.stats.stage_ckpts == 1
        got, man = store.restore(state(0))
        assert man.kind == "application" and man.extra["stage"] == 0

    def test_no_periodic(self, tmp_path):
        coord, md, clock, store = make(tmp_path, CheckpointPolicy.application())
        for step in range(1, 50):
            clock.advance(60.0)
            coord.on_step_end(step, lambda s=step: state(s))
        coord.flush()
        assert coord.stats.periodic_ckpts == 0


class TestOff:
    def test_nothing_saved(self, tmp_path):
        coord, md, clock, store = make(tmp_path, CheckpointPolicy.off())
        md.simulate_eviction()
        clock.advance(2.0)
        assert coord.on_step_end(1, lambda: state(1)) is Signal.PREEMPTING
        coord.on_stage_end(0, 1, state(1))
        coord.flush()
        assert store.committed_steps() == []


class TestRestore:
    def test_restore_latest_valid(self, tmp_path):
        coord, md, clock, store = make(tmp_path, CheckpointPolicy.transparent(1.0))
        store.save(4, state(4))
        store.save(8, state(8))
        got, man = coord.restore_latest(state(0))
        assert got["step"] == 8 and coord.stats.restores == 1

    def test_restore_none_when_empty(self, tmp_path):
        coord, md, clock, store = make(tmp_path, CheckpointPolicy.transparent(1.0))
        assert coord.restore_latest(state(0)) is None
