"""Trip-count-aware HLO analysis vs unrolled references — the correctness
basis of the roofline table (EXPERIMENTS.md §Roofline)."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.launch.hlo_analysis import analyze


def _compile(fn, *args):
    return jax.jit(fn).lower(*args).compile()


def test_scan_flops_match_unrolled():
    L, B, D = 8, 4, 128
    W = jax.random.normal(jax.random.key(0), (L, D, D))
    x = jax.random.normal(jax.random.key(1), (B, D))

    def scanned(x, W):
        y, _ = jax.lax.scan(lambda x, w: (x @ w, None), x, W)
        return y

    def unrolled(x, W):
        for i in range(L):
            x = x @ W[i]
        return x

    a_s = analyze(_compile(scanned, x, W).as_text())
    a_u = analyze(_compile(unrolled, x, W).as_text())
    expect = L * 2 * B * D * D
    assert a_s["flops"] == expect
    assert a_u["flops"] == expect


def test_grad_scan_counts_bwd_loop():
    L, B, D = 8, 4, 64
    W = jax.random.normal(jax.random.key(0), (L, D, D))
    x = jax.random.normal(jax.random.key(1), (B, D))

    def scanned(x, W):
        y, _ = jax.lax.scan(lambda x, w: (x @ w, None), x, W)
        return jnp.sum(y)

    g = _compile(jax.grad(scanned, argnums=(0, 1)), x, W)
    a = analyze(g.as_text())
    # fwd + dx + dW dots = 3 x L matmuls
    assert a["flops"] == 3 * L * 2 * B * D * D


def test_bytes_not_inflated_by_loop_accumulators():
    """xs-stacking via dynamic-update-slice must count update bytes, not the
    full stacked buffer, per iteration."""
    L, D = 16, 256
    x = jax.random.normal(jax.random.key(0), (D,))

    def f(x):
        def body(c, _):
            c = c * 1.0001
            return c, c
        _, ys = jax.lax.scan(body, x, None, length=L)
        return ys

    a = analyze(_compile(f, x).as_text())
    # ys buffer is L*D floats; per-iteration update is D floats. If the full
    # buffer were counted per iteration we'd see ~L^2*D*4 bytes.
    assert a["bytes"] < L * D * 4 * 20, a["bytes"]


def test_collectives_counted_with_trips():
    import os
    import subprocess
    import sys
    import textwrap
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.hlo_analysis import analyze
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((4,), ("model",))
        sh_w = NamedSharding(mesh, P(None, None, "model"))
        sh_x = NamedSharding(mesh, P(None))
        L, D = 4, 64
        W = jax.ShapeDtypeStruct((L, D, D), jnp.float32, sharding=sh_w)
        x = jax.ShapeDtypeStruct((8, D), jnp.float32, sharding=sh_x)
        def f(x, W):
            def body(x, w):
                # column-parallel then implicit gather back to replicated
                h = x @ w
                return jax.lax.with_sharding_constraint(
                    h, NamedSharding(mesh, P(None))), None
            y, _ = jax.lax.scan(body, x, W)
            return y
        with mesh:
            c = jax.jit(f).lower(x, W).compile()
        a = analyze(c.as_text())
        n = sum(a["collective_counts"].values())
        assert n >= L, (n, a["collective_counts"])   # one per layer, x trips
        print("COLL_OK", n)
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run([sys.executable, "-c", script],
                          capture_output=True, text=True, env=env, timeout=300)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "COLL_OK" in proc.stdout


def test_model_flops_sane():
    from repro.configs import get_config
    from repro.launch.roofline import active_matmul_params, model_flops
    cfg = get_config("phi3_mini_3p8b")
    N = active_matmul_params(cfg)
    assert 3.0e9 < N < 4.5e9
    tokens = 256 * 4096
    mf = model_flops(cfg, kind="train", batch=256, seq_len=4096)
    assert mf > 6 * N * tokens                # attention adds on top
    assert mf < 6 * N * tokens * 1.6
    # MoE: active < total
    moe = get_config("grok1_314b")
    assert active_matmul_params(moe) < 0.45 * moe.param_count()
