"""Pallas kernels vs pure-jnp oracles (interpret=True), shape/dtype sweeps."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention import decode_attention_ref, flash_decode
from repro.kernels.flash_attention import attention_ref, flash_attention
from repro.kernels.rglru_scan import lru_scan, rglru_scan, rglru_scan_ref
from repro.kernels.ssm_scan import selective_scan, ssm_scan, ssm_scan_ref


def _rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


FLASH_CASES = [
    # B, S, H, KV, hd, window, bq, bk, dtype, tol
    (2, 256, 4, 2, 64, 0, 128, 128, jnp.float32, 2e-5),
    (1, 256, 4, 1, 64, 64, 64, 64, jnp.float32, 2e-5),
    (2, 192, 2, 2, 32, 0, 128, 128, jnp.float32, 2e-5),  # padding path
    (1, 128, 8, 4, 128, 0, 128, 128, jnp.float32, 2e-5),
    (1, 256, 4, 4, 64, 0, 128, 128, jnp.bfloat16, 2e-2),
    (1, 384, 2, 1, 64, 128, 128, 128, jnp.float32, 2e-5),
]


@pytest.mark.parametrize("B,S,H,KV,hd,window,bq,bk,dtype,tol", FLASH_CASES)
def test_flash_attention_fwd(B, S, H, KV, hd, window, bq, bk, dtype, tol):
    ks = jax.random.split(jax.random.key(S + H), 3)
    q = _rand(ks[0], (B, S, H, hd), dtype)
    k = _rand(ks[1], (B, S, KV, hd), dtype)
    v = _rand(ks[2], (B, S, KV, hd), dtype)
    o = flash_attention(q, k, v, True, window, bq, bk, True)
    o_ref = attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(o_ref, np.float32), atol=tol)


@pytest.mark.parametrize("B,S,H,KV,hd,window,bq,bk,dtype,tol", FLASH_CASES[:4])
def test_flash_attention_grads(B, S, H, KV, hd, window, bq, bk, dtype, tol):
    ks = jax.random.split(jax.random.key(S * H), 3)
    q = _rand(ks[0], (B, S, H, hd), dtype)
    k = _rand(ks[1], (B, S, KV, hd), dtype)
    v = _rand(ks[2], (B, S, KV, hd), dtype)

    def f(q, k, v):
        return jnp.sum(jnp.sin(flash_attention(q, k, v, True, window, bq, bk, True)))

    def fr(q, k, v):
        return jnp.sum(jnp.sin(attention_ref(q, k, v, causal=True, window=window)))

    g = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(fr, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g, gr):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=10 * tol)


@pytest.mark.parametrize("B,H,KV,hd,S,vl,bk,dtype,tol", [
    (2, 4, 2, 64, 256, 200, 128, jnp.float32, 2e-5),
    (1, 8, 1, 128, 512, 512, 256, jnp.float32, 2e-5),
    (3, 4, 4, 32, 128, 1, 64, jnp.float32, 2e-5),
    (2, 8, 2, 64, 256, 77, 128, jnp.bfloat16, 2e-2),
])
def test_flash_decode(B, H, KV, hd, S, vl, bk, dtype, tol):
    ks = jax.random.split(jax.random.key(S + vl), 3)
    q = _rand(ks[0], (B, H, hd), dtype)
    k = _rand(ks[1], (B, S, KV, hd), dtype)
    v = _rand(ks[2], (B, S, KV, hd), dtype)
    o = flash_decode(q, k, v, vl, block_k=bk, interpret=True)
    r = decode_attention_ref(q, k, v, vl)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(r, np.float32), atol=tol)


@pytest.mark.parametrize("B,S,DI,N,bd,ch,dtype,tol", [
    (2, 128, 256, 16, 128, 32, jnp.float32, 1e-4),
    (1, 64, 512, 8, 512, 64, jnp.float32, 1e-4),
    (2, 96, 128, 16, 64, 32, jnp.float32, 1e-4),
    (1, 128, 256, 16, 256, 64, jnp.bfloat16, 5e-2),
])
def test_ssm_scan(B, S, DI, N, bd, ch, dtype, tol):
    ks = jax.random.split(jax.random.key(S * DI), 5)
    u = _rand(ks[0], (B, S, DI), dtype)
    dt = jax.nn.softplus(_rand(ks[1], (B, S, DI), jnp.float32) - 2.0)
    A = -jnp.exp(_rand(ks[2], (DI, N), jnp.float32) * 0.3)
    Bm = _rand(ks[3], (B, S, N), jnp.float32)
    Cm = _rand(ks[4], (B, S, N), jnp.float32)
    D = jnp.full((DI,), 0.5, jnp.float32)
    y, h = ssm_scan(u, dt, A, Bm, Cm, D, block_d=bd, chunk=ch, interpret=True)
    yr, hr = ssm_scan_ref(u, dt, A, Bm, Cm, D)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32), atol=tol)
    np.testing.assert_allclose(np.asarray(h), np.asarray(hr), atol=tol)


def test_ssm_scan_grad_via_ref():
    B, S, DI, N = 1, 32, 64, 8
    ks = jax.random.split(jax.random.key(0), 5)
    u = _rand(ks[0], (B, S, DI), jnp.float32)
    dt = jax.nn.softplus(_rand(ks[1], (B, S, DI), jnp.float32) - 2.0)
    A = -jnp.exp(_rand(ks[2], (DI, N), jnp.float32) * 0.3)
    Bm = _rand(ks[3], (B, S, N), jnp.float32)
    Cm = _rand(ks[4], (B, S, N), jnp.float32)
    D = jnp.full((DI,), 0.5, jnp.float32)
    g = jax.grad(lambda *a: jnp.sum(selective_scan(*a, 64, 16, True)),
                 argnums=(0, 1, 2))(u, dt, A, Bm, Cm, D)
    gr = jax.grad(lambda *a: jnp.sum(ssm_scan_ref(*a)[0]),
                  argnums=(0, 1, 2))(u, dt, A, Bm, Cm, D)
    for a, b in zip(g, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)


@pytest.mark.parametrize("B,S,W,bw,ch,dtype,tol", [
    (2, 128, 256, 128, 32, jnp.float32, 1e-4),
    (1, 256, 512, 512, 128, jnp.float32, 1e-4),
    (3, 64, 128, 64, 64, jnp.float32, 1e-4),
    (1, 128, 256, 128, 32, jnp.bfloat16, 5e-2),
])
def test_rglru_scan(B, S, W, bw, ch, dtype, tol):
    ks = jax.random.split(jax.random.key(S * W), 2)
    a = jax.nn.sigmoid(_rand(ks[0], (B, S, W), jnp.float32)).astype(dtype)
    b = _rand(ks[1], (B, S, W), dtype)
    y, h = rglru_scan(a, b, block_w=bw, chunk=ch, interpret=True)
    yr, hr = rglru_scan_ref(a, b)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32), atol=tol)
    np.testing.assert_allclose(np.asarray(h), np.asarray(hr), atol=tol)


def test_rglru_grad_via_ref():
    B, S, W = 1, 64, 128
    ks = jax.random.split(jax.random.key(1), 2)
    a = jax.nn.sigmoid(_rand(ks[0], (B, S, W), jnp.float32))
    b = _rand(ks[1], (B, S, W), jnp.float32)
    g = jax.grad(lambda a, b: jnp.sum(lru_scan(a, b, 128, 32, True)),
                 argnums=(0, 1))(a, b)
    gr = jax.grad(lambda a, b: jnp.sum(rglru_scan_ref(a, b)[0]),
                  argnums=(0, 1))(a, b)
    for x, y in zip(g, gr):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), atol=1e-4)
