"""Object-store ChunkBackend: round-trip bit-identity, the backend
crash-point matrix, idempotent uploads, outage-spool-reconcile, and the
three-level local → peer → object-store resolution order.

The in-process object store (``backend.InProcessObjectStore``) stands in
for S3/GCS: keyed blobs, ranged GETs, multipart sessions, an outage
switch, and the process-wide FaultPlan surface (``backend.*`` ops) — so
the whole network failure envelope runs in CI with no credentials.

``TestSeededNetworkTorture`` is the randomized storm behind the CI
torture step; it only runs with ``SPOTON_FAULTS=1``.
"""

import hashlib
import os
import random
import shutil

import numpy as np
import pytest

from repro import faults
from repro.checkpoint import CheckpointStore
from repro.checkpoint import backend as bk
from repro.checkpoint import peer_exchange as px
from repro.checkpoint.chunkstore import ChunkPool, ChunkRef


def make_state(seed: int) -> dict:
    rng = np.random.default_rng(seed)
    return {
        "w": rng.standard_normal((64, 33)).astype(np.float32),
        "m": (rng.standard_normal(4096) * 8).astype(np.int32),
        "step": seed,
    }


def template(state: dict) -> dict:
    return {k: (np.zeros_like(v) if isinstance(v, np.ndarray) else 0)
            for k, v in state.items()}


def assert_state_equal(got: dict, want: dict) -> None:
    assert set(got) == set(want)
    for k, v in want.items():
        if isinstance(v, np.ndarray):
            np.testing.assert_array_equal(np.asarray(got[k]), v)
        else:
            assert got[k] == v


def make_backend_store(root, *, server=None, part_size=1024, **kw):
    """Store backed by an in-process object store. The tiny part size
    (vs chunk_size=4096) forces the multipart path on every chunk."""
    server = server or bk.InProcessObjectStore()
    backend = bk.ObjectStoreBackend(server)
    kw.setdefault("chunk_size", 4096)
    kw.setdefault("retention", 5)
    store = CheckpointStore(str(root), backend=backend, **kw)
    store.pool.part_size = part_size
    return store, server


def cache_dir(store: CheckpointStore) -> str:
    return store.pool.root


def tmp_debris(root) -> list:
    return [d for d in os.listdir(root) if ".tmp-" in d]


# -- the in-process server itself ---------------------------------------------


class TestObjectStoreServer:
    def test_put_head_ranged_get(self):
        s = bk.InProcessObjectStore()
        s.put("chunks/ab/abcd", b"0123456789")
        assert s.head("chunks/ab/abcd") == 10
        assert s.head("chunks/ab/missing") is None
        assert s.get_range("chunks/ab/abcd", 3, 4) == b"3456"
        with pytest.raises(OSError):
            s.get_range("chunks/ab/missing", 0, 4)

    def test_multipart_assembles_in_part_order(self):
        s = bk.InProcessObjectStore()
        uid = s.create_multipart("k")
        s.upload_part("k", uid, 1, b"bbb")
        s.upload_part("k", uid, 0, b"aaa")
        s.complete_multipart("k", uid)
        assert s.get_range("k", 0, 6) == b"aaabbb"

    def test_outage_raises_etimedout(self):
        s = bk.InProcessObjectStore()
        s.put("k", b"x")
        s.set_outage(True)
        with pytest.raises(OSError):
            s.head("k")
        s.set_outage(False)
        assert s.head("k") == 1


# -- round-trip bit-identity ---------------------------------------------------


class TestRoundTrip:
    @pytest.mark.parametrize("mode", ["delta", "full"])
    def test_serial_and_streaming_bit_identical(self, tmp_path, mode):
        store, server = make_backend_store(tmp_path, mode=mode)
        s1, s2 = make_state(1), make_state(2)
        store.save(1, s1)
        store.save(2, s2)
        got, man = store.restore(template(s2))
        assert man.step == 2
        assert_state_equal(got, s2)
        got_s, _ = store.restore(template(s2), streaming=True)
        assert_state_equal(got_s, s2)
        got1, man1 = store.restore(template(s1), step=1)
        assert man1.step == 1
        assert_state_equal(got1, s1)
        if mode == "delta":
            # chunk payloads really crossed the modeled link, multipart
            assert server.stats["puts"] > 0
            assert server.stats["parts"] > 0

    def test_cold_restore_from_backend_only(self, tmp_path):
        store, server = make_backend_store(tmp_path)
        s1 = make_state(7)
        store.save(1, s1)
        # replacement instance: manifests on the shared mount survive, the
        # local chunk cache does not
        shutil.rmtree(cache_dir(store))
        fresh, _ = make_backend_store(tmp_path, server=server)
        got, man = fresh.restore(template(s1))
        assert man.step == 1
        assert_state_equal(got, s1)
        assert fresh.pool.stats["backend_reads"] > 0
        # the read-through landed every chunk in the cache: the second
        # (streaming) restore is pure local mmap
        before = server.stats["gets"]
        got_s, _ = fresh.restore(template(s1), streaming=True)
        assert_state_equal(got_s, s1)
        assert fresh.pool.stats["cache_hits"] > 0
        assert server.stats["gets"] == before

    def test_per_shard_region_reads_verified(self, tmp_path):
        store, server = make_backend_store(tmp_path)
        s1 = make_state(9)
        store.save(1, s1)
        shutil.rmtree(cache_dir(store))
        fresh, _ = make_backend_store(tmp_path, server=server)
        man, reader = fresh.latest_valid()
        try:
            # region decode resolves chunks through the same chunk_path
            # hook, so a cold cache faults in only what the region needs
            got = reader.read_region_for_restore("w", ((0, 16), (0, 33)))
            np.testing.assert_array_equal(got, s1["w"][:16, :33])
        finally:
            reader.close()


# -- idempotent uploads --------------------------------------------------------


class TestIdempotentUpload:
    def test_reput_of_committed_address_is_noop(self):
        server = bk.InProcessObjectStore()
        backend = bk.ObjectStoreBackend(server)
        data = np.random.default_rng(3).bytes(5000)
        h = hashlib.sha1(data).hexdigest()
        key = bk.object_key(h)
        sent1 = bk.upload_chunk(backend, h, data, part_size=2048)
        assert sent1 == len(data)
        gen = server.put_generations[key]
        # the re-PUT is a verified no-op: zero bytes, zero new generations
        sent2 = bk.upload_chunk(backend, h, data, part_size=2048)
        assert sent2 == 0
        assert server.put_generations[key] == gen

    def test_torn_upload_debris_rewritten_never_appended(self):
        server = bk.InProcessObjectStore()
        backend = bk.ObjectStoreBackend(server)
        data = np.random.default_rng(4).bytes(3000)
        h = hashlib.sha1(data).hexdigest()
        key = bk.object_key(h)
        server.put(key, data[:100])         # torn-upload debris at the key
        sent = bk.upload_chunk(backend, h, data, part_size=1 << 20)
        assert sent == len(data)            # size mismatch => rewritten whole
        assert server.head(key) == len(data)
        assert backend.get_range(key, 0, len(data)) == data


# -- the backend crash-point matrix --------------------------------------------

#: points whose effect is a killed writer (SimulatedCrash out of the save);
#: persistent errno points exhaust the bounded retry and must DEGRADE (the
#: save parks, spooled, instead of failing); ABSORBED points commit anyway —
#: an errno after complete_multipart is a lost ack, and the retrying
#: uploader's HEAD discovers the object already committed
CRASH_CLASS = {
    ("backend.put", "torn"),
    ("backend.put", "crash"),
    ("backend.complete", "rollback"),
    ("backend.complete", "crash"),
}
ABSORBED = {("backend.complete", "eio")}


class TestBackendCrashMatrix:
    @pytest.mark.parametrize(
        "op,error", faults.BACKEND_CRASH_POINTS,
        ids=[f"{op}-{error}" for op, error in faults.BACKEND_CRASH_POINTS])
    def test_abort_degrade_recover(self, tmp_path, op, error):
        store, server = make_backend_store(tmp_path)
        s1, s2, s3 = make_state(1), make_state(2), make_state(3)
        store.save(1, s1)

        if op == "backend.get":
            self._get_case(tmp_path, store, server, s1, error)
            return

        count = 1 if (op, error) in CRASH_CLASS else -1
        plan = faults.FaultPlan().add(op, error=error, count=count)
        if (op, error) in CRASH_CLASS:
            with faults.active(plan):
                with pytest.raises(faults.SimulatedCrash):
                    store.save(2, s2)
        elif (op, error) in ABSORBED:
            before = bk.snapshot_stats()["backend_retries"]
            with faults.active(plan):
                info = store.save(2, s2)
            assert not info.spooled
            assert plan.fired() >= 1
            assert bk.snapshot_stats()["backend_retries"] > before
            assert store.committed_steps() == [1, 2]
            got2, man2 = store.restore(template(s2))
            assert man2.step == 2
            assert_state_equal(got2, s2)
            return
        else:
            with faults.active(plan):
                info = store.save(2, s2)
            # persistent network errno: bounded retries exhaust, the save
            # spools locally and parks — degradation, not failure
            assert info.spooled
            assert store.spooled_steps() == [2]
            assert store.pool.spooled_bytes() > 0
        assert plan.fired() >= 1, f"crash point {op}/{error} never hit"

        # a fresh store (the restarted process) finds the prior checkpoint
        # bit-identical — a parked or killed save is never half-visible
        reopened, _ = make_backend_store(tmp_path, server=server)
        assert reopened.committed_steps() == [1]
        got, man = reopened.restore(template(s1))
        assert man.step == 1
        assert_state_equal(got, s1)

        # faults cleared: the parked commit reconciles, the killed writer's
        # successor commits over the debris
        if (op, error) not in CRASH_CLASS:
            assert store.reconcile_spooled() == 1
            assert store.committed_steps() == [1, 2]
            got2, man2 = store.restore(template(s2))
            assert man2.step == 2
            assert_state_equal(got2, s2)
        store.save(3, s3)
        got3, man3 = store.restore(template(s3))
        assert man3.step == 3
        assert_state_equal(got3, s3)
        assert tmp_debris(tmp_path) == []

    def _get_case(self, tmp_path, store, server, s1, error):
        # GET faults strike the restore path: transient torn/errno responses
        # are absorbed by the content-address-keyed bounded retry, and a
        # truncated payload is never accepted (it fails the digest)
        shutil.rmtree(cache_dir(store))
        fresh, _ = make_backend_store(tmp_path, server=server)
        before = bk.snapshot_stats()["backend_retries"]
        plan = faults.FaultPlan().add("backend.get", error=error, count=2)
        with faults.active(plan):
            got, man = fresh.restore(template(s1))
        assert plan.fired() >= 1
        assert man.step == 1
        assert_state_equal(got, s1)
        assert bk.snapshot_stats()["backend_retries"] > before


# -- outage: spool, park, reconcile, fresh-process restore ---------------------


class TestOutageSpoolReconcile:
    def test_end_to_end(self, tmp_path):
        store, server = make_backend_store(tmp_path)
        s1, s2, s3 = make_state(11), make_state(12), make_state(13)
        stats0 = bk.snapshot_stats()
        store.save(1, s1)

        server.set_outage(True)
        info2 = store.save(2, s2)
        info3 = store.save(3, s3)
        assert info2.spooled and info3.spooled
        assert store.spooled_steps() == [2, 3]
        assert store.committed_steps() == [1]
        stats1 = bk.snapshot_stats()
        assert stats1["backend_outages"] > stats0["backend_outages"]
        assert stats1["spooled_bytes"] > stats0["spooled_bytes"]
        # readers degrade, never corrupt: latest valid is the durable step
        got, man = store.restore(template(s1))
        assert man.step == 1
        assert_state_equal(got, s1)

        server.set_outage(False)
        assert store.reconcile_spooled() == 2
        assert store.committed_steps() == [1, 2, 3]
        assert store.spooled_steps() == []
        assert store.pool.spooled_bytes() == 0

        # fresh process on a replacement instance, cold cache: the
        # reconciled steps restore bit-identically from the backend alone
        shutil.rmtree(cache_dir(store))
        fresh, _ = make_backend_store(tmp_path, server=server)
        got3, man3 = fresh.restore(template(s3))
        assert man3.step == 3
        assert_state_equal(got3, s3)
        got2, man2 = fresh.restore(template(s2), step=2)
        assert man2.step == 2
        assert_state_equal(got2, s2)

    def test_next_save_drains_backlog_first(self, tmp_path):
        store, server = make_backend_store(tmp_path)
        s1, s2 = make_state(21), make_state(22)
        server.set_outage(True)
        assert store.save(1, s1).spooled
        server.set_outage(False)
        # the next save reconciles parked steps before landing its own, so
        # commit order stays monotone in step order
        info2 = store.save(2, s2)
        assert not info2.spooled
        assert store.committed_steps() == [1, 2]

    def test_stat_keys_mirror_coordinator_fields(self):
        from repro.core.coordinator import CoordinatorStats
        st = CoordinatorStats()
        for key in bk.snapshot_stats():
            assert hasattr(st, key), key


# -- three-level resolution: local -> peer -> object store ---------------------


class TestThreeLevelResolution:
    def test_restore_resolves_local_then_peer_then_store(self, tmp_path):
        store, server = make_backend_store(tmp_path)
        s1 = make_state(31)
        store.save(1, s1)

        # seed a surviving peer with roughly half the chunks, then wipe
        # this member's cache: restore must stitch peer + object store
        chunks = sorted(store.pool.all_chunks())
        peer = ChunkPool(str(tmp_path / "peer" / "chunks"))
        for h, path in chunks[: len(chunks) // 2]:
            with open(path, "rb") as f:
                peer.write(h, f.read(), sync_dir=False)
        shutil.rmtree(cache_dir(store))

        fresh, _ = make_backend_store(tmp_path, server=server)
        local = ChunkPool(str(tmp_path / "local" / "chunks"))
        srv = px.PeerChunkServer(peer).start()
        try:
            rt = px.ReadThroughPool(local, px.PeerChunkClient([srv.address]),
                                    fresh.pool)
            got, man = fresh.restore(template(s1), chunk_pool=rt)
            assert man.step == 1
            assert_state_equal(got, s1)
            assert rt.stats["peer_hits"] > 0
            assert rt.stats["store_reads"] > 0
            assert fresh.pool.stats["backend_reads"] > 0
            # second pass: peer hits are cached in `local`, store reads in
            # the backend pool's cache — the object store is not consulted
            gets = server.stats["gets"]
            got2, _ = fresh.restore(template(s1), chunk_pool=rt)
            assert_state_equal(got2, s1)
            assert rt.stats["local_hits"] > 0
            assert server.stats["gets"] == gets
        finally:
            srv.close()


# -- randomized seeded torture (CI: SPOTON_FAULTS=1) ---------------------------


@pytest.mark.skipif(
    not os.environ.get("SPOTON_FAULTS"),
    reason="seeded network torture: set SPOTON_FAULTS=1 (CI torture step)")
class TestSeededNetworkTorture:
    """Per seed: four saves under a random transient-fault plan (count<=2,
    so attempts=3 absorbs any single op's streak), a mid-storm restore, a
    reconcile, and a cold-cache bit-identity sweep over every step. The
    invariant is the paper's: a save either commits or parks; committed
    state is always bit-identical; nothing is ever half-visible."""

    @pytest.mark.parametrize("seed", range(20))
    def test_storm(self, tmp_path, seed):
        rng = random.Random(0xB0 + seed)
        store, server = make_backend_store(tmp_path)
        states = {}
        for step in range(1, 5):
            states[step] = make_state(100 * seed + step)
            plan = faults.FaultPlan()
            for op in ("backend.head", "backend.get",
                       "backend.put", "backend.complete"):
                if rng.random() < 0.6:
                    plan.add(op, nth=rng.randint(1, 3),
                             count=rng.randint(1, 2),
                             error=rng.choice(["eio", "etimedout"]))
            with faults.active(plan):
                info = store.save(step, states[step])
                committed = store.committed_steps()
                assert committed == sorted(committed)
                if committed:
                    latest = committed[-1]
                    got, man = store.restore(template(states[latest]))
                    assert man.step == latest
                    assert_state_equal(got, states[latest])
            assert info.spooled or step in store.committed_steps()

        store.reconcile_spooled()
        assert store.committed_steps() == [1, 2, 3, 4]
        shutil.rmtree(cache_dir(store))
        fresh, _ = make_backend_store(tmp_path, server=server)
        for step, want in states.items():
            got, man = fresh.restore(template(want), step=step)
            assert man.step == step
            assert_state_equal(got, want)
