"""End-to-end trainer × coordinator × simulator integration — the paper's
workflow, including the headline property: transparent checkpointing makes an
evicted run finish with BIT-EXACT final state and less wall time than
application-stage checkpointing."""

import numpy as np
import pytest

import jax

from repro.checkpoint import CheckpointStore
from repro.configs import get_smoke_config
from repro.core import (CheckpointPolicy, CostAccountant, AZURE_D8S_V3,
                        NoEviction, PeriodicEviction, ScaleSet,
                        SpotOnCoordinator, TimeModel, VirtualClock)
from repro.optim import AdamWConfig
from repro.train import SpotTrainer, TrainJob


def run_job(tmp_path, mode, evict_s, *, total=60, step_time=10.0,
            periodic_s=200.0, tag=""):
    clock = VirtualClock()
    acct = CostAccountant(AZURE_D8S_V3)
    sched = PeriodicEviction(evict_s) if evict_s else NoEviction()
    pool = ScaleSet(clock=clock, schedule=sched, accountant=acct,
                    provisioning_delay_s=60.0, notice_s=30.0)
    store = CheckpointStore(str(tmp_path / f"ckpt{tag}"), time_fn=clock.now)
    policy = {"off": CheckpointPolicy.off(),
              "application": CheckpointPolicy.application(),
              "transparent": CheckpointPolicy.transparent(periodic_s)}[mode]
    coord = SpotOnCoordinator(store, policy, clock, time_model=TimeModel())
    cfg = get_smoke_config("phi3_mini_3p8b")
    job = TrainJob(cfg=cfg, opt=AdamWConfig(total_steps=total),
                   total_steps=total, n_stages=3, batch=2, seq_len=16)
    tr = SpotTrainer(job, coord, pool, clock, step_time_s=step_time,
                     max_sessions=40)
    rep = tr.run()
    coord.close()
    return rep, acct.summary(clock.now())


class TestNoEviction:
    def test_off_and_transparent_equal_time(self, tmp_path):
        off, _ = run_job(tmp_path, "off", None, tag="a")
        tr, _ = run_job(tmp_path, "transparent", None, tag="b")
        assert off.completed and tr.completed
        # Table I rows 1-2: negligible overhead without evictions
        assert tr.total_time_s <= off.total_time_s * 1.05


class TestEvicted:
    def test_transparent_bit_exact_resume(self, tmp_path):
        base, _ = run_job(tmp_path, "off", None, tag="base")
        ev, _ = run_job(tmp_path, "transparent", 250.0, periodic_s=100.0,
                        tag="ev")
        assert ev.completed
        assert ev.evictions_seen >= 1 and ev.restores >= 1
        # identical data order + full state capture => identical final loss
        assert ev.final_loss == pytest.approx(base.final_loss, abs=1e-6)
        assert ev.lost_steps == 0  # termination ckpt caught the frontier

    def test_application_rolls_back_to_stage(self, tmp_path):
        ev, _ = run_job(tmp_path, "application", 420.0, tag="app")
        assert ev.completed
        assert ev.lost_steps > 0          # work since last stage lost
        assert ev.coordinator["termination_ckpts"] == 0

    def test_transparent_faster_and_cheaper_than_application(self, tmp_path):
        app, capp = run_job(tmp_path, "application", 420.0, tag="x")
        tr, ctr = run_job(tmp_path, "transparent", 420.0, periodic_s=100.0,
                          tag="y")
        assert app.completed and tr.completed
        assert tr.total_time_s < app.total_time_s      # paper Fig. 3
        assert ctr["total_usd"] < capp["total_usd"]    # paper Fig. 2

    def test_off_mode_restarts_from_scratch(self, tmp_path):
        rep, _ = run_job(tmp_path, "off", 350.0, tag="z")
        # either limps to completion with full restarts or hits the session cap
        assert rep.cold_starts >= 2 or not rep.completed


class TestStageTimes:
    def test_stage_times_cover_total(self, tmp_path):
        rep, _ = run_job(tmp_path, "transparent", None, tag="st")
        assert rep.completed
        assert not any(np.isnan(rep.stage_times_s))
        assert sum(rep.stage_times_s) == pytest.approx(rep.total_time_s, rel=0.05)
