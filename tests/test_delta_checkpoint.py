"""Incremental (delta) checkpointing: content-addressed chunk dedup, manifest
v2, refcount-aware pool gc, v1 backward compatibility, urgent-save churn."""

import os

import numpy as np
import pytest

from repro.checkpoint import (AsyncCheckpointer, CheckpointStore, ChunkPool,
                              ChunkRef)
from repro.checkpoint import chunkstore
from repro.checkpoint import manifest as mf


def big_state(step, rng_seed=0, churn_frac=0.0):
    """~2 MB state; `churn_frac` of the big tensor's rows are step-dependent
    (mostly-frozen model: only a slice of params moves between saves)."""
    rng = np.random.default_rng(rng_seed)
    big = rng.standard_normal((512, 1024)).astype(np.float32)
    if churn_frac > 0:
        rows = max(1, int(512 * churn_frac))
        big = big.copy()
        big[:rows] += float(step)
    return {"params": {"big": big, "b": np.full((64,), float(step), np.float32)},
            "step": step}


def template():
    return {"params": {"big": np.zeros((512, 1024), np.float32),
                       "b": np.zeros((64,), np.float32)},
            "step": 0}


class TestChunkPool:
    def test_write_is_idempotent_and_content_addressed(self, tmp_path):
        pool = ChunkPool(str(tmp_path / "chunks"))
        data = b"x" * 4096
        h = chunkstore.chunk_digest(data)
        assert pool.write(h, data) == 4096
        assert pool.write(h, data) == 0          # dedup hit: touch only
        import zlib
        ref = ChunkRef(hash=h, nbytes=4096, raw_len=4096,
                       crc32=zlib.crc32(data), comp="raw")
        assert pool.read(ref) == data

    def test_corrupt_chunk_detected(self, tmp_path):
        pool = ChunkPool(str(tmp_path / "chunks"))
        data = b"y" * 1024
        h = chunkstore.chunk_digest(data)
        pool.write(h, data)
        import zlib
        ref = ChunkRef(hash=h, nbytes=1024, raw_len=1024,
                       crc32=zlib.crc32(data), comp="raw")
        raw = bytearray(open(pool.path(h), "rb").read())
        raw[10] ^= 0xFF
        open(pool.path(h), "wb").write(bytes(raw))
        with pytest.raises(IOError):
            pool.read(ref)


class TestDeltaDedup:
    def test_low_churn_writes_under_quarter_of_full(self, tmp_path):
        """Acceptance: mostly-frozen state -> delta save <= 25% of full bytes."""
        store = CheckpointStore(str(tmp_path), retention=10, chunk_size=64 * 1024)
        i1 = store.save(1, big_state(1, churn_frac=0.05))
        assert i1.new_bytes == i1.nbytes          # cold pool: everything dirty
        i2 = store.save(2, big_state(2, churn_frac=0.05))
        assert i2.nbytes > 1 << 20                # full snapshot is ~2 MB
        assert i2.new_bytes <= 0.25 * i2.nbytes, (i2.new_bytes, i2.nbytes)

    def test_dedup_survives_process_restart(self, tmp_path):
        """A fresh store (empty DeltaIndex memo) still dedups against the
        pool by content address — the memo is an optimization, not state."""
        CheckpointStore(str(tmp_path), retention=10).save(1, big_state(1))
        fresh = CheckpointStore(str(tmp_path), retention=10)
        info = fresh.save(2, big_state(2))        # only step/b leaves changed
        assert info.new_bytes < 0.01 * info.nbytes

    def test_restore_old_step_after_later_deltas(self, tmp_path):
        store = CheckpointStore(str(tmp_path), retention=10)
        for step in range(1, 5):
            store.save(step, big_state(step, churn_frac=0.1))
        got, man = store.restore(template(), step=2)
        assert man.step == 2 and man.format_version == 2
        want = big_state(2, churn_frac=0.1)
        np.testing.assert_array_equal(got["params"]["big"], want["params"]["big"])
        np.testing.assert_array_equal(got["params"]["b"], want["params"]["b"])

    def test_multi_writer_same_state_shares_chunks(self, tmp_path):
        """Two stores on one volume (fleet members) converge on one pool copy."""
        a = CheckpointStore(str(tmp_path), retention=10)
        b = CheckpointStore(str(tmp_path), retention=10)
        a.save(1, big_state(1))
        info = b.save(2, big_state(1, rng_seed=0))   # same tensors, new step
        assert info.new_bytes < 0.01 * info.nbytes


class TestCorruptionSelfHeal:
    def test_corrupt_chunk_not_reused_and_rewritten(self, tmp_path):
        """A damaged pool entry must not poison future saves: a failed crc
        removes the file, and the next save of the same content rewrites it
        instead of dedup-reusing the damage."""
        store = CheckpointStore(str(tmp_path), retention=10,
                                validate_on_restore=True)
        store.save(1, big_state(1))
        man1 = mf.read_manifest(os.path.join(str(tmp_path), mf.step_dirname(1)))
        victim = sorted(man1.chunk_hashes())[0]
        path = store.pool.path(victim)
        raw = bytearray(open(path, "rb").read())
        raw[0] ^= 0xFF
        open(path, "wb").write(bytes(raw))
        with pytest.raises(FileNotFoundError):
            store.restore(template())             # step 1 invalid; heals pool
        assert not os.path.exists(path)           # corrupt entry removed
        # fresh store (cold memo) re-saves the same content: chunk rewritten
        fresh = CheckpointStore(str(tmp_path), retention=10,
                                validate_on_restore=True)
        fresh.save(2, big_state(1))
        got, man = fresh.restore(template())
        assert man.step == 2 and os.path.exists(path)

    def test_truncated_chunk_not_dedup_reused(self, tmp_path):
        """Size-mismatched pool entries are overwritten, not touch-reused."""
        store = CheckpointStore(str(tmp_path), retention=10)
        store.save(1, big_state(1))
        man1 = mf.read_manifest(os.path.join(str(tmp_path), mf.step_dirname(1)))
        victim = sorted(man1.chunk_hashes())[0]
        path = store.pool.path(victim)
        open(path, "wb").write(b"short")          # truncate in place
        fresh = CheckpointStore(str(tmp_path), retention=10,
                                validate_on_restore=True)
        fresh.save(2, big_state(1))
        got, man = fresh.restore(template())      # validates every chunk
        assert man.step == 2


class TestPoolGC:
    def test_gc_never_sweeps_chunks_referenced_by_live_manifest(self, tmp_path):
        store = CheckpointStore(str(tmp_path), retention=2, chunk_size=64 * 1024)
        for step in range(1, 6):
            store.save(step, big_state(step, churn_frac=0.1))
        assert store.committed_steps() == [4, 5]
        # age gate disabled: everything unreferenced is sweepable *now*
        store.gc(stale_chunk_age_s=0.0)
        for step in (4, 5):
            got, man = store.restore(template(), step=step)
            assert man.step == step               # all referenced chunks alive
        live = store.live_chunk_hashes()
        on_disk = {h for h, _ in store.pool.all_chunks()}
        assert on_disk == live                    # and nothing else survived

    def test_gc_respects_age_gate_for_unreferenced(self, tmp_path):
        store = CheckpointStore(str(tmp_path), retention=1)
        store.save(1, big_state(1))
        store.save(2, big_state(2, churn_frac=0.2))  # step 1 gc'd by retention
        n_before = sum(1 for _ in store.pool.all_chunks())
        store.gc(stale_chunk_age_s=3600.0)           # fresh orphans: protected
        assert sum(1 for _ in store.pool.all_chunks()) == n_before
        store.gc(stale_chunk_age_s=0.0)
        assert {h for h, _ in store.pool.all_chunks()} == store.live_chunk_hashes()


class TestBackCompat:
    def test_v1_checkpoint_restores_through_new_reader(self, tmp_path):
        """A checkpoint written by the pre-delta (full/v1) writer restores
        through the default (delta-mode) store."""
        v1 = CheckpointStore(str(tmp_path), mode="full")
        s = big_state(7)
        info = v1.save(7, s)
        assert info.new_bytes == info.nbytes
        man = mf.read_manifest(os.path.join(str(tmp_path), mf.step_dirname(7)))
        assert man.format_version == 1
        assert all("file" in rec and "chunks" not in rec for rec in man.tensors)
        got, man2 = CheckpointStore(str(tmp_path)).restore(template())
        assert man2.step == 7
        np.testing.assert_array_equal(got["params"]["big"], s["params"]["big"])

    def test_mixed_history_falls_back_across_formats(self, tmp_path):
        """Latest-valid search walks delta and full checkpoints uniformly."""
        CheckpointStore(str(tmp_path), mode="full", retention=10).save(1, big_state(1))
        store = CheckpointStore(str(tmp_path), retention=10,
                                validate_on_restore=True)
        store.save(2, big_state(2))
        man2 = mf.read_manifest(os.path.join(str(tmp_path), mf.step_dirname(2)))
        for h in sorted(man2.chunk_hashes()):
            os.remove(store.pool.path(h))        # destroy every v2 chunk
        got, man = store.restore(template())
        assert man.step == 1 and man.format_version == 1


class TestUrgentDelta:
    def test_urgent_save_writes_only_dirty_chunks(self, tmp_path):
        """Termination checkpoint after a periodic save: the notice-window
        write is the churn since the snapshot, not the full state."""
        store = CheckpointStore(str(tmp_path), retention=10, chunk_size=64 * 1024)
        ac = AsyncCheckpointer(store)
        ac.save_async(10, big_state(10, churn_frac=0.05))
        ac.wait_until_finished()
        info = ac.save_urgent(11, big_state(11, churn_frac=0.05), timeout_s=60.0)
        ac.close()
        assert info.kind == "termination"
        assert info.new_bytes <= 0.25 * info.nbytes, (info.new_bytes, info.nbytes)
        got, man = store.restore(template())
        assert man.step == 11 and man.kind == "termination"

    def test_urgent_info_surfaces_physical_bytes(self, tmp_path):
        store = CheckpointStore(str(tmp_path), retention=10)
        ac = AsyncCheckpointer(store)
        info1 = ac.save_urgent(1, big_state(1), timeout_s=60.0)
        info2 = ac.save_urgent(2, big_state(1), timeout_s=60.0)  # zero churn
        ac.close()
        assert info1.new_bytes == info1.nbytes
        assert info2.new_bytes < 0.01 * info2.nbytes


class TestParallelCodecs:
    def test_many_tensors_roundtrip_bitexact(self, tmp_path):
        """Worker-pool encode across dozens of tensors stays bit-exact."""
        rng = np.random.default_rng(3)
        state = {f"t{i}": rng.standard_normal((257, 33)).astype(np.float32)
                 for i in range(24)}
        state["ints"] = np.arange(5000, dtype=np.int32)   # zlib-compressed leaf
        store = CheckpointStore(str(tmp_path), chunk_size=8 * 1024)
        store.save(1, state)
        tpl = {k: np.zeros_like(v) for k, v in state.items()}
        got, _ = store.restore(tpl)
        for k in state:
            np.testing.assert_array_equal(got[k], state[k])
