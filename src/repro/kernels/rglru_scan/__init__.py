from .ops import lru_scan
from .ref import rglru_scan_ref
from .rglru_scan import rglru_scan

__all__ = ["lru_scan", "rglru_scan", "rglru_scan_ref"]
