"""Pallas TPU RG-LRU scan: gated diagonal linear recurrence
    h_t = a_t ⊙ h_{t-1} + b_t
with a_t, b_t precomputed (the gate matmuls are MXU work best left to XLA;
the kernel owns only the sequential part — the right compute split on TPU).

Grid (B, W/block_w, S/chunk), chunk innermost; log-depth associative scan in
chunk, (1, block_w) carry in VMEM scratch.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .. import compat

DEFAULT_BLOCK_W = 512
DEFAULT_CHUNK = 128


def _scan_op(l, r):
    a1, b1 = l
    a2, b2 = r
    return a1 * a2, a2 * b1 + b2


def _rglru_kernel(a_ref, b_ref, y_ref, hlast_ref, h_scr):
    ci = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    a = a_ref[0].astype(jnp.float32)          # (chunk, bw)
    b = b_ref[0].astype(jnp.float32)
    acum, bcum = jax.lax.associative_scan(_scan_op, (a, b), axis=0)
    h = acum * h_scr[...] + bcum              # (chunk, bw) via (1,bw) broadcast
    y_ref[0] = h.astype(y_ref.dtype)
    h_scr[...] = h[-1:][...]

    @pl.when(ci == nc - 1)
    def _final():
        hlast_ref[0] = h_scr[0].astype(hlast_ref.dtype)


def rglru_scan(a, b, *, block_w=DEFAULT_BLOCK_W, chunk=DEFAULT_CHUNK,
               interpret=False):
    """a, b: (B,S,W) -> (h (B,S,W), h_last (B,W))."""
    Bb, S, W = a.shape
    block_w = min(block_w, W)
    chunk = min(chunk, S)
    assert W % block_w == 0 and S % chunk == 0, (W, block_w, S, chunk)
    y, hlast = pl.pallas_call(
        _rglru_kernel,
        grid=(Bb, W // block_w, S // chunk),
        in_specs=[
            pl.BlockSpec((1, chunk, block_w), lambda b_, wi, ci: (b_, ci, wi)),
            pl.BlockSpec((1, chunk, block_w), lambda b_, wi, ci: (b_, ci, wi)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, block_w), lambda b_, wi, ci: (b_, ci, wi)),
            pl.BlockSpec((1, block_w), lambda b_, wi, ci: (b_, wi)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(a.shape, a.dtype),
            jax.ShapeDtypeStruct((Bb, W), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((1, block_w), jnp.float32)],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(a, b)
    return y, hlast
