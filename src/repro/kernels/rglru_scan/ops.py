"""Jit-ready RG-LRU scan wrapper; gradients via the jnp reference."""

from __future__ import annotations

import functools

import jax

from .ref import rglru_scan_ref
from .rglru_scan import DEFAULT_BLOCK_W, DEFAULT_CHUNK, rglru_scan


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4))
def lru_scan(a, b, block_w=DEFAULT_BLOCK_W, chunk=DEFAULT_CHUNK,
             interpret=False):
    y, _ = rglru_scan(a, b, block_w=block_w, chunk=chunk, interpret=interpret)
    return y


def _fwd(a, b, block_w, chunk, interpret):
    y, _ = rglru_scan(a, b, block_w=block_w, chunk=chunk, interpret=interpret)
    return y, (a, b)


def _bwd(block_w, chunk, interpret, res, dy):
    a, b = res
    _, vjp = jax.vjp(lambda *x: rglru_scan_ref(*x)[0], a, b)
    return vjp(dy)


lru_scan.defvjp(_fwd, _bwd)
