"""Pure-jnp oracle for the RG-LRU scan (sequential lax.scan)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def rglru_scan_ref(a, b):
    """a, b: (B,S,W) -> (h (B,S,W), h_last (B,W)). Sequential reference."""
    def step(h, xs):
        a_t, b_t = xs
        h = a_t * h + b_t
        return h, h

    a32 = a.astype(jnp.float32).transpose(1, 0, 2)
    b32 = b.astype(jnp.float32).transpose(1, 0, 2)
    h0 = jnp.zeros(a32.shape[1:], jnp.float32)
    h_last, hs = jax.lax.scan(step, h0, (a32, b32))
    return hs.transpose(1, 0, 2).astype(a.dtype), h_last
