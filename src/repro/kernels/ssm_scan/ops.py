"""Jit-ready selective-scan wrapper. Forward runs the Pallas kernel; gradients
fall back to the jnp reference via custom_vjp (the recurrence backward is the
reference's — correctness over speed for the training path on this kernel)."""

from __future__ import annotations

import functools

import jax

from .ref import ssm_scan_ref
from .ssm_scan import DEFAULT_BLOCK_D, DEFAULT_CHUNK, ssm_scan


@functools.partial(jax.custom_vjp, nondiff_argnums=(6, 7, 8))
def selective_scan(u, delta, A, B, C, D, block_d=DEFAULT_BLOCK_D,
                   chunk=DEFAULT_CHUNK, interpret=False):
    y, _ = ssm_scan(u, delta, A, B, C, D, block_d=block_d, chunk=chunk,
                    interpret=interpret)
    return y


def _fwd(u, delta, A, B, C, D, block_d, chunk, interpret):
    y, _ = ssm_scan(u, delta, A, B, C, D, block_d=block_d, chunk=chunk,
                    interpret=interpret)
    return y, (u, delta, A, B, C, D)


def _bwd(block_d, chunk, interpret, res, dy):
    u, delta, A, B, C, D = res
    _, vjp = jax.vjp(lambda *a: ssm_scan_ref(*a)[0], u, delta, A, B, C, D)
    return vjp(dy)


selective_scan.defvjp(_fwd, _bwd)
