"""Pallas TPU selective-scan (Mamba-1 recurrence).

Layout decision (TPU adaptation, not a CUDA port): the GPU mamba kernel
assigns one CUDA block per (batch, channel-slab) and loops time sequentially
with warp shuffles for the intra-block scan. On TPU we instead
*vectorize over channels* (the VPU's 8×128 lanes want the d_inner dimension)
and run a **log-depth associative scan within a sequence chunk**, carrying the
(d_block × d_state) recurrence state across chunks in VMEM scratch. The grid
is (B, d_inner/block_d, S/chunk) with the chunk dimension innermost
("arbitrary") so the carry is legal.

h_t = exp(Δ_t A) h_{t-1} + Δ_t B_t u_t ;  y_t = C_t · h_t + D u_t
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .. import compat

DEFAULT_BLOCK_D = 512
DEFAULT_CHUNK = 64


def _scan_op(l, r):
    a1, b1 = l
    a2, b2 = r
    return a1 * a2, a2 * b1 + b2


def _ssm_kernel(u_ref, dt_ref, A_ref, B_ref, C_ref, D_ref, y_ref, hlast_ref,
                h_scr, *, chunk):
    ci = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    u = u_ref[0].astype(jnp.float32)          # (chunk, bd)
    dt = dt_ref[0].astype(jnp.float32)        # (chunk, bd)
    A = A_ref[...].astype(jnp.float32)        # (bd, N)
    Bm = B_ref[0].astype(jnp.float32)         # (chunk, N)
    Cm = C_ref[0].astype(jnp.float32)         # (chunk, N)
    D = D_ref[...].astype(jnp.float32)        # (bd,)

    dA = jnp.exp(dt[:, :, None] * A[None])                    # (chunk,bd,N)
    dBu = (dt * u)[:, :, None] * Bm[:, None, :]               # (chunk,bd,N)
    # log-depth scan within the chunk, then fuse the carried state:
    # h_t = (prod_{i<=t} dA_i) h_carry + scan_t
    acum, bcum = jax.lax.associative_scan(_scan_op, (dA, dBu), axis=0)
    h = acum * h_scr[...][None] + bcum                        # (chunk,bd,N)
    y = jnp.sum(h * Cm[:, None, :], axis=2) + u * D[None, :]  # (chunk,bd)
    y_ref[0] = y.astype(y_ref.dtype)
    h_scr[...] = h[-1]

    @pl.when(ci == nc - 1)
    def _final():
        hlast_ref[0] = h_scr[...].astype(hlast_ref.dtype)


def ssm_scan(u, delta, A, B, C, D, *, block_d=DEFAULT_BLOCK_D,
             chunk=DEFAULT_CHUNK, interpret=False):
    """u,delta: (B,S,DI); A: (DI,N); B,C: (B,S,N); D: (DI,).
    Returns (y (B,S,DI), h_last (B,DI,N))."""
    Bb, S, DI = u.shape
    N = A.shape[1]
    block_d = min(block_d, DI)
    chunk = min(chunk, S)
    assert DI % block_d == 0 and S % chunk == 0, (DI, block_d, S, chunk)

    kernel = functools.partial(_ssm_kernel, chunk=chunk)
    y, hlast = pl.pallas_call(
        kernel,
        grid=(Bb, DI // block_d, S // chunk),
        in_specs=[
            pl.BlockSpec((1, chunk, block_d), lambda b, di, ci: (b, ci, di)),
            pl.BlockSpec((1, chunk, block_d), lambda b, di, ci: (b, ci, di)),
            pl.BlockSpec((block_d, N), lambda b, di, ci: (di, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, di, ci: (b, ci, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, di, ci: (b, ci, 0)),
            pl.BlockSpec((block_d,), lambda b, di, ci: (di,)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, block_d), lambda b, di, ci: (b, ci, di)),
            pl.BlockSpec((1, block_d, N), lambda b, di, ci: (b, di, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(u.shape, u.dtype),
            jax.ShapeDtypeStruct((Bb, DI, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((block_d, N), jnp.float32)],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(u, delta, A, B, C, D)
    return y, hlast
