"""Pure-jnp oracle for the selective scan (sequential lax.scan)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ssm_scan_ref(u, delta, A, B, C, D):
    """Sequential reference. Same signature as kernels.ssm_scan.ssm_scan."""
    u32 = u.astype(jnp.float32)
    d32 = delta.astype(jnp.float32)

    def step(h, xs):
        u_t, d_t, b_t, c_t = xs           # (B,DI), (B,DI), (B,N), (B,N)
        dA = jnp.exp(d_t[..., None] * A[None])
        h = dA * h + (d_t * u_t)[..., None] * b_t[:, None, :]
        y = jnp.sum(h * c_t[:, None, :], axis=2)
        return h, y

    Bb, S, DI = u.shape
    h0 = jnp.zeros((Bb, DI, A.shape[1]), jnp.float32)
    xs = (u32.transpose(1, 0, 2), d32.transpose(1, 0, 2),
          B.astype(jnp.float32).transpose(1, 0, 2),
          C.astype(jnp.float32).transpose(1, 0, 2))
    h_last, ys = jax.lax.scan(step, h0, xs)
    y = ys.transpose(1, 0, 2) + u32 * D[None, None].astype(jnp.float32)
    return y.astype(u.dtype), h_last
