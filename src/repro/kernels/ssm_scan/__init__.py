from .ops import selective_scan
from .ref import ssm_scan_ref
from .ssm_scan import ssm_scan

__all__ = ["selective_scan", "ssm_scan", "ssm_scan_ref"]
