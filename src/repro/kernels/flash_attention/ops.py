"""Jit-ready wrapper: differentiable flash attention (custom_vjp) with
sequence padding to block multiples. The TPU kernels run with interpret=True
on CPU (tests) and natively on TPU."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .flash_attention import (DEFAULT_BLOCK_K, DEFAULT_BLOCK_Q,
                              flash_attention_bwd, flash_attention_fwd)


def _pad_to(x, size, axis):
    pad = size - x.shape[axis]
    if pad <= 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def flash_attention(q, k, v, causal=True, window=0, block_q=DEFAULT_BLOCK_Q,
                    block_k=DEFAULT_BLOCK_K, interpret=False):
    o, _ = _fwd(q, k, v, causal, window, block_q, block_k, interpret)
    return o


def _fwd(q, k, v, causal, window, block_q, block_k, interpret):
    B, Sq, H, hd = q.shape
    Skv = k.shape[1]
    bq = min(block_q, Sq)
    bk = min(block_k, Skv)
    Sq_p = -(-Sq // bq) * bq
    Skv_p = -(-Skv // bk) * bk
    qp = _pad_to(q, Sq_p, 1)
    kp = _pad_to(k, Skv_p, 1)
    vp = _pad_to(v, Skv_p, 1)
    # padded KV columns are masked by causality only if they sit beyond every
    # real q row; enforce explicitly via window-free causal + kv mask trick:
    # give padded keys position > everything by relying on causal mask when
    # Skv_p > Sq rows exist. Safest: mask via big-negative bias is already
    # implied because padded k rows are zeros -> s=0, which is NOT masked;
    # so we shift padded q positions instead (they are sliced off) and rely on
    # causal>=, requiring Skv padding only when causal. For non-causal use,
    # callers must pass block-aligned Skv.
    if Skv_p != Skv:
        assert causal, "non-causal padding unsupported; align Skv to block_k"
        assert Skv == Sq, "padded flash path assumes self-attention"
    o, lse = flash_attention_fwd(qp, kp, vp, causal=causal, window=window,
                                 block_q=bq, block_k=bk, interpret=interpret)
    return o[:, :Sq], (q, k, v, o[:, :Sq], lse[..., :Sq])


def _fwd_vjp(q, k, v, causal, window, block_q, block_k, interpret):
    o, res = _fwd(q, k, v, causal, window, block_q, block_k, interpret)
    return o, res


def _bwd_vjp(causal, window, block_q, block_k, interpret, res, do):
    q, k, v, o, lse = res
    B, Sq, H, hd = q.shape
    Skv = k.shape[1]
    bq = min(block_q, Sq)
    bk = min(block_k, Skv)
    Sq_p = -(-Sq // bq) * bq
    Skv_p = -(-Skv // bk) * bk
    qp, op, dop = (_pad_to(x, Sq_p, 1) for x in (q, o, do))
    kp, vp = (_pad_to(x, Skv_p, 1) for x in (k, v))
    lsep = _pad_to(lse, Sq_p, 2)
    dq, dk, dv = flash_attention_bwd(qp, kp, vp, op, lsep, dop, causal=causal,
                                     window=window, block_q=bq, block_k=bk,
                                     interpret=interpret)
    return dq[:, :Sq], dk[:, :Skv], dv[:, :Skv]


flash_attention.defvjp(_fwd_vjp, _bwd_vjp)
