"""Pallas TPU flash attention: causal / sliding-window GQA, fwd + bwd.

TPU-native design (not a CUDA port): the grid iterates KV blocks in the
innermost ("arbitrary") dimension while online-softmax statistics (m, l) and
the output accumulator live in VMEM scratch across those iterations; the MXU
sees (block_q × head_dim) @ (head_dim × block_k) matmuls with 128-aligned
defaults. Fully-masked KV blocks (beyond the causal frontier or outside the
sliding-window band) are skipped with `pl.when` — compute for a window layer
is O(S·window), matching the banded XLA reference.

VMEM budget per program @ defaults (bq=bk=128, hd=128, fp32 scratch):
q,k,v,o blocks ≈ 4·128·128·2B = 128 KiB; acc+m+l ≈ 66 KiB — comfortably
inside the ~16 MiB/core VMEM with double buffering.

Backward uses the standard two-pass formulation (dkv pass over KV blocks,
dq pass over Q blocks) with the fwd log-sum-exp and D = rowsum(dO·O)
precomputed. GQA backward writes per-Q-head dk/dv which the ops wrapper
group-sums to KV heads.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .. import compat

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = -2.0 ** 30


def _block_mask(qpos, kpos, *, causal: bool, window: int):
    m = jnp.ones((qpos.shape[0], kpos.shape[0]), jnp.bool_)
    if causal:
        m &= kpos[None, :] <= qpos[:, None]
    if window:
        m &= kpos[None, :] > qpos[:, None] - window
    return m


def _visible(qi, ki, *, block_q, block_k, causal, window):
    """Can block (qi, ki) contain any unmasked element? (traced scalars ok)"""
    q_lo = qi * block_q
    q_hi = q_lo + block_q - 1
    k_lo = ki * block_k
    k_hi = k_lo + block_k - 1
    vis = jnp.bool_(True)
    if causal:
        vis &= k_lo <= q_hi
    if window:
        vis &= k_hi > q_lo - window
    return vis


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, m_scr, l_scr, acc_scr, *,
                scale, causal, window, block_q, block_k):
    ki = pl.program_id(3)
    qi = pl.program_id(2)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    @pl.when(_visible(qi, ki, block_q=block_q, block_k=block_k,
                      causal=causal, window=window))
    def _update():
        q = q_ref[0, :, 0, :].astype(jnp.float32) * scale  # (bq, hd)
        k = k_ref[0, :, 0, :].astype(jnp.float32)          # (bk, hd)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        qpos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, 1), 0)
        kpos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (1, block_k), 1)
        mask = jnp.ones_like(s, jnp.bool_)
        if causal:
            mask &= kpos <= qpos
        if window:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        l = jnp.where(l_scr[...] == 0.0, 1.0, l_scr[...])
        o_ref[0, :, 0, :] = (acc_scr[...] / l).astype(o_ref.dtype)
        lse_ref[0, 0] = (m_scr[...] + jnp.log(l))[:, 0]


def flash_attention_fwd(q, k, v, *, causal=True, window=0, scale=None,
                        block_q=DEFAULT_BLOCK_Q, block_k=DEFAULT_BLOCK_K,
                        interpret=False):
    """q: (B,Sq,H,hd); k,v: (B,Skv,KV,hd). Returns (o (B,Sq,H,hd), lse (B,H,Sq))."""
    B, Sq, H, hd = q.shape
    _, Skv, KV, _ = k.shape
    G = H // KV
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    block_q = min(block_q, Sq)
    block_k = min(block_k, Skv)
    assert Sq % block_q == 0 and Skv % block_k == 0, (Sq, block_q, Skv, block_k)
    grid = (B, H, Sq // block_q, Skv // block_k)

    kernel = functools.partial(_fwd_kernel, scale=scale, causal=causal,
                               window=window, block_q=block_q, block_k=block_k)
    o, lse = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, 1, hd), lambda b, h, qi, ki: (b, qi, h, 0)),
            pl.BlockSpec((1, block_k, 1, hd), lambda b, h, qi, ki: (b, ki, h // G, 0)),
            pl.BlockSpec((1, block_k, 1, hd), lambda b, h, qi, ki: (b, ki, h // G, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_q, 1, hd), lambda b, h, qi, ki: (b, qi, h, 0)),
            pl.BlockSpec((1, 1, block_q), lambda b, h, qi, ki: (b, h, qi)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(q.shape, q.dtype),
            jax.ShapeDtypeStruct((B, H, Sq), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
    return o, lse


# ---------------------------------------------------------------------------
# backward: dkv pass (grid over KV blocks, inner loop over Q blocks)
# ---------------------------------------------------------------------------

def _dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, dsum_ref,
                dk_ref, dv_ref, dk_scr, dv_scr, *,
                scale, causal, window, block_q, block_k):
    qi = pl.program_id(3)
    ki = pl.program_id(2)
    nq = pl.num_programs(3)

    @pl.when(qi == 0)
    def _init():
        dk_scr[...] = jnp.zeros_like(dk_scr)
        dv_scr[...] = jnp.zeros_like(dv_scr)

    @pl.when(_visible(qi, ki, block_q=block_q, block_k=block_k,
                      causal=causal, window=window))
    def _update():
        q = q_ref[0, :, 0, :].astype(jnp.float32) * scale  # (bq,hd)
        k = k_ref[0, :, 0, :].astype(jnp.float32)           # (bk,hd)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        do = do_ref[0, :, 0, :].astype(jnp.float32)         # (bq,hd)
        lse = lse_ref[0, 0]                                 # (bq,)
        dsum = dsum_ref[0, 0]                               # (bq,)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        qpos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, 1), 0)
        kpos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (1, block_k), 1)
        mask = jnp.ones_like(s, jnp.bool_)
        if causal:
            mask &= kpos <= qpos
        if window:
            mask &= kpos > qpos - window
        p = jnp.where(mask, jnp.exp(s - lse[:, None]), 0.0)   # (bq,bk)
        dv_scr[...] += jax.lax.dot_general(p, do, (((0,), (0,)), ((), ())),
                                           preferred_element_type=jnp.float32)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - dsum[:, None])                      # (bq,bk)
        dk_scr[...] += jax.lax.dot_general(ds, q, (((0,), (0,)), ((), ())),
                                           preferred_element_type=jnp.float32)

    @pl.when(qi == nq - 1)
    def _finalize():
        dk_ref[0, :, 0, :] = dk_scr[...].astype(dk_ref.dtype)
        dv_ref[0, :, 0, :] = dv_scr[...].astype(dv_ref.dtype)


def _dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, dsum_ref, dq_ref,
               dq_scr, *, scale, causal, window, block_q, block_k):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        dq_scr[...] = jnp.zeros_like(dq_scr)

    @pl.when(_visible(qi, ki, block_q=block_q, block_k=block_k,
                      causal=causal, window=window))
    def _update():
        q = q_ref[0, :, 0, :].astype(jnp.float32) * scale
        k = k_ref[0, :, 0, :].astype(jnp.float32)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        do = do_ref[0, :, 0, :].astype(jnp.float32)
        lse = lse_ref[0, 0]
        dsum = dsum_ref[0, 0]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        qpos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, 1), 0)
        kpos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (1, block_k), 1)
        mask = jnp.ones_like(s, jnp.bool_)
        if causal:
            mask &= kpos <= qpos
        if window:
            mask &= kpos > qpos - window
        p = jnp.where(mask, jnp.exp(s - lse[:, None]), 0.0)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - dsum[:, None])
        dq_scr[...] += jax.lax.dot(ds, k, preferred_element_type=jnp.float32)

    @pl.when(ki == nk - 1)
    def _finalize():
        dq_ref[0, :, 0, :] = (dq_scr[...] * scale).astype(dq_ref.dtype)


def flash_attention_bwd(q, k, v, o, lse, do, *, causal=True, window=0,
                        scale=None, block_q=DEFAULT_BLOCK_Q,
                        block_k=DEFAULT_BLOCK_K, interpret=False):
    """Returns (dq, dk, dv). dk/dv are group-summed to KV heads."""
    B, Sq, H, hd = q.shape
    _, Skv, KV, _ = k.shape
    G = H // KV
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    block_q = min(block_q, Sq)
    block_k = min(block_k, Skv)
    dsum = jnp.sum(do.astype(jnp.float32) * o.astype(jnp.float32), axis=-1)
    dsum = dsum.transpose(0, 2, 1)  # (B,H,Sq)

    kern = functools.partial(_dkv_kernel, scale=scale, causal=causal,
                             window=window, block_q=block_q, block_k=block_k)
    dkh, dvh = pl.pallas_call(
        kern,
        grid=(B, H, Skv // block_k, Sq // block_q),
        in_specs=[
            pl.BlockSpec((1, block_q, 1, hd), lambda b, h, ki, qi: (b, qi, h, 0)),
            pl.BlockSpec((1, block_k, 1, hd), lambda b, h, ki, qi: (b, ki, h // G, 0)),
            pl.BlockSpec((1, block_k, 1, hd), lambda b, h, ki, qi: (b, ki, h // G, 0)),
            pl.BlockSpec((1, block_q, 1, hd), lambda b, h, ki, qi: (b, qi, h, 0)),
            pl.BlockSpec((1, 1, block_q), lambda b, h, ki, qi: (b, h, qi)),
            pl.BlockSpec((1, 1, block_q), lambda b, h, ki, qi: (b, h, qi)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_k, 1, hd), lambda b, h, ki, qi: (b, ki, h, 0)),
            pl.BlockSpec((1, block_k, 1, hd), lambda b, h, ki, qi: (b, ki, h, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, Skv, H, hd), jnp.float32),
            jax.ShapeDtypeStruct((B, Skv, H, hd), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_k, hd), jnp.float32),
            pltpu.VMEM((block_k, hd), jnp.float32),
        ],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v, do, lse, dsum)
    # group-sum per-Q-head contributions back to KV heads
    dk = dkh.reshape(B, Skv, KV, G, hd).sum(axis=3).astype(k.dtype)
    dv = dvh.reshape(B, Skv, KV, G, hd).sum(axis=3).astype(v.dtype)

    kern_q = functools.partial(_dq_kernel, scale=scale, causal=causal,
                               window=window, block_q=block_q, block_k=block_k)
    dq = pl.pallas_call(
        kern_q,
        grid=(B, H, Sq // block_q, Skv // block_k),
        in_specs=[
            pl.BlockSpec((1, block_q, 1, hd), lambda b, h, qi, ki: (b, qi, h, 0)),
            pl.BlockSpec((1, block_k, 1, hd), lambda b, h, qi, ki: (b, ki, h // G, 0)),
            pl.BlockSpec((1, block_k, 1, hd), lambda b, h, qi, ki: (b, ki, h // G, 0)),
            pl.BlockSpec((1, block_q, 1, hd), lambda b, h, qi, ki: (b, qi, h, 0)),
            pl.BlockSpec((1, 1, block_q), lambda b, h, qi, ki: (b, h, qi)),
            pl.BlockSpec((1, 1, block_q), lambda b, h, qi, ki: (b, h, qi)),
        ],
        out_specs=pl.BlockSpec((1, block_q, 1, hd), lambda b, h, qi, ki: (b, qi, h, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[pltpu.VMEM((block_q, hd), jnp.float32)],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v, do, lse, dsum)
    return dq, dk, dv
