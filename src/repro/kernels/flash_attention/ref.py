"""Pure-jnp oracle for flash attention (naive materialized softmax)."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -2.0 ** 30


def attention_ref(q, k, v, *, causal=True, window=0, scale=None):
    """q: (B,Sq,H,hd); k,v: (B,Skv,KV,hd) -> (B,Sq,H,hd). fp32 softmax."""
    B, Sq, H, hd = q.shape
    _, Skv, KV, _ = k.shape
    G = H // KV
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    qg = q.reshape(B, Sq, KV, G, hd).astype(jnp.float32) * scale
    s = jnp.einsum("bqkgh,bskh->bkgqs", qg, k.astype(jnp.float32))
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), jnp.bool_)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskh->bqkgh", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, H, hd).astype(q.dtype)
