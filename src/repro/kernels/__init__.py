"""Pallas TPU kernels for the workload's compute hot spots (the paper itself
contributes no kernels — these belong to the substrate being checkpointed).

Each subpackage: <name>.py (pl.pallas_call + BlockSpec), ops.py (jit'd
wrapper), ref.py (pure-jnp oracle). Validated in interpret=True on CPU;
TPU is the compile target. The model's XLA paths (models/layers.py chunked
attention, associative scans) implement identical semantics and serve as the
lowering path on non-TPU backends; on TPU, ops here are the drop-in hot path.
"""

from .decode_attention import decode_attention_ref, flash_decode
from .flash_attention import attention_ref, flash_attention
from .quantize import quantize_int8, quantize_int8_ref
from .rglru_scan import lru_scan, rglru_scan, rglru_scan_ref
from .ssm_scan import selective_scan, ssm_scan, ssm_scan_ref

__all__ = [
    "attention_ref", "decode_attention_ref", "flash_attention", "flash_decode",
    "lru_scan", "quantize_int8", "quantize_int8_ref", "rglru_scan",
    "rglru_scan_ref", "selective_scan", "ssm_scan", "ssm_scan_ref",
]
