"""jnp oracle for absmax-int8 quantization.

Bit-compatible with ``checkpoint.serialize.quantize``: the reduce runs on
device, but the scalar scale/inverse arithmetic funnels through
``serialize.int8_scale_inv`` (numpy, float32) and the elementwise step is
multiply-only — XLA's fast-math rewrites division into reciprocal-multiply,
so any division-based formula would drift by 1 ulp between host and device.
The checkpoint format depends on this identity: a device-quantized payload
must dedup against a host-quantized one in the content-addressed pool.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ...checkpoint.serialize import int8_scale_inv

_absmax_jit = jax.jit(lambda x: jnp.max(jnp.abs(x.astype(jnp.float32))))
_quant_jit = jax.jit(lambda x, inv: jnp.clip(
    jnp.round(x.astype(jnp.float32) * inv), -127.0, 127.0).astype(jnp.int8))
_dequant_jit = jax.jit(
    lambda q, scale, dtype: (q.astype(jnp.float32) * scale).astype(dtype),
    static_argnames=("dtype",))


def quantize_int8_ref(x):
    """x (any float dtype) -> (q int8, scale float32 scalar)."""
    if x.size == 0:
        return jnp.zeros(x.shape, jnp.int8), jnp.float32(1.0)
    scale, inv = int8_scale_inv(np.asarray(_absmax_jit(x)))
    return _quant_jit(x, jnp.float32(inv)), jnp.float32(scale)


def dequantize_int8_ref(q, scale, *, dtype):
    """(q int8, absmax scale) -> tensor of ``dtype``; multiply-only in
    float32 with a float32 scale, bit-identical to the host
    ``serialize.finish_payload`` and the Pallas dequant kernel."""
    return _dequant_jit(jnp.asarray(q), jnp.float32(scale), np.dtype(dtype))
