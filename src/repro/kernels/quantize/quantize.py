"""Pallas TPU absmax-int8 quantize/dequantize — the checkpoint hot path's
device halves.

Three small kernels over the same (rows, 128) blocking of the flattened
tensor:

  1. ``absmax`` — sequential grid over row-blocks accumulating max|x| in a
     (1, 1) SMEM scratch cell (a scalar reduction, per the TPU idiom).
  2. ``quantize`` — elementwise fused scale/round/clip/cast; the scalar
     scale rides in SMEM so every block reads it without an HBM round-trip.
  3. ``dequantize`` — the restore mirror: fused int8→float32 widen,
     multiply by the SMEM scalar scale, cast to the logical dtype. Restored
     int8 payloads cross the host→device link at 1/4 width and widen on
     device instead of paying a host ``astype`` double-copy.

The arithmetic (float32 intermediate, round-half-even, clip to ±127,
absmax/127 scale) matches ``checkpoint.serialize.quantize`` bit-for-bit —
that identity is what lets device-quantized urgent-save chunks dedup against
host-quantized periodic-save chunks in the content-addressed pool. The
dequantize matches ``serialize.finish_payload`` the same way (multiply-only
in float32 — never divide, fast-math rewrites division), so a streaming
device restore is bit-identical to the host path.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .. import compat

LANES = 128
DEFAULT_BLOCK_ROWS = 256


def _absmax_kernel(x_ref, out_ref, acc_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        acc_ref[0, 0] = 0.0

    m = jnp.max(jnp.abs(x_ref[...].astype(jnp.float32)))
    acc_ref[0, 0] = jnp.maximum(acc_ref[0, 0], m)

    @pl.when(i == pl.num_programs(0) - 1)
    def _final():
        out_ref[0, 0] = acc_ref[0, 0]


def _quantize_kernel(inv_ref, x_ref, q_ref):
    # multiply by the precomputed 1/scale — never divide: fast-math rewrites
    # division into reciprocal-multiply, and the stored bytes must be
    # bit-identical to the host quantize (see serialize.int8_scale_inv)
    inv = inv_ref[0, 0]
    q_ref[...] = jnp.clip(jnp.round(x_ref[...].astype(jnp.float32) * inv),
                          -127.0, 127.0).astype(jnp.int8)


def _dequant_kernel(scale_ref, q_ref, out_ref):
    # widen → multiply by the scalar scale → cast, all fused in one pass;
    # the float32 intermediate and final cast replicate the host
    # serialize.finish_payload sequence bit-for-bit
    s = scale_ref[0, 0]
    out_ref[...] = (q_ref[...].astype(jnp.float32) * s).astype(out_ref.dtype)


def absmax_2d(x2d, *, block_rows: int = DEFAULT_BLOCK_ROWS, interpret=False):
    """max|x| over a (rows, LANES) array -> (1, 1) float32."""
    rows, cols = x2d.shape
    block_rows = min(block_rows, rows)
    assert rows % block_rows == 0 and cols == LANES, (x2d.shape, block_rows)
    return pl.pallas_call(
        _absmax_kernel,
        grid=(rows // block_rows,),
        in_specs=[pl.BlockSpec((block_rows, cols), lambda i: (i, 0))],
        out_specs=pl.BlockSpec(memory_space=pltpu.SMEM),
        out_shape=jax.ShapeDtypeStruct((1, 1), jnp.float32),
        scratch_shapes=[pltpu.SMEM((1, 1), jnp.float32)],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(x2d)


def quantize_2d(inv, x2d, *, block_rows: int = DEFAULT_BLOCK_ROWS,
                interpret=False):
    """Fused q = int8(clip(round(x * inv))) over (rows, LANES); ``inv`` is
    the precomputed float32 reciprocal of the absmax scale."""
    rows, cols = x2d.shape
    block_rows = min(block_rows, rows)
    assert rows % block_rows == 0 and cols == LANES, (x2d.shape, block_rows)
    return pl.pallas_call(
        _quantize_kernel,
        grid=(rows // block_rows,),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i: (0, 0), memory_space=pltpu.SMEM),
            pl.BlockSpec((block_rows, cols), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, cols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, cols), jnp.int8),
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(jnp.asarray(inv, jnp.float32).reshape(1, 1), x2d)


def dequantize_2d(scale, q2d, *, out_dtype, block_rows: int = DEFAULT_BLOCK_ROWS,
                  interpret=False):
    """Fused x = out_dtype(float32(q) * scale) over (rows, LANES); ``scale``
    is the absmax scale stored in the checkpoint record."""
    rows, cols = q2d.shape
    block_rows = min(block_rows, rows)
    assert rows % block_rows == 0 and cols == LANES, (q2d.shape, block_rows)
    return pl.pallas_call(
        _dequant_kernel,
        grid=(rows // block_rows,),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i: (0, 0), memory_space=pltpu.SMEM),
            pl.BlockSpec((block_rows, cols), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, cols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, cols), out_dtype),
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(jnp.asarray(scale, jnp.float32).reshape(1, 1), q2d)
