from .ops import quantize_int8
from .quantize import absmax_2d, quantize_2d
from .ref import quantize_int8_ref

__all__ = ["absmax_2d", "quantize_2d", "quantize_int8", "quantize_int8_ref"]
