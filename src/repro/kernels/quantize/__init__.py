from .ops import dequantize_int8, dequantize_int8_many, quantize_int8
from .quantize import absmax_2d, dequantize_2d, quantize_2d
from .ref import dequantize_int8_ref, quantize_int8_ref

__all__ = ["absmax_2d", "dequantize_2d", "dequantize_int8",
           "dequantize_int8_many", "dequantize_int8_ref", "quantize_2d",
           "quantize_int8", "quantize_int8_ref"]
