"""Dispatching wrapper: Pallas kernel on TPU, jnp oracle elsewhere.

``quantize_int8`` is what the checkpoint extract calls. On a TPU backend a
single-device tensor goes through the fused Pallas pair (absmax reduce +
quantize); sharded tensors and non-TPU backends take the jitted jnp
reference, which XLA partitions/fuses itself. All paths produce bit-identical
int8 payloads (see ref.py), so the choice never changes the checkpoint.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np

from ...checkpoint.serialize import int8_scale_inv
from .quantize import DEFAULT_BLOCK_ROWS, LANES, absmax_2d, quantize_2d
from .ref import quantize_int8_ref


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def _pad_2d(x, block_rows, interpret):
    n = x.size
    rows = max(1, math.ceil(n / LANES))
    rows = math.ceil(rows / min(block_rows, rows)) * min(block_rows, rows)
    flat = jnp.pad(x.reshape(-1), (0, rows * LANES - n))  # 0-pad: |0| neutral
    return flat.reshape(rows, LANES)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def _absmax_pallas(x2d, block_rows, interpret):
    return absmax_2d(x2d, block_rows=block_rows, interpret=interpret)[0, 0]


@functools.partial(jax.jit, static_argnames=("n", "shape", "block_rows",
                                             "interpret"))
def _quantize_pallas(x2d, inv, n, shape, block_rows, interpret):
    q2d = quantize_2d(inv, x2d, block_rows=block_rows, interpret=interpret)
    return q2d.reshape(-1)[:n].reshape(shape)


def _single_device(x) -> bool:
    try:
        return len(x.sharding.device_set) == 1
    except AttributeError:
        return True


def quantize_int8(x, *, block_rows: int = DEFAULT_BLOCK_ROWS,
                  interpret: bool = False):
    """x -> (q int8 of x.shape, scale float32 scalar), absmax/127 scaling.

    The payload stays on device — the point is to cross the device→host
    link at 1/4 width during urgent checkpoint extraction. Only the absmax
    *scalar* syncs to host, where ``serialize.int8_scale_inv`` computes the
    scale/inverse with the exact float32 rounding sequence the host quantize
    uses (the elementwise device step is multiply-only, which XLA never
    rewrites) — so device- and host-quantized payloads are bit-identical.
    """
    x = jnp.asarray(x)
    if x.size == 0:
        return jnp.zeros(x.shape, jnp.int8), jnp.float32(1.0)
    if interpret or (jax.default_backend() == "tpu" and _single_device(x)):
        x2d = _pad_2d(x, block_rows, interpret)
        am = _absmax_pallas(x2d, block_rows, interpret)
        scale, inv = int8_scale_inv(np.asarray(am))
        q = _quantize_pallas(x2d, jnp.float32(inv), x.size, tuple(x.shape),
                             block_rows, interpret)
        return q, jnp.float32(scale)
    return quantize_int8_ref(x)
