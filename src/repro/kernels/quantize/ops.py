"""Dispatching wrapper: Pallas kernel on TPU, jnp oracle elsewhere.

``quantize_int8`` is what the checkpoint extract calls; ``dequantize_int8``
is the streaming restore's mirror. On a TPU backend a single-device tensor
goes through the fused Pallas kernels (absmax reduce + quantize, or the
dequantize widen); sharded tensors and non-TPU backends take the jitted jnp
reference, which XLA partitions/fuses itself. All paths produce bit-identical
payloads (see ref.py), so the choice never changes the checkpoint — or the
restored state.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np

from ...checkpoint.serialize import int8_scale_inv
from .quantize import (DEFAULT_BLOCK_ROWS, LANES, absmax_2d, dequantize_2d,
                       quantize_2d)
from .ref import dequantize_int8_ref, quantize_int8_ref


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def _pad_2d(x, block_rows, interpret):
    n = x.size
    rows = max(1, math.ceil(n / LANES))
    rows = math.ceil(rows / min(block_rows, rows)) * min(block_rows, rows)
    flat = jnp.pad(x.reshape(-1), (0, rows * LANES - n))  # 0-pad: |0| neutral
    return flat.reshape(rows, LANES)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def _absmax_pallas(x2d, block_rows, interpret):
    return absmax_2d(x2d, block_rows=block_rows, interpret=interpret)[0, 0]


@functools.partial(jax.jit, static_argnames=("n", "shape", "block_rows",
                                             "interpret"))
def _quantize_pallas(x2d, inv, n, shape, block_rows, interpret):
    q2d = quantize_2d(inv, x2d, block_rows=block_rows, interpret=interpret)
    return q2d.reshape(-1)[:n].reshape(shape)


def _single_device(x) -> bool:
    try:
        return len(x.sharding.device_set) == 1
    except AttributeError:
        return True


def quantize_int8(x, *, block_rows: int = DEFAULT_BLOCK_ROWS,
                  interpret: bool = False):
    """x -> (q int8 of x.shape, scale float32 scalar), absmax/127 scaling.

    The payload stays on device — the point is to cross the device→host
    link at 1/4 width during urgent checkpoint extraction. Only the absmax
    *scalar* syncs to host, where ``serialize.int8_scale_inv`` computes the
    scale/inverse with the exact float32 rounding sequence the host quantize
    uses (the elementwise device step is multiply-only, which XLA never
    rewrites) — so device- and host-quantized payloads are bit-identical.
    """
    x = jnp.asarray(x)
    if x.size == 0:
        return jnp.zeros(x.shape, jnp.int8), jnp.float32(1.0)
    if interpret or (jax.default_backend() == "tpu" and _single_device(x)):
        x2d = _pad_2d(x, block_rows, interpret)
        am = _absmax_pallas(x2d, block_rows, interpret)
        scale, inv = int8_scale_inv(np.asarray(am))
        q = _quantize_pallas(x2d, jnp.float32(inv), x.size, tuple(x.shape),
                             block_rows, interpret)
        return q, jnp.float32(scale)
    return quantize_int8_ref(x)


@functools.partial(jax.jit, static_argnames=("n", "shape", "dtype",
                                             "block_rows", "interpret"))
def _dequantize_pallas(q2d, scale, n, shape, dtype, block_rows, interpret):
    x2d = dequantize_2d(scale, q2d, out_dtype=dtype, block_rows=block_rows,
                        interpret=interpret)
    return x2d.reshape(-1)[:n].reshape(shape)


def dequantize_int8(q, scale, *, dtype, block_rows: int = DEFAULT_BLOCK_ROWS,
                    interpret: bool = False):
    """(q int8, absmax scale) -> tensor of ``dtype`` — the restore mirror of
    ``quantize_int8``.

    The int8 payload crosses the host→device link at 1/4 the logical width;
    the widen/multiply/cast runs on device. The scalar arithmetic is
    multiply-only with a float32 scale (the one stored in the checkpoint
    record), so the result is bit-identical to the host
    ``serialize.finish_payload`` path — the streaming restore's correctness
    contract.
    """
    q = jnp.asarray(q)
    dtype = np.dtype(dtype)
    if q.size == 0:
        return jnp.zeros(q.shape, dtype)
    if interpret or (jax.default_backend() == "tpu" and _single_device(q)):
        q2d = _pad_2d(q, block_rows, interpret)
        return _dequantize_pallas(q2d, jnp.float32(scale), q.size,
                                  tuple(q.shape), dtype, block_rows, interpret)
    return dequantize_int8_ref(q, scale, dtype=dtype)


@functools.partial(jax.jit, static_argnames=("dtypes",))
def _dequant_many_jit(qs, scales, dtypes):
    return tuple((q.astype(jnp.float32) * s).astype(np.dtype(d))
                 for q, s, d in zip(qs, scales, dtypes))


def dequantize_int8_many(qs, scales, dtype_names):
    """Batch dequantize: one dispatch for a whole restore's int8 payloads.

    A streaming restore widens many small optimizer-moment tensors; paying a
    per-tensor dispatch would put ~N×dispatch-latency back into the MTTR
    window that the 1/4-width transfer just saved. On TPU each tensor still
    goes through the fused Pallas kernel (per-tensor dispatch is cheap next
    to the H2D savings there); elsewhere a single jitted program widens all
    of them — same multiply-only float32 arithmetic, bit-identical either
    way. ``scales`` may be floats; dtype names key the jit cache.
    """
    if not qs:
        return []
    if jax.default_backend() == "tpu" and all(_single_device(q) for q in qs):
        return [dequantize_int8(q, s, dtype=d)
                for q, s, d in zip(qs, scales, dtype_names)]
    # np.float32, not jnp.float32: the scalars enter the jit as arguments,
    # and an eager jnp conversion would pay one dispatch per scale — the
    # exact per-tensor latency this batched call exists to avoid
    return list(_dequant_many_jit(
        tuple(qs),
        tuple(np.float32(s) for s in scales),
        tuple(str(d) for d in dtype_names)))
