"""Pallas TPU API compatibility across JAX versions.

JAX renamed ``pltpu.TPUCompilerParams`` to ``pltpu.CompilerParams`` (the old
name was removed after the deprecation cycle; on 0.4.x only the TPU-prefixed
name exists). Resolve whichever the installed JAX provides by probe so the
kernels import on both sides of the rename.
"""

from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

_CompilerParams = getattr(pltpu, "CompilerParams", None)
if _CompilerParams is None:
    _CompilerParams = getattr(pltpu, "TPUCompilerParams")


def tpu_compiler_params(**kwargs):
    """Build TPU compiler params under whichever class name this JAX has."""
    return _CompilerParams(**kwargs)
