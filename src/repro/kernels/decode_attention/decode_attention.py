"""Pallas TPU flash-decode: one query token against a deep KV cache.

The decode regime is memory-bound (every cache byte read once per token), so
the kernel is organized around streaming KV blocks through VMEM with online
softmax state in scratch — grid (B, H, NK) with the KV-block dimension
innermost ("arbitrary"). `valid_len` (the filled cache depth) arrives in SMEM
so one compiled kernel serves every decode position.

For the 500k-token cells, the KV stream per (batch, head) is S·hd·2·2 bytes;
block_k=512 keeps each resident block at 512·hd·4 B ≈ 256 KiB (hd=128) —
VMEM-safe with double buffering while maximizing DMA efficiency.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .. import compat

NEG_INF = -2.0 ** 30
DEFAULT_BLOCK_K = 512


def _decode_kernel(vlen_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                   *, scale, block_k):
    ki = pl.program_id(2)
    nk = pl.num_programs(2)
    valid_len = vlen_ref[0]

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    @pl.when(ki * block_k < valid_len)
    def _update():
        q = q_ref[0, 0, :].astype(jnp.float32) * scale        # (hd,)
        k = k_ref[0, :, 0, :].astype(jnp.float32)             # (bk, hd)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jnp.sum(k * q[None, :], axis=1)[None, :]          # (1, bk)
        kpos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (1, block_k), 1)
        s = jnp.where(kpos < valid_len, s, NEG_INF)
        m_prev = m_scr[...]                                   # (1,1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)                                # (1, bk)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32)         # (1, hd)
        m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        l = jnp.where(l_scr[...] == 0.0, 1.0, l_scr[...])
        o_ref[0, 0, :] = (acc_scr[...] / l)[0].astype(o_ref.dtype)


def decode_attention(q, k, v, valid_len, *, scale=None,
                     block_k=DEFAULT_BLOCK_K, interpret=False):
    """q: (B,H,hd); k,v: (B,S,KV,hd); valid_len: int32 scalar (tokens filled).
    Returns o: (B,H,hd). Causality is implied by valid_len (the query is the
    newest token)."""
    B, H, hd = q.shape
    _, S, KV, _ = k.shape
    G = H // KV
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    block_k = min(block_k, S)
    assert S % block_k == 0, (S, block_k)
    vlen = jnp.asarray(valid_len, jnp.int32).reshape(1)

    kernel = functools.partial(_decode_kernel, scale=scale, block_k=block_k)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, H, S // block_k),
        in_specs=[
            pl.BlockSpec((1, 1, hd), lambda b, h, ki, vl: (b, h, 0)),
            pl.BlockSpec((1, block_k, 1, hd), lambda b, h, ki, vl: (b, ki, h // G, 0)),
            pl.BlockSpec((1, block_k, 1, hd), lambda b, h, ki, vl: (b, ki, h // G, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, hd), lambda b, h, ki, vl: (b, h, 0)),
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, hd), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, hd), q.dtype),
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(vlen, q, k, v)
