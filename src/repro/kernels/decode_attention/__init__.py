from .ops import flash_decode
from .ref import decode_attention_ref

__all__ = ["flash_decode", "decode_attention_ref"]
