"""Pure-jnp oracle for flash-decode."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -2.0 ** 30


def decode_attention_ref(q, k, v, valid_len, *, scale=None):
    """q: (B,H,hd); k,v: (B,S,KV,hd) -> (B,H,hd)."""
    B, H, hd = q.shape
    _, S, KV, _ = k.shape
    G = H // KV
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    qg = q.reshape(B, KV, G, hd).astype(jnp.float32) * scale
    s = jnp.einsum("bkgh,bskh->bkgs", qg, k.astype(jnp.float32))
    mask = jnp.arange(S)[None, None, None, :] < valid_len
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskh->bkgh", p, v.astype(jnp.float32))
    return o.reshape(B, H, hd).astype(q.dtype)
