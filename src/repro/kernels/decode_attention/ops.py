"""Jit-ready flash-decode wrapper (inference-only; no vjp needed)."""

from __future__ import annotations

import jax.numpy as jnp

from .decode_attention import DEFAULT_BLOCK_K, decode_attention


def flash_decode(q, k, v, valid_len, *, block_k=DEFAULT_BLOCK_K,
                 interpret=False):
    """q: (B,1,H,hd) or (B,H,hd); k,v: (B,S,KV,hd). Returns same rank as q."""
    squeeze = q.ndim == 4
    if squeeze:
        q = q[:, 0]
    S = k.shape[1]
    bk = min(block_k, S)
    while S % bk != 0:
        bk //= 2
    o = decode_attention(q, k, v, valid_len, block_k=max(bk, 1),
                         interpret=interpret)
    return o[:, None] if squeeze else o
