from .fingerprint import fingerprint_blocks_2d
from .ops import (fingerprint_blocks, fingerprint_blocks_ref,
                  fingerprint_diff, supported_dtype)
from .ref import fmix32, mix_words, n_blocks_of, word_bytes, words_per_block

__all__ = ["fingerprint_blocks", "fingerprint_blocks_2d",
           "fingerprint_blocks_ref", "fingerprint_diff", "fmix32",
           "mix_words", "n_blocks_of", "supported_dtype", "word_bytes",
           "words_per_block"]
