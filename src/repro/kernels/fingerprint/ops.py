"""Dispatching wrapper: Pallas fingerprint kernel on TPU, jnp oracle elsewhere.

``fingerprint_blocks`` is what the delta save path calls: tensor in (any
layout, device-resident), uint32[n_blocks] digest array out — one digest per
``block_bytes`` window of the tensor's raw bytes, aligned with the chunk
boundaries ``chunkstore.iter_chunks`` uses, so "digest b changed" means
exactly "pool chunk b must be re-encoded". The result stays on device: the
tracker compares it against the previous save's digests with one elementwise
``!=`` and only the tiny bool vector crosses device→host.

All paths (Pallas, jitted jnp, numpy ref) produce bit-identical digests —
the tracker stores device digests across saves and the tests pin the
identity in interpret mode.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np

from .fingerprint import LANES, MAX_BLOCK_ROWS, fingerprint_blocks_2d
from .ref import (fingerprint_blocks_ref, fmix32, mix_words, n_blocks_of,
                  word_bytes, words_per_block)

__all__ = ["fingerprint_blocks", "fingerprint_blocks_ref", "supported_dtype"]


def supported_dtype(dtype) -> bool:
    """Dtypes the word stream is defined for (everything the checkpoint
    stores except bool, whose bitcast semantics differ across backends)."""
    dt = np.dtype(dtype)
    return dt.kind != "b" and dt.itemsize in (1, 2, 4, 8)


def _words_impl(x, wpb, n_blocks):
    """Trace-time helper: ``x`` flattened to its uint32 word stream,
    zero-padded to whole blocks, shaped (n_blocks, wpb)."""
    flat = x.reshape(-1)
    it = np.dtype(x.dtype).itemsize
    if it == 4:
        w = jax.lax.bitcast_convert_type(flat, jnp.uint32)
    elif it == 2:
        w = jax.lax.bitcast_convert_type(flat, jnp.uint16).astype(jnp.uint32)
    elif it == 1:
        w = jax.lax.bitcast_convert_type(flat, jnp.uint8).astype(jnp.uint32)
    else:  # 8-byte elements split into two uint32 words (memory order)
        w = jax.lax.bitcast_convert_type(flat, jnp.uint32).reshape(-1)
    pad = n_blocks * wpb - w.size
    if pad:
        w = jnp.pad(w, (0, pad))
    return w.reshape(n_blocks, wpb)


@functools.partial(jax.jit, static_argnames=("wpb", "n_blocks"))
def _fp_jnp(x, wpb, n_blocks):
    # bitcast + mix + reduce in ONE jit: XLA fuses the word stream into the
    # mixer, so the uint32 view never materializes — the digest pass reads
    # the tensor once at memory bandwidth
    w2d = _words_impl(x, wpb, n_blocks)
    pos = jnp.arange(wpb, dtype=jnp.uint32)
    h = mix_words(w2d, pos)
    return fmix32(jnp.sum(h, axis=1, dtype=jnp.uint32))


@functools.partial(jax.jit, static_argnames=("wpb", "n_blocks"))
def _fp_diff_jnp(x, old_fp, wpb, n_blocks):
    fp = _fp_jnp(x, wpb, n_blocks)
    return fp, fp != old_fp


@functools.partial(jax.jit, static_argnames=("wpb", "n_blocks", "interpret"))
def _fp_pallas(x, wpb, n_blocks, interpret):
    # word-stream prep fused into the same jit as the pallas_call: for
    # 4-byte dtypes the bitcast is a free aliasing view inside XLA, so the
    # kernel reads the leaf's own buffer instead of a full-size uint32
    # temporary. (1/2-byte dtypes still pay the zero-extend to uint32 —
    # the kernel's word width — which is inherent until the widen moves
    # inside the kernel body.)
    rows = wpb // LANES
    w = _words_impl(x, wpb, n_blocks).reshape(n_blocks * rows, LANES)
    return fingerprint_blocks_2d(w, rows_per_block=rows,
                                 interpret=interpret).reshape(n_blocks)


def _single_device(x) -> bool:
    try:
        return len(x.sharding.device_set) == 1
    except AttributeError:
        return True


def fingerprint_blocks(x, *, block_bytes: int, interpret: bool = False):
    """x (device array) -> uint32[n_blocks] digests, one per ``block_bytes``
    window of its raw bytes. The digests stay on device."""
    x = jnp.asarray(x)
    if block_bytes % 4 or block_bytes < 4:
        raise ValueError(f"block_bytes must be a multiple of 4, got {block_bytes}")
    dt = np.dtype(x.dtype)
    if not supported_dtype(dt):
        raise TypeError(f"fingerprint unsupported for dtype {dt}")
    nbytes = x.size * dt.itemsize
    if nbytes == 0:
        return jnp.zeros(0, jnp.uint32)
    wpb = words_per_block(block_bytes, dt.itemsize)
    n_blocks = n_blocks_of(nbytes, block_bytes)
    rows = wpb // LANES
    if ((interpret or jax.default_backend() == "tpu") and _single_device(x)
            and wpb % LANES == 0 and 0 < rows <= MAX_BLOCK_ROWS):
        return _fp_pallas(x, wpb, n_blocks, interpret)
    return _fp_jnp(x, wpb, n_blocks)


def fingerprint_diff(x, old_fp, *, block_bytes: int, interpret: bool = False):
    """(new fingerprints, per-block changed mask) in one dispatch.

    The save path's hot call: digest + compare against the previous save's
    device-resident fingerprints without materializing anything but the two
    small output arrays. ``old_fp`` must have n_blocks entries for ``x``
    (the tracker guarantees it via its shape/dtype identity checks)."""
    x = jnp.asarray(x)
    dt = np.dtype(x.dtype)
    wpb = words_per_block(block_bytes, dt.itemsize)
    n_blocks = n_blocks_of(x.size * dt.itemsize, block_bytes)
    rows = wpb // LANES
    if ((interpret or jax.default_backend() == "tpu") and _single_device(x)
            and wpb % LANES == 0 and 0 < rows <= MAX_BLOCK_ROWS):
        fp = _fp_pallas(x, wpb, n_blocks, interpret)
        return fp, fp != old_fp
    return _fp_diff_jnp(x, old_fp, wpb, n_blocks)
