"""Pallas TPU per-block fingerprint kernel.

Grid = one program per block: each step loads one (rows, 128) uint32 window
of the word stream into VMEM, mixes every word with its position, reduces to
a single uint32 sum and writes the finalized digest to its slot of the
(n_blocks, 1) SMEM output — the save path keeps that small array device-
resident and compares it against the previous save's without any transfer.

The arithmetic is ``ref.mix_words``/``ref.fmix32`` verbatim (integer xor,
multiply, logical shift on uint32 — all wrap mod 2^32 identically on VPU,
XLA and numpy), which is what the interpret-mode parity tests pin down.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .. import compat
from .ref import fmix32, mix_words

LANES = 128
# one block's words must fit VMEM: 8192 rows x 128 lanes x 4 B = 4 MiB,
# which covers a 1 MiB chunk of int8 (the widest word expansion)
MAX_BLOCK_ROWS = 8192


def _fingerprint_kernel(w_ref, out_ref):
    rows, lanes = w_ref.shape
    r = jax.lax.broadcasted_iota(jnp.uint32, (rows, lanes), 0)
    c = jax.lax.broadcasted_iota(jnp.uint32, (rows, lanes), 1)
    pos = r * jnp.uint32(lanes) + c
    h = mix_words(w_ref[...], pos)
    out_ref[0, 0] = fmix32(jnp.sum(h, dtype=jnp.uint32))


def fingerprint_blocks_2d(w2d, *, rows_per_block: int, interpret=False):
    """(n_blocks * rows_per_block, LANES) uint32 words -> (n_blocks, 1)
    uint32 digests. Rows of one block are contiguous."""
    total_rows, cols = w2d.shape
    assert cols == LANES and total_rows % rows_per_block == 0, (
        w2d.shape, rows_per_block)
    n_blocks = total_rows // rows_per_block
    return pl.pallas_call(
        _fingerprint_kernel,
        grid=(n_blocks,),
        in_specs=[pl.BlockSpec((rows_per_block, cols), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, 1), lambda i: (i, 0),
                               memory_space=pltpu.SMEM),
        out_shape=jax.ShapeDtypeStruct((n_blocks, 1), jnp.uint32),
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(w2d)
