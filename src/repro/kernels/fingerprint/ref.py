"""Numpy reference for the per-block fingerprint digest.

One uint32 digest per ``block_bytes``-sized window of a tensor's raw bytes —
the device-resident change detector of the delta save path. The mixer is a
murmur-style integer fold chosen to be *identically computable* three ways
(vectorized numpy on host, jitted jnp, the Pallas TPU kernel), because the
save path compares digests produced on device across saves and the tests
compare all three implementations bit-for-bit:

    word_i  = i-th native-width word of the block, zero-extended to uint32
              (4-byte dtypes bitcast whole; 2-/1-byte dtypes widen per word;
              8-byte dtypes split into two uint32 words — always exactly the
              block's raw little-endian bytes)
    h_i     = ((word_i ^ (i * C1)) * C2) ; h_i ^= h_i >> 15
    digest  = fmix32( sum_i h_i  mod 2^32 )

Position is folded in via ``i * C1`` so word swaps change the digest; the
xorshift after the multiply breaks the linearity that would let paired
deltas cancel in the sum; ``fmix32`` is murmur3's finalizer. The digest is
32 bits per block: it decides which blocks *skip* the device→host copy, it
is NOT the content address — transferred blocks still get the pool's sha1
(see chunkstore). A collision (2^-32 per changed block) costs a stale block
in one checkpoint, the same failure class as any digest-based delta scheme;
blocks are additionally guarded by shape/dtype/codec identity checks.

All three implementations use python-int constants on uint32 arrays: numpy,
jnp and Pallas all keep uint32 and wrap mod 2^32, so the bytes agree.
"""

from __future__ import annotations

import numpy as np

# np.uint32 scalars, not python ints: jnp refuses weak int literals above
# int32 range, while a typed uint32 scalar mixes into numpy, jnp and Pallas
# uint32 arrays identically (wrapping mod 2^32)
C1 = np.uint32(0x9E3779B1)       # golden-ratio odd constant (position mix)
C2 = np.uint32(0x85EBCA6B)       # murmur3 fmix multiplier (word mix)
_F1 = np.uint32(0x85EBCA6B)
_F2 = np.uint32(0xC2B2AE35)


def fmix32(h):
    """murmur3 finalizer; works on numpy and jnp uint32 arrays alike."""
    h = h ^ (h >> 16)
    h = h * _F1
    h = h ^ (h >> 13)
    h = h * _F2
    h = h ^ (h >> 16)
    return h


def mix_words(w, pos):
    """Per-word mix (uint32 arrays in, uint32 out); shared with the jnp
    oracle and the Pallas kernel so the arithmetic cannot drift."""
    h = (w ^ (pos * C1)) * C2
    return h ^ (h >> 15)


def word_bytes(itemsize: int) -> int:
    """Width of one digest word for a dtype: ≤4-byte dtypes hash one word
    per element; 8-byte dtypes split each element into two uint32 words."""
    return min(int(itemsize), 4)


def words_per_block(block_bytes: int, itemsize: int) -> int:
    return block_bytes // word_bytes(itemsize)


def n_blocks_of(nbytes: int, block_bytes: int) -> int:
    return -(-int(nbytes) // int(block_bytes))


def fingerprint_blocks_ref(arr: np.ndarray, block_bytes: int) -> np.ndarray:
    """uint32[n_blocks] digest of ``arr``'s raw bytes, one per block."""
    a = np.ascontiguousarray(arr)
    it = a.dtype.itemsize
    if block_bytes % 4 or block_bytes < 4:
        raise ValueError(f"block_bytes must be a multiple of 4, got {block_bytes}")
    nbytes = a.size * it
    if nbytes == 0:
        return np.zeros(0, np.uint32)
    wb = word_bytes(it)
    w = a.reshape(-1).view(np.dtype(f"<u{wb}")).astype(np.uint32)
    wpb = words_per_block(block_bytes, it)
    n_blocks = n_blocks_of(nbytes, block_bytes)
    pad = n_blocks * wpb - w.size
    if pad:
        w = np.concatenate([w, np.zeros(pad, np.uint32)])
    w = w.reshape(n_blocks, wpb)
    pos = np.arange(wpb, dtype=np.uint32)
    h = mix_words(w, pos)
    return fmix32(np.sum(h, axis=1, dtype=np.uint32))
