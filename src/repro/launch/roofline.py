"""Roofline bookkeeping: analytic model FLOPs, HLO collective-byte parsing,
and the three roofline terms (EXPERIMENTS.md §Roofline).

All compiled artifacts on the 512-device host platform are SPMD per-device
modules, so cost_analysis()['flops'], 'bytes accessed' and parsed collective
operand bytes are PER-DEVICE quantities; with the prompt's formulas
  compute = HLO_FLOPs/(chips·peak), memory = bytes/(chips·HBM),
  collective = coll_bytes/(chips·link)
the chips factor cancels: term = per-device quantity / per-chip rate.
"""

from __future__ import annotations

import re

from ..models.config import ModelConfig, SSMConfig, RGLRUConfig
from .mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

# ---------------------------------------------------------------------------
# analytic model FLOPs (the "useful work" numerator)
# ---------------------------------------------------------------------------


def active_matmul_params(cfg: ModelConfig) -> int:
    """Per-token matmul parameters: routed experts counted at top_k (+shared),
    embedding lookup excluded, lm_head included, norms ignored."""
    D, F = cfg.d_model, cfg.d_ff
    n_mats = 3 if cfg.mlp_gated else 2
    total = 0
    kinds = cfg.layer_kinds()
    prelude = cfg.moe.dense_prelude_layers if cfg.moe else 0
    for li, kind in enumerate(kinds):
        if kind in ("global", "local"):
            total += D * cfg.q_dim + 2 * D * cfg.kv_dim + cfg.q_dim * D
            if cfg.moe is not None and li >= prelude:
                m = cfg.moe
                total += D * m.n_experts
                total += (m.top_k + m.n_shared) * n_mats * D * m.d_expert
            else:
                f = cfg.moe.d_ff_prelude if (cfg.moe and li < prelude) else F
                total += n_mats * D * f
        elif kind == "mamba":
            s = cfg.ssm or SSMConfig()
            di = s.expand * D
            dt = s.resolved_dt_rank(D)
            total += D * 2 * di + di * s.d_conv + di * (dt + 2 * s.d_state)
            total += dt * di + di * D
        elif kind == "rglru":
            r = cfg.rglru or RGLRUConfig()
            W = r.lru_width or D
            nb = r.n_blocks or cfg.n_heads
            total += 2 * D * W + W * r.d_conv + 2 * nb * (W // nb) ** 2 + W * D
            total += n_mats * D * F
    total += cfg.d_model * cfg.vocab_size  # lm head
    return total


def _attn_context_sum(cfg: ModelConfig, S: int) -> float:
    """Σ over layers of Σ_i ctx(i) for a causal prefill of length S."""
    total = 0.0
    for kind in cfg.layer_kinds():
        if kind == "global":
            total += S * (S + 1) / 2
        elif kind == "local":
            W = cfg.window or S
            if S <= W:
                total += S * (S + 1) / 2
            else:
                total += W * (W + 1) / 2 + (S - W) * W
    return total


def _scan_flops_per_token(cfg: ModelConfig) -> float:
    """Elementwise recurrence flops per token (mamba/rglru layers)."""
    total = 0.0
    for kind in cfg.layer_kinds():
        if kind == "mamba":
            s = cfg.ssm or SSMConfig()
            total += 10.0 * (s.expand * cfg.d_model) * s.d_state
        elif kind == "rglru":
            r = cfg.rglru or RGLRUConfig()
            total += 12.0 * (r.lru_width or cfg.d_model)
    return total


def model_flops(cfg: ModelConfig, *, kind: str, batch: int, seq_len: int) -> float:
    """Analytic MODEL_FLOPS for one step of the given cell (global, not
    per-device). train = 3× forward (the standard 6ND convention)."""
    N = active_matmul_params(cfg)
    if kind == "train":
        tokens = batch * seq_len
        mm = 2.0 * N * tokens
        attn = 4.0 * cfg.n_heads * cfg.head_dim * batch * _attn_context_sum(cfg, seq_len)
        scan = _scan_flops_per_token(cfg) * tokens
        return 3.0 * (mm + attn + scan)
    if kind == "prefill":
        tokens = batch * seq_len
        mm = 2.0 * N * tokens
        attn = 4.0 * cfg.n_heads * cfg.head_dim * batch * _attn_context_sum(cfg, seq_len)
        return mm + attn + _scan_flops_per_token(cfg) * tokens
    if kind == "decode":
        mm = 2.0 * N * batch
        ctx = 0.0
        for k in cfg.layer_kinds():
            if k == "global":
                ctx += seq_len
            elif k == "local":
                ctx += min(cfg.window or seq_len, seq_len)
        attn = 4.0 * cfg.n_heads * cfg.head_dim * batch * ctx
        return mm + attn + _scan_flops_per_token(cfg) * batch
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# HLO collective parsing
# ---------------------------------------------------------------------------

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLL_KINDS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute", "ragged-all-to-all")
_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_bytes(hlo_text: str) -> dict[str, float]:
    """Per-device operand bytes of every collective op, by kind + count."""
    out = {k: 0.0 for k in _COLL_KINDS}
    counts = {k: 0 for k in _COLL_KINDS}
    for line in hlo_text.splitlines():
        for kind in _COLL_KINDS:
            tok = f" {kind}("
            tok_start = f" {kind}-start("
            if tok in line:
                opname = tok
            elif tok_start in line:
                opname = tok_start
            else:
                continue
            operands = line.split(opname, 1)[1].split(")", 1)[0]
            for dt, dims in _SHAPE_RE.findall(operands):
                out[kind] += _shape_bytes(dt, dims)
            counts[kind] += 1
            break
    total = sum(out.values())
    return {"by_kind": out, "counts": counts, "total": total}


# ---------------------------------------------------------------------------
# roofline terms
# ---------------------------------------------------------------------------

def roofline_terms(*, per_device_flops: float, per_device_bytes: float,
                   per_device_coll_bytes: float) -> dict:
    compute_s = per_device_flops / PEAK_FLOPS_BF16
    memory_s = per_device_bytes / HBM_BW
    coll_s = per_device_coll_bytes / ICI_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": coll_s}
    dominant = max(terms, key=terms.get)
    bound_s = terms[dominant]
    return {**terms, "dominant": dominant.replace("_s", ""),
            "bound_s": bound_s}
