"""Production mesh construction. TPU v5e pod targets: 16×16 = 256 chips/pod
("data", "model"); multi-pod 2×16×16 = 512 chips ("pod", "data", "model").

A FUNCTION, not a module constant — importing this module must never touch
jax device state (the dry-run pins the device count before first jax init).
"""

from __future__ import annotations

import jax

# TPU v5e hardware constants (roofline denominators)
PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link


def make_mesh(shape, axes, *, devices=None):
    """``jax.make_mesh`` across the AxisType API drift.

    Newer JAX grew ``jax.sharding.AxisType`` and an ``axis_types`` kwarg on
    ``make_mesh`` (explicit-sharding meshes); 0.4.x has neither. We always
    want the default Auto axes, so pass the kwarg only where it exists —
    probed once on the live module, not by version string.
    """
    kwargs = {}
    if devices is not None:
        kwargs["devices"] = devices
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        kwargs["axis_types"] = (axis_type.Auto,) * len(axes)
    return jax.make_mesh(tuple(shape), tuple(axes), **kwargs)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def mesh_info(mesh) -> dict:
    return {"shape": [int(s) for s in mesh.devices.shape],
            "axes": list(mesh.axis_names)}
