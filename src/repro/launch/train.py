"""Production training entrypoint: Spot-on-protected training of any assigned
architecture.

    PYTHONPATH=src python -m repro.launch.train \
        --arch gemma3-1b --smoke --steps 200 --ckpt-dir /nfs/ckpts \
        --mode transparent --interval 300 --simulate-eviction-every 3600

On a real cluster this runs under the pod scheduler with a real metadata
backend; in this container `--smoke` selects the reduced config and the
simulated cloud so the full eviction→termination-checkpoint→restore loop is
exercised end-to-end on CPU. All Spot-on machinery (coordinator, atomic
sharded store, async writer, scale-set replacement, cost accounting) is the
production code path either way.
"""

from __future__ import annotations

import argparse
import json
import os


def setup_compilation_cache(cache_dir: str) -> bool:
    """Point XLA's persistent compilation cache at a directory that survives
    instance replacement (the checkpoint volume is the natural home).

    This is the compile leg of the fast-resume pipeline: a replacement
    instance deserializes the step executable from the shared cache instead
    of re-running XLA passes, so `SpotTrainer.resume`'s overlapped
    precompile degenerates to a disk read. Thresholds are zeroed because on
    a spot fleet *every* recompile sits inside the MTTR window. Best-effort
    across JAX versions; returns False when unsupported.
    """
    import jax

    try:
        os.makedirs(cache_dir, exist_ok=True)
        jax.config.update("jax_compilation_cache_dir", cache_dir)
        for knob, val in (("jax_persistent_cache_min_compile_time_secs", 0.0),
                          ("jax_persistent_cache_min_entry_size_bytes", 0)):
            try:
                jax.config.update(knob, val)
            except (AttributeError, ValueError):  # knob renamed/absent
                pass
        return True
    except (AttributeError, ValueError, OSError):
        try:  # pre-config-flag JAX: explicit initializer API
            from jax.experimental.compilation_cache import compilation_cache
            compilation_cache.set_cache_dir(cache_dir)
            return True
        except Exception:
            return False


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--stages", type=int, default=5)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--ckpt-dir", default="/tmp/spoton_ckpts")
    ap.add_argument("--mode", choices=["off", "application", "transparent"],
                    default="transparent")
    ap.add_argument("--interval", type=float, default=60.0,
                    help="periodic transparent-checkpoint interval (s)")
    ap.add_argument("--simulate-eviction-every", type=float, default=0.0,
                    help="inject an eviction every N seconds (0 = none)")
    ap.add_argument("--provision-delay", type=float, default=5.0)
    ap.add_argument("--quantize-moments", type=int, default=0)
    ap.add_argument("--compile-cache-dir", default="",
                    help="persistent XLA compilation cache (e.g. a dir on "
                         "the checkpoint volume); empty disables")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--remat", default="none")
    ap.add_argument("--microbatches", type=int, default=1)
    args = ap.parse_args(argv)

    if args.compile_cache_dir:
        setup_compilation_cache(args.compile_cache_dir)

    from ..configs import get_config, get_smoke_config
    from ..checkpoint import CheckpointStore
    from ..core import (AZURE_D8S_V3, CheckpointPolicy, CostAccountant, Mode,
                        NoEviction, PeriodicEviction, ScaleSet,
                        SpotOnCoordinator, StragglerDetector, WallClock)
    from ..optim import AdamWConfig
    from ..train import SpotTrainer, TrainJob

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    clock = WallClock()
    accountant = CostAccountant(AZURE_D8S_V3)
    schedule = PeriodicEviction(args.simulate_eviction_every) \
        if args.simulate_eviction_every else NoEviction()
    pool = ScaleSet(clock=clock, schedule=schedule, accountant=accountant,
                    provisioning_delay_s=args.provision_delay)
    store = CheckpointStore(args.ckpt_dir,
                            quantize_moments=bool(args.quantize_moments))
    policy = {
        "off": CheckpointPolicy.off(),
        "application": CheckpointPolicy.application(),
        "transparent": CheckpointPolicy.transparent(args.interval),
    }[args.mode]
    coord = SpotOnCoordinator(store, policy, clock,
                              straggler=StragglerDetector())
    job = TrainJob(cfg=cfg, opt=AdamWConfig(total_steps=args.steps),
                   total_steps=args.steps, n_stages=args.stages,
                   batch=args.batch, seq_len=args.seq_len, seed=args.seed,
                   remat=args.remat, microbatches=args.microbatches)
    trainer = SpotTrainer(job, coord, pool, clock)
    report = trainer.run()
    coord.close()
    summary = {
        "arch": cfg.name, "completed": report.completed,
        "total_time_s": round(report.total_time_s, 2),
        "final_loss": report.final_loss,
        "steps_executed": report.steps_executed,
        "lost_steps": report.lost_steps,
        "restores": report.restores,
        "instances_used": report.instances_used,
        "evictions": report.evictions_seen,
        "coordinator": report.coordinator,
        "cost": accountant.summary(clock.now()),
    }
    print(json.dumps(summary, indent=1))
    return 0 if report.completed else 1


if __name__ == "__main__":
    raise SystemExit(main())
