"""Production training entrypoint: Spot-on-protected training of any assigned
architecture.

    PYTHONPATH=src python -m repro.launch.train \
        --arch gemma3-1b --smoke --steps 200 --ckpt-dir /nfs/ckpts \
        --mode transparent --interval 300 --simulate-eviction-every 3600

On a real cluster this runs under the pod scheduler with a real metadata
backend; in this container `--smoke` selects the reduced config and the
simulated cloud so the full eviction→termination-checkpoint→restore loop is
exercised end-to-end on CPU. All Spot-on machinery (coordinator, atomic
sharded store, async writer, scale-set replacement, cost accounting) is the
production code path either way.
"""

from __future__ import annotations

import argparse
import json
import os


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--stages", type=int, default=5)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--ckpt-dir", default="/tmp/spoton_ckpts")
    ap.add_argument("--mode", choices=["off", "application", "transparent"],
                    default="transparent")
    ap.add_argument("--interval", type=float, default=60.0,
                    help="periodic transparent-checkpoint interval (s)")
    ap.add_argument("--simulate-eviction-every", type=float, default=0.0,
                    help="inject an eviction every N seconds (0 = none)")
    ap.add_argument("--provision-delay", type=float, default=5.0)
    ap.add_argument("--quantize-moments", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--remat", default="none")
    ap.add_argument("--microbatches", type=int, default=1)
    args = ap.parse_args(argv)

    from ..configs import get_config, get_smoke_config
    from ..checkpoint import CheckpointStore
    from ..core import (AZURE_D8S_V3, CheckpointPolicy, CostAccountant, Mode,
                        NoEviction, PeriodicEviction, ScaleSet,
                        SpotOnCoordinator, StragglerDetector, WallClock)
    from ..optim import AdamWConfig
    from ..train import SpotTrainer, TrainJob

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    clock = WallClock()
    accountant = CostAccountant(AZURE_D8S_V3)
    schedule = PeriodicEviction(args.simulate_eviction_every) \
        if args.simulate_eviction_every else NoEviction()
    pool = ScaleSet(clock=clock, schedule=schedule, accountant=accountant,
                    provisioning_delay_s=args.provision_delay)
    store = CheckpointStore(args.ckpt_dir,
                            quantize_moments=bool(args.quantize_moments))
    policy = {
        "off": CheckpointPolicy.off(),
        "application": CheckpointPolicy.application(),
        "transparent": CheckpointPolicy.transparent(args.interval),
    }[args.mode]
    coord = SpotOnCoordinator(store, policy, clock,
                              straggler=StragglerDetector())
    job = TrainJob(cfg=cfg, opt=AdamWConfig(total_steps=args.steps),
                   total_steps=args.steps, n_stages=args.stages,
                   batch=args.batch, seq_len=args.seq_len, seed=args.seed,
                   remat=args.remat, microbatches=args.microbatches)
    trainer = SpotTrainer(job, coord, pool, clock)
    report = trainer.run()
    coord.close()
    summary = {
        "arch": cfg.name, "completed": report.completed,
        "total_time_s": round(report.total_time_s, 2),
        "final_loss": report.final_loss,
        "steps_executed": report.steps_executed,
        "lost_steps": report.lost_steps,
        "restores": report.restores,
        "instances_used": report.instances_used,
        "evictions": report.evictions_seen,
        "coordinator": report.coordinator,
        "cost": accountant.summary(clock.now()),
    }
    print(json.dumps(summary, indent=1))
    return 0 if report.completed else 1


if __name__ == "__main__":
    raise SystemExit(main())
