"""Production training entrypoint: Spot-on-protected training of any assigned
architecture.

    PYTHONPATH=src python -m repro.launch.train \
        --arch gemma3-1b --smoke --steps 200 --ckpt-dir /nfs/ckpts \
        --mode transparent --interval 300 --simulate-eviction-every 3600

On a real cluster this runs under the pod scheduler with a real metadata
backend; in this container `--smoke` selects the reduced config and the
simulated cloud so the full eviction→termination-checkpoint→restore loop is
exercised end-to-end on CPU. All Spot-on machinery (coordinator, atomic
sharded store, async writer, scale-set replacement, cost accounting) is the
production code path either way.
"""

from __future__ import annotations

import argparse
import json
import os
import time

# compile-cache retention knobs: same shape as the checkpoint store's sweep
# (age gate first, then a size budget), tuned for a shared volume that many
# fleet members write executables into
CACHE_GC_MAX_BYTES = 2 << 30          # 2 GiB of cached executables
CACHE_GC_MAX_AGE_S = 14 * 86400       # entries idle two weeks are dead weight
CACHE_GC_MIN_INTERVAL_S = 300.0       # walk the dir at most once per 5 min

_last_cache_gc = 0.0


def sweep_compilation_cache(cache_dir: str, *,
                            max_bytes: int = CACHE_GC_MAX_BYTES,
                            max_age_s: float = CACHE_GC_MAX_AGE_S,
                            min_interval_s: float = CACHE_GC_MIN_INTERVAL_S,
                            ) -> int:
    """Size/age-gated gc of the persistent XLA compilation cache.

    The cache dir on the shared checkpoint volume grows without bound (every
    new model config / jax version adds executables; nothing ever removes
    them). Retention mirrors the checkpoint store's pool sweep: entries past
    the age gate go first (mtime refreshes on cache hits, so "old" means
    *unused*), then the oldest entries beyond the size budget. Runs
    opportunistically after checkpoint commits (``CheckpointStore.post_commit``)
    and rate-limits itself so the directory walk never becomes a per-save
    cost. Best-effort throughout — a janitor must never fail a save. Returns
    bytes removed.
    """
    import stat as stat_mod

    global _last_cache_gc
    now = time.time()
    if min_interval_s > 0 and now - _last_cache_gc < min_interval_s:
        return 0
    _last_cache_gc = now
    entries = []       # (mtime, size, path)
    try:
        for name in os.listdir(cache_dir):
            path = os.path.join(cache_dir, name)
            try:
                st = os.stat(path)
            except OSError:
                continue
            if stat_mod.S_ISREG(st.st_mode):   # one stat per entry, no TOCTOU
                entries.append((st.st_mtime, st.st_size, path))
    except OSError:
        return 0
    removed = 0

    def _rm(size: int, path: str) -> int:
        try:
            os.remove(path)
            return size
        except OSError:
            return 0

    entries.sort()                      # oldest first
    kept = []
    for mtime, size, path in entries:
        if now - mtime > max_age_s:
            removed += _rm(size, path)
        else:
            kept.append((mtime, size, path))
    total = sum(size for _, size, _ in kept)
    for mtime, size, path in kept:      # oldest-first until under budget
        if total <= max_bytes:
            break
        removed += _rm(size, path)
        total -= size
    return removed


def setup_compilation_cache(cache_dir: str) -> bool:
    """Point XLA's persistent compilation cache at a directory that survives
    instance replacement (the checkpoint volume is the natural home).

    This is the compile leg of the fast-resume pipeline: a replacement
    instance deserializes the step executable from the shared cache instead
    of re-running XLA passes, so `SpotTrainer.resume`'s overlapped
    precompile degenerates to a disk read. Thresholds are zeroed because on
    a spot fleet *every* recompile sits inside the MTTR window. Best-effort
    across JAX versions; returns False when unsupported.
    """
    import jax

    try:
        os.makedirs(cache_dir, exist_ok=True)
        jax.config.update("jax_compilation_cache_dir", cache_dir)
        for knob, val in (("jax_persistent_cache_min_compile_time_secs", 0.0),
                          ("jax_persistent_cache_min_entry_size_bytes", 0)):
            try:
                jax.config.update(knob, val)
            except (AttributeError, ValueError):  # knob renamed/absent
                pass
        return True
    except (AttributeError, ValueError, OSError):
        try:  # pre-config-flag JAX: explicit initializer API
            from jax.experimental.compilation_cache import compilation_cache
            compilation_cache.set_cache_dir(cache_dir)
            return True
        except Exception:
            return False


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--stages", type=int, default=5)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--ckpt-dir", default="/tmp/spoton_ckpts")
    ap.add_argument("--mode", choices=["off", "application", "transparent"],
                    default="transparent")
    ap.add_argument("--interval", type=float, default=60.0,
                    help="periodic transparent-checkpoint interval (s)")
    ap.add_argument("--simulate-eviction-every", type=float, default=0.0,
                    help="inject an eviction every N seconds (0 = none)")
    ap.add_argument("--provision-delay", type=float, default=5.0)
    ap.add_argument("--quantize-moments", type=int, default=0)
    ap.add_argument("--compile-cache-dir", default="",
                    help="persistent XLA compilation cache (e.g. a dir on "
                         "the checkpoint volume); empty disables")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--remat", default="none")
    ap.add_argument("--microbatches", type=int, default=1)
    args = ap.parse_args(argv)

    if args.compile_cache_dir:
        setup_compilation_cache(args.compile_cache_dir)

    from ..configs import get_config, get_smoke_config
    from ..checkpoint import CheckpointStore
    from ..core import (AZURE_D8S_V3, CheckpointPolicy, CostAccountant, Mode,
                        NoEviction, PeriodicEviction, ScaleSet,
                        SpotOnCoordinator, StragglerDetector, WallClock)
    from ..optim import AdamWConfig
    from ..train import SpotTrainer, TrainJob

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    clock = WallClock()
    accountant = CostAccountant(AZURE_D8S_V3)
    schedule = PeriodicEviction(args.simulate_eviction_every) \
        if args.simulate_eviction_every else NoEviction()
    pool = ScaleSet(clock=clock, schedule=schedule, accountant=accountant,
                    provisioning_delay_s=args.provision_delay)
    store = CheckpointStore(args.ckpt_dir,
                            quantize_moments=bool(args.quantize_moments))
    if args.compile_cache_dir:
        # cache hygiene rides the checkpoint cadence: after each commit the
        # (rate-limited) sweep keeps the shared cache dir inside its
        # size/age budget — off the save's critical path, never fatal
        store.post_commit.append(
            lambda d=args.compile_cache_dir: sweep_compilation_cache(d))
    policy = {
        "off": CheckpointPolicy.off(),
        "application": CheckpointPolicy.application(),
        "transparent": CheckpointPolicy.transparent(args.interval),
    }[args.mode]
    coord = SpotOnCoordinator(store, policy, clock,
                              straggler=StragglerDetector())
    job = TrainJob(cfg=cfg, opt=AdamWConfig(total_steps=args.steps),
                   total_steps=args.steps, n_stages=args.stages,
                   batch=args.batch, seq_len=args.seq_len, seed=args.seed,
                   remat=args.remat, microbatches=args.microbatches)
    trainer = SpotTrainer(job, coord, pool, clock)
    report = trainer.run()
    coord.close()
    summary = {
        "arch": cfg.name, "completed": report.completed,
        "total_time_s": round(report.total_time_s, 2),
        "final_loss": report.final_loss,
        "steps_executed": report.steps_executed,
        "lost_steps": report.lost_steps,
        "restores": report.restores,
        "instances_used": report.instances_used,
        "evictions": report.evictions_seen,
        "coordinator": report.coordinator,
        "cost": accountant.summary(clock.now()),
    }
    print(json.dumps(summary, indent=1))
    return 0 if report.completed else 1


if __name__ == "__main__":
    raise SystemExit(main())
