import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture × input-shape × mesh)
cell against the production mesh with ShapeDtypeStruct stand-ins (no
allocation), then extract memory_analysis / cost_analysis / collective bytes
for the roofline table.

The two lines above MUST precede every other import: jax locks the device
count at first init, and the dry-run needs 512 placeholder host devices for
jax.make_mesh. Run as
    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma3-1b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
Results land in results/dryrun/<tag>/<mesh>/<arch>__<shape>.json.
`--all` executes each cell in a subprocess (compiler memory isolation on the
1-core container) and skips cells whose JSON already exists.
"""

import argparse
import json
import subprocess
import sys
import time
import traceback

import jax

from ..configs import SHAPES, ARCH_IDS, cell_is_runnable, get_config, resolve
from ..distributed.sharding import (ShardingRules, cache_shardings,
                                    tree_shardings, use_sharding_rules)
from ..models.config import ModelConfig
from ..models.model import cache_specs, input_specs, param_specs
from ..optim import AdamWConfig
from ..serve.serve_step import make_decode_step, make_prefill
from ..train.train_step import init_train_state, make_train_step
from .hlo_analysis import analyze
from .mesh import make_production_mesh, mesh_info
from .roofline import model_flops, roofline_terms

from jax.sharding import NamedSharding, PartitionSpec as P

# Baseline per-arch lowering knobs (§Perf changes these and re-measures).
# fsdp applies to train cells; fsdp_inference to prefill/decode cells (serving
# wants TP-only weights unless the model cannot fit one chip row: >=100B).
ARCH_DEFAULTS = {
    "command_r_plus_104b": dict(fsdp=True, fsdp_inference=True, remat="full", microbatches=8, seq_parallel=True),
    "grok1_314b": dict(fsdp=True, fsdp_inference=True, remat="full", microbatches=8, seq_parallel=True),
    "llava_next_34b": dict(fsdp=True, fsdp_inference=False, remat="full", microbatches=4, seq_parallel=True),
    "minitron_8b": dict(fsdp=True, remat="dots", microbatches=4),
    "deepseek_moe_16b": dict(fsdp=True, remat="dots", microbatches=2),
    "falcon_mamba_7b": dict(fsdp=True, remat="full", microbatches=4),
    "musicgen_medium": dict(fsdp=False, remat="dots", microbatches=4),
    "gemma3_1b": dict(fsdp=False, remat="dots", microbatches=4),
    "phi3_mini_3p8b": dict(fsdp=True, remat="dots", microbatches=4),
    "recurrentgemma_2b": dict(fsdp=False, remat="dots", microbatches=4),
}


def _knobs(arch: str, args, kind: str = "train") -> dict:
    k = dict(ARCH_DEFAULTS.get(arch, dict(fsdp=False, remat="dots", microbatches=1)))
    k.setdefault("seq_parallel", False)
    k.setdefault("fused_ce", True)
    k.setdefault("fsdp_inference", False)
    if kind != "train":
        k["fsdp"] = k.pop("fsdp_inference")
    else:
        k.pop("fsdp_inference")
    if args.remat is not None:
        k["remat"] = args.remat
    if args.microbatches is not None:
        k["microbatches"] = args.microbatches
    if args.fsdp is not None:
        k["fsdp"] = bool(args.fsdp)
    if args.seq_parallel is not None:
        k["seq_parallel"] = bool(args.seq_parallel)
    if args.fused_ce is not None:
        k["fused_ce"] = bool(args.fused_ce)
    if args.pure_fsdp is not None:
        k["pure_fsdp"] = bool(args.pure_fsdp)
    k.setdefault("pure_fsdp", False)
    if args.factored_opt is not None:
        k["factored_opt"] = bool(args.factored_opt)
    k.setdefault("factored_opt", False)
    return k


def _rules(mesh, knobs) -> ShardingRules:
    if knobs.get("pure_fsdp"):
        # full-mesh data parallelism: every axis carries batch; weights are
        # ZeRO-3-sharded over the same combined axis set
        data_axes = tuple(mesh.axis_names)
    else:
        data_axes = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    return ShardingRules(mesh, data_axes=data_axes, fsdp=knobs["fsdp"],
                         seq_parallel=knobs["seq_parallel"],
                         pure_fsdp=knobs.get("pure_fsdp", False))


def _batch_shardings(rules, specs):
    def shard(s):
        dp = rules.dp_axes_for(s.shape[0]) if s.ndim >= 1 else None
        return NamedSharding(rules.mesh, P(dp, *([None] * (s.ndim - 1)))) \
            if s.ndim >= 1 else NamedSharding(rules.mesh, P())
    return {k: shard(v) for k, v in specs.items()}


def lower_cell(cfg: ModelConfig, shape_name: str, mesh, knobs: dict):
    """Build + lower + compile the step function for one cell.
    Returns (lowered, compiled, extras)."""
    sh = SHAPES[shape_name]
    kind, S, B = sh["kind"], sh["seq_len"], sh["global_batch"]
    rules = _rules(mesh, knobs)

    with use_sharding_rules(rules), mesh:
        if kind == "train":
            opt_cfg = AdamWConfig(total_steps=10_000,
                                  factored_second_moment=knobs.get("factored_opt", False))
            step = make_train_step(cfg, opt_cfg, remat=knobs["remat"],
                                   microbatches=knobs["microbatches"],
                                   fused_ce=knobs.get("fused_ce", True))
            state_shapes = jax.eval_shape(lambda: init_train_state(cfg, opt_cfg, 0))
            state_sh = tree_shardings(state_shapes, rules)
            in_specs = input_specs(cfg, kind="train", seq_len=S, batch=B)
            batch_sh = _batch_shardings(rules, in_specs)
            jitted = jax.jit(step, in_shardings=(state_sh, batch_sh),
                             out_shardings=(state_sh, None), donate_argnums=(0,))
            lowered = jitted.lower(state_shapes, in_specs)
        elif kind == "prefill":
            fn = make_prefill(cfg)
            p_shapes = param_specs(cfg)
            p_sh = tree_shardings(p_shapes, rules)
            in_specs = input_specs(cfg, kind="prefill", seq_len=S, batch=B)
            batch_sh = _batch_shardings(rules, in_specs)
            c_shapes = jax.eval_shape(lambda: cache_specs(cfg, B, S))
            c_sh = cache_shardings(c_shapes, rules)
            jitted = jax.jit(fn, in_shardings=(p_sh, batch_sh["inputs"]),
                             out_shardings=(None, c_sh, None))
            lowered = jitted.lower(p_shapes, in_specs["inputs"])
        elif kind == "decode":
            fn = make_decode_step(cfg)
            p_shapes = param_specs(cfg)
            p_sh = tree_shardings(p_shapes, rules)
            in_specs = input_specs(cfg, kind="decode", seq_len=S, batch=B)
            c_shapes = cache_specs(cfg, B, S)
            c_sh = cache_shardings(c_shapes, rules)
            tok_sh = _batch_shardings(rules, {"x": in_specs["inputs"]})["x"]
            pos_sh = NamedSharding(rules.mesh, P())
            jitted = jax.jit(fn, in_shardings=(p_sh, tok_sh, c_sh, pos_sh),
                             out_shardings=(None, None, c_sh),
                             donate_argnums=(2,))
            lowered = jitted.lower(p_shapes, in_specs["inputs"], c_shapes,
                                   in_specs["pos"])
        else:
            raise ValueError(kind)
        compiled = lowered.compile()
    return lowered, compiled


def _mem_dict(compiled) -> dict:
    ma = compiled.memory_analysis()
    out = {}
    for f in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes"):
        v = getattr(ma, f, None)
        if v is not None:
            out[f] = int(v)
    out["live_bytes"] = (out.get("argument_size_in_bytes", 0)
                         + out.get("output_size_in_bytes", 0)
                         + out.get("temp_size_in_bytes", 0)
                         - out.get("alias_size_in_bytes", 0))
    return out


def run_cell(arch: str, shape_name: str, mesh_kind: str, args) -> dict:
    arch = resolve(arch)
    cfg = get_config(arch)
    runnable, reason = cell_is_runnable(cfg, shape_name)
    if not runnable:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                "status": "skipped", "reason": reason}
    knobs = _knobs(arch, args, SHAPES[shape_name]["kind"])
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_chips = mesh.devices.size
    t0 = time.time()
    lowered, compiled = lower_cell(cfg, shape_name, mesh, knobs)
    compile_s = time.time() - t0
    # trip-count-aware analysis (cost_analysis counts loop bodies once; see
    # launch/hlo_analysis.py) — all quantities are PER-DEVICE (SPMD module).
    hlo = analyze(compiled.as_text())
    flops = float(hlo["flops"])
    bytes_accessed = float(hlo["bytes"])
    xla_raw = compiled.cost_analysis() or {}
    sh = SHAPES[shape_name]
    mf = model_flops(cfg, kind=sh["kind"], batch=sh["global_batch"],
                     seq_len=sh["seq_len"])
    terms = roofline_terms(per_device_flops=flops,
                           per_device_bytes=bytes_accessed,
                           per_device_coll_bytes=hlo["collective_bytes"])
    hlo_flops_global = flops * n_chips
    result = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "mesh_info": mesh_info(mesh), "status": "ok",
        "knobs": knobs, "compile_s": compile_s,
        "n_chips": n_chips,
        "per_device": {
            "hlo_flops": flops,
            "hlo_bytes": bytes_accessed,
            "collective_bytes": hlo["collective_bytes"],
            "collectives": hlo["collectives"],
            "collective_counts": hlo["collective_counts"],
            "xla_raw_flops": float(xla_raw.get("flops", 0.0)),
            "xla_raw_bytes": float(xla_raw.get("bytes accessed", 0.0)),
        },
        "memory": _mem_dict(compiled),
        "model_flops": mf,
        "hlo_flops_global": hlo_flops_global,
        "useful_flops_frac": (mf / hlo_flops_global) if hlo_flops_global else None,
        "roofline": terms,
    }
    return result


def _out_path(args, mesh_kind, arch, shape_name):
    d = os.path.join(args.out, args.tag, mesh_kind)
    os.makedirs(d, exist_ok=True)
    return os.path.join(d, f"{resolve(arch)}__{shape_name}.json")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--remat", default=None)
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--fsdp", type=int, default=None)
    ap.add_argument("--seq-parallel", type=int, default=None)
    ap.add_argument("--fused-ce", type=int, default=None)
    ap.add_argument("--pure-fsdp", type=int, default=None)
    ap.add_argument("--factored-opt", type=int, default=None)
    ap.add_argument("--timeout", type=float, default=1800.0)
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args(argv)

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    if args.all:
        cells = [(a, s, m) for m in meshes for a in ARCH_IDS for s in SHAPES]
        failures = []
        for arch, shape_name, mesh_kind in cells:
            path = _out_path(args, mesh_kind, arch, shape_name)
            if os.path.exists(path) and not args.force:
                print(f"[skip-cached] {mesh_kind}/{arch}/{shape_name}")
                continue
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", arch, "--shape", shape_name, "--mesh", mesh_kind,
                   "--out", args.out, "--tag", args.tag]
            for flag, val in (("--remat", args.remat),
                              ("--microbatches", args.microbatches),
                              ("--fsdp", args.fsdp),
                              ("--seq-parallel", args.seq_parallel),
                              ("--fused-ce", args.fused_ce)):
                if val is not None:
                    cmd += [flag, str(val)]
            print(f"[run] {mesh_kind}/{arch}/{shape_name}", flush=True)
            try:
                rc = subprocess.run(cmd, timeout=args.timeout).returncode
            except subprocess.TimeoutExpired:
                rc = -9
            if rc != 0:
                failures.append((mesh_kind, arch, shape_name, rc))
                with open(path, "w") as f:
                    json.dump({"arch": arch, "shape": shape_name,
                               "mesh": mesh_kind, "status": "failed",
                               "returncode": rc}, f)
        print(f"done; {len(failures)} failures: {failures}")
        sys.exit(1 if failures else 0)

    assert args.arch and args.shape, "--arch and --shape (or --all) required"
    for mesh_kind in meshes:
        path = _out_path(args, mesh_kind, args.arch, args.shape)
        try:
            result = run_cell(args.arch, args.shape, mesh_kind, args)
        except Exception:
            traceback.print_exc()
            result = {"arch": resolve(args.arch), "shape": args.shape,
                      "mesh": mesh_kind, "status": "error",
                      "error": traceback.format_exc()[-2000:]}
        with open(path, "w") as f:
            json.dump(result, f, indent=1)
        status = result["status"]
        if status == "ok":
            r = result["roofline"]
            print(f"{mesh_kind}/{result['arch']}/{args.shape}: OK "
                  f"compile={result['compile_s']:.0f}s "
                  f"compute={r['compute_s']:.3e}s memory={r['memory_s']:.3e}s "
                  f"coll={r['collective_s']:.3e}s dominant={r['dominant']} "
                  f"useful={result['useful_flops_frac'] and round(result['useful_flops_frac'],3)} "
                  f"live={result['memory']['live_bytes']/2**30:.2f}GiB/dev")
        else:
            print(f"{mesh_kind}/{result['arch']}/{args.shape}: {status}")
            if status == "error":
                sys.exit(1)


if __name__ == "__main__":
    main()
