"""Trip-count-aware HLO cost analysis.

XLA's HloCostAnalysis (what `compiled.cost_analysis()` reports) counts a
while-loop body ONCE — under scan-over-layers every per-layer matmul, byte and
collective is under-counted by the trip count (64× for a 64-layer model). This
module re-derives flops / bytes-accessed / collective bytes from the
post-optimization HLO text, walking the computation graph with while bodies
multiplied by their static trip counts (jax scan lowers to `while` whose
condition compares the induction variable against a constant).

Conventions follow HloCostAnalysis where it is correct:
  * dot flops = 2 · prod(output dims) · prod(lhs contracting dims)
  * bytes accessed per op = operand bytes + output bytes; fusions are counted
    at the fusion boundary (internals are register traffic, not HBM), except
    dots inside fusion bodies still count as flops
  * collective bytes = operand bytes of all-reduce / all-gather /
    reduce-scatter / all-to-all / collective-permute (and -start forms)

Validated against unrolled references in tests/test_hlo_analysis.py.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e4m3b11fnuz": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_COMMENT_RE = re.compile(r"/\*.*?\*/")
_ASSIGN_RE = re.compile(r"^(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_OPCODE_RE = re.compile(r"([a-zA-Z][\w\-]*)\(")
_COMP_HDR_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->.*\{")
_COLL_KINDS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute", "ragged-all-to-all")
_SKIP_OPS = {"parameter", "constant", "get-tuple-element", "tuple", "bitcast",
             "after-all", "partition-id", "replica-id", "iota", "rng-bit-generator"}


def _shapes_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _first_shape_dims(text: str) -> tuple[int, ...] | None:
    m = _SHAPE_RE.search(text)
    if not m:
        return None
    return tuple(int(d) for d in m.group(2).split(",") if d)


@dataclass
class Costs:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_by_kind: dict = field(default_factory=dict)
    coll_counts: dict = field(default_factory=dict)

    def add(self, o: "Costs", scale: float = 1.0):
        self.flops += o.flops * scale
        self.bytes += o.bytes * scale
        self.coll_bytes += o.coll_bytes * scale
        for k, v in o.coll_by_kind.items():
            self.coll_by_kind[k] = self.coll_by_kind.get(k, 0.0) + v * scale
        for k, v in o.coll_counts.items():
            self.coll_counts[k] = self.coll_counts.get(k, 0) + v * scale


@dataclass
class _Op:
    name: str
    ret: str
    opcode: str
    operands: list[str]
    attrs: str
    line: str
    # inline operand types ("f32[4,128]{1,0}"), parallel to `operands`; None
    # when the HLO printer emitted bare names (older XLA elides them)
    operand_types: list[str | None] = field(default_factory=list)


_OPERAND_NAME_RE = re.compile(r"%?([\w.\-]+)$")


def _split_call(rest: str) -> tuple[str, str, list[str], list[str | None], str] | None:
    """Parse ``<ret-type> opcode(operand, ...) attrs`` with balanced parens.

    Operand lists may contain tuple types — ``(s32[], f32[4,2]{1,0}) %arg`` —
    so both the closing paren and the operand separators must be found at
    bracket depth 0, not by naive ``split``. Each operand is ``[type] %name``
    (type optional depending on the XLA printer's verbosity).
    """
    mo = _OPCODE_RE.search(rest)
    if not mo:
        return None
    opcode = mo.group(1)
    ret = rest[:mo.start()].strip()
    depth = 1
    i = mo.end()
    j = i
    while j < len(rest) and depth:
        c = rest[j]
        if c in "([{":
            depth += 1
        elif c in ")]}":
            depth -= 1
        j += 1
    operand_text = rest[i:j - 1]
    attrs = rest[j:]
    names: list[str] = []
    types: list[str | None] = []
    depth = 0
    start = 0
    pieces = []
    for k, c in enumerate(operand_text):
        if c in "([{":
            depth += 1
        elif c in ")]}":
            depth -= 1
        elif c == "," and depth == 0:
            pieces.append(operand_text[start:k])
            start = k + 1
    pieces.append(operand_text[start:])
    for piece in pieces:
        piece = piece.strip()
        if not piece:
            continue
        mn = _OPERAND_NAME_RE.search(piece)
        if not mn:
            continue
        names.append(mn.group(1))
        prefix = piece[:mn.start()].rstrip().rstrip("%").rstrip()
        types.append(prefix or None)
    return ret, opcode, names, types, attrs


class HloModule:
    def __init__(self, text: str):
        self.computations: dict[str, list[_Op]] = {}
        self.symtab: dict[str, dict[str, str]] = {}  # comp -> name -> ret type
        self.entry: str | None = None
        cur = None
        for raw in text.splitlines():
            line = raw.strip()
            if not line or line.startswith("//"):
                continue
            hdr = _COMP_HDR_RE.match(line)
            if hdr:
                cur = hdr.group(2)
                self.computations[cur] = []
                self.symtab[cur] = {}
                if hdr.group(1):
                    self.entry = cur
                # parameters from the signature: "name: f32[...]"
                for pname, ptype in re.findall(r"%?([\w.\-]+)\s*:\s*([^,)]+)",
                                               hdr.group(3)):
                    self.symtab[cur][pname] = ptype
                continue
            if line.startswith("}"):
                cur = None
                continue
            if cur is None:
                continue
            line_nc = _COMMENT_RE.sub("", line)
            m = _ASSIGN_RE.match(line_nc)
            if not m:
                continue
            name, rest = m.groups()
            parsed = _split_call(rest)
            if parsed is None:
                continue
            ret, opcode, ops, op_types, attrs = parsed
            self.computations[cur].append(_Op(name, ret, opcode, ops,
                                              attrs, line_nc, op_types))
            self.symtab[cur][name] = ret
        self._memo: dict[str, Costs] = {}

    # -- helpers ----------------------------------------------------------------

    def _operand_type(self, comp: str, op: _Op, i: int) -> str:
        """Type text of operand i: inline annotation first, symtab fallback."""
        if i >= len(op.operands):
            return ""
        if i < len(op.operand_types) and op.operand_types[i]:
            return op.operand_types[i]
        return self.symtab[comp].get(op.operands[i], "")

    def _operand_bytes(self, comp: str, op: _Op) -> int:
        total = 0
        for i in range(len(op.operands)):
            t = self._operand_type(comp, op, i)
            if t:
                total += _shapes_bytes(t)
        return total

    def _dot_flops(self, comp: str, op: _Op) -> float:
        out_dims = _first_shape_dims(op.ret)
        if out_dims is None:
            return 0.0
        lhs_t = self._operand_type(comp, op, 0)
        lhs_dims = _first_shape_dims(lhs_t) or ()
        mc = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.line)
        contract = 1
        if mc and mc.group(1) and lhs_dims:
            for i in mc.group(1).split(","):
                contract *= lhs_dims[int(i)]
        out_elems = 1
        for d in out_dims:
            out_elems *= d
        return 2.0 * out_elems * contract

    def trip_count(self, cond_name: str) -> int:
        consts = []
        for op in self.computations.get(cond_name, []):
            if op.opcode == "constant":
                m = re.search(r"constant\((-?\d+)\)", op.line)
                if m:
                    consts.append(int(m.group(1)))
        pos = [c for c in consts if c > 0]
        return max(pos) if pos else 1

    def _fusion_flops(self, comp_name: str) -> float:
        total = 0.0
        for op in self.computations.get(comp_name, []):
            if op.opcode == "dot":
                total += self._dot_flops(comp_name, op)
            elif op.opcode == "fusion":
                mc = re.search(r"calls=%?([\w.\-]+)", op.line)
                if mc:
                    total += self._fusion_flops(mc.group(1))
        return total

    def _dus_adjustment(self, comp_name: str) -> int:
        """In-place dynamic-update-slice inside a fusion: the full accumulator
        buffer is aliased, not read+written — count 2×update instead
        (HloCostAnalysis convention). Returns bytes to SUBTRACT from the
        boundary count (full buffers) minus bytes to add back (updates)."""
        adj = 0
        for op in self.computations.get(comp_name, []):
            if op.opcode == "dynamic-update-slice" and len(op.operands) >= 2:
                target_t = self._operand_type(comp_name, op, 0)
                update_t = self._operand_type(comp_name, op, 1)
                adj += 2 * _shapes_bytes(target_t) - 2 * _shapes_bytes(update_t)
            elif op.opcode == "fusion":
                mc = re.search(r"calls=%?([\w.\-]+)", op.line)
                if mc:
                    adj += self._dus_adjustment(mc.group(1))
        return adj

    # -- main walk ----------------------------------------------------------------

    def computation_costs(self, name: str, _depth: int = 0) -> Costs:
        if name in self._memo:
            return self._memo[name]
        total = Costs()
        if _depth > 60:
            return total
        for op in self.computations.get(name, []):
            base = op.opcode[:-6] if op.opcode.endswith("-start") else op.opcode
            if op.opcode == "while":
                mb = re.search(r"body=%?([\w.\-]+)", op.line)
                mc = re.search(r"condition=%?([\w.\-]+)", op.line)
                if mb and mc:
                    trips = self.trip_count(mc.group(1))
                    total.add(self.computation_costs(mb.group(1), _depth + 1),
                              scale=trips)
            elif op.opcode == "conditional":
                mbr = re.search(r"branch_computations=\{([^}]*)\}", op.line)
                if mbr:
                    branches = [b.strip().lstrip("%") for b in mbr.group(1).split(",")]
                    costs = [self.computation_costs(b, _depth + 1) for b in branches]
                    if costs:
                        best = max(costs, key=lambda c: c.flops + c.bytes)
                        total.add(best)
            elif op.opcode == "call":
                mcal = re.search(r"to_apply=%?([\w.\-]+)", op.line)
                if mcal:
                    total.add(self.computation_costs(mcal.group(1), _depth + 1))
            elif op.opcode == "fusion":
                mcal = re.search(r"calls=%?([\w.\-]+)", op.line)
                b = self._operand_bytes(name, op) + _shapes_bytes(op.ret)
                if mcal:
                    total.flops += self._fusion_flops(mcal.group(1))
                    b -= self._dus_adjustment(mcal.group(1))
                total.bytes += max(b, 0)
            elif op.opcode == "dynamic-update-slice":
                upd = self._operand_type(name, op, 1)
                total.bytes += 2 * _shapes_bytes(upd)
            elif op.opcode in ("dynamic-slice", "gather"):
                total.bytes += 2 * _shapes_bytes(op.ret)
            elif op.opcode == "scatter":
                upd = self._operand_type(name, op, len(op.operands) - 1) \
                    if op.operands else ""
                total.bytes += 4 * _shapes_bytes(upd)  # read+write idx'd region
            elif op.opcode == "dot":
                total.flops += self._dot_flops(name, op)
                total.bytes += self._operand_bytes(name, op) + _shapes_bytes(op.ret)
            elif base in _COLL_KINDS:
                b = self._operand_bytes(name, op)
                total.coll_bytes += b
                total.coll_by_kind[base] = total.coll_by_kind.get(base, 0.0) + b
                total.coll_counts[base] = total.coll_counts.get(base, 0) + 1
                total.bytes += b + _shapes_bytes(op.ret)
            elif base.endswith("-done") or op.opcode in _SKIP_OPS:
                pass
            else:
                total.bytes += self._operand_bytes(name, op) + _shapes_bytes(op.ret)
        self._memo[name] = total
        return total


def analyze(hlo_text: str) -> dict:
    mod = HloModule(hlo_text)
    entry = mod.entry or next(iter(mod.computations), None)
    if entry is None:
        return {"flops": 0.0, "bytes": 0.0, "collective_bytes": 0.0,
                "collectives": {}, "collective_counts": {}}
    c = mod.computation_costs(entry)
    return {"flops": c.flops, "bytes": c.bytes,
            "collective_bytes": c.coll_bytes,
            "collectives": c.coll_by_kind,
            "collective_counts": c.coll_counts}
