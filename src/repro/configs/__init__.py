"""Architecture registry: one module per assigned arch + the paper workload.

``get_config(arch_id)`` returns the full published config;
``get_smoke_config(arch_id)`` a reduced same-family config for CPU tests.
"""

from __future__ import annotations

import importlib

from ..models.config import ModelConfig

ARCH_IDS = (
    "musicgen_medium",
    "gemma3_1b",
    "command_r_plus_104b",
    "minitron_8b",
    "phi3_mini_3p8b",
    "deepseek_moe_16b",
    "grok1_314b",
    "falcon_mamba_7b",
    "llava_next_34b",
    "recurrentgemma_2b",
)

# cli-friendly aliases (hyphens, paper spellings)
ALIASES = {a.replace("_", "-"): a for a in ARCH_IDS}
ALIASES.update({
    "phi3-mini-3.8b": "phi3_mini_3p8b",
    "grok-1-314b": "grok1_314b",
    "command-r-plus-104b": "command_r_plus_104b",
})


def resolve(arch: str) -> str:
    arch_n = arch.replace("-", "_").replace(".", "p")
    if arch_n in ARCH_IDS:
        return arch_n
    if arch in ALIASES:
        return ALIASES[arch]
    raise KeyError(f"unknown arch {arch!r}; known: {list(ARCH_IDS)}")


def get_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f".{resolve(arch)}", __name__)
    return mod.CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f".{resolve(arch)}", __name__)
    return mod.SMOKE


def all_configs() -> dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}


# -- input shapes (assigned) --------------------------------------------------

SHAPES = {
    "train_4k": {"seq_len": 4096, "global_batch": 256, "kind": "train"},
    "prefill_32k": {"seq_len": 32768, "global_batch": 32, "kind": "prefill"},
    "decode_32k": {"seq_len": 32768, "global_batch": 128, "kind": "decode"},
    "long_500k": {"seq_len": 524288, "global_batch": 1, "kind": "decode"},
}


def cell_is_runnable(cfg: ModelConfig, shape_name: str) -> tuple[bool, str]:
    """(runnable, reason). long_500k only for sub-quadratic archs (DESIGN.md §4)."""
    if shape_name == "long_500k" and not cfg.supports_long_context:
        return False, "pure full-attention arch: 500k dense KV cache excluded by shape contract"
    return True, ""
