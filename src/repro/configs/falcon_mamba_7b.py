"""falcon-mamba-7b [ssm]: attention-free Mamba-1. 64L d_model=4096
(d_inner=8192, state=16, conv=4, dt_rank=256) vocab=65024. [arXiv:2410.05355]"""

from ..models.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b", family="ssm",
    n_layers=64, d_model=4096, n_heads=1, n_kv_heads=1, head_dim=1,
    d_ff=0, vocab_size=65024,
    block_pattern=("mamba",),
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2),
)

SMOKE = CONFIG.scaled(n_layers=4, d_model=64, vocab_size=512,
                      ssm=SSMConfig(d_state=4, d_conv=4, expand=2))
