"""command-r-plus-104b [dense]: 64L d_model=12288 96H (GQA kv=8) d_ff=33792
vocab=256000, no biases, full attention. [hf:CohereForAI/c4ai-command-r-v01]"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="command-r-plus-104b", family="dense",
    n_layers=64, d_model=12288, n_heads=96, n_kv_heads=8, head_dim=128,
    d_ff=33792, vocab_size=256000,
    act="silu", mlp_gated=True,
)

SMOKE = CONFIG.scaled(n_layers=4, d_model=96, n_heads=8, n_kv_heads=2,
                      head_dim=12, d_ff=256, vocab_size=512)
