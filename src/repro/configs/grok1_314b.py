"""grok-1-314b [moe]: 64L d_model=6144 48H (GQA kv=8) d_ff=32768 vocab=131072,
8 experts top-2 (no shared). [hf:xai-org/grok-1]"""

from ..models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="grok-1-314b", family="moe",
    n_layers=64, d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
    d_ff=32768, vocab_size=131072,
    moe=MoEConfig(n_experts=8, top_k=2, d_expert=32768),
    act="gelu", mlp_gated=True,
)

SMOKE = CONFIG.scaled(
    n_layers=3, d_model=64, n_heads=8, n_kv_heads=2, head_dim=8,
    d_ff=128, vocab_size=512,
    moe=MoEConfig(n_experts=4, top_k=2, d_expert=128, capacity_factor=2.0))
