"""recurrentgemma-2b [hybrid]: Griffin — RG-LRU + local attention, pattern
(rec, rec, attn). 26L d_model=2560 10H (GQA kv=1) d_ff=7680 vocab=256000,
lru_width=2560, window=2048, head_dim 256. [arXiv:2402.19427; hf]"""

from ..models.config import ModelConfig, RGLRUConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b", family="hybrid",
    n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1, head_dim=256,
    d_ff=7680, vocab_size=256000,
    block_pattern=("rglru", "rglru", "local"), window=2048,
    rglru=RGLRUConfig(lru_width=2560, d_conv=4, n_blocks=10),
    act="gelu", mlp_gated=True, tie_embeddings=True,
    notes="26 = 8 (rec,rec,attn) periods + 2 rec remainder; local attn window 2048",
)

SMOKE = CONFIG.scaled(n_layers=5, d_model=80, n_heads=4, n_kv_heads=1,
                      head_dim=16, d_ff=160, vocab_size=512, window=16,
                      rglru=RGLRUConfig(lru_width=80, d_conv=4, n_blocks=4))
