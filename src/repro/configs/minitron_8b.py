"""minitron-8b [dense]: pruned Nemotron. 32L d_model=4096 32H (GQA kv=8)
d_ff=16384 vocab=256000, squared-ReLU ungated MLP. [arXiv:2407.14679; hf]"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="minitron-8b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=16384, vocab_size=256000,
    act="relu2", mlp_gated=False,
)

SMOKE = CONFIG.scaled(n_layers=4, d_model=64, n_heads=8, n_kv_heads=2,
                      head_dim=8, d_ff=256, vocab_size=512)
