"""gemma3-1b [dense]: 26L d_model=1152 4H (GQA kv=1) d_ff=6912 vocab=262144.
5:1 local:global attention, sliding window 512, RoPE theta 10k local / 1M
global, head_dim 256 (independent of d_model). [hf:google/gemma-3-1b-pt]"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-1b", family="dense",
    n_layers=26, d_model=1152, n_heads=4, n_kv_heads=1, head_dim=256,
    d_ff=6912, vocab_size=262144,
    block_pattern=("local",) * 5 + ("global",), window=512,
    rope_theta=10_000.0, rope_theta_global=1_000_000.0,
    act="gelu", mlp_gated=True, tie_embeddings=True,
    notes="26 = 4 full (5L+1G) periods + 2 local remainder",
)

SMOKE = CONFIG.scaled(n_layers=8, d_model=64, n_heads=4, n_kv_heads=1,
                      head_dim=16, d_ff=128, vocab_size=512, window=16,
                      block_pattern=("local",) * 2 + ("global",))
