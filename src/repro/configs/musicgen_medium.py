"""musicgen-medium [audio]: decoder-only over EnCodec tokens.
48L d_model=1536 24H (MHA kv=24) d_ff=6144 vocab=2048. [arXiv:2306.05284; hf]
Frontend (EnCodec + codebook interleaving) is a stub: input_specs() provides
precomputed frame embeddings (B,S,1536); ungated ReLU MLP per the original;
RMSNorm/RoPE standardized across the zoo (deviation noted)."""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium", family="audio",
    n_layers=48, d_model=1536, n_heads=24, n_kv_heads=24, head_dim=64,
    d_ff=6144, vocab_size=2048,
    act="relu", mlp_gated=False, embed_inputs=False,
    notes="audio frontend stubbed: frame embeddings in, EnCodec token logits out",
)

SMOKE = CONFIG.scaled(n_layers=4, d_model=64, n_heads=4, n_kv_heads=4,
                      head_dim=16, d_ff=128, vocab_size=256)
