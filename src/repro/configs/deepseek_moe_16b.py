"""deepseek-moe-16b [moe]: 28L d_model=2048 16H (MHA kv=16) vocab=102400.
Fine-grained MoE: 64 routed experts top-6 + 2 shared, d_expert=1408; layer 0
is a dense-MLP prelude (d_ff 10944). [arXiv:2401.06066; hf]"""

from ..models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b", family="moe",
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
    d_ff=1408, vocab_size=102400,
    moe=MoEConfig(n_experts=64, top_k=6, d_expert=1408, n_shared=2,
                  dense_prelude_layers=1, d_ff_prelude=10944),
    act="silu", mlp_gated=True,
)

SMOKE = CONFIG.scaled(
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=96, vocab_size=512,
    moe=MoEConfig(n_experts=8, top_k=2, d_expert=96, n_shared=1,
                  dense_prelude_layers=1, d_ff_prelude=128, capacity_factor=4.0))
