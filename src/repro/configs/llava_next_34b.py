"""llava-next-34b [vlm]: LM backbone only (anyres vision tiling stubbed).
60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000.
[hf:llava-hf/llava-v1.6-*] input_specs() provides precomputed patch+text
embeddings (B,S,7168)."""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b", family="vlm",
    n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8, head_dim=128,
    d_ff=20480, vocab_size=64000,
    act="silu", mlp_gated=True, embed_inputs=False,
    notes="vision frontend stubbed: patch embeddings in",
)

SMOKE = CONFIG.scaled(n_layers=4, d_model=64, n_heads=8, n_kv_heads=2,
                      head_dim=8, d_ff=128, vocab_size=512)
