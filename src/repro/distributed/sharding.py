"""Sharding rules: DP over ("pod","data"), TP/EP over "model", optional FSDP.

Two mechanisms:

* **Parameter shardings** — `tree_shardings(tree, mesh)` walks a (shape) pytree
  and assigns a PartitionSpec per leaf from its key path + shape:
  Megatron-style column/row parallel projections, expert-parallel MoE when the
  expert count divides the model axis (DeepSeek: 64/16) and tensor-parallel
  *inside* experts otherwise (Grok: 8 experts, d_expert 32768/16), vocab-
  sharded embedding/head. `fsdp=True` additionally shards the first free,
  divisible dimension over the data axes (params+moments; all-gather at use).
  Every rule checks divisibility and falls back to replication — a config
  never fails to lower because of an indivisible dimension.

* **Activation constraints** — model code calls `shard_act(x, name)` at the
  canonical cut points (residual stream, attention heads, logits). Rules are
  installed with `use_sharding_rules(...)`; without rules, it is a no-op (CPU
  smoke tests never touch a mesh).
"""

from __future__ import annotations

import contextlib
import contextvars
from dataclasses import dataclass, field

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_RULES: contextvars.ContextVar["ShardingRules | None"] = \
    contextvars.ContextVar("sharding_rules", default=None)


@dataclass(frozen=True)
class ShardingRules:
    mesh: Mesh
    data_axes: tuple[str, ...] = ("data",)   # ("pod","data") multi-pod
    model_axis: str = "model"
    fsdp: bool = False
    seq_parallel: bool = False               # Megatron-SP: residual S-sharded
    seq_shard_logits: bool = True            # shard logits seq dim too (memory)
    pure_fsdp: bool = False                  # ZeRO-3: weights 2D-sharded over
                                             # (data, model); activations pure
                                             # DP — no TP collectives per layer

    @property
    def dp_size(self) -> int:
        n = 1
        for a in self.data_axes:
            n *= self.mesh.shape[a]
        return n

    @property
    def tp_size(self) -> int:
        return self.mesh.shape[self.model_axis]

    def dp_axes_for(self, dim: int):
        """Data axes if batch divides, else None (e.g. batch=1 long-context)."""
        return self.data_axes if dim % self.dp_size == 0 else None

    @property
    def model_in_dp(self) -> bool:
        return self.model_axis in self.data_axes

    def tp_axis_for(self, dim: int):
        if self.pure_fsdp or self.model_in_dp:
            return None                      # activations stay data-parallel
        return self.model_axis if dim % self.tp_size == 0 else None


def elastic_rules(mesh: Mesh, *, model_axis: str = "model",
                  fsdp: bool = False, seq_parallel: bool = False) -> ShardingRules:
    """ShardingRules for a freshly re-planned (elastic-rescale) mesh.

    Every mesh axis except ``model_axis`` carries data parallelism — the shape
    produced by ``core.elastic.plan_mesh_for`` / ``fleet_mesh_plan`` after an
    eviction shrinks or regrows the pool. Used by the fleet coordinator to
    rebuild activation/parameter shardings when surviving capacity changes.
    """
    data_axes = tuple(a for a in mesh.axis_names if a != model_axis)
    if not data_axes:  # degenerate 1-axis mesh: model axis doubles as data
        data_axes = tuple(mesh.axis_names)
    return ShardingRules(mesh=mesh, data_axes=data_axes, model_axis=model_axis,
                         fsdp=fsdp, seq_parallel=seq_parallel)


def use_sharding_rules(rules: ShardingRules | None):
    @contextlib.contextmanager
    def cm():
        token = _RULES.set(rules)
        try:
            yield rules
        finally:
            _RULES.reset(token)
    return cm()


def current_rules() -> ShardingRules | None:
    return _RULES.get()


# ---------------------------------------------------------------------------
# activation constraints
# ---------------------------------------------------------------------------

def shard_act(x, name: str):
    r = _RULES.get()
    if r is None:
        return x
    dp = r.dp_axes_for(x.shape[0])
    if name == "residual":            # (B,S,D)
        # Megatron sequence parallelism: between blocks the residual stream is
        # sharded over tokens (norms are per-token, so this is transparent);
        # GSPMD inserts the all-gather at attention/MLP entry and the
        # reduce-scatter after — activation memory / tp_size.
        sp = r.tp_axis_for(x.shape[1]) if (r.seq_parallel and x.shape[1] > 1) else None
        spec = P(dp, sp, None)
    elif name in ("heads", "kv_heads"):  # (B,S,H,hd)
        tp_h = r.tp_axis_for(x.shape[2])
        if tp_h is not None or x.shape[1] == 1:
            spec = P(dp, None, tp_h, None)
        else:
            # head count doesn't divide the model axis (musicgen 24H,
            # gemma3 4H/1KV): context-parallel fallback — shard the sequence
            # dim so attention math distributes instead of replicating.
            spec = P(dp, r.tp_axis_for(x.shape[1]), None, None)
    elif name == "logits":            # (B,S,V) or (B,V)
        # vocab stays model-sharded even under pure_fsdp: the CE/logit work is
        # the one place the model axis pays for itself at training shapes
        # (measured 16x byte/flop inflation when unsharded — §Perf A3). In
        # full-DP mode the model axis is part of dp and carries batch instead.
        tp_v = r.model_axis if (x.shape[-1] % r.tp_size == 0
                                and not r.model_in_dp) else None
        if x.ndim == 3:
            sp = r.tp_axis_for(x.shape[1]) if (r.seq_parallel and x.shape[1] > 1) else None
            spec = P(dp, sp, tp_v if sp is None else None)
        else:
            spec = P(dp, tp_v)
    elif name == "ffn":               # (B,S,F)
        spec = P(dp, None, r.tp_axis_for(x.shape[-1]))
    elif name == "moe_groups":        # (G, T/G, D)
        spec = P(r.dp_axes_for(x.shape[0]), None, None)
    elif name == "moe_experts":       # (G, E, C, D) — EP over experts
        spec = P(r.dp_axes_for(x.shape[0]), r.tp_axis_for(x.shape[1]),
                 None, None)
    else:
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(r.mesh, spec))


def batch_spec(rules: ShardingRules, batch: int) -> P:
    return P(rules.dp_axes_for(batch))


def shard_microbatched(tree):
    """Constrain (n_microbatch, B/n, ...) arrays to shard dim 1 over data —
    keeps the microbatch reshape from triggering involuntary resharding."""
    r = _RULES.get()
    if r is None:
        return tree

    def per_leaf(x):
        if x.ndim < 2:
            return x
        dp = r.dp_axes_for(x.shape[1])
        spec = P(None, dp, *([None] * (x.ndim - 2)))
        return jax.lax.with_sharding_constraint(x, NamedSharding(r.mesh, spec))
    return jax.tree.map(per_leaf, tree)


# ---------------------------------------------------------------------------
# parameter shardings
# ---------------------------------------------------------------------------

def _with_fsdp(spec: tuple, shape: tuple[int, ...], rules: ShardingRules) -> tuple:
    """Shard the first free, divisible dim over the data axes (FSDP/ZeRO-3).

    For stacked layer params (ndim>=3, leading scan dim) the scan dim is never
    claimed: a scan dynamic-slices it per layer, and GSPMD would otherwise
    all-gather the ENTIRE weight stack before the loop (measured: the full
    per-arch parameter bytes materialized per step). Sharding an inner dim
    instead yields the correct FSDP behaviour — a per-layer all-gather at use.
    """
    if not rules.fsdp:
        return spec
    spec = list(spec)
    start = 1 if len(shape) >= 3 else 0
    for i in range(start, len(shape)):
        if (spec[i] is None and shape[i] % rules.dp_size == 0
                and shape[i] >= rules.dp_size):
            spec[i] = rules.data_axes
            break
    return tuple(spec)


def param_pspec(path: str, shape: tuple[int, ...], rules: ShardingRules) -> P:
    """PartitionSpec from a parameter's key path and shape.

    Layer-stacked params carry a leading repeats dim (scan axis) which is
    never sharded by TP; FSDP may claim it if divisible.
    """
    tp = rules.model_axis
    leaf = path.rsplit("/", 1)[-1]

    if rules.pure_fsdp and (leaf not in ("embed", "lm_head")
                            or rules.model_in_dp):
        # ZeRO-3 weight sharding over the data axes (all mesh axes when the
        # mesh is reinterpreted as full data-parallel); activations never see
        # the model axis (tp_axis_for returns None). Outside full-DP mode the
        # embedding/LM head keep vocab-model sharding (shard_act "logits").
        spec = [None] * len(shape)
        start = 1 if len(shape) >= 3 else 0
        claimed_data = False
        for i in range(start, len(shape)):
            if not claimed_data and shape[i] % rules.dp_size == 0 \
                    and shape[i] >= rules.dp_size:
                spec[i] = rules.data_axes
                claimed_data = True
            elif (not rules.model_in_dp
                  and shape[i] % rules.tp_size == 0
                  and shape[i] >= rules.tp_size):
                spec[i] = tp
                break
        return P(*spec)

    def col(io=(-2, -1)):
        """column-parallel: shard output (last) dim; fall back to input dim."""
        spec = [None] * len(shape)
        if shape[io[1]] % rules.tp_size == 0:
            spec[io[1] % len(shape)] = tp
        elif shape[io[0]] % rules.tp_size == 0:
            spec[io[0] % len(shape)] = tp
        return spec

    def row():
        """row-parallel: shard input (second-to-last) dim."""
        spec = [None] * len(shape)
        if shape[-2] % rules.tp_size == 0:
            spec[-2] = tp
        elif shape[-1] % rules.tp_size == 0:
            spec[-1] = tp
        return spec

    if leaf in ("wq", "wk", "wv", "up", "gate", "in_proj", "dt_proj",
                "in_x", "in_gate"):
        spec = col()
    elif leaf in ("wo", "down", "out_proj", "out", "x_proj"):
        spec = row()
    elif leaf == "embed":
        spec = [tp if shape[0] % rules.tp_size == 0 else None, None]
    elif leaf == "lm_head":
        spec = [None, tp if shape[1] % rules.tp_size == 0 else None]
    elif leaf in ("conv", "A_log", "D", "dt_bias"):
        # elementwise-over-d_inner tensors: shard the d_inner dim
        spec = [None] * len(shape)
        for i in range(len(shape) - 1, -1, -1):
            if shape[i] % rules.tp_size == 0 and shape[i] >= rules.tp_size:
                spec[i] = tp
                break
    elif leaf == "router":
        spec = [None] * len(shape)
    elif "experts" in path and leaf in ("up", "down", "gate"):
        spec = col()  # unreachable; experts handled below
    else:
        spec = [None] * len(shape)

    # MoE expert stacks: (L, E, D, F) / (L, E, F, D)
    if "experts" in path.split("/"):
        spec = [None] * len(shape)
        e_dim = len(shape) - 3          # expert dim position
        if shape[e_dim] % rules.tp_size == 0:
            spec[e_dim] = tp            # expert parallelism
        elif leaf in ("up", "gate") and shape[-1] % rules.tp_size == 0:
            spec[-1] = tp               # TP within expert (column)
        elif leaf == "down" and shape[-2] % rules.tp_size == 0:
            spec[-2] = tp               # TP within expert (row)
    if "lru" in path.split("/"):
        spec = [None] * len(shape)      # small block-diag gates: replicate

    spec = _with_fsdp(tuple(spec), shape, rules)
    return P(*spec)


def tree_shardings(tree, rules: ShardingRules):
    """Same-structure pytree of NamedShardings for params/opt-state shapes."""
    def per_leaf(path, leaf):
        from ..checkpoint.serialize import _key_str
        pstr = _key_str(path)
        # optimizer state wraps params: mu/params/..., nu/params/...
        shape = tuple(leaf.shape)
        return NamedSharding(rules.mesh, param_pspec(pstr, shape, rules))
    return jax.tree_util.tree_map_with_path(per_leaf, tree)


def cache_pspec(leaf_name: str, shape: tuple[int, ...], rules: ShardingRules) -> P:
    """Decode-cache shardings. Caches are stacked (n_repeats, B, ...).

    KV caches shard heads over "model" when the KV head count divides the
    axis; otherwise (GQA kv=1/8 on a 16-way axis) they shard the *sequence*
    dim — cross-chip flash-decode: per-shard partial softmax combined by the
    all-reduces GSPMD inserts. Recurrent states shard their channel dim.
    """
    tp = rules.model_axis
    b_dim = 1  # (L, B, ...)
    dp = rules.dp_axes_for(shape[b_dim])
    if leaf_name in ("k", "v") and len(shape) == 5:   # (L,B,S,KV,hd)
        if shape[3] % rules.tp_size == 0:
            return P(None, dp, None, tp, None)
        if shape[2] % rules.tp_size == 0:
            return P(None, dp, tp, None, None)        # sequence-sharded cache
        return P(None, dp, None, None, None)
    if leaf_name == "conv" and len(shape) == 4:        # (L,B,K-1,C)
        return P(None, dp, None, rules.tp_axis_for(shape[3]))
    if leaf_name == "ssm" and len(shape) == 4:         # (L,B,DI,N)
        return P(None, dp, rules.tp_axis_for(shape[2]), None)
    if leaf_name == "h" and len(shape) == 3:           # (L,B,W)
        return P(None, dp, rules.tp_axis_for(shape[2]))
    return P(*([None] * len(shape)))


def cache_shardings(tree, rules: ShardingRules):
    def per_leaf(path, leaf):
        from ..checkpoint.serialize import _key_str
        name = _key_str(path).rsplit("/", 1)[-1]
        return NamedSharding(rules.mesh, cache_pspec(name, tuple(leaf.shape), rules))
    return jax.tree_util.tree_map_with_path(per_leaf, tree)


# ---------------------------------------------------------------------------
# per-process shard addressing (pod-scale restore)
# ---------------------------------------------------------------------------

def addressable_shard_spans(sharding, shape) -> list:
    """Deduplicated global index spans this process must materialize.

    One ``((start, stop), ...)`` tuple per distinct shard region held by an
    *addressable* device of ``sharding`` — the planning input of the
    per-shard streaming restore: in a multihost pod each process enqueues
    decode work only for its own rows, while a single-process mesh (all
    devices addressable) gets every region, exactly the shards
    ``jax.make_array_from_callback`` will ask for. Falls back to all devices
    when the sharding exposes no addressability (host ndarrays in tests).
    """
    shape = tuple(int(s) for s in shape)
    imap = sharding.devices_indices_map(shape)
    try:
        addressable = set(sharding.addressable_devices)
    except Exception:
        addressable = None
    out: dict = {}
    for dev, slices in imap.items():
        if addressable is not None and dev not in addressable:
            continue
        key = tuple(
            (0 if sl.start is None else int(sl.start),
             dim if sl.stop is None else int(sl.stop))
            for sl, dim in zip(slices, shape))
        out.setdefault(key, None)
    return list(out)
