"""Multihost-style synchronization with an in-process simulation mode.

``sync_global_devices(name)`` mirrors the API of
``jax.experimental.multihost_utils.sync_global_devices``: every participant
blocks until all participants reach the same named point. Three backends,
picked automatically:

* **simulated** — when a ``SimulatedBarrier`` is installed (via
  ``use_simulated_barrier``), participants are *threads* of one process.
  This is how CPU CI exercises the pod-restore rendezvous: N fleet members
  run restore concurrently and none may take its first step until every
  member has materialized its shards.
* **real multihost** — ``jax.process_count() > 1``: delegate to
  ``jax.experimental.multihost_utils`` (an actual cross-host barrier over
  the distributed runtime).
* **single process, no simulation** — a no-op; there is nobody to wait for.

The simulated barrier is keyed by name so distinct sync points never
release each other, and each named ``threading.Barrier`` is cyclic, so the
same name can be reused across restore attempts (JAX reuses barrier names
the same way).
"""

from __future__ import annotations

import threading
from contextlib import contextmanager

import jax

__all__ = ["SimulatedBarrier", "sync_global_devices", "use_simulated_barrier"]


class SimulatedBarrier:
    """In-process stand-in for the multihost barrier: ``parties`` threads
    rendezvous per sync-point name. A timeout turns a lost participant into
    a loud ``RuntimeError`` instead of a silent hang (CI-friendly)."""

    def __init__(self, parties: int, *, timeout_s: float = 60.0):
        if parties < 1:
            raise ValueError("barrier needs at least one party")
        self.parties = parties
        self.timeout_s = timeout_s
        self._lock = threading.Lock()
        self._barriers: dict[str, threading.Barrier] = {}

    def _barrier_for(self, name: str) -> threading.Barrier:
        with self._lock:
            b = self._barriers.get(name)
            if b is None:
                b = self._barriers[name] = threading.Barrier(self.parties)
            return b

    def wait(self, name: str) -> None:
        try:
            self._barrier_for(name).wait(timeout=self.timeout_s)
        except threading.BrokenBarrierError:
            raise RuntimeError(
                f"simulated multihost barrier {name!r} broken: a participant "
                f"crashed or missed the {self.timeout_s}s rendezvous window"
            ) from None


_sim_lock = threading.Lock()
_simulated: SimulatedBarrier | None = None


def install_simulated_barrier(barrier: SimulatedBarrier | None) -> None:
    global _simulated
    with _sim_lock:
        _simulated = barrier


@contextmanager
def use_simulated_barrier(barrier: SimulatedBarrier):
    """Route ``sync_global_devices`` through ``barrier`` for the duration.

    Install once in the driver thread *before* spawning the participant
    threads; the participants themselves only call ``sync_global_devices``.
    """
    install_simulated_barrier(barrier)
    try:
        yield barrier
    finally:
        install_simulated_barrier(None)


def sync_global_devices(name: str) -> None:
    """Block until every participant reaches the sync point ``name``."""
    with _sim_lock:
        sim = _simulated
    if sim is not None:
        sim.wait(name)
        return
    if jax.process_count() > 1:  # pragma: no cover - needs a real multihost run
        from jax.experimental import multihost_utils
        multihost_utils.sync_global_devices(name)
