from .sharding import (ShardingRules, addressable_shard_spans, batch_spec,
                       cache_shardings, shard_act, tree_shardings,
                       use_sharding_rules)

__all__ = ["ShardingRules", "addressable_shard_spans", "batch_spec",
           "cache_shardings", "shard_act", "tree_shardings",
           "use_sharding_rules"]
