from .sharding import (ShardingRules, batch_spec, cache_shardings, shard_act,
                       tree_shardings, use_sharding_rules)

__all__ = ["ShardingRules", "batch_spec", "cache_shardings", "shard_act",
           "tree_shardings", "use_sharding_rules"]
