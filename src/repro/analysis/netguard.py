"""SPOT041/SPOT042 — object-store network-path discipline.

The ChunkBackend contract (``repro.checkpoint.backend``) makes the network
layer survivable the same way SPOT001/002 make the POSIX commit protocol
survivable: content addressing turns every transfer into something that can
be *verified and repeated*. These rules police the two ways call sites
forfeit that property.

SPOT041 — bare or unverified ranged GET. A torn response is re-fetchable by
hash, but only if the caller (a) runs the GET under the bounded-retry
substrate (``core.retry.call_with_retry`` — directly, through a wrapper
that forwards to it, or transitively from a function that is itself
retried) and (b) re-digests the payload against its content address before
trusting a byte (``chunk_content_ok`` / ``chunk_digest``). A one-shot
``backend.get_range(...)`` with no retry is flagged, as is a retried fetch
whose closure never verifies — retrying a corrupt-accepting read just
re-accepts the corruption. Methods *named* ``get_range`` are exempt: a
backend implementation delegating to its transport is the interface seam,
the retry contract binds the consumer.

SPOT042 — chunk-key PUT in a loop without an idempotence guard. Re-driving
an upload loop (reconcile after an outage, a retried save) must be a
verified no-op for chunks that already landed — the key is the content, so
a blind re-PUT wastes the link at best and clobbers a concurrent writer's
committed object at worst. A ``<backendish>.put(...)`` inside a for/while
loop is flagged unless the loop body consults existence first (``head`` /
``check`` / ``exists``). The receiver must look like an object-store client
(``backend``, ``objstore``, ``s3``, ...) so queue/dict ``.put`` stays out
of scope.
"""

from __future__ import annotations

import ast
from typing import Optional

from .core import (Finding, ModuleInfo, RepoModel, calls_in, dotted,
                   iter_funcs, terminal_name)

#: the root of the bounded-retry substrate; functions whose bodies call a
#: wrapper are wrappers themselves (fixpoint), so `_backend_retry(...)`
#: style forwarding keeps the property visible
RETRY_ROOT = "call_with_retry"

#: a retried fetch closure must re-digest against the content address with
#: one of these before accepting the payload
VERIFY_TERMINALS = {"chunk_content_ok", "chunk_digest", "verify_digest"}

GET_TERMINALS = {"get_range"}

PUT_TERMINALS = {"put", "put_object"}

#: receiver name segments that mark a call target as an object-store client
#: (deliberately narrow: `queue.put` / `index.put` are not network uploads)
BACKENDISH_SEGMENTS = {
    "backend", "objstore", "object_store", "obj_store", "s3", "gcs",
    "bucket", "remote",
}

GUARD_TERMINALS = {"head", "check", "exists", "head_object"}


def check_repo(model: RepoModel) -> list[Finding]:
    wrappers = _retry_wrappers(model)
    wrapped = _retry_wrapped_functions(model, wrappers)
    findings: list[Finding] = []
    for mod in model.modules:
        findings.extend(_check_gets(mod, wrapped, wrappers))
        findings.extend(_check_put_loops(mod))
    return findings


# -- SPOT041 -------------------------------------------------------------------


def _retry_wrappers(model: RepoModel) -> set[str]:
    """Function names that forward their callable argument into the bounded
    retry substrate: ``call_with_retry`` itself plus any repo function whose
    body reaches a wrapper (fixpoint over one level of forwarding per
    round)."""
    wrappers = {RETRY_ROOT}
    changed = True
    while changed:
        changed = False
        for name, entries in model.functions.items():
            if name in wrappers:
                continue
            for e in entries:
                if any(terminal_name(c.func) in wrappers
                       for c in calls_in(e.node)):
                    wrappers.add(name)
                    changed = True
                    break
    return wrappers


def _retry_wrapped_functions(model: RepoModel,
                             wrappers: set[str]) -> set[str]:
    """Names of functions that execute under a bounded retry: referenced (or
    lambda-called) in the argument list of a wrapper call, closed over the
    calls their bodies make (a retried function's callees are retried too)."""
    wrapped: set[str] = set()
    for mod in model.modules:
        for call in calls_in(mod.tree):
            if terminal_name(call.func) not in wrappers:
                continue
            args = list(call.args) + [kw.value for kw in call.keywords]
            for a in args:
                if isinstance(a, ast.Lambda):
                    for sub in calls_in(a):
                        t = terminal_name(sub.func)
                        if t:
                            wrapped.add(t)
                else:
                    t = terminal_name(a)
                    if t:
                        wrapped.add(t)
    # transitive closure, bounded to repo-defined functions
    changed = True
    while changed:
        changed = False
        for name in list(wrapped):
            for e in model.functions.get(name, []):
                for c in calls_in(e.node):
                    t = terminal_name(c.func)
                    if t and t in model.functions and t not in wrapped:
                        wrapped.add(t)
                        changed = True
    return wrapped


def _own_calls(fn: ast.FunctionDef | ast.AsyncFunctionDef) -> list[ast.Call]:
    """Call nodes in ``fn``'s own body — nested def subtrees excluded (they
    are analyzed as their own functions), lambdas included (they run in this
    function's dynamic extent for the patterns we police)."""
    out: list[ast.Call] = []

    def walk(node: ast.AST) -> None:
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            if isinstance(child, ast.Call):
                out.append(child)
            walk(child)

    walk(fn)
    out.sort(key=lambda c: (c.lineno, c.col_offset))
    return out


def _check_gets(mod: ModuleInfo, wrapped: set[str],
                wrappers: set[str]) -> list[Finding]:
    findings: list[Finding] = []
    for _classname, fn in iter_funcs(mod.tree):
        if fn.name in GET_TERMINALS:
            continue  # interface delegation inside a backend implementation
        calls = _own_calls(fn)
        is_wrapped = fn.name in wrapped or fn.name in wrappers
        verifies = any(terminal_name(c.func) in VERIFY_TERMINALS
                       for c in calls)
        for call in calls:
            if terminal_name(call.func) not in GET_TERMINALS:
                continue
            if not is_wrapped:
                findings.append(Finding(
                    path=mod.relpath, line=call.lineno,
                    col=call.col_offset, code="SPOT041",
                    message=(
                        "bare one-shot ranged GET: a torn or short response "
                        "is re-fetchable by content address, but only inside "
                        "the bounded retry substrate — run this through "
                        "core.retry.call_with_retry (e.g. "
                        "backend.fetch_chunk_verified) and re-digest before "
                        "accepting"),
                ))
            elif not verifies:
                findings.append(Finding(
                    path=mod.relpath, line=call.lineno,
                    col=call.col_offset, code="SPOT041",
                    message=(
                        "retried but unverified ranged GET: the retry "
                        "closure never re-digests the payload against its "
                        "content address (chunk_content_ok/chunk_digest), "
                        "so a corrupt response is accepted on the first "
                        "try — retrying cannot help what is never checked"),
                ))
    return findings


# -- SPOT042 -------------------------------------------------------------------


def _backendish(call: ast.Call) -> Optional[str]:
    """Receiver dotted name when the call target looks like an object-store
    client method, else None."""
    if not isinstance(call.func, ast.Attribute):
        return None
    if call.func.attr not in PUT_TERMINALS:
        return None
    recv = dotted(call.func.value)
    if recv is None:
        return None
    segments = {s.lstrip("_") for s in recv.split(".")}
    if segments & BACKENDISH_SEGMENTS:
        return recv
    return None


def _check_put_loops(mod: ModuleInfo) -> list[Finding]:
    findings: list[Finding] = []
    for _classname, fn in iter_funcs(mod.tree):
        for put, loops in _calls_with_loops(fn):
            recv = _backendish(put)
            if recv is None or not loops:
                continue
            guarded = any(
                any(terminal_name(c.func) in GUARD_TERMINALS
                    for c in calls_in(loop))
                for loop in loops)
            if not guarded:
                findings.append(Finding(
                    path=mod.relpath, line=put.lineno,
                    col=put.col_offset, code="SPOT042",
                    message=(
                        f"chunk-key PUT in a loop without an idempotence "
                        f"guard: re-driving this loop re-uploads every "
                        f"object blind — consult `{recv}.head(...)` (or "
                        f"check/exists) first so an already-committed "
                        f"address is a verified no-op, never an append "
                        f"(see backend.upload_chunk)"),
                ))
    return findings


def _calls_with_loops(
    fn: ast.FunctionDef | ast.AsyncFunctionDef,
) -> list[tuple[ast.Call, list[ast.AST]]]:
    """(call, enclosing for/while loops innermost-last) pairs for ``fn``'s
    own body — nested defs excluded, like :func:`_own_calls`."""
    out: list[tuple[ast.Call, list[ast.AST]]] = []

    def walk(node: ast.AST, loops: list[ast.AST]) -> None:
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            child_loops = loops
            if isinstance(child, (ast.For, ast.While)):
                child_loops = loops + [child]
            if isinstance(child, ast.Call):
                out.append((child, list(child_loops)))
            walk(child, child_loops)

    walk(fn, [])
    return out
