"""SPOT001/SPOT002 — the fsync→rename→dir-fsync commit protocol.

A checkpoint the store reported COMMITTED must survive a crash at any
instruction (the PR 3 durability invariant). Statically that means every
`os.replace` / `os.rename` on a commit path must be *dominated* by an fsync
of the data being renamed (SPOT001) and *followed* by an fsync of the parent
directory so the rename itself is durable (SPOT002).

The analysis is per-function and order-based: a rename at position P needs a
blessed fsync-bearing call lexically before P and a dir-fsync reference
lexically after P in the same function body. `ioutil` helpers and the
manifest commit methods are modeled as blessed because they perform the
fsyncs internally:

- fsync-bearing (satisfy SPOT001): direct ``os.fsync``/``fsync``, plus
  ``write_manifest`` / ``mark_committed`` / ``write_shard_file`` which all
  fsync what they wrote before returning;
- dir-fsync-bearing (satisfy SPOT002): any reference to ``fsync_dir`` or
  ``mark_committed`` after the rename — a *reference* (not only a direct
  call) so `executor.submit(fsync_dir, root)` counts; the store overlaps the
  root dir fsync on an executor lane and joins it before reporting
  COMMITTED.
"""

from __future__ import annotations

import ast

from .core import Finding, ModuleInfo, RepoModel, dotted, iter_funcs, terminal_name

RENAME_CALLS = {"os.replace", "os.rename"}
FSYNC_BEARING = {"fsync", "write_manifest", "mark_committed", "write_shard_file"}
DIRSYNC_BEARING = {"fsync_dir", "mark_committed"}


def _pos(node: ast.AST) -> tuple[int, int]:
    return (node.lineno, node.col_offset)


def check_repo(model: RepoModel) -> list[Finding]:
    findings: list[Finding] = []
    for mod in model.modules:
        findings.extend(_check_module(mod))
    return findings


def _check_module(mod: ModuleInfo) -> list[Finding]:
    findings: list[Finding] = []
    for _classname, fn in iter_funcs(mod.tree):
        renames = []
        for node in ast.walk(fn):
            if isinstance(node, ast.Call):
                name = dotted(node.func)
                if name in RENAME_CALLS:
                    renames.append(node)
        if not renames:
            continue
        # gather every call and every bare reference in source order once
        calls = [n for n in ast.walk(fn) if isinstance(n, ast.Call)]
        refs = [n for n in ast.walk(fn)
                if isinstance(n, (ast.Name, ast.Attribute))]
        for rn in renames:
            rp = _pos(rn)
            fsynced = any(
                terminal_name(c.func) in FSYNC_BEARING and _pos(c) < rp
                for c in calls)
            if not fsynced:
                findings.append(Finding(
                    path=mod.relpath, line=rn.lineno, col=rn.col_offset,
                    code="SPOT001",
                    message=(f"{dotted(rn.func)} without a preceding fsync of "
                             f"the source in this function — a crash after the "
                             f"rename can publish an empty/partial file; fsync "
                             f"the data first (os.fsync, or a blessed helper: "
                             f"{', '.join(sorted(FSYNC_BEARING - {'fsync'}))})"),
                ))
            dir_synced = any(
                _name_of(r) in DIRSYNC_BEARING and _pos(r) > rp
                for r in refs)
            if not dir_synced:
                findings.append(Finding(
                    path=mod.relpath, line=rn.lineno, col=rn.col_offset,
                    code="SPOT002",
                    message=(f"{dotted(rn.func)} without a following parent-dir "
                             f"fsync — the rename itself is not durable until "
                             f"the directory is fsynced; call "
                             f"ioutil.fsync_dir(parent) (or mark_committed) "
                             f"after the rename"),
                ))
    return findings


def _name_of(node: ast.AST) -> str | None:
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        return node.attr
    return None
