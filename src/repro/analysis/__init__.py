"""spotlint — repo-specific static analysis for the Spot-on checkpoint layer.

The checkpoint subsystem enforces three load-bearing invariants purely by
convention: the fsync→rename→dir-fsync commit protocol (a checkpoint the
store reported COMMITTED must survive a crash at any instruction), the
one-copy/no-aliasing rule for snapshot payloads and mmap views (zero-copy
buffers must never alias state a concurrent step could mutate, and mmap
views must not outlive their release scope), and the codec-scheduler lane
discipline (never block a lane on its own lane; periodic encode loops must
yield between chunks). Nothing in the test suite exercises "a new call site
forgot the fsync" — tier-1 stays green until a real eviction corrupts a
pool.

This package closes that gap with two halves:

* **spotlint** (``python -m repro.analysis.spotlint src/``) — an AST pass
  (stdlib ``ast``, no new dependencies) with repo-specific rules grouped in
  four families: crash-consistency (SPOT001/002), scheduler lane discipline
  (SPOT010/011/012), zero-copy lifetimes (SPOT020/021) and lock discipline
  (SPOT030/031). Every finding carries a fix-it message; intentional
  violations are suppressed inline (``# spotlint: ignore[CODE]``) or via a
  committed baseline file whose entries go stale — and fail the run — when
  their target line changes.
* **lock witness** (``analysis.lock_witness``) — an opt-in runtime monitor
  that instruments ``threading`` lock acquisition order while the test
  suite runs and fails on observed order inversions, so the static lock
  graph of SPOT030 is validated against reality instead of trusted.
"""

from .core import Finding  # noqa: F401
