"""SPOT010/011/012 — codec-scheduler lane discipline.

The scheduler is one worker pool with three strict-priority lanes
(URGENT=0 > RESTORE=1 > PERIODIC=2) and *cooperative* preemption: queued
higher-priority jobs jump the queue, but a worker already inside a job is
only reclaimed when that job calls ``maybe_yield()`` between chunks. Three
conventions keep that sound, and each gets a rule:

- **SPOT010** — a function that itself runs as a lane job must never block
  (``.result()`` / ``futures.wait``) on a future it submitted to a lane of
  equal-or-lower priority: with every worker busy, nothing can ever run the
  child job, and the parent holds its worker forever (self-deadlock).
- **SPOT011** — restore-path code must submit to the RESTORE lane;
  submitting MTTR-window work to PERIODIC (or URGENT) either queues it
  behind background encodes or steals the eviction-notice budget.
- **SPOT012** — chunk-granular encode loops (anything calling
  ``store_chunk`` in a loop) must call ``codec_sched.maybe_yield()`` in the
  loop body, or a long periodic encode holds its worker for a whole piece
  and restore/urgent preemption latency degrades from one chunk to one
  piece.

Lane inference is lexical: ``codec_executor()``/``restore_executor()``/
``urgent_executor()`` and ``lane(PERIODIC|RESTORE|URGENT)`` map to lane
numbers; plain local assignments (including the known branch of an
``a if c else b`` executor default) propagate the lane to names.
"""

from __future__ import annotations

import ast
from typing import Optional

from .core import Finding, ModuleInfo, RepoModel, iter_funcs, terminal_name

LANE_FACTORIES = {
    "codec_executor": 2,
    "restore_executor": 1,
    "urgent_executor": 0,
}
LANE_CONSTANTS = {"URGENT": 0, "RESTORE": 1, "PERIODIC": 2}
LANE_LABEL = {0: "URGENT", 1: "RESTORE", 2: "PERIODIC"}
WAIT_FUNCS = {"futures_wait", "wait"}


def lane_of_expr(expr: ast.AST, env: dict[str, int]) -> Optional[int]:
    """Lane number of an executor-valued expression, if statically known."""
    if isinstance(expr, ast.Name):
        return env.get(expr.id)
    if isinstance(expr, ast.Call):
        t = terminal_name(expr.func)
        if t in LANE_FACTORIES:
            return LANE_FACTORIES[t]
        if t == "lane" and expr.args:
            return _lane_const(expr.args[0])
        return None
    if isinstance(expr, ast.IfExp):
        # `executor if executor is not None else codec_executor()` — the
        # fallback branch is the statically known default
        known = [lane_of_expr(expr.body, env), lane_of_expr(expr.orelse, env)]
        known = [k for k in known if k is not None]
        if len(known) == 1:
            return known[0]
        if len(known) == 2 and known[0] == known[1]:
            return known[0]
        return None
    return None


def _lane_const(expr: ast.AST) -> Optional[int]:
    t = terminal_name(expr)
    if t in LANE_CONSTANTS:
        return LANE_CONSTANTS[t]
    if isinstance(expr, ast.Constant) and isinstance(expr.value, int):
        return expr.value if expr.value in LANE_LABEL else None
    return None


def _lane_env(fn: ast.AST) -> dict[str, int]:
    """Propagate lanes through simple local assignments (one pass is enough
    for the straight-line `ex = ...` idiom used by the encode/restore
    paths)."""
    env: dict[str, int] = {}
    for _ in range(2):  # second pass resolves name-to-name chains
        for node in ast.walk(fn):
            if isinstance(node, ast.Assign) and len(node.targets) == 1:
                tgt = node.targets[0]
                if isinstance(tgt, ast.Name):
                    lane = lane_of_expr(node.value, env)
                    if lane is not None:
                        env[tgt.id] = lane
    return env


def _submit_lane(call: ast.Call, env: dict[str, int]) -> Optional[int]:
    """Lane of a `<executor>.submit(...)` or `scheduler().submit(PRIO, ...)`
    call, if statically known."""
    if not (isinstance(call.func, ast.Attribute) and call.func.attr == "submit"):
        return None
    recv_lane = lane_of_expr(call.func.value, env)
    if recv_lane is not None:
        return recv_lane
    # CodecScheduler.submit(priority, fn, ...) — receiver is a scheduler
    if call.args:
        return _lane_const(call.args[0])
    return None


def _submitted_callable(call: ast.Call) -> Optional[str]:
    """Bare name of the callable handed to a submit call."""
    if not (isinstance(call.func, ast.Attribute) and call.func.attr == "submit"):
        return None
    args = call.args
    if not args:
        return None
    # scheduler().submit(PRIO, fn, ...) vs lane.submit(fn, ...)
    cand = args[1] if (_lane_const(args[0]) is not None and len(args) > 1) \
        else args[0]
    return terminal_name(cand)


def check_repo(model: RepoModel) -> list[Finding]:
    findings: list[Finding] = []

    # pass 1: which functions are submitted as jobs, and to which lanes
    submitted_to: dict[str, set[int]] = {}
    for mod in model.modules:
        for _cls, fn in iter_funcs(mod.tree):
            env = _lane_env(fn)
            for node in ast.walk(fn):
                if not isinstance(node, ast.Call):
                    continue
                lane = _submit_lane(node, env)
                if lane is None:
                    continue
                callee = _submitted_callable(node)
                if callee:
                    submitted_to.setdefault(callee, set()).add(lane)

    # pass 2: per-function rules
    for mod in model.modules:
        for _cls, fn in iter_funcs(mod.tree):
            env = _lane_env(fn)
            own_lanes = submitted_to.get(fn.name, set())
            findings.extend(_check_fn(mod, fn, env, own_lanes))
    return findings


def _check_fn(mod: ModuleInfo, fn, env: dict[str, int],
              own_lanes: set[int]) -> list[Finding]:
    findings: list[Finding] = []
    is_restore_path = "restore" in fn.name.lower()

    # tainted future names: futures this function submitted to a lane of
    # equal-or-lower priority than the lane(s) the function itself runs on
    tainted: set[str] = set()

    for node in ast.walk(fn):
        if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                and isinstance(node.value, ast.Call):
            lane = _submit_lane(node.value, env)
            if lane is not None and own_lanes \
                    and any(lane >= mine for mine in own_lanes):
                tgt = node.targets[0]
                if isinstance(tgt, ast.Name):
                    tainted.add(tgt.id)
        # futs.append(ex.submit(...)) taints the list name
        if isinstance(node, ast.Call) and isinstance(node.func, ast.Attribute) \
                and node.func.attr == "append" and node.args \
                and isinstance(node.args[0], ast.Call) \
                and isinstance(node.func.value, ast.Name):
            lane = _submit_lane(node.args[0], env)
            if lane is not None and own_lanes \
                    and any(lane >= mine for mine in own_lanes):
                tainted.add(node.func.value.id)

    for node in ast.walk(fn):
        if not isinstance(node, ast.Call):
            continue
        lane = _submit_lane(node, env)

        if is_restore_path and lane is not None and lane != 1:
            findings.append(Finding(
                path=mod.relpath, line=node.lineno, col=node.col_offset,
                code="SPOT011",
                message=(f"restore-path function {fn.name!r} submits to the "
                         f"{LANE_LABEL[lane]} lane — MTTR-window work belongs "
                         f"on the RESTORE lane; use restore_executor() / "
                         f"lane(RESTORE)"),
            ))

        if tainted:
            # fut.result() on a tainted future
            if isinstance(node.func, ast.Attribute) \
                    and node.func.attr in ("result", "wait") \
                    and isinstance(node.func.value, ast.Name) \
                    and node.func.value.id in tainted:
                findings.append(_spot010(mod, fn, node))
            # futures_wait(futs) / wait(futs) on a tainted list
            elif terminal_name(node.func) in WAIT_FUNCS and node.args \
                    and isinstance(node.args[0], ast.Name) \
                    and node.args[0].id in tainted:
                findings.append(_spot010(mod, fn, node))

    # SPOT012: encode chunk loops must yield
    for node in ast.walk(fn):
        if not isinstance(node, (ast.For, ast.While)):
            continue
        body_calls = {terminal_name(c.func)
                      for stmt in node.body for c in ast.walk(stmt)
                      if isinstance(c, ast.Call)}
        if "store_chunk" in body_calls and "maybe_yield" not in body_calls:
            findings.append(Finding(
                path=mod.relpath, line=node.lineno, col=node.col_offset,
                code="SPOT012",
                message=("chunk encode loop without codec_sched.maybe_yield() "
                         "in the body — a periodic encode holds its worker "
                         "for the whole piece and restore/urgent preemption "
                         "latency degrades to one piece; yield once per "
                         "chunk"),
            ))
    return findings


def _spot010(mod: ModuleInfo, fn, node: ast.Call) -> Finding:
    return Finding(
        path=mod.relpath, line=node.lineno, col=node.col_offset,
        code="SPOT010",
        message=(f"{fn.name!r} runs as a lane job and blocks on a future "
                 f"submitted to an equal-or-lower-priority lane — with all "
                 f"workers busy the child can never start (lane "
                 f"self-deadlock); restructure to run the work inline or "
                 f"submit strictly higher priority"),
    )
