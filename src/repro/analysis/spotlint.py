"""spotlint CLI — run the repo-specific rules over a source tree.

Usage::

    PYTHONPATH=src python -m repro.analysis.spotlint src/
    PYTHONPATH=src python -m repro.analysis.spotlint --no-baseline path.py

Exit status is 0 only when every finding is suppressed (inline
``# spotlint: ignore[CODE]`` on the offending line, or a matching entry in
the baseline file) *and* no baseline entry is stale. A stale entry — one
whose recorded file:line no longer holds the recorded source text — fails
the run: baseline suppressions are promises about specific lines, and a
moved or edited line must be re-justified, not silently inherited.
"""

from __future__ import annotations

import argparse
import os
import sys

from . import crash_consistency, lanes, lifetimes, locks, netguard, retries
from .core import (BaselineEntry, Finding, ModuleInfo, RepoModel,
                   load_baseline, load_module, stale_baseline_entries)

RULE_MODULES = (crash_consistency, lanes, lifetimes, locks, netguard, retries)

DEFAULT_BASELINE = "spotlint.baseline"


def collect_files(paths: list[str]) -> list[str]:
    files: list[str] = []
    for p in paths:
        if os.path.isdir(p):
            for dirpath, dirnames, filenames in os.walk(p):
                dirnames[:] = [d for d in dirnames
                               if d not in ("__pycache__", ".git")]
                for fn in sorted(filenames):
                    if fn.endswith(".py"):
                        files.append(os.path.join(dirpath, fn))
        elif p.endswith(".py"):
            files.append(p)
    return files


def analyze(files: list[str]) -> list[Finding]:
    """Parse `files` and run every rule; returns raw (unsuppressed)
    findings, deduplicated on (path, line, col, code)."""
    modules: list[ModuleInfo] = []
    for path in files:
        mod = load_module(path, os.path.normpath(path))
        if mod is not None:
            modules.append(mod)
    model = RepoModel(modules)
    findings: list[Finding] = []
    for rule in RULE_MODULES:
        findings.extend(rule.check_repo(model))
    seen: set[tuple] = set()
    out: list[Finding] = []
    for f in sorted(findings, key=Finding.sort_key):
        k = (f.path, f.line, f.col, f.code)
        if k not in seen:
            seen.add(k)
            out.append(f)
    return out


def apply_suppressions(findings: list[Finding], modules_by_path: dict[str, ModuleInfo],
                       baseline: list[BaselineEntry]) -> list[Finding]:
    by_key = {e.key(): e for e in baseline}
    kept: list[Finding] = []
    for f in findings:
        mod = modules_by_path.get(f.path)
        if mod is not None:
            inline = mod.suppressed.get(f.line, set())
            if f.code in inline:
                continue
        entry = by_key.get((f.path, f.code, f.line))
        if entry is not None and mod is not None \
                and mod.line_text(f.line).strip() == entry.content:
            entry.used = True
            continue
        kept.append(f)
    return kept


def main(argv: list[str] | None = None) -> int:
    parser = argparse.ArgumentParser(
        prog="spotlint",
        description="repo-specific static analysis for the checkpoint layer")
    parser.add_argument("paths", nargs="+", help="files or directories")
    parser.add_argument("--baseline", default=None,
                        help=f"baseline suppression file "
                             f"(default: ./{DEFAULT_BASELINE} when present)")
    parser.add_argument("--no-baseline", action="store_true",
                        help="ignore any baseline file")
    args = parser.parse_args(argv)

    baseline_path = None
    if not args.no_baseline:
        baseline_path = args.baseline or (
            DEFAULT_BASELINE if os.path.exists(DEFAULT_BASELINE) else None)

    baseline: list[BaselineEntry] = []
    stale: list[str] = []
    if baseline_path:
        try:
            baseline = load_baseline(baseline_path)
        except (OSError, ValueError) as e:
            print(f"spotlint: cannot read baseline {baseline_path}: {e}",
                  file=sys.stderr)
            return 2
        stale = stale_baseline_entries(baseline)

    files = collect_files(args.paths)
    if not files:
        print("spotlint: no python files found", file=sys.stderr)
        return 2

    modules_by_path: dict[str, ModuleInfo] = {}
    for path in files:
        mod = load_module(path, os.path.normpath(path))
        if mod is not None:
            modules_by_path[mod.relpath] = mod

    model = RepoModel(list(modules_by_path.values()))
    raw: list[Finding] = []
    for rule in RULE_MODULES:
        raw.extend(rule.check_repo(model))
    seen: set[tuple] = set()
    findings: list[Finding] = []
    for f in sorted(raw, key=Finding.sort_key):
        k = (f.path, f.line, f.col, f.code)
        if k not in seen:
            seen.add(k)
            findings.append(f)

    findings = apply_suppressions(findings, modules_by_path, baseline)

    for f in findings:
        print(f.format())
    for msg in stale:
        print(f"stale-baseline: {msg}")
    for e in baseline:
        if not e.used and not stale:
            print(f"spotlint: note: unused baseline entry "
                  f"{e.relpath}:{e.lineno} {e.code} (line still matches; "
                  f"remove it if the violation is gone)", file=sys.stderr)

    n_files = len(modules_by_path)
    if findings or stale:
        print(f"spotlint: {len(findings)} finding(s), {len(stale)} stale "
              f"baseline entr(ies) across {n_files} file(s)")
        return 1
    print(f"spotlint: clean — {n_files} file(s), "
          f"{len(baseline)} baseline suppression(s)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
