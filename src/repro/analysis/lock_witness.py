"""Runtime lock-order witness — validate the static lock graph under test.

SPOT030 reasons about a *static* lock graph; this module checks the graph
the code actually exercises. When installed it replaces the
``threading.Lock`` / ``RLock`` / ``Condition`` factories with versions
that, for locks created from repo code (creation-site path filter), return
thin proxies recording per-thread acquisition order. Holding A while
acquiring B adds the observed edge A→B, attributed to the first thread and
creation sites that produced it; at teardown, any pair with both A→B and
B→A observed is an **order inversion** — a deadlock needing only the right
interleaving — and the test session fails.

Identity is the lock's *creation site* (file:line of the factory call),
matching SPOT030's creation-site-class keys: every ``CheckpointStore``
instance's ``_commit_lock`` maps to one node, so an inversion between two
store instances is still caught.

``Condition.wait`` is modeled as release + re-acquire: edges into the
condition are re-recorded when the wait returns, and the condition is not
"held" while waiting. Re-entrant acquisition of the same site (RLock, or
two instances from one site) records no self-edge.

Opt-in: ``SPOTON_LOCK_WITNESS=1 pytest ...`` (wired in tests/conftest.py).
"""

from __future__ import annotations

import os
import sys
import threading
from typing import Callable, Optional


def _default_path_filter(filename: str) -> bool:
    fn = filename.replace(os.sep, "/")
    if fn.endswith("/lock_witness.py"):
        # Never witness the witness: with two witnesses stacked (a
        # test-local one over the env-var global one), the inner factory is
        # called from this file — wrapping there would hand Condition a
        # proxied lock whose ownership fallback misreads RLocks.
        return False
    return "/repro/" in fn or fn.endswith("repro")


class _Held(threading.local):
    def __init__(self) -> None:
        self.stack: list[str] = []


class LockWitness:
    def __init__(self, path_filter: Optional[Callable[[str], bool]] = None):
        self.path_filter = path_filter or _default_path_filter
        self._orig_lock = threading.Lock
        self._orig_rlock = threading.RLock
        self._orig_condition = threading.Condition
        # graph state is shared across threads; guard with an *original*
        # (unwitnessed) lock so the witness never observes itself
        self._graph_lock = self._orig_lock()
        # (held_site, acquired_site) -> description of first occurrence
        self.edges: dict[tuple[str, str], str] = {}
        self._held = _Held()
        self._installed = False

    # -- bookkeeping ---------------------------------------------------------

    def _creation_site(self, depth: int = 2) -> Optional[str]:
        frame = sys._getframe(depth)
        filename = frame.f_code.co_filename
        if not self.path_filter(filename):
            return None
        return f"{os.path.basename(filename)}:{frame.f_lineno}"

    def _record_acquire(self, site: str) -> None:
        stack = self._held.stack
        if site not in stack:  # re-entrant same-site acquire: no self-edges
            for held in stack:
                key = (held, site)
                if key not in self.edges:
                    desc = (f"thread {threading.current_thread().name!r} "
                            f"acquired {site} while holding {held}")
                    with self._graph_lock:
                        self.edges.setdefault(key, desc)
        stack.append(site)

    def _record_release(self, site: str) -> None:
        stack = self._held.stack
        for i in range(len(stack) - 1, -1, -1):
            if stack[i] == site:
                del stack[i]
                return

    # -- results -------------------------------------------------------------

    def inversions(self) -> list[str]:
        with self._graph_lock:
            edges = dict(self.edges)
        out: list[str] = []
        reported: set[frozenset] = set()
        for (a, b), desc in sorted(edges.items()):
            if a == b or frozenset((a, b)) in reported:
                continue
            rev = edges.get((b, a))
            if rev is not None:
                reported.add(frozenset((a, b)))
                out.append(f"lock-order inversion between {a} and {b}:\n"
                           f"  {desc}\n  {rev}")
        return out

    # -- installation --------------------------------------------------------

    def install(self) -> None:
        if self._installed:
            return
        witness = self

        def make_lock(*a, **kw):
            site = witness._creation_site()
            real = witness._orig_lock(*a, **kw)
            return real if site is None else _WitnessLock(real, site, witness)

        def make_rlock(*a, **kw):
            site = witness._creation_site()
            real = witness._orig_rlock(*a, **kw)
            return real if site is None else _WitnessLock(real, site, witness)

        def make_condition(lock=None, *a, **kw):
            site = witness._creation_site()
            if isinstance(lock, _WitnessLock):
                lock = lock._real
            real = witness._orig_condition(lock, *a, **kw)
            return real if site is None \
                else _WitnessCondition(real, site, witness)

        threading.Lock = make_lock  # type: ignore[misc]
        threading.RLock = make_rlock  # type: ignore[misc]
        threading.Condition = make_condition  # type: ignore[misc,assignment]
        self._installed = True

    def uninstall(self) -> None:
        if not self._installed:
            return
        threading.Lock = self._orig_lock  # type: ignore[misc]
        threading.RLock = self._orig_rlock  # type: ignore[misc]
        threading.Condition = self._orig_condition  # type: ignore[misc]
        self._installed = False


class _WitnessLock:
    """Proxy around a real Lock/RLock recording acquisition order."""

    def __init__(self, real, site: str, witness: LockWitness):
        self._real = real
        self._site = site
        self._witness = witness

    def acquire(self, *a, **kw):
        got = self._real.acquire(*a, **kw)
        if got:
            self._witness._record_acquire(self._site)
        return got

    def release(self):
        self._real.release()
        self._witness._record_release(self._site)

    def locked(self):
        return self._real.locked()

    def __enter__(self):
        self.acquire()
        return self

    def __exit__(self, *exc):
        self.release()
        return False

    def __repr__(self):
        return f"<WitnessLock {self._site} {self._real!r}>"


class _WitnessCondition:
    """Proxy around a real Condition; wait() is release + re-acquire."""

    def __init__(self, real, site: str, witness: LockWitness):
        self._real = real
        self._site = site
        self._witness = witness

    def acquire(self, *a, **kw):
        got = self._real.acquire(*a, **kw)
        if got:
            self._witness._record_acquire(self._site)
        return got

    def release(self):
        self._real.release()
        self._witness._record_release(self._site)

    def __enter__(self):
        self.acquire()
        return self

    def __exit__(self, *exc):
        self.release()
        return False

    def wait(self, timeout=None):
        self._witness._record_release(self._site)
        try:
            return self._real.wait(timeout)
        finally:
            self._witness._record_acquire(self._site)

    def wait_for(self, predicate, timeout=None):
        # delegate to our wait() so held-state stays correct per iteration
        self._witness._record_release(self._site)
        try:
            return self._real.wait_for(predicate, timeout)
        finally:
            self._witness._record_acquire(self._site)

    def notify(self, n=1):
        self._real.notify(n)

    def notify_all(self):
        self._real.notify_all()

    def __repr__(self):
        return f"<WitnessCondition {self._site} {self._real!r}>"


# -- module-level convenience used by conftest --------------------------------

_active: LockWitness | None = None


def install_from_env(env_var: str = "SPOTON_LOCK_WITNESS") -> LockWitness | None:
    """Install a process-wide witness when `env_var` is set; idempotent."""
    global _active
    if not os.environ.get(env_var):
        return None
    if _active is None:
        _active = LockWitness()
        _active.install()
    return _active


def active() -> LockWitness | None:
    return _active


def uninstall() -> list[str]:
    """Tear down the process-wide witness; returns observed inversions."""
    global _active
    if _active is None:
        return []
    _active.uninstall()
    inv = _active.inversions()
    _active = None
    return inv
