"""SPOT040 — unbounded IO retry loops.

The retry substrate (``repro.core.retry``) exists so every retried IO op is
*bounded* and *backed off*: a bare ``while True`` that swallows OSError
around a filesystem or endpoint call retries a dead disk forever, burning
the eviction-notice window and hanging shutdown. The rule flags::

    while True:              # SPOT040
        try:
            os.replace(tmp, path)
            return
        except OSError:
            pass             # no bound, no backoff, swallowed

A loop is flagged when ALL of these hold:

- the loop condition is constantly true (``while True`` / ``while 1``) —
  counter-bounded loops (``for _ in range(n)``, ``while attempts < n``)
  are exits by construction;
- a ``try`` in the loop body wraps a *primitive IO* call (``os.*``,
  ``shutil.*``, bare ``open``, ``fsync``/``replace``/``rename``-style
  terminals, ``urlopen``, ``.poll``) — worker loops that dispatch
  higher-level jobs are not retry loops and are left alone;
- some matching handler catches an IO-ish exception class (``OSError``,
  ``IOError``, ``Exception``, bare except, ...) and its body neither
  re-raises, breaks, nor returns — i.e. it swallows and loops — and
  contains no backoff (a ``sleep``-terminal call exempts: an infinite
  *paced* poll loop is a deliberate design, not an accident).

The fix is ``repro.core.retry.call_with_retry`` (bounded attempts,
exponential backoff, jitter, transient-errno classification) or an explicit
attempt bound with a terminal ``raise``.
"""

from __future__ import annotations

import ast

from .core import Finding, ModuleInfo, RepoModel, dotted, iter_funcs, terminal_name

# primitive-IO call surface: dotted prefixes and terminal names that mark a
# try body as "retrying an IO op" (kept narrow on purpose — flagging job
# dispatch in worker loops would drown the signal)
IO_DOTTED_PREFIXES = ("os.", "shutil.", "urllib.")
IO_TERMINALS = {
    "open", "fsync", "fsync_dir", "replace", "rename", "unlink", "remove",
    "readinto", "urlopen", "poll", "poll_once", "recv", "send", "connect",
    "flush", "stat", "utime",
}

# exception classes whose swallowing inside a retry loop hides IO failure
CAUGHT_IO_CLASSES = {
    "OSError", "IOError", "PermissionError", "TimeoutError",
    "ConnectionError", "Exception", "BaseException",
}

# a call with one of these terminals inside the handler counts as backoff
BACKOFF_TERMINALS = {"sleep", "wait", "maybe_yield"}


def check_repo(model: RepoModel) -> list[Finding]:
    findings: list[Finding] = []
    for mod in model.modules:
        findings.extend(_check_module(mod))
    return findings


def _check_module(mod: ModuleInfo) -> list[Finding]:
    findings: list[Finding] = []
    for _classname, fn in iter_funcs(mod.tree):
        for node in ast.walk(fn):
            if isinstance(node, ast.While) and _const_true(node.test):
                hit = _unbounded_retry(node)
                if hit is not None:
                    findings.append(Finding(
                        path=mod.relpath, line=node.lineno,
                        col=node.col_offset, code="SPOT040",
                        message=(
                            f"unbounded retry loop: `while True` re-attempts "
                            f"{hit} with a handler that swallows the failure "
                            f"(no raise/break/return) and never backs off — "
                            f"a persistent fault spins forever; use "
                            f"repro.core.retry.call_with_retry or bound the "
                            f"attempts and re-raise"),
                    ))
    return findings


def _const_true(test: ast.AST) -> bool:
    return isinstance(test, ast.Constant) and bool(test.value)


def _io_call_name(call: ast.Call) -> str | None:
    d = dotted(call.func)
    if d is not None and d.startswith(IO_DOTTED_PREFIXES):
        return d
    t = terminal_name(call.func)
    if t in IO_TERMINALS:
        return d or t
    return None


def _catches_io(handler: ast.ExceptHandler) -> bool:
    if handler.type is None:                      # bare except
        return True
    types = (handler.type.elts if isinstance(handler.type, ast.Tuple)
             else [handler.type])
    return any(terminal_name(t) in CAUGHT_IO_CLASSES for t in types)


def _swallows(handler: ast.ExceptHandler) -> bool:
    """Handler body has no exit (raise/break/return) and no backoff call."""
    for node in ast.walk(handler):
        if isinstance(node, (ast.Raise, ast.Break, ast.Return)):
            return False
        if (isinstance(node, ast.Call)
                and terminal_name(node.func) in BACKOFF_TERMINALS):
            return False
    return True


def _unbounded_retry(loop: ast.While) -> str | None:
    """Name of the retried IO call when `loop` is an unbounded swallowing
    retry around primitive IO, else None."""
    for node in ast.walk(loop):
        if not isinstance(node, ast.Try):
            continue
        io_name = None
        for sub in ast.walk(node):
            # only the try body's calls count; walking the whole Try also
            # visits handlers, so filter by position against the handlers
            if isinstance(sub, ast.Call):
                name = _io_call_name(sub)
                if name is not None and _in_try_body(node, sub):
                    io_name = name
                    break
        if io_name is None:
            continue
        for handler in node.handlers:
            if _catches_io(handler) and _swallows(handler):
                return io_name
    return None


def _in_try_body(tr: ast.Try, node: ast.AST) -> bool:
    return any(node is b or any(node is d for d in ast.walk(b))
               for b in tr.body)
