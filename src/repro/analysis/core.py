"""Shared model for spotlint rules: parsed modules, suppressions, baseline.

Everything here is stdlib-only (``ast`` + ``re``); rule modules consume a
:class:`RepoModel` built once over all analyzed files so cross-module rules
(lock graph, lane taint) see the whole picture.
"""

from __future__ import annotations

import ast
import os
import re
from dataclasses import dataclass, field
from typing import Iterator, Optional

# `# spotlint: ignore[SPOT001]` or `# spotlint: ignore[SPOT001, SPOT031]`
SUPPRESS_RE = re.compile(r"#\s*spotlint:\s*ignore\[([A-Z0-9,\s]+)\]")

# Attribute-call names too generic to resolve to a repo method: calling
# `obj.get(...)` must not be treated as a call into every class that happens
# to define a `get` method (that is how a lock graph grows phantom cycles).
GENERIC_METHODS = frozenset({
    "get", "put", "pop", "append", "add", "remove", "discard", "clear",
    "update", "items", "keys", "values", "read", "write", "close", "wait",
    "set", "result", "cancel", "join", "start", "submit", "touch", "check",
    "copy", "encode", "decode", "format", "strip", "split", "exists",
    "mkdir", "unlink", "acquire", "release", "notify", "notify_all",
    "task_done", "get_nowait", "put_nowait",
})


@dataclass(frozen=True)
class Finding:
    path: str  # path as given on the command line / relative to cwd
    line: int
    col: int
    code: str
    message: str

    def format(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.code} {self.message}"

    def sort_key(self) -> tuple:
        return (self.path, self.line, self.col, self.code)


@dataclass
class ModuleInfo:
    path: str  # absolute path on disk
    relpath: str  # as reported in findings
    module_name: str  # dotted, e.g. "repro.checkpoint.store"
    source: str
    lines: list[str]
    tree: ast.Module
    suppressed: dict[int, set[str]] = field(default_factory=dict)

    def line_text(self, lineno: int) -> str:
        if 1 <= lineno <= len(self.lines):
            return self.lines[lineno - 1]
        return ""


@dataclass
class FuncEntry:
    name: str
    classname: Optional[str]
    module: ModuleInfo
    node: ast.FunctionDef | ast.AsyncFunctionDef

    @property
    def qualname(self) -> str:
        if self.classname:
            return f"{self.module.module_name}.{self.classname}.{self.name}"
        return f"{self.module.module_name}.{self.name}"


def parse_suppressions(lines: list[str]) -> dict[int, set[str]]:
    out: dict[int, set[str]] = {}
    for i, line in enumerate(lines, start=1):
        m = SUPPRESS_RE.search(line)
        if m:
            codes = {c.strip() for c in m.group(1).split(",") if c.strip()}
            out[i] = codes
    return out


def module_name_for(relpath: str) -> str:
    parts = relpath.replace(os.sep, "/").split("/")
    if parts and parts[0] == "src":
        parts = parts[1:]
    if parts and parts[-1].endswith(".py"):
        parts[-1] = parts[-1][:-3]
    if parts and parts[-1] == "__init__":
        parts = parts[:-1]
    return ".".join(parts)


def load_module(path: str, relpath: str) -> Optional[ModuleInfo]:
    try:
        with open(path, "r", encoding="utf-8") as f:
            source = f.read()
        tree = ast.parse(source, filename=path)
    except (OSError, SyntaxError):
        return None
    lines = source.splitlines()
    return ModuleInfo(
        path=path,
        relpath=relpath,
        module_name=module_name_for(relpath),
        source=source,
        lines=lines,
        tree=tree,
        suppressed=parse_suppressions(lines),
    )


# -- AST helpers ---------------------------------------------------------------


def dotted(node: ast.AST) -> Optional[str]:
    """`a.b.c` -> "a.b.c"; `name` -> "name"; anything else -> None."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        base = dotted(node.value)
        if base is None:
            return None
        return f"{base}.{node.attr}"
    return None


def terminal_name(func: ast.AST) -> Optional[str]:
    """Last path component of a call target: os.replace -> "replace"."""
    if isinstance(func, ast.Name):
        return func.id
    if isinstance(func, ast.Attribute):
        return func.attr
    return None


def iter_funcs(tree: ast.Module) -> Iterator[tuple[Optional[str], ast.FunctionDef | ast.AsyncFunctionDef]]:
    """Yield (enclosing class name or None, function node) for every def,
    including nested defs (attributed to the enclosing class, if any)."""

    def walk(node: ast.AST, classname: Optional[str]) -> Iterator:
        for child in ast.iter_child_nodes(node):
            if isinstance(child, ast.ClassDef):
                yield from walk(child, child.name)
            elif isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                yield classname, child
                yield from walk(child, classname)
            else:
                yield from walk(child, classname)

    yield from walk(tree, None)


def calls_in(node: ast.AST) -> list[ast.Call]:
    """All Call nodes under `node`, in source order."""
    out = [n for n in ast.walk(node) if isinstance(n, ast.Call)]
    out.sort(key=lambda c: (c.lineno, c.col_offset))
    return out


class RepoModel:
    """Cross-module index built once and shared by all rules."""

    def __init__(self, modules: list[ModuleInfo]):
        self.modules = modules
        # bare function/method name -> every def with that name
        self.functions: dict[str, list[FuncEntry]] = {}
        # (module_name, classname) of classes that define close()/__exit__
        self.closeable_classes: set[tuple[str, str]] = set()
        for mod in modules:
            for classname, fn in iter_funcs(mod.tree):
                self.functions.setdefault(fn.name, []).append(
                    FuncEntry(name=fn.name, classname=classname, module=mod, node=fn))
                if classname and fn.name in ("close", "__exit__", "release"):
                    self.closeable_classes.add((mod.module_name, classname))

    def resolve_call(self, call: ast.Call, module: ModuleInfo,
                     classname: Optional[str]) -> list[FuncEntry]:
        """Map a call site to candidate FuncEntry targets.

        - bare `name(...)`: a module-level def named `name` — same module
          first, else a unique repo-wide module-level def (covers
          `from .x import name` without import tracking);
        - `self.m(...)`: method `m` of the enclosing class;
        - `obj.m(...)`: any method named `m`, unless `m` is too generic
          (GENERIC_METHODS) to resolve soundly.
        """
        func = call.func
        if isinstance(func, ast.Name):
            cands = self.functions.get(func.id, [])
            local = [e for e in cands
                     if e.module is module and e.classname is None]
            if local:
                return local
            toplevel = [e for e in cands if e.classname is None]
            if len(toplevel) == 1:
                return toplevel
            return []
        if isinstance(func, ast.Attribute):
            name = func.attr
            if isinstance(func.value, ast.Name) and func.value.id == "self" and classname:
                return [e for e in self.functions.get(name, [])
                        if e.module is module and e.classname == classname]
            if name in GENERIC_METHODS:
                return []
            return [e for e in self.functions.get(name, [])
                    if e.classname is not None]
        return []


# -- baseline ------------------------------------------------------------------


@dataclass
class BaselineEntry:
    relpath: str
    code: str
    lineno: int
    content: str  # stripped source line the suppression was recorded against
    used: bool = False

    def key(self) -> tuple[str, str, int]:
        return (self.relpath, self.code, self.lineno)


def load_baseline(path: str) -> list[BaselineEntry]:
    entries: list[BaselineEntry] = []
    with open(path, "r", encoding="utf-8") as f:
        for raw in f:
            line = raw.rstrip("\n")
            if not line or line.startswith("#"):
                continue
            parts = line.split("\t", 3)
            if len(parts) != 4:
                raise ValueError(f"malformed baseline line: {line!r}")
            relpath, code, lineno_s, content = parts
            entries.append(BaselineEntry(relpath=relpath, code=code,
                                         lineno=int(lineno_s), content=content))
    return entries


def stale_baseline_entries(entries: list[BaselineEntry],
                           root: str = ".") -> list[str]:
    """Entries whose target file/line no longer matches the recorded content.

    A baseline suppression is a promise about one specific line; once that
    line moves or changes, the promise must be re-examined, so a stale entry
    fails the run instead of silently suppressing whatever now lives there.
    """
    problems: list[str] = []
    for e in entries:
        path = os.path.join(root, e.relpath)
        try:
            with open(path, "r", encoding="utf-8") as f:
                lines = f.read().splitlines()
        except OSError:
            problems.append(f"{e.relpath}: file missing for baseline entry "
                            f"{e.code} line {e.lineno}")
            continue
        if not (1 <= e.lineno <= len(lines)):
            problems.append(f"{e.relpath}:{e.lineno}: baseline entry {e.code} "
                            f"points past end of file ({len(lines)} lines)")
            continue
        if lines[e.lineno - 1].strip() != e.content:
            problems.append(
                f"{e.relpath}:{e.lineno}: baseline entry {e.code} is stale — "
                f"line now reads {lines[e.lineno - 1].strip()!r}, baseline "
                f"recorded {e.content!r}")
    return problems
