"""SPOT030/031 — lock discipline across the checkpoint layer.

The checkpoint layer has four modules with internal locks (`codec_sched`'s
scheduler condition, `store`'s pin/stage/commit locks, `device_delta`'s
tracker lock, `async_ckpt`'s writer lock) and threads that cross them: lane
workers run store callbacks, the async writer runs tracker commit
bookkeeping, atexit runs scheduler shutdown. Two static rules:

- **SPOT030** — the static lock-acquisition graph (edge A→B when code
  acquires B while holding A, directly via nested ``with`` or through any
  resolvable call chain) must be acyclic. A cycle is a deadlock waiting for
  the right thread interleaving.
- **SPOT031** — no blocking work while holding a Lock/Condition: fsync,
  rename, rmtree, ``.result()``/``wait()``/``join()`` on futures/threads,
  device fingerprint round-trips. A lock that is held across IO turns every
  other participant (including URGENT-lane work in the eviction-notice
  window) into a queue behind that IO. ``cond.wait()`` on the *held*
  condition is exempt — that is the one blocking call a condition exists
  for, and it releases the lock while waiting.

Lock identity is the *creation site class*: ``self.X = threading.Lock()``
in class C defines lock "module.C.X"; every instance of C shares that node
in the graph (the runtime lock witness in ``lock_witness.py`` keys by
creation site for the same reason, so the static and observed graphs are
comparable).
"""

from __future__ import annotations

import ast
from typing import Optional

from .core import (Finding, FuncEntry, ModuleInfo, RepoModel, dotted,
                   iter_funcs, terminal_name)

LOCK_CTORS = {"Lock", "RLock", "Condition"}

BLOCKING_DOTTED = {
    "os.fsync", "os.replace", "os.rename", "os.remove", "os.unlink",
    "os.listdir", "os.utime", "os.stat", "os.makedirs", "os.scandir",
    "shutil.rmtree", "time.sleep", "socket.create_connection",
}
BLOCKING_BARE = {
    "fsync_dir", "futures_wait", "fingerprint_diff", "fingerprint_blocks",
    "sleep", "open",
}
BLOCKING_METHODS = {
    "result", "wait", "join", "touch", "check", "mark_committed",
    "write_manifest", "readinto", "flush",
    # peer_exchange client/server socket surface: a peer network call under
    # the tracker or pool lock stalls every thread behind a dead peer's
    # timeout — fetch/push (PeerChunkClient), sendall/recv/recv_into/
    # accept/connect (raw sockets) all wait on the network
    "sendall", "recv", "recv_into", "connect", "accept", "fetch", "push",
    # object-store ChunkBackend client surface (checkpoint.backend): every
    # one of these rides the network (or a modeled link) and may burn a full
    # bounded-retry cycle — under the pool's tracker lock that serializes
    # all writers behind one flaky endpoint
    "get_range", "put", "complete_multipart", "create_multipart",
    "upload_part", "head",
}


def _is_lock_ctor(expr: ast.AST) -> Optional[str]:
    if isinstance(expr, ast.Call):
        t = terminal_name(expr.func)
        d = dotted(expr.func)
        if t in LOCK_CTORS and (d == t or (d or "").startswith("threading.")):
            return t
    return None


class LockIndex:
    """Creation-site lock identities discovered across the repo."""

    def __init__(self, model: RepoModel):
        # (module_name, classname) -> {attr: key}
        self.class_locks: dict[tuple[str, str], dict[str, str]] = {}
        # (module_name, name) -> key
        self.module_locks: dict[tuple[str, str], str] = {}
        # attr name -> every key using that attr (for obj.attr resolution)
        self.attr_owners: dict[str, set[str]] = {}
        for mod in model.modules:
            for node in mod.tree.body:
                if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                        and isinstance(node.targets[0], ast.Name) \
                        and _is_lock_ctor(node.value):
                    name = node.targets[0].id
                    key = f"{mod.module_name}.{name}"
                    self.module_locks[(mod.module_name, name)] = key
            for classname, fn in iter_funcs(mod.tree):
                if classname is None:
                    continue
                for sub in ast.walk(fn):
                    if isinstance(sub, ast.Assign) and len(sub.targets) == 1 \
                            and isinstance(sub.targets[0], ast.Attribute):
                        tgt = sub.targets[0]
                        if isinstance(tgt.value, ast.Name) \
                                and tgt.value.id == "self" \
                                and _is_lock_ctor(sub.value):
                            key = f"{mod.module_name}.{classname}.{tgt.attr}"
                            self.class_locks.setdefault(
                                (mod.module_name, classname), {})[tgt.attr] = key
                            self.attr_owners.setdefault(tgt.attr, set()).add(key)

    def resolve(self, expr: ast.AST, mod: ModuleInfo,
                classname: Optional[str]) -> Optional[str]:
        """Lock key of a `with <expr>:` context expression, if known."""
        if isinstance(expr, ast.Name):
            return self.module_locks.get((mod.module_name, expr.id))
        if isinstance(expr, ast.Attribute):
            if isinstance(expr.value, ast.Name) and expr.value.id == "self" \
                    and classname is not None:
                attrs = self.class_locks.get((mod.module_name, classname), {})
                if expr.attr in attrs:
                    return attrs[expr.attr]
            owners = self.attr_owners.get(expr.attr, set())
            if len(owners) == 1:
                return next(iter(owners))
        return None


def check_repo(model: RepoModel) -> list[Finding]:
    index = LockIndex(model)

    entries: list[FuncEntry] = [e for lst in model.functions.values()
                                for e in lst]
    by_node: dict[int, FuncEntry] = {id(e.node): e for e in entries}

    # direct lock acquisitions + resolved callees per function
    direct_acq: dict[int, set[str]] = {}
    callees: dict[int, list[FuncEntry]] = {}
    for e in entries:
        acq: set[str] = set()
        outs: list[FuncEntry] = []
        for node in ast.walk(e.node):
            if isinstance(node, ast.With):
                for item in node.items:
                    key = index.resolve(item.context_expr, e.module, e.classname)
                    if key:
                        acq.add(key)
            elif isinstance(node, ast.Call):
                outs.extend(model.resolve_call(node, e.module, e.classname))
        direct_acq[id(e.node)] = acq
        callees[id(e.node)] = outs

    # fixpoint: locks a function may acquire, transitively through calls
    may_acq: dict[int, set[str]] = {k: set(v) for k, v in direct_acq.items()}
    changed = True
    while changed:
        changed = False
        for e in entries:
            mine = may_acq[id(e.node)]
            before = len(mine)
            for callee in callees[id(e.node)]:
                mine |= may_acq.get(id(callee.node), set())
            if len(mine) != before:
                changed = True

    findings: list[Finding] = []
    # edges: (held, acquired) -> (relpath, line, col, via)
    edges: dict[tuple[str, str], tuple[str, int, int, str]] = {}

    for e in entries:
        for node in ast.walk(e.node):
            if not isinstance(node, ast.With):
                continue
            item_keys = [(item, index.resolve(item.context_expr, e.module,
                                              e.classname))
                         for item in node.items]
            held = [(item, k) for item, k in item_keys if k]
            if not held:
                continue
            # `with a, b:` acquires b while holding a
            for i in range(len(held) - 1):
                for j in range(i + 1, len(held)):
                    a, b = held[i][1], held[j][1]
                    if a != b:
                        edges.setdefault((a, b), (
                            e.module.relpath, node.lineno, node.col_offset,
                            f"`with {a.rsplit('.', 1)[-1]}, "
                            f"{b.rsplit('.', 1)[-1]}` in {e.qualname}"))
            for item, key in held:
                held_dotted = dotted(item.context_expr)
                for sub_stmt in node.body:
                    for sub in ast.walk(sub_stmt):
                        if isinstance(sub, ast.With):
                            for it2 in sub.items:
                                k2 = index.resolve(it2.context_expr, e.module,
                                                   e.classname)
                                if k2 and k2 != key:
                                    edges.setdefault((key, k2), (
                                        e.module.relpath, sub.lineno,
                                        sub.col_offset,
                                        f"nested with in {e.qualname}"))
                        elif isinstance(sub, ast.Call):
                            findings.extend(_check_blocking(
                                e, sub, key, held_dotted))
                            for callee in model.resolve_call(
                                    sub, e.module, e.classname):
                                for k2 in may_acq.get(id(callee.node), set()):
                                    if k2 != key:
                                        edges.setdefault((key, k2), (
                                            e.module.relpath, sub.lineno,
                                            sub.col_offset,
                                            f"call to {callee.qualname} "
                                            f"in {e.qualname}"))

    findings.extend(_cycle_findings(edges))
    return findings


def _check_blocking(e: FuncEntry, call: ast.Call, lock_key: str,
                    held_dotted: Optional[str]) -> list[Finding]:
    d = dotted(call.func)
    t = terminal_name(call.func)
    reason = None
    if d in BLOCKING_DOTTED:
        reason = d
    elif isinstance(call.func, ast.Name) and t in BLOCKING_BARE:
        reason = t
    elif isinstance(call.func, ast.Attribute) and t in BLOCKING_METHODS:
        recv = dotted(call.func.value)
        # cond.wait()/notify patterns on the lock being held are the point
        # of a condition variable, not a violation
        if recv is not None and recv == held_dotted:
            reason = None
        # `os.path.join` and `", ".join(...)` are pure, not thread joins
        elif t == "join" and (recv in ("os.path", "posixpath", "ntpath")
                              or isinstance(call.func.value, ast.Constant)):
            reason = None
        elif isinstance(call.func.value, ast.Constant):
            reason = None
        else:
            reason = f".{t}()"
    if reason is None:
        return []
    return [Finding(
        path=e.module.relpath, line=call.lineno, col=call.col_offset,
        code="SPOT031",
        message=(f"blocking call {reason} while holding {lock_key} — every "
                 f"thread contending for that lock (including urgent-save "
                 f"work in the eviction-notice window) now queues behind "
                 f"this IO; move the blocking work outside the critical "
                 f"section or snapshot state under the lock and operate on "
                 f"the snapshot"),
    )]


def _cycle_findings(
        edges: dict[tuple[str, str], tuple[str, int, int, str]]) -> list[Finding]:
    """Tarjan SCC over the lock graph; any SCC with ≥2 locks is a potential
    deadlock cycle. One finding per SCC, anchored at its lexically-first
    edge."""
    adj: dict[str, set[str]] = {}
    for (a, b) in edges:
        adj.setdefault(a, set()).add(b)
        adj.setdefault(b, set())

    idx: dict[str, int] = {}
    low: dict[str, int] = {}
    on_stack: set[str] = set()
    stack: list[str] = []
    counter = [0]
    sccs: list[list[str]] = []

    def strongconnect(v: str) -> None:
        # iterative Tarjan: (node, iterator) frames
        work = [(v, iter(adj.get(v, ())))]
        idx[v] = low[v] = counter[0]
        counter[0] += 1
        stack.append(v)
        on_stack.add(v)
        while work:
            node, it = work[-1]
            advanced = False
            for w in it:
                if w not in idx:
                    idx[w] = low[w] = counter[0]
                    counter[0] += 1
                    stack.append(w)
                    on_stack.add(w)
                    work.append((w, iter(adj.get(w, ()))))
                    advanced = True
                    break
                elif w in on_stack:
                    low[node] = min(low[node], idx[w])
            if advanced:
                continue
            work.pop()
            if work:
                parent = work[-1][0]
                low[parent] = min(low[parent], low[node])
            if low[node] == idx[node]:
                scc = []
                while True:
                    w = stack.pop()
                    on_stack.discard(w)
                    scc.append(w)
                    if w == node:
                        break
                if len(scc) > 1:
                    sccs.append(scc)

    for v in list(adj):
        if v not in idx:
            strongconnect(v)

    findings: list[Finding] = []
    for scc in sccs:
        members = set(scc)
        cyc_edges = sorted(
            ((site, (a, b)) for (a, b), site in edges.items()
             if a in members and b in members),
            key=lambda x: (x[0][0], x[0][1], x[0][2]))
        site, (a, b) = cyc_edges[0]
        detail = "; ".join(
            f"{a2}→{b2} ({s[3]})" for s, (a2, b2) in cyc_edges)
        findings.append(Finding(
            path=site[0], line=site[1], col=site[2],
            code="SPOT030",
            message=(f"lock-acquisition cycle: {' ↔ '.join(sorted(members))} "
                     f"— edges: {detail}; impose a single acquisition order "
                     f"(or drop to a snapshot-then-operate pattern) to make "
                     f"this deadlock impossible"),
        ))
    return findings
