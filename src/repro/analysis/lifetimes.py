"""SPOT020/021 — zero-copy buffer lifetimes and the one-copy payload rule.

The read path hands out ``memoryview``s over pool-owned mmaps
(``mmap_view`` / ``ChunkPool.read_view`` / ``read_payload_view``): cheap,
but the mapping behind the view can be unmapped on eviction, so a view must
stay inside a scope that ends with ``release_view`` (or be returned, which
transfers that obligation to the caller, or live on an object that owns the
mapping and exposes ``close``). A view stashed on ``self``/a global with no
close path outlives its mapping and becomes a use-after-unmap (SPOT020).

The write path has the dual rule (the PR 3 freeze fix): snapshot payloads
must be built from *copied* host arrays — ``np.asarray(x)`` on a caller-
owned array is a no-copy alias, and the async writer thread then encodes
memory the training step is concurrently mutating, producing a checkpoint
that is internally torn (SPOT021). Use ``serialize.to_host`` /
``np.array(..., copy=True)``.
"""

from __future__ import annotations

import ast
from typing import Optional

from .core import Finding, ModuleInfo, RepoModel, dotted, iter_funcs, terminal_name

# producers of mmap-backed views whose release must be tracked
VIEW_PRODUCERS = {"mmap_view", "read_view", "read_payload_view"}
# additionally forbidden from living on self/globals without a close path
STORED_VIEW_PRODUCERS = VIEW_PRODUCERS | {"memoryview"}


def check_repo(model: RepoModel) -> list[Finding]:
    findings: list[Finding] = []
    for mod in model.modules:
        findings.extend(_check_module(mod, model))
    return findings


def _check_module(mod: ModuleInfo, model: RepoModel) -> list[Finding]:
    findings: list[Finding] = []

    # module-level `NAME = mmap_view(...)` — a global view never dies
    for node in mod.tree.body:
        if isinstance(node, ast.Assign) and _producer_of(node.value,
                                                        STORED_VIEW_PRODUCERS):
            findings.append(Finding(
                path=mod.relpath, line=node.lineno, col=node.col_offset,
                code="SPOT020",
                message=("mmap/memoryview stored in a module global — the "
                         "view outlives any release scope and pins (or "
                         "dangles into) its mapping forever; keep views "
                         "function-local with release_view, or on an object "
                         "with close()"),
            ))

    for classname, fn in iter_funcs(mod.tree):
        findings.extend(_check_fn(mod, model, classname, fn))
    return findings


def _producer_of(expr: ast.AST, producers: set[str]) -> Optional[str]:
    if isinstance(expr, ast.Call):
        t = terminal_name(expr.func)
        if t in producers:
            return t
    return None


def _check_fn(mod: ModuleInfo, model: RepoModel, classname: Optional[str],
              fn) -> list[Finding]:
    findings: list[Finding] = []

    locals_to_track: list[tuple[str, ast.Assign]] = []
    for node in ast.walk(fn):
        if not isinstance(node, ast.Assign) or len(node.targets) != 1:
            continue
        tgt = node.targets[0]
        producer = _producer_of(node.value, STORED_VIEW_PRODUCERS)
        if producer is None:
            continue
        # self.X = <view producer>: allowed only when the class owns the
        # lifetime, i.e. defines close()/__exit__/release()
        if isinstance(tgt, ast.Attribute) and isinstance(tgt.value, ast.Name) \
                and tgt.value.id == "self":
            if classname and (mod.module_name, classname) in model.closeable_classes:
                continue
            findings.append(Finding(
                path=mod.relpath, line=node.lineno, col=node.col_offset,
                code="SPOT020",
                message=(f"view stored on self.{tgt.attr} but "
                         f"{classname or 'this class'} has no close()/"
                         f"__exit__ — the view escapes every release scope; "
                         f"give the class a close() that release_view()s it, "
                         f"or keep the view function-local"),
            ))
        elif isinstance(tgt, ast.Name) \
                and _producer_of(node.value, VIEW_PRODUCERS):
            locals_to_track.append((tgt.id, node))

    if locals_to_track:
        released: set[str] = set()
        returned: set[str] = set()
        for node in ast.walk(fn):
            if isinstance(node, ast.Call) \
                    and terminal_name(node.func) == "release_view":
                for arg in node.args:
                    if isinstance(arg, ast.Name):
                        released.add(arg.id)
            elif isinstance(node, ast.Return) and node.value is not None:
                for sub in ast.walk(node.value):
                    if isinstance(sub, ast.Name):
                        returned.add(sub.id)
        for name, assign in locals_to_track:
            if name in released or name in returned:
                continue
            findings.append(Finding(
                path=mod.relpath, line=assign.lineno, col=assign.col_offset,
                code="SPOT020",
                message=(f"mmap-backed view {name!r} is neither "
                         f"release_view()'d nor returned from "
                         f"{fn.name!r} — the mapping leaks and a later "
                         f"eviction turns the view into a use-after-unmap; "
                         f"release it in a finally block or return it to "
                         f"transfer ownership"),
            ))

    # SPOT021: np.asarray on a bare name in the checkpoint layer aliases
    # caller memory into the payload instead of copying it. Scoped to
    # repro.checkpoint.* — elsewhere (kernels, optim) asarray is a
    # device→host materialization, which *does* copy. Exempt:
    #   - jnp/jax.asarray (host→device put, copies);
    #   - float(np.asarray(x)) / int(...) scalar conversions (no buffer
    #     survives);
    #   - functions that also call x.copy() or np.array(x, ...): the
    #     to_host idiom, where asarray is the jax/sequence branch and the
    #     numpy branch is explicitly copied.
    if not mod.module_name.startswith("repro.checkpoint"):
        return findings
    scalar_wrapped: set[int] = set()
    copied_names: set[str] = set()
    for node in ast.walk(fn):
        if not isinstance(node, ast.Call):
            continue
        t = terminal_name(node.func)
        if isinstance(node.func, ast.Name) and node.func.id in ("float", "int"):
            for arg in node.args:
                scalar_wrapped.add(id(arg))
        elif t == "copy" and isinstance(node.func, ast.Attribute) \
                and isinstance(node.func.value, ast.Name):
            copied_names.add(node.func.value.id)
        elif t == "array" and node.args and isinstance(node.args[0], ast.Name):
            copied_names.add(node.args[0].id)
    for node in ast.walk(fn):
        if isinstance(node, ast.Call) and terminal_name(node.func) == "asarray" \
                and node.args and isinstance(node.args[0], ast.Name):
            d = dotted(node.func) or ""
            if d.startswith(("jnp.", "jax.")):
                continue
            if id(node) in scalar_wrapped:
                continue
            if node.args[0].id in copied_names:
                continue
            findings.append(Finding(
                path=mod.relpath, line=node.lineno, col=node.col_offset,
                code="SPOT021",
                message=(f"np.asarray({node.args[0].id}) does not copy — a "
                         f"snapshot leaf built from it aliases memory the "
                         f"training step keeps mutating while the writer "
                         f"thread encodes it (torn checkpoint); use "
                         f"serialize.to_host / np.array(..., copy=True)"),
            ))
    return findings
