"""Peer-to-peer chunk exchange — replacements warm-restore from neighbors.

A replacement instance's restore normally cold-reads shared storage, even
though the surviving fleet members hold most of the checkpoint's chunks in
their instance-local pools (page-cache hot, NIC-close). This module closes
that gap with the smallest possible protocol: every fleet member runs a tiny
length-prefixed TCP server over its **content-addressed** local pool, and a
restoring process consults the peers *before* shared storage.

Why this is safe with so little machinery: chunks are addressed by the
sha1 of their stored bytes (``chunkstore.chunk_digest``), so a fetched
payload is validated by re-digesting it against the address that was
requested — a lying, stale or truncated peer is indistinguishable from a
miss and simply falls through to the store. No peer is trusted; the shared
store remains the durable source of truth.

Wire protocol (all integers big-endian; one request per connection round):

    request  := op(1) | hash(40 ascii hex) | [PUT only: len(u64) | payload]
    response := status(1) | [GET hit: len(u64) | payload]

    ops:    b"G" get chunk        b"P" put (push) chunk
    status: b"H" hit   b"M" miss   b"O" ok   b"E" error

Read-through restore (``ReadThroughPool``): the decode path resolves each
chunk local pool → peer fetch → shared store. A peer hit is written into
the local pool first (``sync_dir=False`` — the local pool is a cache; the
store holds the durable copy) and decoded from there on the RESTORE lane;
a miss or dead peer falls back to the store's chunk file, whose decode
already runs under ``core.retry``'s bounded IO retry. Seeding happens in
the eviction-notice window: ``FleetPeerExchange.seed_from`` pushes the
evictee's hottest chunks (most recently written first) to every survivor,
so the replacement warms from neighbors at NIC speed instead of re-reading
the shared volume.
"""

from __future__ import annotations

import logging
import os
import socket
import threading
from typing import Iterable, Sequence

from ..faults import inject as faults
from . import chunkstore
from . import codec_sched
from .chunkstore import ChunkRef
from .ioutil import mmap_view, release_view

log = logging.getLogger("spoton.peer")

HASH_LEN = 40                       # ascii hex sha1 (same width as blake2b-160)
OP_GET, OP_PUT = b"G", b"P"
ST_HIT, ST_MISS, ST_OK, ST_ERR = b"H", b"M", b"O", b"E"
MAX_CHUNK_BYTES = 1 << 28           # frame sanity bound, far above any chunk


def _recv_exact(sock: socket.socket, n: int) -> bytes | None:
    """Read exactly ``n`` bytes or return None on a short/closed stream."""
    buf = bytearray(n)
    view = memoryview(buf)
    got = 0
    while got < n:
        try:
            k = sock.recv_into(view[got:], n - got)
        except OSError:
            return None
        if not k:
            return None
        got += k
    return bytes(buf)


class PeerChunkServer:
    """One fleet member's chunk server: serves sha1-addressed chunks out of
    its local pool over loopback/NIC TCP. GET streams the pool file through
    an mmap view (page cache → socket, no intermediate copy); PUT accepts a
    digest-verified chunk into the pool (the seeding path). Connections are
    handled on short-lived daemon threads — the request unit is one chunk,
    and the accept loop owns no locks, so a stuck peer never wedges saves."""

    def __init__(self, pool: chunkstore.ChunkPool, *, host: str = "127.0.0.1",
                 port: int = 0):
        self.pool = pool
        self._sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._sock.bind((host, port))
        self._sock.listen(16)
        self._sock.settimeout(0.2)      # bounded accept wait -> clean close
        self.address: tuple[str, int] = self._sock.getsockname()
        self._stop = threading.Event()
        self._accept_thread: threading.Thread | None = None
        self.stats = {"get_hits": 0, "get_misses": 0, "puts": 0,
                      "bytes_served": 0}
        self._stats_lock = threading.Lock()

    def start(self) -> "PeerChunkServer":
        t = threading.Thread(target=self._accept_loop, daemon=True,
                             name=f"peer-chunk-{self.address[1]}")
        self._accept_thread = t
        t.start()
        return self

    def close(self) -> None:
        self._stop.set()
        if self._accept_thread is not None:
            self._accept_thread.join(timeout=2.0)
        try:
            self._sock.close()
        except OSError:
            pass

    def _bump(self, key: str, n: int = 1) -> None:
        with self._stats_lock:
            self.stats[key] += n

    def _accept_loop(self) -> None:
        while not self._stop.is_set():
            try:
                conn, _addr = self._sock.accept()
            except socket.timeout:
                continue
            except OSError:
                return
            threading.Thread(target=self._serve, args=(conn,),
                             daemon=True).start()

    def _serve(self, conn: socket.socket) -> None:
        conn.settimeout(5.0)
        try:
            with conn:
                while True:
                    head = _recv_exact(conn, 1 + HASH_LEN)
                    if head is None:
                        return
                    op, h = head[:1], head[1:].decode("ascii", "replace")
                    if op == OP_GET:
                        self._handle_get(conn, h)
                    elif op == OP_PUT:
                        self._handle_put(conn, h)
                    else:
                        conn.sendall(ST_ERR)
                        return
        except (OSError, ValueError):
            pass                        # peer vanished mid-request: its loss
        except faults.SimulatedCrash:
            pass                        # injected mid-transfer death (tests)

    def _handle_get(self, conn: socket.socket, h: str) -> None:
        path = self.pool.path(h)
        try:
            view = mmap_view(path)
        except OSError:
            self._bump("get_misses")
            conn.sendall(ST_MISS)
            return
        try:
            header = ST_HIT + len(view).to_bytes(8, "big")
            try:
                faults.fault_point("peer.send", path)
            except BaseException:
                # injected mid-transfer death: announce the full length but
                # deliver half, then drop the connection — exactly what a
                # preempted instance does to its clients
                conn.sendall(header + bytes(view[:len(view) // 2]))
                raise
            conn.sendall(header)
            conn.sendall(view)          # mmap fast path: page cache -> socket
            self._bump("get_hits")
            self._bump("bytes_served", len(view))
        finally:
            release_view(view)

    def _handle_put(self, conn: socket.socket, h: str) -> None:
        head = _recv_exact(conn, 8)
        if head is None:
            return
        n = int.from_bytes(head, "big")
        if not 0 < n <= MAX_CHUNK_BYTES:
            conn.sendall(ST_ERR)
            return
        data = _recv_exact(conn, n)
        # digest-verify before pooling: a push may not plant bytes under an
        # address they don't hash to (content addressing is the trust model)
        if data is None or chunkstore.chunk_digest(data) != h:
            conn.sendall(ST_ERR)
            return
        try:
            # local pool is a cache of the durable store -> no dir fsync
            self.pool.write(h, data, sync_dir=False)
        except OSError:
            conn.sendall(ST_ERR)
            return
        self._bump("puts")
        conn.sendall(ST_OK)


class PeerChunkClient:
    """Fetch/push sha1-addressed chunks from/to a set of peer servers.

    ``fetch`` rotates its starting peer by the chunk hash (cheap load
    spreading across survivors) and tries each peer once; any connection
    error, timeout, short read or digest mismatch moves on to the next peer
    and ultimately returns None — the caller's store fallback is the only
    retry that matters (``core.retry`` bounds it). Never raises for a dead
    peer; a dead peer must cost one timeout, not a restore."""

    def __init__(self, peers: Sequence[tuple[str, int]], *,
                 timeout_s: float = 1.0):
        self.peers = list(peers)
        self.timeout_s = timeout_s
        self.stats = {"hits": 0, "misses": 0, "bytes_fetched": 0,
                      "pushes": 0, "push_failures": 0}
        self._stats_lock = threading.Lock()

    def _bump(self, key: str, n: int = 1) -> None:
        with self._stats_lock:
            self.stats[key] += n

    def fetch(self, ref: ChunkRef) -> bytes | None:
        """Stored bytes of ``ref`` from the first peer that has them, or
        None. The returned payload has already been validated against the
        content address (``chunk_content_ok``)."""
        if not self.peers:
            return None
        start = int(ref.hash[:8], 16) % len(self.peers)
        for k in range(len(self.peers)):
            data = self._fetch_one(self.peers[(start + k) % len(self.peers)],
                                   ref)
            if data is not None:
                self._bump("hits")
                self._bump("bytes_fetched", len(data))
                return data
        self._bump("misses")
        return None

    def _fetch_one(self, addr: tuple[str, int], ref: ChunkRef) -> bytes | None:
        try:
            faults.fault_point("peer.fetch", ref.hash)
            with socket.create_connection(addr, timeout=self.timeout_s) as s:
                s.settimeout(self.timeout_s)
                s.sendall(OP_GET + ref.hash.encode("ascii"))
                head = _recv_exact(s, 1)
                if head != ST_HIT:
                    return None
                size = _recv_exact(s, 8)
                if size is None or int.from_bytes(size, "big") != ref.nbytes:
                    return None
                data = _recv_exact(s, ref.nbytes)
        except OSError:
            return None                 # dead/unreachable peer == miss
        if data is None or not chunkstore.chunk_content_ok(ref, data):
            return None
        return data

    def push(self, addr: tuple[str, int], h: str, data) -> bool:
        """Push one chunk to one peer (the eviction-notice seeding path)."""
        try:
            with socket.create_connection(addr, timeout=self.timeout_s) as s:
                s.settimeout(self.timeout_s)
                s.sendall(OP_PUT + h.encode("ascii")
                          + len(data).to_bytes(8, "big"))
                s.sendall(data)
                ok = _recv_exact(s, 1) == ST_OK
        except OSError:
            ok = False
        self._bump("pushes" if ok else "push_failures")
        return ok


class ReadThroughPool(chunkstore.ChunkPool):
    """Chunk resolution for a replacement's restore: local → peers → store.

    Subclasses ``ChunkPool`` and overrides the single ``chunk_path`` hook
    the decode path resolves files through, so every reader/restore code
    path (range-addressed, streaming, zero-copy mmap) gets peer read-through
    without knowing it. A peer hit lands in the local pool first (atomic
    write, no dir fsync — it's a cache) and decodes from there; a miss
    resolves to the shared store's file, where the existing decode path's
    bounded IO retry (``core.retry``) applies. Content addressing makes the
    three sources interchangeable: whatever file the path points at must
    still digest to the ref's address before any byte is trusted.
    """

    def __init__(self, local: chunkstore.ChunkPool, client: PeerChunkClient,
                 shared: chunkstore.ChunkPool):
        super().__init__(local.root)
        self.local = local
        self.client = client
        self.shared = shared
        self.stats = {"local_hits": 0, "peer_hits": 0, "store_reads": 0}
        self._stats_lock = threading.Lock()

    def _bump(self, key: str) -> None:
        with self._stats_lock:
            self.stats[key] += 1

    def _resolve(self, ref: ChunkRef) -> chunkstore.ChunkPool:
        if self.local.check(ref.hash, ref.nbytes):
            self._bump("local_hits")
            return self.local
        data = self.client.fetch(ref)
        if data is not None:
            try:
                self.local.write(ref.hash, data, sync_dir=False)
                self._bump("peer_hits")
                return self.local
            except OSError:
                pass                    # cache write failed: cold-read store
        self._bump("store_reads")
        return self.shared

    def chunk_path(self, ref: ChunkRef) -> str:
        # delegate the hook, not the raw path: a plain pool's chunk_path IS
        # its path, but a backend cache pool (backend.BackendChunkPool) uses
        # chunk_path to fault the chunk in from the object store — composing
        # here gives the full local → peer → object-store resolution order
        return self._resolve(ref).chunk_path(ref)

    def read_view(self, ref: ChunkRef):
        return self._resolve(ref).read_view(ref)

    def check(self, h: str, nbytes: int) -> bool:
        return self.local.check(h, nbytes) or self.shared.check(h, nbytes)

    def touch(self, h: str) -> bool:
        return self.local.touch(h) or self.shared.touch(h)


def warm_restore_from_peers(pool: ReadThroughPool,
                            refs: Iterable[ChunkRef | dict],
                            *, executor=None, batch: int = 32) -> dict:
    """Prefetch a restore's chunks from peers into the local pool.

    Restore-window work: fetch batches run on the scheduler's RESTORE lane
    (they jump queued periodic encodes) and yield between chunks
    (``codec_sched.maybe_yield``), the same preemption discipline every
    chunk loop in the store path follows. Purely an optimization — the
    read-through pool fetches on demand anyway — but prefetching overlaps
    the peer RTTs with manifest parsing and template planning, which is
    where the replacement's MTTR goes. Returns {"warmed", "already_local",
    "missed", "total"}.
    """
    ex = executor if executor is not None else chunkstore.restore_executor()
    crefs = [r if isinstance(r, ChunkRef) else ChunkRef.from_json(r)
             for r in refs]

    def fetch_batch(part: list[ChunkRef]) -> tuple[int, int]:
        warmed = local = 0
        for ref in part:
            codec_sched.maybe_yield()
            if pool.local.check(ref.hash, ref.nbytes):
                local += 1
                continue
            data = pool.client.fetch(ref)
            if data is None:
                continue
            try:
                pool.local.write(ref.hash, data, sync_dir=False)
                warmed += 1
            except OSError:
                pass
        return warmed, local

    futs = [ex.submit(fetch_batch, crefs[i:i + batch])
            for i in range(0, len(crefs), batch)]
    warmed = already = 0
    for f in futs:
        w, a = f.result()
        warmed += w
        already += a
    return {"warmed": warmed, "already_local": already,
            "missed": len(crefs) - warmed - already, "total": len(crefs)}


class FleetPeerExchange:
    """The fleet's exchange fabric: one (local pool, chunk server) pair per
    member, plus the eviction-notice seeding policy.

    The local pools model each member's instance-local storage (NVMe/page
    cache) as distinct directories under ``root`` — caches over the shared
    store, never the durable copy. ``seed_from`` is the notice-window move:
    the evictee pushes its hottest chunks — most recently written first,
    bounded by ``budget_bytes`` sized to what the notice window (AWS
    rebalance ≈120 s) can ship — to every survivor, so whichever member
    restores next finds them a NIC hop away."""

    def __init__(self, root: str, n_members: int, *,
                 budget_bytes: int = 256 << 20, timeout_s: float = 1.0):
        self.root = root
        self.budget_bytes = budget_bytes
        self.timeout_s = timeout_s
        self.members: list[tuple[chunkstore.ChunkPool, PeerChunkServer]] = []
        for i in range(n_members):
            pool = chunkstore.ChunkPool(
                os.path.join(root, f"member{i:02d}", chunkstore.CHUNKS_DIRNAME))
            self.members.append((pool, PeerChunkServer(pool).start()))
        self.stats = {"seed_events": 0, "seeded_chunks": 0, "seeded_bytes": 0}

    def close(self) -> None:
        for _pool, srv in self.members:
            srv.close()

    def addresses(self, *, exclude: int | None = None) -> list[tuple[str, int]]:
        return [srv.address for i, (_p, srv) in enumerate(self.members)
                if i != exclude]

    def client_for(self, member: int) -> PeerChunkClient:
        """A client over everyone *except* ``member`` (you don't fetch from
        yourself — the local pool already answered)."""
        return PeerChunkClient(self.addresses(exclude=member),
                               timeout_s=self.timeout_s)

    def read_through(self, member: int,
                     shared: chunkstore.ChunkPool) -> ReadThroughPool:
        """The pool ``member``'s restore should decode through."""
        return ReadThroughPool(self.members[member][0],
                               self.client_for(member), shared)

    def seed_from(self, evictee: int, source_pool: chunkstore.ChunkPool,
                  hashes: Iterable[str], *,
                  budget_bytes: int | None = None) -> dict:
        """Evictee push during the notice window: hottest chunks first.

        Hotness is write recency (pool mtime — ``touch`` keeps reused
        chunks fresh, so recency tracks the live working set, not just the
        last delta). Pushes stop at the byte budget; every pushed chunk
        goes to *all* survivors, so the seeding survives a second eviction.
        Returns {"chunks", "bytes", "survivors"}.
        """
        budget = self.budget_bytes if budget_bytes is None else budget_bytes
        addrs = self.addresses(exclude=evictee)
        if not addrs:
            return {"chunks": 0, "bytes": 0, "survivors": 0}
        items = []
        for h in hashes:
            try:
                st = os.stat(source_pool.path(h))
            except OSError:
                continue                # swept or never landed: nothing to push
            items.append((st.st_mtime, st.st_size, h))
        items.sort(reverse=True)        # hottest (newest write) first
        client = PeerChunkClient(addrs, timeout_s=self.timeout_s)
        sent = sent_bytes = 0
        for _mt, size, h in items:
            if sent_bytes + size > budget:
                break
            try:
                with open(source_pool.path(h), "rb") as f:
                    data = f.read()
            except OSError:
                continue
            landed = [client.push(addr, h, data) for addr in addrs]
            if any(landed):
                sent += 1
                sent_bytes += len(data)
        self.stats["seed_events"] += 1
        self.stats["seeded_chunks"] += sent
        self.stats["seeded_bytes"] += sent_bytes
        log.info("peer seed: member %d pushed %d chunks (%d bytes) to %d "
                 "survivors", evictee, sent, sent_bytes, len(addrs))
        return {"chunks": sent, "bytes": sent_bytes, "survivors": len(addrs)}
