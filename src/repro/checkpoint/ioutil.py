"""Low-level IO helpers shared by the checkpoint hot path.

Three concerns live here because every layer of the save/restore path needs
them and none owns them:

* **Buffer views** — the save path's one-copy invariant (a tensor is
  materialized on the host at most once; hashing, chunking and compression all
  run on ``memoryview`` windows over that buffer) needs a way to see any
  numpy array, including ml_dtypes extended types that reject the buffer
  protocol's format negotiation, as flat bytes without copying.
* **mmap with fallback** — the restore path maps each container/pool file
  once and slices it, but must degrade to plain reads on filesystems or
  platforms where mmap fails (some network mounts reject ``MAP_SHARED``).
* **Directory durability** — an ``os.replace`` is atomic but not durable
  until the parent directory's entry is fsynced; a crash right after rename
  may otherwise roll the name back and lose a "committed" checkpoint.
"""

from __future__ import annotations

import mmap
import os

import numpy as np


def array_bytes_view(arr: np.ndarray) -> memoryview:
    """Flat ``memoryview`` (format 'B') over an array's buffer, zero-copy.

    The view goes through a uint8 reinterpretation so extended dtypes
    (bfloat16, float8) export cleanly. Requires a C-contiguous array; callers
    on the save path guarantee that (``quantize`` returns contiguous).
    """
    return memoryview(arr.reshape(-1).view(np.uint8).data)


def mmap_view(path: str) -> memoryview:
    """Read-only view of a whole file: mmap-backed when possible, else a
    plain read. The returned memoryview keeps its backing object (mmap or
    bytes) alive; pass it to ``release_view`` for deterministic teardown."""
    with open(path, "rb") as f:
        try:
            return memoryview(mmap.mmap(f.fileno(), 0, access=mmap.ACCESS_READ))
        except (ValueError, OSError):     # empty file / fs without mmap
            return memoryview(f.read())


def release_view(view: memoryview) -> None:
    """Release a view from ``mmap_view`` and close its mapping now rather
    than at GC time (an open mapping pins the file on some filesystems).
    If other exports of the mapping are still alive (zero-copy restore
    payloads slice it), the close is deferred to their GC instead."""
    backing = view.obj
    view.release()
    close = getattr(backing, "close", None)   # mmap has close(); bytes doesn't
    if close is not None:
        try:
            close()
        except BufferError:
            pass  # a live payload view still exports this mapping


def fsync_dir(path: str) -> None:
    """fsync a directory so renames/creates inside it survive a crash.

    Best-effort: directories aren't opendable for fsync on every platform
    (or may race with a concurrent sweep), and losing the *durability* of a
    rename is strictly better than failing the save that performed it.
    """
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:
        return
    try:
        os.fsync(fd)
    except OSError:
        pass
    finally:
        os.close(fd)
