"""Low-level IO helpers shared by the checkpoint hot path.

Three concerns live here because every layer of the save/restore path needs
them and none owns them:

* **Buffer views** — the save path's one-copy invariant (a tensor is
  materialized on the host at most once; hashing, chunking and compression all
  run on ``memoryview`` windows over that buffer) needs a way to see any
  numpy array, including ml_dtypes extended types that reject the buffer
  protocol's format negotiation, as flat bytes without copying.
* **mmap with fallback** — the restore path maps each container/pool file
  once and slices it, but must degrade to plain reads on filesystems or
  platforms where mmap fails (some network mounts reject ``MAP_SHARED``).
* **Directory durability** — an ``os.replace`` is atomic but not durable
  until the parent directory's entry is fsynced; a crash right after rename
  may otherwise roll the name back and lose a "committed" checkpoint.
"""

from __future__ import annotations

import errno
import logging
import mmap
import os
import threading

import numpy as np

from ..faults import inject as faults

log = logging.getLogger(__name__)


def array_bytes_view(arr: np.ndarray) -> memoryview:
    """Flat ``memoryview`` (format 'B') over an array's buffer, zero-copy.

    The view goes through a uint8 reinterpretation so extended dtypes
    (bfloat16, float8) export cleanly. Requires a C-contiguous array; callers
    on the save path guarantee that (``quantize`` returns contiguous).
    """
    return memoryview(arr.reshape(-1).view(np.uint8).data)


def mmap_view(path: str) -> memoryview:
    """Read-only view of a whole file: mmap-backed when possible, else a
    plain read. The returned memoryview keeps its backing object (mmap or
    bytes) alive; pass it to ``release_view`` for deterministic teardown."""
    faults.fault_point("file.mmap", path)
    with open(path, "rb") as f:
        try:
            return memoryview(mmap.mmap(f.fileno(), 0, access=mmap.ACCESS_READ))
        except (ValueError, OSError):     # empty file / fs without mmap
            return memoryview(f.read())


def release_view(view: memoryview) -> None:
    """Release a view from ``mmap_view`` and close its mapping now rather
    than at GC time (an open mapping pins the file on some filesystems).
    If other exports of the mapping are still alive (zero-copy restore
    payloads slice it), the close is deferred to their GC instead."""
    backing = view.obj
    view.release()
    close = getattr(backing, "close", None)   # mmap has close(); bytes doesn't
    if close is not None:
        try:
            close()
        except BufferError:
            pass  # a live payload view still exports this mapping


# Filesystems that cannot fsync a directory fd report one of these; the
# rename is as durable as that mount can make it, so warn once and move on.
_FSYNC_UNSUPPORTED = frozenset(e for e in (
    errno.EINVAL,
    getattr(errno, "ENOTSUP", None),
    getattr(errno, "EOPNOTSUPP", None),
    errno.ENOSYS,
    errno.EBADF,
) if e is not None)

_fsync_warn_lock = threading.Lock()
_fsync_warned = False


def fsync_dir(path: str) -> None:
    """fsync a directory so renames/creates inside it survive a crash.

    Tolerant of filesystems that cannot fsync a directory fd (EINVAL /
    ENOTSUP / ENOSYS — common on overlayfs and some network mounts): those
    warn once per process and return, since the mount offers no stronger
    durability anyway. A *real* IO failure (EIO and friends) propagates —
    the rename's durability was genuinely lost and the commit must not be
    reported as durable.
    """
    global _fsync_warned
    faults.fault_point("dir.fsync", path)
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:
        return  # directory vanished (concurrent sweep) or not opendable
    try:
        os.fsync(fd)
    except OSError as exc:
        if exc.errno in _FSYNC_UNSUPPORTED:
            with _fsync_warn_lock:
                if not _fsync_warned:
                    _fsync_warned = True
                    log.warning(
                        "directory fsync unsupported on this filesystem "
                        "(%s for %s); renames are only as durable as the "
                        "mount allows", errno.errorcode.get(exc.errno or 0,
                                                            exc.errno), path)
            return
        raise
    finally:
        os.close(fd)
