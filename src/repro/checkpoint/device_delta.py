"""Device-resident delta detection — the save path's change detector.

Before this module, every save staged the *full* model+optimizer state
device→host and then discovered on the host (sha1 per chunk, ``DeltaIndex``
memo) that most chunks hadn't changed — full-state D2H bandwidth and host
hashing spent on bytes the save then threw away. The tracker moves the
decision onto the device:

* after each committed save it keeps, per tensor piece, the uint32 per-block
  fingerprint array (``kernels.fingerprint``) **device-resident**, plus the
  pool ``ChunkRef`` of every block from that save's manifest;
* the next save recomputes fingerprints on device, compares them against the
  previous save's with one elementwise ``!=`` (only the tiny bool vector
  crosses the link), gathers **only the dirty blocks** into one device array
  and copies that to host;
* clean blocks reuse the previous save's chunk refs — they skip the D2H
  copy, the host sha1 *and* the encode entirely. Transferred (dirty) blocks
  still get the pool's sha1 content address, so the pool, manifests, gc and
  restore are untouched and restores stay bit-identical.

Fingerprint vs content address: the device digest (32 bits/block) decides
what to *skip*; the host sha1 (160 bits) remains the *addressing* and
integrity scheme for every byte that lands in the pool. A fingerprint
collision (2^-32 per changed block) would reuse a stale block in one
checkpoint — the inherent risk of any digest-delta scheme, bounded by the
shape/dtype/codec/chunk-size identity checks below, which also make the
*systematic* aliasing cases (reshaped or recast leaf with identical bytes)
take the full path rather than trusting the digest.

Consistency contract: a block is skipped **only** against refs recorded from
this process's last *committed* save (the commit callback fires after the
COMMITTED marker lands), so every reused ref is reachable from a committed
manifest — the pool gc never sweeps those. Cross-writer sweeps on a shared
store are age-gated (hours) and held off by the throttled ``touch`` below
(seconds); the periodic re-verify additionally re-checks clean refs against
the pool *while the device data is still available*, so a missing chunk
simply turns its block dirty instead of dangling.

Urgent (termination) saves bypass the tracker: the eviction-notice window
cannot wait for a fingerprint round-trip at a step boundary, so they take
the full prestage path (and may on-device-quantize, which the tracker never
mixes with — quantized payloads have tensor-global scales, so one changed
element dirties every block anyway).
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable

import numpy as np

import jax
import jax.numpy as jnp
import functools

from ..kernels.fingerprint import (fingerprint_blocks, fingerprint_diff,
                                   n_blocks_of, supported_dtype)
from . import chunkstore
from . import codec_sched
from . import serialize as ser
from .ioutil import array_bytes_view

# leaves below this size take the dense path: the fingerprint dispatch +
# bookkeeping costs more than just copying them
MIN_FINGERPRINT_BYTES = 1 << 16


@dataclass
class DeltaBlocks:
    """Sparse payload of one tensor piece: dirty blocks on host, clean
    blocks as pool refs from the last committed save. Stands in for the
    dense ndarray inside ``Snapshot.leaves[...].pieces`` — the write path
    encodes the dirty rows and reuses the clean refs verbatim."""

    shape: tuple[int, ...]
    dtype_name: str            # payload dtype (tracked pieces never quantize)
    nbytes: int                # full raw payload bytes
    chunk_size: int
    n_blocks: int
    codec: str                 # resolved, compression-only codec
    dirty_ids: tuple[int, ...]
    dirty_data: np.ndarray | None   # (k, elems_per_block), payload dtype
    clean_refs: dict[int, chunkstore.ChunkRef] = field(default_factory=dict)

    def dirty_bytes(self) -> int:
        return sum(min(self.chunk_size, self.nbytes - ci * self.chunk_size)
                   for ci in self.dirty_ids)

    def dirty_view(self, j: int, ci: int) -> memoryview:
        """Raw-byte window of the j-th dirty row (block ``ci``), trimmed to
        the block's valid length (the last block may be partial)."""
        valid = min(self.chunk_size, self.nbytes - ci * self.chunk_size)
        return array_bytes_view(self.dirty_data[j])[:valid]


def stable_piece_key(name: str, index, global_shape,
                     dtype_name: str) -> tuple[str, int]:
    """Rescale-stable tracker key for one tensor piece.

    ``(leaf name, global flat byte offset of the piece's first element)`` —
    derived from the *global logical coordinates*, not from any local block
    or device numbering, so the same stored bytes map to the same key on
    every topology. That is what lets an elastic rescale remap surviving
    fingerprints instead of invalidating the tracker: a piece a process
    still addresses after the mesh re-plan keeps its entry under the
    identical key. Fully-replicated and whole-tensor pieces sit at offset 0,
    which keeps the common single-piece lookups trivial.
    """
    itemsize = ser.name_to_dtype(dtype_name).itemsize
    off_elems = 0
    stride = 1
    for (lo, _hi), dim in zip(reversed(tuple(index or ())),
                              reversed(tuple(global_shape or ()))):
        off_elems += int(lo) * stride
        stride *= int(dim)
    return name, off_elems * itemsize


@dataclass
class _Entry:
    """Per-piece state from the last committed save."""

    fp: Any                    # device uint32[n_blocks]
    refs: list[chunkstore.ChunkRef]
    codec: str
    shape: tuple[int, ...]
    dtype_name: str
    chunk_size: int
    verified_at: float         # monotonic ts of last pool check/touch
    # global byte span [offset, offset+length) this piece covers, plus the
    # whole leaf's logical byte size — the inputs of the rescale
    # addressability decision (see DeviceDeltaTracker.rescale)
    span: tuple[int, int] = (0, 0)
    total_nbytes: int = 0


@dataclass
class _Pending:
    """Fingerprint work issued at prestage, consumed by extract."""

    leaf: Any                  # the array the digests were computed over
    fp: Any                    # device uint32[n_blocks]
    diff: Any | None           # device bool[n_blocks] (when an entry existed)
    # the exact entry the diff was computed against: an async commit may
    # replace the entry between prestage and extract, and a diff against
    # the old fingerprints must never be paired with the new refs (a block
    # that reverted to its older value would silently reuse a stale chunk)
    ent: "_Entry | None" = None


@functools.partial(jax.jit, static_argnames=("epb", "n_blocks"))
def _gather_blocks(x, ids, epb, n_blocks):
    """One device gather of the dirty blocks: (k, epb) in x's dtype. The
    result is a fresh buffer, so a donated/overwritten ``x`` on the next
    train step can never alias the bytes being written out."""
    flat = x.reshape(-1)
    pad = n_blocks * epb - flat.size
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat.reshape(n_blocks, epb)[ids]


def _copy_to_host_async(arr) -> None:
    try:
        arr.copy_to_host_async()
    except Exception:
        pass                   # backend without async transfer: gather blocks


class _Staged:
    """One leaf's in-flight delta extraction (diff dispatched on device)."""

    def __init__(self, tracker: "DeviceDeltaTracker", name: str, leaf,
                 ent: _Entry, fp_new, diff_dev, codec: str):
        self.tracker = tracker
        self.name = name
        self.leaf = leaf
        self.ent = ent
        self.fp_new = fp_new
        self.diff_dev = diff_dev
        self.codec = codec
        self.dense = False         # high churn: gather wouldn't pay
        self._gathered = None
        self._dirty: np.ndarray | None = None

    def resolve(self) -> None:
        """Sync the tiny diff vector, re-verify clean refs if due, and issue
        the device gather + async D2H for the dirty blocks. Called in the
        extract's staging pass so gathers of different leaves overlap.

        When most blocks are dirty the block gather cannot beat a plain
        full-leaf stage (it's the same bytes plus an index pass), so the
        leaf falls back to the dense path — the fingerprints are still
        committed, so the next low-churn save deltas normally."""
        diff = np.asarray(self.diff_dev)
        dirty = set(np.nonzero(diff)[0].tolist())
        ent = self.ent
        if len(dirty) > self.tracker.dense_fallback_frac * len(ent.refs):
            self.dense = True
            _copy_to_host_async(self.leaf)
            return
        now = time.monotonic()
        if now - ent.verified_at > self.tracker.touch_interval_s:
            # periodic liveness pass over the clean refs — while the device
            # data is still here, so a swept chunk just turns dirty. touch
            # keeps reused chunks' mtimes ahead of cross-writer age gates;
            # throttling it is what removes the per-chunk stat+utime
            # syscalls from the steady-state save, and the pass itself runs
            # batched on the scheduler's RESTORE lane (stat/utime release
            # the GIL) so a large leaf — thousands of blocks — doesn't
            # serialize two syscalls per chunk on the thread the trainer is
            # stalled on; the restore lane because the trainer is stalled
            # on this pass right now — it must not queue behind background
            # periodic encodes
            pool = self.tracker.pool
            refs = ent.refs

            def _verify(ids):
                return [ci for ci in ids
                        if not (pool.check(refs[ci].hash, refs[ci].nbytes)
                                and pool.touch(refs[ci].hash))]

            clean = [ci for ci in range(len(refs)) if ci not in dirty]
            batch = 512
            if len(clean) <= batch:
                dirty.update(_verify(clean))
            else:
                ex = chunkstore.restore_executor()
                for fut in [ex.submit(_verify, clean[i:i + batch])
                            for i in range(0, len(clean), batch)]:
                    dirty.update(fut.result())
            ent.verified_at = now
        self._dirty = np.asarray(sorted(dirty), dtype=np.int64)
        if self._dirty.size:
            epb = ent.chunk_size // np.dtype(self.leaf.dtype).itemsize
            # pad the id vector to a power-of-two bucket: the ids' shape is
            # part of the jit cache key, and churn drifts save-to-save, so
            # unbucketed gathers would recompile on the trainer thread for
            # every new dirty count. Padding repeats the last id; finish()
            # slices the duplicate rows off after the host copy.
            k = self._dirty.size
            k_pad = min(1 << (k - 1).bit_length() if k > 1 else 1,
                        len(ent.refs))
            ids = np.pad(self._dirty, (0, k_pad - k), mode="edge")
            self._gathered = _gather_blocks(self.leaf, jnp.asarray(ids),
                                            epb, len(ent.refs))
            _copy_to_host_async(self._gathered)

    def finish(self) -> tuple[DeltaBlocks, int, int] | None:
        """Materialize: returns (piece payload, d2h bytes, skipped bytes),
        or None when ``resolve`` chose the dense fallback (the caller
        gathers the whole leaf as usual)."""
        if self.dense:
            return None
        ent = self.ent
        data = (np.asarray(self._gathered)[:self._dirty.size]
                if self._gathered is not None else None)
        dirty_ids = tuple(int(i) for i in self._dirty)
        nbytes = int(np.prod(ent.shape)) * ser.name_to_dtype(ent.dtype_name).itemsize
        db = DeltaBlocks(
            shape=ent.shape, dtype_name=ent.dtype_name, nbytes=nbytes,
            chunk_size=ent.chunk_size, n_blocks=len(ent.refs),
            codec=self.codec, dirty_ids=dirty_ids, dirty_data=data,
            clean_refs={ci: ent.refs[ci] for ci in range(len(ent.refs))
                        if ci not in set(dirty_ids)})
        self.tracker.stats["blocks_transferred"] += len(dirty_ids)
        self.tracker.stats["blocks_skipped"] += len(ent.refs) - len(dirty_ids)
        # honest link accounting: the bucket-padded gather rows crossed too,
        # plus the diff bool vector
        moved = (self._gathered.size * np.dtype(self.leaf.dtype).itemsize
                 if self._gathered is not None else 0)
        d2h = moved + len(ent.refs)
        return db, d2h, nbytes - db.dirty_bytes()


class DeviceDeltaTracker:
    """Owns the device-resident fingerprints and clean-block refs across
    saves. One tracker per (store, training process); thread-safe — the
    async writer commits on its own thread while the trainer stages the
    next save."""

    def __init__(self, pool: chunkstore.ChunkPool, *, chunk_size: int,
                 compress: bool = True, quantize_moments: bool = False,
                 min_bytes: int = MIN_FINGERPRINT_BYTES,
                 touch_interval_s: float = 30.0,
                 dense_fallback_frac: float = 0.5):
        self.pool = pool
        self.chunk_size = int(chunk_size)
        self.compress = compress
        self.quantize_moments = quantize_moments
        self.min_bytes = min_bytes
        self.touch_interval_s = touch_interval_s
        self.dense_fallback_frac = dense_fallback_frac
        self._lock = threading.Lock()
        self._entries: dict[tuple[str, int], _Entry] = {}
        self._pending: dict[str, _Pending] = {}
        # observability: decisions this process made, read by tests/benches
        self.stats = {"tracked_saves": 0, "blocks_skipped": 0,
                      "blocks_transferred": 0, "fallbacks": 0,
                      "rescale_events": 0, "fp_kept": 0, "fp_dropped": 0}

    # -- eligibility --------------------------------------------------------

    def _codec_for(self, name: str, leaf) -> str | None:
        """Resolved codec when ``leaf`` can take the fingerprint path, else
        None (dense). Tracked pieces must be single-device jax arrays with a
        bitcastable dtype and a quantization-free codec — the int8 absmax
        scale is tensor-global, so quantized payloads re-encode wholesale
        whenever anything changed and block deltas buy nothing."""
        if self.chunk_size % 4:
            return None
        if not isinstance(leaf, jax.Array) or leaf.ndim < 1:
            return None
        try:
            if not (leaf.is_fully_replicated
                    or len(leaf.sharding.device_set) == 1):
                return None
        except Exception:
            return None
        dt = np.dtype(leaf.dtype)
        if not supported_dtype(dt) or leaf.nbytes < self.min_bytes:
            return None
        codec = ser.resolve_codec(ser.codec_for_meta(
            name, dt, leaf.nbytes, ndim=leaf.ndim, compress=self.compress,
            quantize_moments=self.quantize_moments))
        quant, _comp = ser.split_codec(codec)
        return None if quant else codec

    # -- prestage (trainer supplier) ---------------------------------------

    def prestage_leaf(self, name: str, leaf) -> bool:
        """Kick the fingerprint + diff compute for one leaf at checkpoint-
        decision time, so the device work overlaps the gap until extract.
        Returns False when the leaf is not fingerprint-eligible (caller
        falls back to the plain D2H prestage)."""
        codec = self._codec_for(name, leaf)
        if codec is None:
            return False
        with self._lock:
            ent = self._entries.get((name, 0))
        # fingerprint dispatch runs OUTSIDE the tracker lock: the same lock
        # serializes the async writer's commit bookkeeping, and a commit
        # callback queued behind a device kernel dispatch would stall the
        # writer thread (and anything waiting on it) for no correctness
        # gain — _Entry values are never mutated in place, and begin()'s
        # `pend.ent is ent` guard already discards a diff whose entry was
        # swapped by a commit that landed in between
        if ent is not None and self._usable(ent, leaf, codec):
            fp, diff = fingerprint_diff(leaf, ent.fp,
                                        block_bytes=self.chunk_size)
            _copy_to_host_async(diff)
        else:
            fp, diff, ent = fingerprint_blocks(
                leaf, block_bytes=self.chunk_size), None, None
        with self._lock:
            self._pending[name] = _Pending(leaf=leaf, fp=fp, diff=diff,
                                           ent=ent)
        return True

    def _usable(self, ent: _Entry, leaf, codec: str) -> bool:
        """The previous save's entry may only suppress transfers when every
        identity the digest does NOT cover matches — shape, dtype, chunk
        size, codec, block count. A fingerprint match across any of these
        (the forced-collision case) must take the full path."""
        return (ent.shape == tuple(leaf.shape)
                and ent.dtype_name == ser.dtype_to_name(leaf.dtype)
                and ent.chunk_size == self.chunk_size
                and ent.codec == codec
                and len(ent.refs) == n_blocks_of(leaf.nbytes, self.chunk_size))

    # -- extract ------------------------------------------------------------

    def begin(self, named: dict[str, Any]) -> tuple[
            dict[str, _Staged], Callable[[list[dict]], None]]:
        """Start one save's delta extraction over the flattened state.

        Returns (staged, on_committed): ``staged`` maps leaf name to its
        in-flight dirty-block extraction (only leaves with a usable previous
        entry — everything else takes the dense path, while its fingerprint
        is still computed here so the *next* save can delta against it);
        ``on_committed`` must be invoked with the final manifest records
        after the checkpoint commits, and installs the new fingerprints +
        refs as the comparison point for the next save.
        """
        staged: dict[str, _Staged] = {}
        new_fps: dict[str, tuple[Any, str]] = {}   # name -> (fp_dev, codec)
        # decision pass under the lock (snapshot the entry + consume the
        # pending prestage for each leaf), device dispatch outside it: the
        # lock also serializes the async writer's commit bookkeeping, and
        # holding it across fingerprint kernel dispatches would queue the
        # writer thread behind device work. Safe because _Entry values are
        # never mutated in place and the `pend.ent is ent` identity check
        # below rejects any diff whose entry a concurrent commit swapped.
        plan: list[tuple] = []
        with self._lock:
            for name, leaf in named.items():
                codec = self._codec_for(name, leaf)
                if codec is None:
                    continue
                pend = self._pending.pop(name, None)
                ent = self._entries.get((name, 0))
                plan.append((name, leaf, codec, ent, pend))
            self._pending.clear()                  # saves never interleave
        fallbacks = 0
        for name, leaf, codec, ent, pend in plan:
            usable = ent is not None and self._usable(ent, leaf, codec)
            if pend is not None and pend.leaf is leaf:
                fp = pend.fp
                # the prestaged diff is only valid against the entry it
                # was computed from; if an async commit swapped the
                # entry in between, recompute below against the new one
                diff = pend.diff if pend.ent is ent else None
            elif usable:
                fp, diff = fingerprint_diff(leaf, ent.fp,
                                            block_bytes=self.chunk_size)
                _copy_to_host_async(diff)
            else:
                fp, diff = fingerprint_blocks(
                    leaf, block_bytes=self.chunk_size), None
            new_fps[name] = (fp, codec)
            if not usable:
                if ent is not None:
                    fallbacks += 1
                continue                           # dense path this save
            if diff is None:
                diff = fp != ent.fp
                _copy_to_host_async(diff)
            staged[name] = _Staged(self, name, leaf, ent, fp, diff, codec)
        with self._lock:
            self.stats["fallbacks"] += fallbacks
            if staged:
                self.stats["tracked_saves"] += 1
        return staged, self._make_commit_cb(new_fps)

    # -- commit -------------------------------------------------------------

    def _make_commit_cb(self, new_fps: dict[str, tuple[Any, str]]):
        def on_committed(records: list[dict]) -> None:
            by_name = {rec["name"]: rec for rec in records}
            with self._lock:
                for name, (fp, codec) in new_fps.items():
                    rec = by_name.get(f"{name}#0")
                    if rec is None or "chunks" not in rec:
                        continue
                    if rec.get("codec", "raw") != codec:
                        continue                   # policy changed mid-save
                    refs = [chunkstore.ChunkRef.from_json(c)
                            for c in rec["chunks"]]
                    if len(refs) != int(np.prod(fp.shape)):
                        continue
                    itemsize = ser.name_to_dtype(rec["dtype"]).itemsize
                    nbytes = int(np.prod(rec["shape"])) * itemsize
                    key = stable_piece_key(name, rec["index"],
                                           rec["global_shape"], rec["dtype"])
                    self._entries[key] = _Entry(
                        fp=fp, refs=refs, codec=codec,
                        shape=tuple(rec["shape"]), dtype_name=rec["dtype"],
                        chunk_size=self.chunk_size,
                        verified_at=time.monotonic(),
                        span=(key[1], nbytes),
                        total_nbytes=(int(np.prod(rec["global_shape"]))
                                      * itemsize))
        return on_committed

    # -- elastic topology changes -------------------------------------------

    def rescale(self, addressable: Callable[[str, int, int, int], bool]
                | None = None) -> dict[str, int]:
        """Remap tracker state across an elastic topology change.

        ``addressable(name, byte_lo, byte_hi, total_nbytes)`` answers
        whether this process still owns the piece's global byte span under
        the new mesh; None means fully-replicated data parallelism (the
        fleet's model), where every span stays addressable. Entries are
        keyed by global logical offset (``stable_piece_key``), so a
        surviving span keeps its device fingerprints — the next delta save
        still skips every clean block instead of re-transferring the world,
        which is what carries the D2H win through a rescale. Entries whose
        span the process no longer owns are dropped (their chunks remain in
        the pool; a save from their new owner re-seeds them). In-flight
        prestage work is discarded either way: it was computed against the
        old mesh's arrays.

        Returns ``{"kept": k, "dropped": d}`` and accumulates the same into
        ``stats``. For a full reset (restore onto unknown state) use
        ``invalidate``.
        """
        with self._lock:
            self._pending.clear()
            snapshot = [(key, ent.span, ent.total_nbytes)
                        for key, ent in self._entries.items()]
        # the predicate is caller code — never run it under the tracker lock
        drop = [key for key, (lo, ln), total in snapshot
                if addressable is not None
                and not addressable(key[0], lo, lo + ln, total)]
        with self._lock:
            dropped = 0
            for key in drop:
                if self._entries.pop(key, None) is not None:
                    dropped += 1
            kept = len(self._entries)
            self.stats["rescale_events"] += 1
            self.stats["fp_kept"] += kept
            self.stats["fp_dropped"] += dropped
        return {"kept": kept, "dropped": dropped}

    def invalidate(self) -> None:
        """Drop all device state; the next save takes the full path (and
        re-seeds the tracker). The blunt instrument — restores onto
        arbitrary state need it; elastic topology changes should call
        ``rescale`` instead, which keeps every still-addressable span."""
        with self._lock:
            self._entries.clear()
            self._pending.clear()


def write_delta_blocks_piece(pool: chunkstore.ChunkPool, key: tuple,
                             db: DeltaBlocks,
                             index: chunkstore.DeltaIndex | None,
                             pin: Callable[[str], None],
                             dirty_dirs: set | None):
    """Write-path worker for a sparse piece: encode+store the dirty blocks,
    reuse the clean refs verbatim (pinned so gc keeps them until the
    manifest commits). Mirrors ``chunkstore.store_payload_chunks`` for the
    dirty subset; the DeltaIndex memo is kept warm so a later tracker-less
    save of the same state still gets its raw-digest skips."""
    _quant, comp = ser.split_codec(db.codec)
    dirty_pos = {ci: j for j, ci in enumerate(db.dirty_ids)}
    refs: list[chunkstore.ChunkRef] = []
    written = 0
    for ci in range(db.n_blocks):
        j = dirty_pos.get(ci)
        if j is None:
            ref = db.clean_refs[ci]
            pin(ref.hash)
            refs.append(ref)
            continue
        # periodic-save encode: hand the worker to queued restore/urgent
        # jobs between blocks (chunk-granular preemption)
        codec_sched.maybe_yield()
        ref, n, rd = chunkstore.store_chunk(
            pool, db.dirty_view(j, ci), comp=comp, pin=pin,
            dirty_dirs=dirty_dirs)
        if index is not None:
            index.put((key, ci), rd, db.codec, ref)
        written += n
        refs.append(ref)
    return db.codec, None, refs, written, db.nbytes
