"""Checkpoint manifest + atomic commit protocol.

Layout of one committed checkpoint on the shared store::

    <root>/step_00000042/
        manifest.json       # global view: tensors, shard files, tree structure
        shard_p000.spot     # per-writer (per-host) shard container(s)
        shard_p001.spot
        COMMITTED           # written LAST; its presence marks validity

Writers stage everything in ``step_00000042.tmp-<nonce>/`` and atomically
rename to the final name, then create COMMITTED. A reader considers a
checkpoint restorable iff COMMITTED exists *and* the manifest parses *and*
(optionally) every shard's crc validates. Any failure → fall back to the next
older checkpoint: this is the paper's "search for the most recent *valid*
checkpoint" generalized to handle partially-written or corrupted state from a
writer killed mid-eviction.
"""

from __future__ import annotations

import json
import os
import time
from dataclasses import dataclass, field
from typing import Any

from ..faults import inject as faults
from .ioutil import fsync_dir

MANIFEST_NAME = "manifest.json"
COMMIT_MARKER = "COMMITTED"
STEP_PREFIX = "step_"


def step_dirname(step: int) -> str:
    return f"{STEP_PREFIX}{step:010d}"


def parse_step(dirname: str) -> int | None:
    if not dirname.startswith(STEP_PREFIX):
        return None
    tail = dirname[len(STEP_PREFIX):]
    if not tail.isdigit():
        return None
    return int(tail)


@dataclass
class Manifest:
    """Global description of one checkpoint.

    ``format_version`` 1: tensor records carry a ``file`` key pointing into a
    shard container inside the step dir. Version 2 (incremental/delta):
    records instead carry ``chunks`` — references into the store's shared
    content-addressed pool (``<root>/chunks/``) — plus ``raw_nbytes``;
    ``chunk_size`` records the split used at save time. Readers dispatch per
    record, so v1 checkpoints written before the delta subsystem stay
    restorable through the same code path."""

    step: int
    kind: str                      # "transparent" | "application" | "termination"
    created_at: float
    tensors: list[dict]            # TensorRecord JSONs (+ "file" v1 / "chunks" v2)
    leaf_order: list[str]          # pytree leaf names in treedef order
    treedef_repr: str              # human-readable treedef (debugging aid)
    mesh: dict                     # {"shape": [...], "axes": [...]} at save time
    extra: dict[str, Any] = field(default_factory=dict)  # small JSON state
    format_version: int = 1
    chunk_size: int | None = None  # v2 only

    def to_json(self) -> dict:
        d = {
            "format_version": self.format_version, "step": self.step,
            "kind": self.kind, "created_at": self.created_at,
            "tensors": self.tensors, "leaf_order": self.leaf_order,
            "treedef_repr": self.treedef_repr, "mesh": self.mesh,
            "extra": self.extra,
        }
        if self.chunk_size is not None:
            d["chunk_size"] = self.chunk_size
        return d

    @staticmethod
    def from_json(d: dict) -> "Manifest":
        return Manifest(
            step=d["step"], kind=d["kind"], created_at=d["created_at"],
            tensors=d["tensors"], leaf_order=d["leaf_order"],
            treedef_repr=d.get("treedef_repr", ""), mesh=d.get("mesh", {}),
            extra=d.get("extra", {}),
            format_version=d.get("format_version", 1),
            chunk_size=d.get("chunk_size"),
        )

    def chunk_hashes(self) -> set[str]:
        """All pool chunk hashes this manifest references (empty for v1)."""
        out: set[str] = set()
        for rec in self.tensors:
            for c in rec.get("chunks", ()):
                out.add(c["h"])
        return out


# -- per-leaf shard -> chunk-span map (format_version 2, optional) --------------
#
# A v2 tensor record may carry ``shard_spans``: one ``[row_lo, row_hi)`` pair
# per chunk ref, giving the axis-0 row band of the (stored-dtype) payload that
# chunk covers. A restoring process that only addresses rows ``[a, b)`` of a
# leaf can select exactly the chunks whose bands intersect ``[a, b)`` without
# first materializing prefix sums over every ref — and, more importantly, the
# map makes the save-time chunking *auditable*: the reader can cross-check the
# bands against the refs' ``raw_len`` prefix sums and refuse a manifest whose
# map lies. Absent on records written before this version (readers fall back
# to the prefix sums) and on scalar/0-d payloads (no row axis to band).


def chunk_byte_offsets(rec: dict) -> list[int]:
    """Prefix sums of a v2 record's chunk ``raw_len``s: chunk ``j`` covers
    bytes ``[offs[j], offs[j+1])`` of the flattened raw payload."""
    offs = [0]
    for c in rec.get("chunks", ()):
        offs.append(offs[-1] + int(c["r"]))
    return offs


def shard_span_map(shape, row_bytes: int, chunk_raw_lens) -> list | None:
    """Axis-0 row band per chunk, or None when the payload has no row axis.

    ``row_bytes`` is the stored-dtype byte size of one axis-0 row (trailing
    dims collapsed). Chunks are sequential windows over the flat payload, so
    chunk ``j`` spanning bytes ``[off, off+len)`` touches rows
    ``[off // row_bytes, ceil((off+len) / row_bytes))``.
    """
    if not shape or row_bytes <= 0:
        return None
    spans = []
    off = 0
    for raw_len in chunk_raw_lens:
        end = off + int(raw_len)
        spans.append([off // row_bytes, -(-end // row_bytes)])
        off = end
    return spans


def record_shard_spans(rec: dict) -> list | None:
    """A record's shard->chunk-span map, validated against the chunk refs.

    Returns the map as ``[(row_lo, row_hi), ...]`` or None when the record
    predates the map (or has no row axis). A map inconsistent with the refs
    (wrong length, non-monotonic, or bands that cannot contain the chunk's
    bytes) is treated as absent — the prefix-sum fallback is always correct,
    so a corrupt map must never be able to skip chunks a shard needs.
    """
    spans = rec.get("shard_spans")
    if spans is None:
        return None
    chunks = rec.get("chunks", ())
    if len(spans) != len(chunks):
        return None
    out = []
    prev_hi = 0
    for pair in spans:
        if not isinstance(pair, (list, tuple)) or len(pair) != 2:
            return None
        lo, hi = int(pair[0]), int(pair[1])
        if lo < 0 or hi < lo or lo > prev_hi:
            return None  # gap or inversion: bands must tile monotonically
        prev_hi = max(prev_hi, hi)
        out.append((lo, hi))
    return out


def write_manifest(dirpath: str, manifest: Manifest) -> None:
    path = os.path.join(dirpath, MANIFEST_NAME)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        # one-shot dumps (C-accelerated encoder), not json.dump's python
        # chunked iterencode: a delta manifest carries thousands of chunk
        # refs and the encode sits on every save's commit path — measured
        # ~16 ms -> ~2 ms on the 16 MiB / 64 KiB-chunk fixture. Compact
        # separators also shrink the file ~10%.
        faults.write_bytes(
            f, json.dumps(manifest.to_json(), separators=(",", ":")),
            op="manifest.write", path=tmp)
        f.flush()
        os.fsync(f.fileno())
    faults.fault_point("manifest.replace", path)
    os.replace(tmp, path)  # spotlint: ignore[SPOT002]
    faults.fault_point("manifest.replaced", path, rollback=(path, tmp))
    # no directory fsync here: the step dir keeps its inode through the
    # stage->final rename, so the single fsync_dir in mark_committed
    # persists this entry and the COMMITTED entry together — and COMMITTED
    # durable without the manifest entry is impossible (same flush). Every
    # fsync on the commit path is latency inside the eviction-notice window,
    # so each one has to pay for itself.


def read_manifest(dirpath: str) -> Manifest:
    with open(os.path.join(dirpath, MANIFEST_NAME)) as f:
        return Manifest.from_json(json.load(f))


def mark_committed(dirpath: str) -> None:
    path = os.path.join(dirpath, COMMIT_MARKER)
    with open(path, "w") as f:
        faults.write_bytes(f, f"{time.time()}\n", op="marker.write", path=path)
        f.flush()
        os.fsync(f.fileno())
    # one dir fsync persists the COMMITTED entry *and* the manifest entry
    # created before the rename (same dir inode) — a crash after this point
    # cannot lose a checkpoint the writer reported as committed
    fsync_dir(dirpath)


def is_committed(dirpath: str) -> bool:
    return os.path.exists(os.path.join(dirpath, COMMIT_MARKER))
