"""CheckpointStore — atomic commit, latest-valid search, retention GC.

The store models the paper's shared NFS volume: every instance (host) mounts
the same ``root``. Its invariants:

* **Atomicity** — a checkpoint is either fully committed (COMMITTED marker
  present, manifest + shards complete) or invisible to readers. Staging dir +
  rename + marker-last ordering guarantees this even if the writer is killed
  mid-eviction (the paper's "opportunistic" termination checkpoint).
* **Latest-valid search** — restore scans committed steps newest-first and
  returns the first that passes validation, exactly the coordinator behaviour
  in the paper ("automatically searches for the most recent valid checkpoint").
* **Retention** — keep the newest K committed checkpoints (bounded NFS bill;
  the cost model charges provisioned bytes).
"""

from __future__ import annotations

import os
import shutil
import threading
import time
import uuid
from dataclasses import dataclass
from typing import Any, Callable

from . import manifest as mf
from . import sharded


@dataclass
class CheckpointInfo:
    step: int
    path: str
    kind: str
    nbytes: int
    elapsed_s: float


class CheckpointStore:
    def __init__(
        self,
        root: str,
        *,
        retention: int = 3,
        validate_on_restore: bool = False,
        compress: bool = True,
        quantize_moments: bool = False,
        time_fn: Callable[[], float] = time.time,
        tags: dict | None = None,
        fault_injector: Callable[[str], None] | None = None,
    ):
        self.root = root
        self.retention = retention
        self.validate_on_restore = validate_on_restore
        self.compress = compress
        self.quantize_moments = quantize_moments
        self.time_fn = time_fn
        # store-level provenance (e.g. {"provider": "aws", "fleet": "f0"})
        # merged under every manifest's extras; per-save extras win on clash.
        self.tags = dict(tags or {})
        # test hook: called between commit phases; raising simulates a writer
        # killed mid-eviction at that phase.
        self.fault_injector = fault_injector or (lambda phase: None)
        # staging dirs with a writer currently inside them (fleet: N async
        # writers share one store) — gc must never sweep these
        self._stage_lock = threading.Lock()
        self._inflight_stages: set[str] = set()
        # serializes the replace+mark phase across this store's writers so a
        # same-step commit race can never delete a committed checkpoint
        self._commit_lock = threading.Lock()
        os.makedirs(root, exist_ok=True)

    # -- write ---------------------------------------------------------------

    def save_snapshot(self, snapshot: sharded.Snapshot, *, kind: str = "transparent",
                      extra: dict | None = None) -> CheckpointInfo:
        t0 = self.time_fn()
        final = os.path.join(self.root, mf.step_dirname(snapshot.step))
        stage = final + f".tmp-{uuid.uuid4().hex[:8]}"
        os.makedirs(stage, exist_ok=True)
        with self._stage_lock:
            self._inflight_stages.add(stage)
        try:
            records = sharded.write_snapshot(
                stage, snapshot, compress=self.compress,
                quantize_moments=self.quantize_moments)
            self.fault_injector("shards_written")
            man = mf.Manifest(
                step=snapshot.step, kind=kind, created_at=self.time_fn(),
                tensors=records, leaf_order=snapshot.leaf_order,
                treedef_repr=snapshot.treedef_repr, mesh=snapshot.mesh,
                extra={**self.tags, **(extra or {})})
            mf.write_manifest(stage, man)
            self.fault_injector("manifest_written")
            with self._commit_lock:
                if mf.is_committed(final):
                    # another fleet member already committed this step; the
                    # committed copy captures the same state — never delete
                    # it (our writer may die mid-eviction before re-creating)
                    shutil.rmtree(stage, ignore_errors=True)
                else:
                    if os.path.exists(final):  # uncommitted leftover: replace
                        shutil.rmtree(final)
                    os.replace(stage, final)
                    self.fault_injector("renamed")
                    mf.mark_committed(final)
        except BaseException:
            # leave staging dir for post-mortem; it is invisible to readers
            raise
        finally:
            with self._stage_lock:
                self._inflight_stages.discard(stage)
        nbytes = sum(r["nbytes"] for r in records)
        info = CheckpointInfo(step=snapshot.step, path=final, kind=kind,
                              nbytes=nbytes, elapsed_s=self.time_fn() - t0)
        self.gc()
        return info

    def save(self, step: int, state, *, kind: str = "transparent",
             mesh_info: dict | None = None, extra: dict | None = None) -> CheckpointInfo:
        """Synchronous convenience: extract + write + commit."""
        snap = sharded.extract_snapshot(state, step=step, mesh_info=mesh_info)
        return self.save_snapshot(snap, kind=kind, extra=extra)

    # -- read ----------------------------------------------------------------

    def committed_steps(self) -> list[int]:
        steps = []
        try:
            entries = os.listdir(self.root)
        except FileNotFoundError:
            return []
        for d in entries:
            step = mf.parse_step(d)
            if step is None:
                continue
            if mf.is_committed(os.path.join(self.root, d)):
                steps.append(step)
        return sorted(steps)

    def _try_open(self, step: int, *, validate: bool) -> tuple[mf.Manifest, sharded.CheckpointReader] | None:
        path = os.path.join(self.root, mf.step_dirname(step))
        try:
            man = mf.read_manifest(path)
            reader = sharded.CheckpointReader(path, man.tensors)
            if validate:
                reader.validate()
            return man, reader
        except Exception:
            return None

    def latest_valid(self, *, max_step: int | None = None) -> tuple[mf.Manifest, sharded.CheckpointReader] | None:
        """Newest committed checkpoint that parses (and validates); else older."""
        for step in reversed(self.committed_steps()):
            if max_step is not None and step > max_step:
                continue
            opened = self._try_open(step, validate=self.validate_on_restore)
            if opened is not None:
                return opened
        return None

    def restore(self, template, *, step: int | None = None):
        """Restore into `template`'s structure/shardings. Returns (state, manifest)."""
        if step is not None:
            opened = self._try_open(step, validate=self.validate_on_restore)
        else:
            opened = self.latest_valid()
        if opened is None:
            raise FileNotFoundError(f"no valid checkpoint under {self.root}")
        man, reader = opened
        state = sharded.restore_to_template(reader, template)
        return state, man

    # -- maintenance -----------------------------------------------------------

    def gc(self, *, stale_staging_age_s: float = 3600.0) -> list[int]:
        """Keep the newest `retention` committed checkpoints; drop the rest."""
        steps = self.committed_steps()
        doomed = steps[:-self.retention] if self.retention > 0 else []
        for step in doomed:
            shutil.rmtree(os.path.join(self.root, mf.step_dirname(step)),
                          ignore_errors=True)
        # sweep dead staging dirs — but never one a live writer is inside
        # (this process: tracked set; another host on the shared volume:
        # age-gated by real mtime, an eviction notice is seconds not hours)
        with self._stage_lock:
            inflight = set(self._inflight_stages)
        for d in os.listdir(self.root):
            if ".tmp-" not in d:
                continue
            path = os.path.join(self.root, d)
            if path in inflight:
                continue
            try:
                if time.time() - os.path.getmtime(path) < stale_staging_age_s:
                    continue
            except OSError:
                pass  # already gone (or unreadable): try the sweep anyway
            shutil.rmtree(path, ignore_errors=True)
        return doomed

    def total_bytes(self) -> int:
        total = 0
        for dirpath, _, files in os.walk(self.root):
            for f in files:
                try:
                    total += os.path.getsize(os.path.join(dirpath, f))
                except OSError:
                    pass
        return total
