"""CheckpointStore — atomic commit, latest-valid search, retention GC.

The store models the paper's shared NFS volume: every instance (host) mounts
the same ``root``. Its invariants:

* **Atomicity** — a checkpoint is either fully committed (COMMITTED marker
  present, manifest + shards complete) or invisible to readers. Staging dir +
  rename + marker-last ordering guarantees this even if the writer is killed
  mid-eviction (the paper's "opportunistic" termination checkpoint).
* **Latest-valid search** — restore scans committed steps newest-first and
  returns the first that passes validation, exactly the coordinator behaviour
  in the paper ("automatically searches for the most recent valid checkpoint").
* **Retention** — keep the newest K committed checkpoints (bounded NFS bill;
  the cost model charges provisioned bytes).
* **Incremental saves** (``mode="delta"``, the default) — tensor payloads are
  chunked into a content-addressed pool shared by all steps
  (``<root>/chunks/<hh>/<hash>``); a save writes only chunks whose content
  changed since the last committed state, and the manifest (v2) records
  per-tensor chunk references so any retained step reassembles from the pool.
  ``mode="full"`` keeps the original self-contained v1 shard files; both
  formats restore through the same reader.
"""

from __future__ import annotations

import logging
import os
import shutil
import threading
import time
import uuid
from collections import Counter
from concurrent.futures import CancelledError
from dataclasses import dataclass
from typing import Any, Callable

from . import backend as backend_mod
from . import chunkstore
from . import manifest as mf
from . import sharded
from ..faults import inject as faults
from .ioutil import fsync_dir


@dataclass
class CheckpointInfo:
    step: int
    path: str
    kind: str
    nbytes: int          # logical encoded size of the checkpoint
    elapsed_s: float
    new_bytes: int = 0   # bytes physically written (== nbytes for full saves)
    # device→host accounting from the snapshot's extract: bytes that crossed
    # the link vs. bytes the fingerprint path proved unchanged and skipped,
    # and the wall time the trainer was stalled inside extract
    d2h_bytes: int = 0
    d2h_bytes_skipped: int = 0
    save_stall_ms: float = 0.0
    # True when an object-store outage parked this save: the chunks are safe
    # in the local spool and the staged manifest commits in reconcile once
    # every ref is durable — latest_valid() does NOT see it yet
    spooled: bool = False


@dataclass
class _ParkedCommit:
    """A staged save waiting out an object-store outage: every chunk is in
    the local spool, the manifest is written in ``stage``, and the commit
    (rename + marker) runs only after ``upload_now`` confirms all refs
    durable. The stage stays in the in-flight set and the chunk pins stay
    held until then — gc treats a parked save exactly like a live writer."""

    stage: str
    final: str
    kind: str
    step: int
    records: list
    hashes: set
    pinned: list


class CheckpointStore:
    def __init__(
        self,
        root: str,
        *,
        retention: int = 3,
        validate_on_restore: bool = False,
        compress: bool = True,
        quantize_moments: bool = False,
        mode: str = "delta",
        chunk_size: int = chunkstore.DEFAULT_CHUNK_SIZE,
        time_fn: Callable[[], float] = time.time,
        tags: dict | None = None,
        fault_injector: Callable[[str], None] | None = None,
        chunk_sweep_interval_s: float = 60.0,
        backend: backend_mod.ChunkBackend | None = None,
    ):
        if mode not in ("delta", "full"):
            raise ValueError(f"mode must be 'delta' or 'full', got {mode!r}")
        self.root = root
        self.retention = retention
        self.validate_on_restore = validate_on_restore
        self.compress = compress
        self.quantize_moments = quantize_moments
        self.mode = mode
        self.chunk_size = chunk_size
        self.time_fn = time_fn
        # opportunistic (per-save) pool sweeps are rate-limited: nothing the
        # sweep could reclaim is younger than the age gate (hours), but the
        # walk itself — one listdir per fan-out dir plus a manifest parse
        # per retained step — is tens of ms of syscalls on a networked fs,
        # paid inside every save that drops a retained step
        self.chunk_sweep_interval_s = chunk_sweep_interval_s
        self._last_chunk_sweep = -float("inf")
        pool_root = os.path.join(root, chunkstore.CHUNKS_DIRNAME)
        if backend is not None:
            # object-store tier: the local tree becomes a read-through cache
            # and every manifest commit waits on chunk-upload durability
            self.pool: chunkstore.ChunkPool = backend_mod.BackendChunkPool(
                pool_root, backend)
        else:
            self.pool = chunkstore.ChunkPool(pool_root)
        # saves parked by an object-store outage, FIFO by step; committed by
        # reconcile_spooled() once the store is reachable again
        self._spool_lock = threading.Lock()
        self._spooled_commits: list[_ParkedCommit] = []
        self._delta_index = chunkstore.DeltaIndex()
        # chunk hashes referenced by saves in flight (manifest not yet
        # committed) — the pool sweep must never remove these
        self._pin_lock = threading.Lock()
        self._pinned_chunks: Counter[str] = Counter()
        # store-level provenance (e.g. {"provider": "aws", "fleet": "f0"})
        # merged under every manifest's extras; per-save extras win on clash.
        self.tags = dict(tags or {})
        # test hook: called between commit phases; raising simulates a writer
        # killed mid-eviction at that phase. The seedable FaultPlan layer
        # (repro.faults) hits the same phases as "commit.<phase>" ops plus
        # every primitive IO op underneath them.
        self.fault_injector = fault_injector or (lambda phase: None)
        # embedded in this store's staging dir names: gc can reclaim a dead
        # same-token stage immediately (same process, not in the in-flight
        # set => its writer is gone), while foreign debris on the shared
        # volume stays age-gated.
        self._stage_token = uuid.uuid4().hex[:6]
        # staging dirs with a writer currently inside them (fleet: N async
        # writers share one store) — gc must never sweep these
        self._stage_lock = threading.Lock()
        self._inflight_stages: set[str] = set()
        # serializes the replace+mark phase across this store's writers so a
        # same-step commit race can never delete a committed checkpoint
        self._commit_lock = threading.Lock()
        # opportunistic maintenance callbacks run after each successful
        # commit, off the critical path (e.g. compile-cache retention gc) —
        # failures are swallowed, a janitor must never fail a save
        self.post_commit: list[Callable[[], None]] = []
        os.makedirs(root, exist_ok=True)

    # -- write ---------------------------------------------------------------

    def _pin(self, h: str, pinned: list) -> None:
        with self._pin_lock:
            self._pinned_chunks[h] += 1
        pinned.append(h)

    def _unpin_all(self, pinned: list) -> None:
        with self._pin_lock:
            for h in pinned:
                self._pinned_chunks[h] -= 1
                if self._pinned_chunks[h] <= 0:
                    del self._pinned_chunks[h]

    def _phase(self, name: str) -> None:
        """One commit-phase boundary: the legacy per-store injector hook and
        the process-wide FaultPlan layer both see it."""
        self.fault_injector(name)
        faults.fault_point("commit." + name)

    def _finish_commit(self, stage: str, final: str, kind: str) -> bool:
        """The replace+mark commit phase: stage → final rename, root fsync
        overlapped with the COMMITTED marker write. Shared by the normal
        save path and the outage reconcile path (a parked save commits
        through exactly the same protocol once its refs are durable).
        Returns True when this writer committed, False when another fleet
        member already had."""
        # The commit-phase IO below (rmtree/replace/mark_committed/root
        # fsync join) intentionally runs under _commit_lock and is
        # baseline-suppressed for spotlint SPOT031: the lock exists
        # precisely to serialize the replace+mark phase across this
        # store's writers (a same-step commit race must never delete a
        # committed checkpoint), so the IO *is* the critical section.
        # Everything that can leave it has: shard/chunk writes, manifest
        # encode and fsync all happen before the lock; the root-dir
        # fsync overlaps on an executor lane and only its join remains.
        # The os.replace is likewise baseline-suppressed for SPOT001:
        # the source-fsync the rule wants happened in the caller —
        # write_snapshot's shard/manifest fsyncs (and, on a backend
        # pool, flush_uploads' durability barrier) all complete before
        # a stage dir is ever handed to this function.
        with self._commit_lock:
            if mf.is_committed(final):
                # another fleet member already committed this step; the
                # committed copy captures the same state — never delete
                # it (our writer may die mid-eviction before re-creating)
                shutil.rmtree(stage, ignore_errors=True)
                return False
            if os.path.exists(final):  # uncommitted leftover: replace
                shutil.rmtree(final)
            faults.fault_point("store.replace", final)
            os.replace(stage, final)
            faults.fault_point("store.replaced", final,
                               rollback=(final, stage))
            # durable, not just atomic: sync the root so a crash
            # right after the rename can't roll the step dir back.
            # The root fsync overlaps the marker write — they are
            # independent (rename rollback removes the whole dir,
            # marker included: invisible, never inconsistent), and
            # fsync latency sits inside the eviction-notice window
            try:
                root_sync = (chunkstore.urgent_executor()
                             if kind == "termination" else
                             chunkstore.codec_executor()).submit(
                    fsync_dir, self.root)
            except RuntimeError:
                # scheduler already shut down (periodic save racing
                # the atexit hook at interpreter exit): durability
                # cannot be skipped, fsync inline instead
                fsync_dir(self.root)
                root_sync = None
            self._phase("renamed")
            try:
                mf.mark_committed(final)
            finally:
                if root_sync is not None:
                    try:
                        root_sync.result()
                    except CancelledError:
                        # queued fsync swept up by a concurrent
                        # shutdown(cancel_pending): fsync inline —
                        # COMMITTED must imply rename durability
                        fsync_dir(self.root)
            self._phase("committed")
            return True

    def save_snapshot(self, snapshot: sharded.Snapshot, *, kind: str = "transparent",
                      extra: dict | None = None) -> CheckpointInfo:
        t0 = self.time_fn()
        if self._spooled_commits:
            # outage backlog first: parked steps must commit in order before
            # a newer step lands, and a reachable store drains them cheaply
            self.reconcile_spooled()
        final = os.path.join(self.root, mf.step_dirname(snapshot.step))
        stage = final + f".tmp-{self._stage_token}-{uuid.uuid4().hex[:8]}"
        os.makedirs(stage, exist_ok=True)
        with self._stage_lock:
            self._inflight_stages.add(stage)
        pinned: list[str] = []
        we_committed = False
        parked = False
        try:
            self._phase("staged")
            if self.mode == "delta":
                # dirty chunks land in the shared pool (atomic, idempotent
                # per chunk); the step dir itself holds only the manifest, so
                # the stage->rename->marker protocol is unchanged. Chunks from
                # a writer killed here are orphans, swept by gc once old.
                # Termination saves encode on the scheduler's URGENT lane
                # so the notice window never queues behind periodic save
                # traffic — and periodic encodes yield their workers to it.
                records, new_bytes = sharded.write_snapshot_delta(
                    snapshot, self.pool, compress=self.compress,
                    quantize_moments=self.quantize_moments,
                    chunk_size=self.chunk_size, index=self._delta_index,
                    pin=lambda h: self._pin(h, pinned),
                    executor=(chunkstore.urgent_executor()
                              if kind == "termination" else None))
            else:
                records = sharded.write_snapshot(
                    stage, snapshot, compress=self.compress,
                    quantize_moments=self.quantize_moments)
                new_bytes = sum(r["nbytes"] for r in records)
            self._phase("shards_written")
            man = mf.Manifest(
                step=snapshot.step, kind=kind, created_at=self.time_fn(),
                tensors=records, leaf_order=snapshot.leaf_order,
                treedef_repr=snapshot.treedef_repr, mesh=snapshot.mesh,
                extra={**self.tags, **(extra or {})},
                format_version=2 if self.mode == "delta" else 1,
                chunk_size=self.chunk_size if self.mode == "delta" else None)
            mf.write_manifest(stage, man)
            self._phase("manifest_written")
            # Durability barrier before commit: with an object-store backend
            # every pipelined chunk upload must have landed before the
            # manifest may reference it. A non-empty undurable set means the
            # store is out — park the staged commit in the spool instead.
            undurable: set[str] = set()
            flush = getattr(self.pool, "flush_uploads", None)
            if flush is not None:
                undurable = flush(set(pinned))
            self._phase("uploads_flushed")
            if undurable:
                with self._spool_lock:
                    self._spooled_commits.append(_ParkedCommit(
                        stage=stage, final=final, kind=kind,
                        step=snapshot.step, records=records,
                        hashes=set(pinned), pinned=list(pinned)))
                parked = True
                logging.getLogger("spoton").warning(
                    "object store outage: step %d save spooled locally "
                    "(%d chunks awaiting upload); manifest parked until "
                    "reconcile", snapshot.step, len(undurable))
            else:
                we_committed = self._finish_commit(stage, final, kind)
        except BaseException:
            # leave staging dir for post-mortem; it is invisible to readers
            raise
        finally:
            # a parked save stays a live writer: its stage must survive gc
            # and its chunk pins must hold until reconcile commits it
            if not parked:
                with self._stage_lock:
                    self._inflight_stages.discard(stage)
                self._unpin_all(pinned)
        if (we_committed or parked) and snapshot.on_committed is not None:
            # device-delta bookkeeping: the snapshot's fingerprints + chunk
            # refs become the next save's comparison point only now that the
            # manifest referencing them is durably committed — or parked with
            # its chunks pinned in the spool, which keeps delta continuity for
            # this process (the parked refs are locally present and protected
            # from gc until reconcile commits them). Never fatal — a tracker
            # hiccup costs the next save its delta, not the save.
            try:
                snapshot.on_committed(records)
            except Exception as e:  # pragma: no cover - defensive
                logging.getLogger("spoton").warning(
                    "post-commit delta bookkeeping failed: %s", e)
        nbytes = sum(r["nbytes"] for r in records)
        info = CheckpointInfo(step=snapshot.step, path=final, kind=kind,
                              nbytes=nbytes, elapsed_s=self.time_fn() - t0,
                              new_bytes=new_bytes,
                              d2h_bytes=snapshot.d2h_bytes or snapshot.nbytes,
                              d2h_bytes_skipped=snapshot.d2h_skipped,
                              save_stall_ms=snapshot.stall_s * 1e3,
                              spooled=parked)
        # sweep_chunks=None: walk the pool only when retention actually
        # dropped a step — a full pool scan on every commit would sit inside
        # the urgent termination path for no reclaimable garbage
        self.gc(sweep_chunks=None)
        for cb in self.post_commit:
            try:
                cb()
            except Exception as e:  # pragma: no cover - defensive
                logging.getLogger("spoton").warning(
                    "post-commit hook failed: %s", e)
        return info

    def save(self, step: int, state, *, kind: str = "transparent",
             mesh_info: dict | None = None, extra: dict | None = None,
             tracker=None) -> CheckpointInfo:
        """Synchronous convenience: extract + write + commit. ``tracker``
        (a ``DeviceDeltaTracker``, delta mode only) routes eligible leaves
        through the device fingerprint path."""
        snap = sharded.extract_snapshot(
            state, step=step, mesh_info=mesh_info,
            tracker=tracker if self.mode == "delta" else None)
        return self.save_snapshot(snap, kind=kind, extra=extra)

    def spooled_steps(self) -> list[int]:
        """Steps whose saves are parked in the outage spool (oldest first)."""
        with self._spool_lock:
            return [p.step for p in self._spooled_commits]

    def reconcile_spooled(self) -> int:
        """Commit outage-parked saves whose chunks can now be made durable.

        Probes the backend first (a cheap HEAD; also clears outage mode on
        success), then drains the spool FIFO: re-upload each parked save's
        refs synchronously (``upload_now``) and run the normal replace+mark
        commit — manifest commit strictly after every ref is durable. Stops
        at the first save the store still refuses, so a half-recovered
        outage commits a prefix of the backlog in step order. Returns the
        number of checkpoints committed."""
        with self._spool_lock:
            pending = list(self._spooled_commits)
        if not pending:
            return 0
        pool = self.pool
        probe = getattr(pool, "probe", None)
        if probe is not None and not probe():
            return 0
        upload_now = getattr(pool, "upload_now", None)
        committed = 0
        for parked in pending:
            if upload_now is not None and not upload_now(parked.hashes):
                break
            self._finish_commit(parked.stage, parked.final, parked.kind)
            with self._spool_lock:
                try:
                    self._spooled_commits.remove(parked)
                except ValueError:  # pragma: no cover - concurrent reconcile
                    pass
            with self._stage_lock:
                self._inflight_stages.discard(parked.stage)
            self._unpin_all(parked.pinned)
            committed += 1
            logging.getLogger("spoton").info(
                "reconciled spooled step %d: all refs durable, manifest "
                "committed", parked.step)
        if committed:
            self.gc(sweep_chunks=None)
        return committed

    # -- read ----------------------------------------------------------------

    def committed_steps(self) -> list[int]:
        steps = []
        try:
            entries = os.listdir(self.root)
        except FileNotFoundError:
            return []
        for d in entries:
            step = mf.parse_step(d)
            if step is None:
                continue
            if mf.is_committed(os.path.join(self.root, d)):
                steps.append(step)
        return sorted(steps)

    def _try_open(self, step: int, *, validate: bool,
                  chunk_pool: chunkstore.ChunkPool | None = None
                  ) -> tuple[mf.Manifest, sharded.CheckpointReader] | None:
        path = os.path.join(self.root, mf.step_dirname(step))
        try:
            man = mf.read_manifest(path)
            reader = sharded.CheckpointReader(path, man.tensors,
                                              chunk_pool=chunk_pool or self.pool)
            if validate:
                reader.validate()
            return man, reader
        except Exception:
            return None

    def latest_valid(self, *, max_step: int | None = None,
                     chunk_pool: chunkstore.ChunkPool | None = None
                     ) -> tuple[mf.Manifest, sharded.CheckpointReader] | None:
        """Newest committed checkpoint that parses (and validates); else older."""
        for step in reversed(self.committed_steps()):
            if max_step is not None and step > max_step:
                continue
            opened = self._try_open(step, validate=self.validate_on_restore,
                                    chunk_pool=chunk_pool)
            if opened is not None:
                return opened
        return None

    def restore(self, template, *, step: int | None = None,
                streaming: bool = False,
                chunk_pool: chunkstore.ChunkPool | None = None):
        """Restore into `template`'s structure/shardings. Returns (state, manifest).

        ``streaming`` pipelines read→decode→device_put per tensor (see
        ``sharded.restore_to_template_streaming``) — bit-identical results,
        shorter eviction→first-step-back window when template leaves carry
        device shardings. ``chunk_pool`` overrides where v2 chunk bytes are
        resolved from — a replacement passes its peer read-through pool
        (``peer_exchange.ReadThroughPool``) to warm-restore from surviving
        fleet members before falling back to this store."""
        if step is not None:
            opened = self._try_open(step, validate=self.validate_on_restore,
                                    chunk_pool=chunk_pool)
        else:
            opened = self.latest_valid(chunk_pool=chunk_pool)
        if opened is None:
            raise FileNotFoundError(f"no valid checkpoint under {self.root}")
        man, reader = opened
        if streaming:
            state = sharded.restore_to_template_streaming(reader, template)
        else:
            state = sharded.restore_to_template(reader, template)
        return state, man

    # -- maintenance -----------------------------------------------------------

    def gc(self, *, stale_staging_age_s: float = 3600.0,
           stale_chunk_age_s: float = 3600.0,
           sweep_chunks: bool | None = True) -> list[int]:
        """Keep the newest `retention` committed checkpoints; drop the rest.

        ``sweep_chunks``: True sweeps the chunk pool now; None (the per-save
        default) sweeps only when this call doomed a step — the only event
        that makes pool entries newly unreferenced."""
        steps = self.committed_steps()
        doomed = steps[:-self.retention] if self.retention > 0 else []
        for step in doomed:
            shutil.rmtree(os.path.join(self.root, mf.step_dirname(step)),
                          ignore_errors=True)
        # sweep dead staging dirs — but never one a live writer is inside
        # (this process: tracked set; another host on the shared volume:
        # age-gated by real mtime, an eviction notice is seconds not hours).
        # A stage carrying *this store's* token that is not in the in-flight
        # set is debris from one of our own aborted commits — its writer
        # already unwound through save_snapshot's finally — so it is
        # reclaimed immediately, no age gate: this is how the save after a
        # crash-point abort self-heals the previous attempt's leftovers.
        with self._stage_lock:
            inflight = set(self._inflight_stages)
        own_marker = f".tmp-{self._stage_token}-"
        for d in os.listdir(self.root):
            if ".tmp-" not in d:
                continue
            path = os.path.join(self.root, d)
            if path in inflight:
                continue
            if own_marker not in d:
                try:
                    if (time.time() - os.path.getmtime(path)
                            < stale_staging_age_s):
                        continue
                except OSError:
                    pass  # already gone (or unreadable): try the sweep anyway
            shutil.rmtree(path, ignore_errors=True)
        due = time.time() - self._last_chunk_sweep >= self.chunk_sweep_interval_s
        if sweep_chunks or (sweep_chunks is None and doomed and due):
            self._gc_chunks(stale_chunk_age_s)
            self._last_chunk_sweep = time.time()
        return doomed

    def live_chunk_hashes(self) -> set[str]:
        """Chunks referenced by any committed manifest or an in-flight save."""
        live: set[str] = set()
        for step in self.committed_steps():
            path = os.path.join(self.root, mf.step_dirname(step))
            try:
                live |= mf.read_manifest(path).chunk_hashes()
            except Exception:
                continue  # unreadable manifest: its step is dead anyway
        with self._pin_lock:
            live |= set(self._pinned_chunks)
        return live

    def _gc_chunks(self, stale_chunk_age_s: float) -> None:
        """Refcount-aware pool sweep: a chunk referenced by any committed
        manifest (even one shared across steps) is never removed; unreferenced
        chunks are removed only past the age gate, which protects writers on
        other hosts that are mid-save (pool writes and reuse touches keep
        their chunks' mtimes fresh)."""
        live = self.live_chunk_hashes()
        now = time.time()
        for name, path, is_tmp in self.pool.entries():
            if not is_tmp and name in live:
                continue
            # unreferenced chunk or crashed-writer tmp file: sweep past age
            try:
                if now - os.path.getmtime(path) < stale_chunk_age_s:
                    continue
            except OSError:
                pass
            try:
                os.remove(path)
            except OSError:
                pass

    def total_bytes(self) -> int:
        total = 0
        for dirpath, _, files in os.walk(self.root):
            for f in files:
                try:
                    total += os.path.getsize(os.path.join(dirpath, f))
                except OSError:
                    pass
        return total
