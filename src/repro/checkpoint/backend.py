"""ChunkBackend — the chunk pool's durable tier, POSIX or object store.

The content-addressed pool (``chunkstore.ChunkPool``) historically assumed
one POSIX mount shared by every fleet member. This module abstracts *where
the durable copy of a chunk lives* behind a small backend interface so the
same pool semantics run against an S3/GCS-style object store reached over a
lossy network — the most failure-prone layer of a real spot deployment.

Pieces:

* :class:`ChunkBackend` — the interface: ``head`` / ``get_range`` / ``put``
  / multipart upload (``create_multipart`` → ``upload_part`` →
  ``complete_multipart``) / ``delete`` / ``list_keys``. Keys mirror the
  POSIX fan-out exactly (``chunks/<hh>/<hash>``), so a bucket listing and a
  pool ``ls`` are the same namespace.
* :class:`PosixBackend` — the existing layout behind the interface (a
  directory tree, atomic tmp+rename puts). The default store remains a
  plain ``ChunkPool`` — zero behavior change without an explicit backend.
* :class:`InProcessObjectStore` + :class:`ObjectStoreBackend` — an
  in-process S3-style server (keyed blobs, ranged GETs, multipart upload
  sessions) with an injectable :class:`NetworkModel` (latency + serialized
  link bandwidth) and an outage switch, plus the client that talks to it.
  CI exercises the whole network failure surface with no cloud credentials.
* :class:`BackendChunkPool` — a ``ChunkPool`` whose root directory is a
  local **read-through cache** and whose durable tier is a backend. It
  overrides the same single ``chunk_path`` hook the peer-exchange pool
  uses, so every decode/restore path (streaming, range-addressed, mmap
  zero-copy) gets backend read-through without knowing it — and composes
  under ``peer_exchange.ReadThroughPool`` as the shared tier, giving the
  full local → peer → object-store resolution order.

Robustness contract (the reason this module exists):

* **Every ranged GET is retried, keyed by content address.** A torn or
  short response is re-fetchable by hash: :func:`fetch_chunk_verified`
  re-digests the payload against the address *before accepting it* and
  re-fetches on mismatch, bounded attempts with jitter seeded from the
  content address (``core.retry.call_with_retry``). No byte is trusted
  until it hashes to its name — the same trust model as the peer exchange.
* **Uploads are idempotent per chunk key.** :func:`upload_chunk` HEADs the
  address first; a re-PUT of an already-committed address is a verified
  no-op (size must match — a truncated blob from a torn upload is
  *rewritten*, never trusted), never an append. Multipart parts are keyed
  by part number inside a session, so a crashed upload restarts cleanly.
* **Uploads overlap encode.** ``BackendChunkPool.write`` lands the local
  cache copy synchronously (dedup and mmap re-reads stay fast) and
  pipelines the backend upload on the codec executor's PERIODIC lane,
  calling ``codec_sched.maybe_yield`` so RESTORE-lane traffic preempts
  queued uploads. ``flush_uploads`` is the save's pre-commit barrier: the
  manifest may only commit once every referenced chunk is durable.
* **A persistent outage degrades, never corrupts.** :class:`BackendHealth`
  flips outage mode after N consecutive failed ops; writes then spool to
  the local cache (counted in ``spooled_bytes``) and the store parks the
  staged manifest instead of committing it. ``CheckpointStore.
  reconcile_spooled`` re-uploads and commits once ``probe`` sees the store
  again — manifest commit strictly after every ref is durable. Restores
  fall back local → peer → store throughout, so an outage during an
  eviction storm does not strand survivors.

Fault surface: the client consults the process-wide fault plan at
``backend.get`` / ``backend.put`` / ``backend.head`` / ``backend.complete``
(errno, torn-response and rename-rollback-analogue behaviours — see
``faults.plan``). Process-wide ``backend_retries`` / ``backend_outages`` /
``spooled_bytes`` counters are folded into ``CoordinatorStats`` the same
way io_retries are.
"""

from __future__ import annotations

import errno
import logging
import os
import threading
import time
import uuid
from dataclasses import dataclass
from typing import Callable, Dict, Iterable, Iterator, Optional

from ..faults import inject as faults
from . import chunkstore
from . import codec_sched
from .chunkstore import ChunkRef
from .ioutil import fsync_dir

log = logging.getLogger("spoton.backend")

__all__ = [
    "BackendChunkPool",
    "BackendHealth",
    "ChunkBackend",
    "InProcessObjectStore",
    "NetworkModel",
    "ObjectStoreBackend",
    "PosixBackend",
    "fetch_chunk_verified",
    "object_key",
    "snapshot_stats",
    "upload_chunk",
]

#: objects larger than this upload as multipart (chunks are usually 1 MiB,
#: so simple PUT dominates; tests shrink this to force the multipart path)
DEFAULT_PART_SIZE = 8 << 20

OBJECT_PREFIX = "chunks"


def object_key(h: str) -> str:
    """Bucket key of a chunk address — mirrors the POSIX ``chunks/<hh>/<hash>``
    fan-out so the object namespace and a pool directory are interchangeable."""
    return f"{OBJECT_PREFIX}/{h[:2]}/{h}"


def _retry():
    # same deferred import as chunkstore: repro.core's __init__ imports the
    # coordinator which imports repro.checkpoint — importing core.retry at
    # module level would observe a half-initialized package
    from ..core import retry
    return retry


# -- process-wide robustness counters ------------------------------------------

_stats_lock = threading.Lock()
_backend_retries = 0
_backend_outages = 0
_spooled_bytes = 0


def snapshot_stats() -> Dict[str, int]:
    """Monotonic process-wide backend robustness counters since import:
    retry attempts burned on backend ops, outage windows entered, and bytes
    spooled locally while the store was unreachable."""
    with _stats_lock:
        return {"backend_retries": _backend_retries,
                "backend_outages": _backend_outages,
                "spooled_bytes": _spooled_bytes}


def _count(retries: int = 0, outages: int = 0, spooled: int = 0) -> None:
    global _backend_retries, _backend_outages, _spooled_bytes
    with _stats_lock:
        _backend_retries += retries
        _backend_outages += outages
        _spooled_bytes += spooled


# -- the backend interface -----------------------------------------------------


class ChunkBackend:
    """Durable keyed-blob tier behind a chunk pool.

    Implementations must make ``put``/``complete_multipart`` *atomic per
    key* — a reader never observes a partially-landed object under its
    final key (POSIX: tmp+rename; object stores give this natively). They
    are NOT required to be idempotent or reliable: the call sites own both
    (content-address verification, bounded retry, HEAD-before-PUT)."""

    def head(self, key: str) -> Optional[int]:
        """Size of the committed object at ``key``, or None if absent.
        Raises OSError when the store is unreachable."""
        raise NotImplementedError

    def get_range(self, key: str, start: int, length: int) -> bytes:
        """Bytes ``[start, start+length)`` of the object. Missing key raises
        ENOENT; an unreachable store raises a transient OSError. Callers
        must verify the payload against the content address before trusting
        it (``fetch_chunk_verified``)."""
        raise NotImplementedError

    def put(self, key: str, data) -> None:
        raise NotImplementedError

    def create_multipart(self, key: str) -> str:
        raise NotImplementedError

    def upload_part(self, key: str, upload_id: str, part_no: int, data) -> None:
        raise NotImplementedError

    def complete_multipart(self, key: str, upload_id: str) -> None:
        raise NotImplementedError

    def delete(self, key: str) -> None:
        raise NotImplementedError

    def list_keys(self) -> Iterator[str]:
        raise NotImplementedError


class PosixBackend(ChunkBackend):
    """The existing POSIX layout behind the backend interface: a directory
    tree with the same ``chunks/<hh>/<hash>`` fan-out, atomic tmp+rename
    puts. Useful to run the backend-pool machinery against an NFS mount —
    the default store keeps using a plain ``ChunkPool`` directly."""

    def __init__(self, root: str):
        self.root = root
        self._sessions: dict[str, tuple[str, dict[int, bytes]]] = {}
        self._lock = threading.Lock()

    def _path(self, key: str) -> str:
        return os.path.join(self.root, *key.split("/"))

    def head(self, key: str) -> Optional[int]:
        try:
            return os.path.getsize(self._path(key))
        except OSError:
            return None

    def get_range(self, key: str, start: int, length: int) -> bytes:
        with open(self._path(key), "rb") as f:
            f.seek(start)
            return f.read(length)

    def put(self, key: str, data) -> None:
        path = self._path(key)
        os.makedirs(os.path.dirname(path), exist_ok=True)
        tmp = path + f".tmp-{uuid.uuid4().hex[:8]}"
        try:
            with open(tmp, "wb") as f:
                f.write(data)
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, path)
            fsync_dir(os.path.dirname(path))
        except Exception:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise

    def create_multipart(self, key: str) -> str:
        uid = uuid.uuid4().hex[:12]
        with self._lock:
            self._sessions[uid] = (key, {})
        return uid

    def upload_part(self, key: str, upload_id: str, part_no: int, data) -> None:
        with self._lock:
            sess = self._sessions.get(upload_id)
            if sess is None or sess[0] != key:
                raise OSError(errno.ENOENT,
                              f"no such multipart upload: {upload_id}")
            sess[1][part_no] = bytes(data)

    def complete_multipart(self, key: str, upload_id: str) -> None:
        with self._lock:
            sess = self._sessions.pop(upload_id, None)
        if sess is None or sess[0] != key:
            raise OSError(errno.ENOENT, f"no such multipart upload: {upload_id}")
        self.put(key, b"".join(sess[1][i] for i in sorted(sess[1])))

    def delete(self, key: str) -> None:
        try:
            os.unlink(self._path(key))
        except FileNotFoundError:
            pass

    def list_keys(self) -> Iterator[str]:
        base = os.path.join(self.root, OBJECT_PREFIX)
        try:
            shards = sorted(os.listdir(base))
        except FileNotFoundError:
            return
        for hh in shards:
            sub = os.path.join(base, hh)
            try:
                names = sorted(os.listdir(sub))
            except (NotADirectoryError, FileNotFoundError):
                continue
            for name in names:
                if ".tmp-" not in name:
                    yield f"{OBJECT_PREFIX}/{hh}/{name}"


# -- in-process object store ---------------------------------------------------


@dataclass(frozen=True)
class NetworkModel:
    """Latency + serialized-link bandwidth model for the in-process store.
    ``gbps=0`` means an unmodeled (infinite) link."""

    latency_s: float = 0.0
    gbps: float = 0.0

    def transfer_s(self, nbytes: int) -> float:
        bw = self.gbps * 1e9
        return self.latency_s + (nbytes / bw if bw > 0 else 0.0)


class InProcessObjectStore:
    """An S3-style keyed-blob server living in this process.

    Blobs commit atomically per key (the dict assignment is the commit
    point); multipart uploads stage parts in a session keyed by upload id
    and only ``complete_multipart`` makes the object visible. Every op pays
    the :class:`NetworkModel`'s latency and — for payload-carrying ops —
    its serialized link bandwidth; ``set_outage(True)`` makes every op
    raise ETIMEDOUT, modelling an unreachable endpoint. ``put_generations``
    counts commits per key so tests can prove a re-PUT was a no-op rather
    than an append or a second copy."""

    def __init__(self, *, network: NetworkModel | None = None):
        self.network = network or NetworkModel()
        self.outage = False
        self.objects: dict[str, bytes] = {}
        self.put_generations: dict[str, int] = {}
        self._sessions: dict[str, tuple[str, dict[int, bytes]]] = {}
        self._lock = threading.Lock()
        self._link = threading.Lock()
        self.stats = {"heads": 0, "gets": 0, "puts": 0, "parts": 0,
                      "completes": 0, "deletes": 0,
                      "bytes_in": 0, "bytes_out": 0}

    def set_outage(self, on: bool) -> None:
        self.outage = bool(on)

    def _io(self, nbytes: int) -> None:
        if self.outage:
            raise OSError(errno.ETIMEDOUT, "object store unreachable (outage)")
        dt = self.network.transfer_s(nbytes)
        if dt > 0.0:
            with self._link:
                # the lock IS the model: one NIC/egress link, transfers
                # serialize on it exactly like the bench's modeled pools
                time.sleep(dt)  # spotlint: ignore[SPOT031]

    def head(self, key: str) -> Optional[int]:
        self._io(0)
        with self._lock:
            self.stats["heads"] += 1
            blob = self.objects.get(key)
        return None if blob is None else len(blob)

    def get_range(self, key: str, start: int, length: int) -> bytes:
        with self._lock:
            blob = self.objects.get(key)
            if blob is not None:
                self.stats["gets"] += 1
                data = blob[start:start + length]
                self.stats["bytes_out"] += len(data)
        if blob is None:
            self._io(0)
            raise OSError(errno.ENOENT, f"no such object: {key}")
        self._io(len(data))
        return data

    def put(self, key: str, data) -> None:
        data = bytes(data)
        self._io(len(data))
        with self._lock:
            self.stats["puts"] += 1
            self.stats["bytes_in"] += len(data)
            self.objects[key] = data
            self.put_generations[key] = self.put_generations.get(key, 0) + 1

    def create_multipart(self, key: str) -> str:
        self._io(0)
        uid = uuid.uuid4().hex[:12]
        with self._lock:
            self._sessions[uid] = (key, {})
        return uid

    def upload_part(self, key: str, upload_id: str, part_no: int, data) -> None:
        data = bytes(data)
        self._io(len(data))
        with self._lock:
            sess = self._sessions.get(upload_id)
            if sess is None or sess[0] != key:
                raise OSError(errno.ENOENT,
                              f"no such multipart upload: {upload_id}")
            sess[1][part_no] = data
            self.stats["parts"] += 1
            self.stats["bytes_in"] += len(data)

    def complete_multipart(self, key: str, upload_id: str) -> None:
        self._io(0)
        with self._lock:
            sess = self._sessions.pop(upload_id, None)
            if sess is None or sess[0] != key:
                raise OSError(errno.ENOENT,
                              f"no such multipart upload: {upload_id}")
            self.objects[key] = b"".join(sess[1][i] for i in sorted(sess[1]))
            self.stats["completes"] += 1
            self.put_generations[key] = self.put_generations.get(key, 0) + 1

    def delete(self, key: str) -> None:
        self._io(0)
        with self._lock:
            if self.objects.pop(key, None) is not None:
                self.stats["deletes"] += 1

    def list_keys(self) -> Iterator[str]:
        with self._lock:
            keys = sorted(self.objects)
        yield from keys

    def total_bytes(self) -> int:
        with self._lock:
            return sum(len(b) for b in self.objects.values())


class ObjectStoreBackend(ChunkBackend):
    """Client half of the in-process object store: each op consults the
    process-wide fault plan (``backend.head`` / ``backend.get`` /
    ``backend.put`` / ``backend.complete``), so the torture suites drive
    errno faults, torn requests/responses and post-complete rollbacks
    through the same machinery the POSIX commit path uses. In a real
    deployment this class is the seam where an S3/GCS SDK slots in."""

    def __init__(self, server: InProcessObjectStore):
        self.server = server

    def head(self, key: str) -> Optional[int]:
        faults.fault_point("backend.head", key)
        return self.server.head(key)

    def get_range(self, key: str, start: int, length: int) -> bytes:
        # single plan check per GET, on the response: a ``torn`` rule
        # truncates the body (connection died mid-transfer) and the
        # content-address check upstream turns it into a retry
        data = self.server.get_range(key, start, length)
        return faults.response_bytes(data, op="backend.get", path=key)

    def put(self, key: str, data) -> None:
        # torn rule: only a prefix reaches the server before the "process"
        # dies — the truncated blob sits under the final key and must be
        # detected by the verified re-PUT, never trusted by existence alone
        faults.send_bytes(lambda d: self.server.put(key, d), data,
                          op="backend.put", path=key)

    def create_multipart(self, key: str) -> str:
        return self.server.create_multipart(key)

    def upload_part(self, key: str, upload_id: str, part_no: int, data) -> None:
        faults.send_bytes(
            lambda d: self.server.upload_part(key, upload_id, part_no, d),
            data, op="backend.put", path=f"{key}#part{part_no}")

    def complete_multipart(self, key: str, upload_id: str) -> None:
        self.server.complete_multipart(key, upload_id)
        # post-complete fault point: an errno here models a lost ack (the
        # object IS committed — the retrying uploader's HEAD discovers that
        # and no-ops); a ``rollback`` rule un-commits the object first, the
        # object-store analogue of a rename that never became durable
        faults.fault_point("backend.complete", key,
                           rollback=lambda: self.server.delete(key))

    def delete(self, key: str) -> None:
        self.server.delete(key)

    def list_keys(self) -> Iterator[str]:
        return self.server.list_keys()


# -- verified transfer helpers -------------------------------------------------


def _backend_retry(fn: Callable[[], object], *, describe: str,
                   h: str = "", policy=None):
    """Bounded backend-op retry: ``core.retry.call_with_retry`` with jitter
    seeded from the content address (deterministic per chunk, decorrelated
    across chunks) and the process-wide ``backend_retries`` counter bumped
    once per re-attempt."""
    import random
    rng = random.Random(int(h[:8], 16)) if h else None

    def _sleep(delay: float) -> None:
        _count(retries=1)
        time.sleep(delay)

    r = _retry()
    return r.call_with_retry(fn, policy=policy or r.IO_RETRY, sleep=_sleep,
                             rng=rng, describe=describe)


def fetch_chunk_verified(backend: ChunkBackend, ref: ChunkRef, *,
                         policy=None) -> bytes:
    """One chunk's stored bytes from the backend, verified and retried.

    The ranged GET runs in a bounded retry loop *keyed by the content
    address*: the payload is re-digested against ``ref.hash`` before being
    accepted (``chunk_content_ok``), and a short/torn/corrupt response is
    indistinguishable from a transient network fault — re-fetch by hash,
    bounded attempts, address-seeded jitter. Raises OSError once the bound
    is exhausted; never returns unverified bytes."""
    return _backend_retry(lambda: _fetch_chunk_once(backend, ref),
                          describe=f"backend get {ref.hash[:10]}",
                          h=ref.hash, policy=policy)


def _fetch_chunk_once(backend: ChunkBackend, ref: ChunkRef) -> bytes:
    data = backend.get_range(object_key(ref.hash), 0, ref.nbytes)
    if len(data) != ref.nbytes or not chunkstore.chunk_content_ok(ref, data):
        # EIO is transient to the retry classifier: a re-fetch may succeed
        # verbatim, which is exactly what content addressing licenses
        raise OSError(errno.EIO,
                      f"backend chunk {ref.hash[:10]}: short or corrupt "
                      f"ranged GET ({len(data)}/{ref.nbytes} bytes)")
    return data


def upload_chunk(backend: ChunkBackend, h: str, data, *,
                 part_size: int = DEFAULT_PART_SIZE) -> int:
    """Idempotent upload of one chunk to its content address.

    HEAD first: an already-committed address with the expected size is a
    verified no-op (0 bytes sent) — a re-PUT is never an append and never a
    second copy, because the key *is* the content. A size mismatch (torn
    upload debris) is rewritten whole. Large payloads go multipart —
    parts keyed by number inside a fresh session, so a crashed upload
    restarts cleanly — with a ``maybe_yield`` between parts so RESTORE-lane
    traffic preempts. Returns bytes sent."""
    key = object_key(h)
    if backend.head(key) == len(data):
        return 0
    if len(data) <= part_size:
        backend.put(key, data)
        return len(data)
    uid = backend.create_multipart(key)
    view = memoryview(data) if not isinstance(data, (bytes, memoryview)) \
        else data
    for pno, off in enumerate(range(0, len(data), part_size)):
        codec_sched.maybe_yield()
        backend.upload_part(key, uid, pno, view[off:off + part_size])
    backend.complete_multipart(key, uid)
    return len(data)


# -- outage detection ----------------------------------------------------------


class BackendHealth:
    """Consecutive-failure outage detector for one backend connection.

    Individual op failures already retried and spooled per chunk; this
    tracks the *state* — ``outage_after`` consecutive failed ops flip
    outage mode (counted process-wide in ``backend_outages``), which
    short-circuits further upload/HEAD attempts until an explicit probe or
    any successful op clears it. One success resets the streak: a flaky
    link is retries, not an outage."""

    def __init__(self, *, outage_after: int = 3):
        self.outage_after = outage_after
        self._failures = 0
        self._outage = False
        self._lock = threading.Lock()

    def note_failure(self) -> None:
        with self._lock:
            self._failures += 1
            flipped = (not self._outage
                       and self._failures >= self.outage_after)
            if flipped:
                self._outage = True
        if flipped:
            _count(outages=1)
            log.warning("object store unreachable after %d consecutive "
                        "failed ops: entering outage mode (writes spool "
                        "locally, manifests park until reconcile)",
                        self.outage_after)

    def note_success(self) -> None:
        with self._lock:
            recovered = self._outage
            self._failures = 0
            self._outage = False
        if recovered:
            log.info("object store reachable again: outage mode cleared")

    def in_outage(self) -> bool:
        with self._lock:
            return self._outage


# -- the backend-backed chunk pool ---------------------------------------------


class BackendChunkPool(chunkstore.ChunkPool):
    """A chunk pool whose root is a local read-through cache and whose
    durable tier is a :class:`ChunkBackend`.

    Reads resolve through the standard ``chunk_path`` hook: cache hit →
    the mmap fast path is untouched; miss → a verified, retried ranged GET
    lands the chunk in the cache and decode proceeds from the file. Writes
    land in the cache synchronously (dedup against the running save stays
    one stat) and pipeline the backend upload on the codec executor,
    overlapped with encode; ``flush_uploads`` is the save's pre-commit
    barrier. During an outage writes spool (tracked per hash — the cache
    file is the spool) and ``CheckpointStore`` parks the manifest until
    ``upload_now``/``probe`` reconcile. Composes as the *shared* tier of
    ``peer_exchange.ReadThroughPool`` for local → peer → store resolution.
    """

    #: the cache is not the durable copy: per-save fan-out dir fsyncs are
    #: wasted work here, the durability bar is "every ref uploaded before
    #: the manifest commits" (see chunkstore.store_chunk)
    durable_dirs = False

    def __init__(self, cache_root: str, backend: ChunkBackend, *,
                 part_size: int = DEFAULT_PART_SIZE,
                 retry_policy=None,
                 health: BackendHealth | None = None,
                 upload_lane: int = codec_sched.PERIODIC):
        super().__init__(cache_root)
        self.backend = backend
        self.part_size = part_size
        self.retry_policy = retry_policy
        self.health = health or BackendHealth()
        self.upload_lane = upload_lane
        self._track_lock = threading.Lock()
        self._durable: set[str] = set()       # confirmed in the backend
        self._spooled: dict[str, int] = {}    # h -> nbytes awaiting upload
        self._uploads: dict[str, object] = {}  # h -> in-flight Future
        # cache-fill reentrancy guard: while the read path writes a fetched
        # chunk into the cache, ``check`` must answer from the local tree
        # only — otherwise ChunkPool.write's dedup sees the backend copy and
        # skips creating the very file the decode is about to open
        self._local_only = threading.local()
        self.stats = {"cache_hits": 0, "backend_reads": 0, "uploads": 0,
                      "upload_bytes": 0, "spooled": 0, "reconciled": 0}
        self._stats_lock = threading.Lock()

    def _bump(self, key: str, n: int = 1) -> None:
        with self._stats_lock:
            self.stats[key] += n

    # -- read path -------------------------------------------------------------

    def chunk_path(self, ref: ChunkRef) -> str:
        path = self.path(ref.hash)
        if os.path.exists(path):
            self._bump("cache_hits")
            return path
        try:
            data = fetch_chunk_verified(self.backend, ref,
                                        policy=self.retry_policy)
        except OSError:
            self.health.note_failure()
            raise
        self.health.note_success()
        with self._track_lock:
            self._durable.add(ref.hash)
        # cache fill: atomic write, no dir fsync — the backend holds the
        # durable copy, the cache only has to win the mmap fast path
        self._local_only.on = True
        try:
            super().write(ref.hash, data, sync_dir=False)
        finally:
            self._local_only.on = False
        self._bump("backend_reads")
        return path

    def _head_size(self, h: str) -> Optional[int]:
        """Committed size of ``h`` in the backend, None when absent or
        unreachable. Short-circuits during an outage so dedup checks don't
        hammer a dead endpoint."""
        if self.health.in_outage():
            return None
        try:
            size = self.backend.head(object_key(h))
        except OSError:
            self.health.note_failure()
            return None
        self.health.note_success()
        return size

    def check(self, h: str, nbytes: int) -> bool:
        if super().check(h, nbytes):
            if getattr(self._local_only, "on", False):
                return True
            with self._track_lock:
                known = (h in self._durable or h in self._spooled
                         or h in self._uploads)
            if not known:
                # cache entry from a previous process: dedup may reuse it
                # only once the durable copy is confirmed — or scheduled
                if self._head_size(h) == nbytes:
                    with self._track_lock:
                        self._durable.add(h)
                else:
                    self._schedule_upload(h)
            return True
        if getattr(self._local_only, "on", False):
            return False
        return self._head_size(h) == nbytes

    def touch(self, h: str) -> bool:
        if super().touch(h):
            return True
        return self._head_size(h) is not None

    # -- write path ------------------------------------------------------------

    def write(self, h: str, data, *, sync_dir: bool = True) -> int:
        n = super().write(h, data, sync_dir=False)
        self._schedule_upload(h)
        return n

    def _schedule_upload(self, h: str) -> None:
        with self._track_lock:
            if (h in self._durable or h in self._uploads
                    or h in self._spooled):
                return
            if self.health.in_outage():
                self._spool_locked(h)
                return
            try:
                # enqueue-only under the lock (no wait): upload jobs overlap
                # the remaining encode work on the same executor
                fut = codec_sched.lane(self.upload_lane).submit(
                    self._upload_job, h)
            except RuntimeError:
                # scheduler already shut down (interpreter exit): spool —
                # reconcile on the next process owns the upload
                self._spool_locked(h)
                return
            self._uploads[h] = fut

    def _upload_job(self, h: str) -> bool:
        # preemption checkpoint: queued uploads hand their worker to any
        # RESTORE/URGENT job before touching the network
        codec_sched.maybe_yield()
        try:
            with open(self.path(h), "rb") as f:
                data = f.read()
        except OSError:
            # cache entry vanished (sweep race): the next writer of this
            # content re-lands it; nothing to upload now
            with self._track_lock:
                self._uploads.pop(h, None)
            return False
        try:
            sent = _backend_retry(
                lambda: upload_chunk(self.backend, h, data,
                                     part_size=self.part_size),
                describe=f"backend put {h[:10]}", h=h,
                policy=self.retry_policy)
        except Exception:
            # bounded retries exhausted: the chunk is safe in the cache —
            # spool it and let the outage machinery own the re-upload
            self.health.note_failure()
            with self._track_lock:
                self._uploads.pop(h, None)
                self._spool_locked(h, len(data))
            return False
        except BaseException:
            # SimulatedCrash and friends: leave the future in the tracking
            # table so flush_uploads finds it and re-raises (the save dies
            # there, exactly like a process kill mid-upload) — popping it
            # here would let the durability barrier miss the dead upload
            # and commit a manifest over a ref that never landed
            raise
        self.health.note_success()
        self._bump("uploads")
        self._bump("upload_bytes", sent)
        with self._track_lock:
            self._uploads.pop(h, None)
            self._durable.add(h)
        return True

    def _spool_locked(self, h: str, nbytes: int | None = None) -> None:
        """Record ``h`` as awaiting upload (caller holds ``_track_lock``).
        The cache file IS the spool — only bookkeeping lives here."""
        if h in self._spooled:
            return
        if nbytes is None:
            try:
                nbytes = os.path.getsize(self.path(h))
            except OSError:
                nbytes = 0
        self._spooled[h] = nbytes
        _count(spooled=nbytes)
        self._bump("spooled")

    # -- durability barrier / reconcile ----------------------------------------

    def flush_uploads(self, hashes: Iterable[str] | None = None) -> set[str]:
        """Wait for in-flight uploads, then report which of ``hashes`` (all
        tracked spool entries when None) are still not durable. The save
        path calls this before its manifest commit: a non-empty return
        means "park, don't commit". Re-raises a crash injected into an
        upload job — a killed uploader kills the save."""
        want = None if hashes is None else set(hashes)
        while True:
            with self._track_lock:
                pending = [(h, f) for h, f in self._uploads.items()
                           if want is None or h in want]
            if not pending:
                break
            for h, f in pending:
                try:
                    f.result()
                except BaseException:
                    # surface the crash, but clear the dead upload's
                    # tracking entry first: the successor save must be
                    # able to reschedule this chunk, not wait forever on
                    # (or re-die at) a future that already failed
                    with self._track_lock:
                        if self._uploads.get(h) is f:
                            del self._uploads[h]
                    raise
        with self._track_lock:
            spooled = set(self._spooled)
        return spooled if want is None else spooled & want

    def undurable(self, hashes: Iterable[str]) -> set[str]:
        """Subset of ``hashes`` with no confirmed durable copy (spooled or
        never uploaded)."""
        with self._track_lock:
            return {h for h in hashes if h not in self._durable}

    def upload_now(self, hashes: Iterable[str]) -> bool:
        """Synchronously make every hash in ``hashes`` durable (the
        reconcile path). True when all are; False at the first chunk the
        backend still refuses — the caller's parked commit stays parked."""
        want = set(hashes)
        self.flush_uploads(want)
        with self._track_lock:
            todo = sorted(h for h in want if h not in self._durable)
        for h in todo:
            path = self.path(h)
            if not os.path.exists(path):
                # not spooled locally (e.g. another member's chunk): only a
                # confirmed backend copy can satisfy the durability bar
                if self._head_size(h) is not None:
                    with self._track_lock:
                        self._durable.add(h)
                        self._spooled.pop(h, None)
                    continue
                return False
            try:
                with open(path, "rb") as f:
                    data = f.read()
                sent = _backend_retry(
                    lambda: upload_chunk(self.backend, h, data,
                                         part_size=self.part_size),
                    describe=f"backend reconcile {h[:10]}", h=h,
                    policy=self.retry_policy)
            except Exception:
                self.health.note_failure()
                return False
            self.health.note_success()
            self._bump("uploads")
            self._bump("upload_bytes", sent)
            self._bump("reconciled")
            with self._track_lock:
                self._spooled.pop(h, None)
                self._durable.add(h)
        return True

    def probe(self) -> bool:
        """One cheap HEAD against the store; a response — hit or miss —
        proves reachability and clears outage mode."""
        if not self.health.in_outage():
            return True
        try:
            self.backend.head(object_key("0" * 40))
        except OSError:
            self.health.note_failure()
            return False
        self.health.note_success()
        return True

    def spooled_bytes(self) -> int:
        with self._track_lock:
            return sum(self._spooled.values())
