"""Tensor (de)serialization for Spot-on checkpoints.

A checkpoint shard file is a self-describing container:

    MAGIC | u32 header_len | header JSON (utf-8) | payload

The header lists every tensor stored in the file with its name (pytree key
path), dtype, local shape, global shape, the global index (slice) this piece
covers, byte offset/length into the payload, a crc32 checksum, and optional
codec ("zstd"/"zlib" per-tensor compression, "int8" absmax quantization for
optimizer moments).  Per-tensor compression keeps partial reads cheap: an
elastic restore that needs one tensor's bytes never decompresses the whole
file.  ``zstandard`` is an optional dependency: when it is not installed,
requested zstd codecs degrade to the stdlib ``zlib`` codec at encode time
(recorded as such in the header, so files stay self-describing), the default
codec policy compresses only payloads where zlib pays (integer/bool data —
on float tensors zlib's ~20 MB/s for a ~7% ratio would dominate checkpoint
time, so they stay raw), and reading a zstd-coded file raises a clear error
instead of an ImportError at import.

bfloat16 (and other ml_dtypes extended types) round-trip via dtype-name lookup
rather than numpy's descr machinery, which cannot serialize custom dtypes.
"""

from __future__ import annotations

import io
import json
import struct
import zlib
from dataclasses import dataclass, field
from typing import Any, Callable, Iterable, Sequence

import numpy as np

try:  # optional: zstd beats zlib on ratio+speed, but zlib always exists
    import zstandard
    HAVE_ZSTD = True
except ImportError:  # pragma: no cover - environment-dependent
    zstandard = None
    HAVE_ZSTD = False

import jax
import jax.numpy as jnp
import ml_dtypes  # ships with jax

MAGIC = b"SPOTON1\n"
_U32 = struct.Struct("<I")

# dtype registry covering numpy natives + ml_dtypes extensions used by JAX.
_EXTENDED_DTYPES = {
    "bfloat16": ml_dtypes.bfloat16,
    "float8_e4m3fn": ml_dtypes.float8_e4m3fn,
    "float8_e5m2": ml_dtypes.float8_e5m2,
}


def dtype_to_name(dtype) -> str:
    return np.dtype(dtype).name


def name_to_dtype(name: str) -> np.dtype:
    if name in _EXTENDED_DTYPES:
        return np.dtype(_EXTENDED_DTYPES[name])
    return np.dtype(name)


@dataclass
class TensorRecord:
    """Metadata for one stored tensor piece."""

    name: str
    dtype: str                    # logical dtype (pre-quantization)
    shape: tuple[int, ...]        # local (stored piece) shape
    global_shape: tuple[int, ...]
    index: tuple[tuple[int, int], ...]  # [start, stop) per dim, global coords
    offset: int = 0
    nbytes: int = 0
    crc32: int = 0
    codec: str = "raw"            # raw | zstd | int8 | int8+zstd
    scale: float | None = None    # absmax scale for int8 codec

    def to_json(self) -> dict:
        d = {
            "name": self.name, "dtype": self.dtype, "shape": list(self.shape),
            "global_shape": list(self.global_shape),
            "index": [list(p) for p in self.index],
            "offset": self.offset, "nbytes": self.nbytes, "crc32": self.crc32,
            "codec": self.codec,
        }
        if self.scale is not None:
            d["scale"] = self.scale
        return d

    @staticmethod
    def from_json(d: dict) -> "TensorRecord":
        return TensorRecord(
            name=d["name"], dtype=d["dtype"], shape=tuple(d["shape"]),
            global_shape=tuple(d["global_shape"]),
            index=tuple(tuple(p) for p in d["index"]),
            offset=d["offset"], nbytes=d["nbytes"], crc32=d["crc32"],
            codec=d.get("codec", "raw"), scale=d.get("scale"),
        )


# ---------------------------------------------------------------------------
# pytree <-> named leaves
# ---------------------------------------------------------------------------

def _key_str(path) -> str:
    """Stable, filesystem-free name for a pytree key path."""
    parts = []
    for k in path:
        if isinstance(k, jax.tree_util.DictKey):
            parts.append(str(k.key))
        elif isinstance(k, jax.tree_util.SequenceKey):
            parts.append(str(k.idx))
        elif isinstance(k, jax.tree_util.GetAttrKey):
            parts.append(str(k.name))
        else:  # pragma: no cover - future key kinds
            parts.append(str(k))
    return "/".join(parts)


def flatten_state(tree) -> dict[str, Any]:
    """Flatten a pytree into {keypath: leaf}. Leaves may be jax/np arrays or scalars."""
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in leaves:
        name = _key_str(path)
        if name in out:
            raise ValueError(f"duplicate leaf name {name!r}")
        out[name] = leaf
    return out


def tree_structure_of(tree):
    return jax.tree_util.tree_structure(tree)


def unflatten_state(treedef, named: dict[str, Any], order: Sequence[str]):
    return jax.tree_util.tree_unflatten(treedef, [named[n] for n in order])


def to_host(leaf) -> np.ndarray:
    """Device/py leaf -> numpy array (blocking device->host copy for jax.Array)."""
    if isinstance(leaf, jax.Array):
        return np.asarray(leaf)
    if isinstance(leaf, np.ndarray):
        return leaf
    return np.asarray(leaf)


# ---------------------------------------------------------------------------
# codecs
# ---------------------------------------------------------------------------

def resolve_codec(codec: str) -> str:
    """Degrade zstd-suffixed codecs to zlib when zstandard is unavailable."""
    if codec.endswith("zstd") and not HAVE_ZSTD:
        return codec[:-len("zstd")] + "zlib"
    return codec


def split_codec(codec: str) -> tuple[str, str]:
    """Codec string -> (quantization, compression) halves.

    The delta write path applies the two halves at different granularities:
    quantization per tensor (the absmax scale is tensor-global), compression
    per chunk (so unchanged chunks can skip the compressor entirely).
    """
    quant = "int8" if codec.startswith("int8") else ""
    if codec.endswith("zstd"):
        comp = "zstd"
    elif codec.endswith("zlib"):
        comp = "zlib"
    else:
        comp = ""
    return quant, comp


def quantize(arr: np.ndarray, quant: str) -> tuple[bytes, float | None]:
    """Tensor -> contiguous raw payload (+ absmax scale for int8)."""
    if quant == "int8":
        absmax = float(np.max(np.abs(arr.astype(np.float32)))) if arr.size else 0.0
        scale = absmax / 127.0 if absmax > 0 else 1.0
        q = np.clip(np.round(arr.astype(np.float32) / scale), -127, 127).astype(np.int8)
        return q.tobytes(), scale
    return np.ascontiguousarray(arr).tobytes(), None


def compress_bytes(buf: bytes, comp: str) -> bytes:
    if comp == "zstd":
        return zstandard.ZstdCompressor(level=3).compress(buf)
    if comp == "zlib":
        return zlib.compress(buf, 3)
    return buf


def decompress_bytes(buf: bytes, comp: str) -> bytes:
    if comp == "zstd":
        if not HAVE_ZSTD:
            raise IOError(
                "payload was written with the zstd codec but the 'zstandard' "
                "package is not installed (pip install zstandard)")
        return zstandard.ZstdDecompressor().decompress(buf)
    if comp == "zlib":
        return zlib.decompress(buf)
    return buf


def payload_to_array(raw: bytes, *, dtype_name: str, shape, quant: str,
                     scale: float | None) -> np.ndarray:
    """Decoded (decompressed) raw payload -> tensor."""
    shape = tuple(shape)
    if quant == "int8":
        q = np.frombuffer(raw, dtype=np.int8).reshape(shape)
        return (q.astype(np.float32) * scale).astype(name_to_dtype(dtype_name))
    return np.frombuffer(raw, dtype=name_to_dtype(dtype_name)).reshape(shape).copy()


def _encode(arr: np.ndarray, codec: str) -> tuple[bytes, float | None]:
    quant, comp = split_codec(codec)
    raw, scale = quantize(arr, quant)
    return compress_bytes(raw, comp), scale


def _decode(buf: bytes, rec: TensorRecord) -> np.ndarray:
    quant, comp = split_codec(rec.codec)
    try:
        raw = decompress_bytes(buf, comp)
    except IOError as e:
        raise IOError(f"tensor {rec.name!r}: {e}") from None
    return payload_to_array(raw, dtype_name=rec.dtype, shape=rec.shape,
                            quant=quant, scale=rec.scale)


# ---------------------------------------------------------------------------
# shard file writer / reader
# ---------------------------------------------------------------------------

@dataclass
class PendingTensor:
    record: TensorRecord
    payload: bytes


def encode_tensor(
    name: str,
    arr: np.ndarray,
    *,
    global_shape: tuple[int, ...] | None = None,
    index: tuple[tuple[int, int], ...] | None = None,
    codec: str = "raw",
) -> PendingTensor:
    arr = np.asarray(arr)
    codec = resolve_codec(codec)
    gshape = tuple(global_shape if global_shape is not None else arr.shape)
    idx = tuple(index if index is not None else tuple((0, s) for s in arr.shape))
    payload, scale = _encode(arr, codec)
    rec = TensorRecord(
        name=name, dtype=dtype_to_name(arr.dtype), shape=tuple(arr.shape),
        global_shape=gshape, index=idx, nbytes=len(payload),
        crc32=zlib.crc32(payload), codec=codec, scale=scale,
    )
    return PendingTensor(rec, payload)


def write_shard_file(path, tensors: Iterable[PendingTensor]) -> list[TensorRecord]:
    """Write a shard container; returns finalized records (offsets filled)."""
    tensors = list(tensors)
    offset = 0
    records = []
    for t in tensors:
        t.record.offset = offset
        offset += t.record.nbytes
        records.append(t.record)
    header = json.dumps({"tensors": [r.to_json() for r in records]}).encode()
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(_U32.pack(len(header)))
        f.write(header)
        for t in tensors:
            f.write(t.payload)
        f.flush()
        import os
        os.fsync(f.fileno())
    return records


class ShardFileReader:
    """Random access into a shard container; validates crc per read."""

    def __init__(self, path):
        self.path = path
        with open(path, "rb") as f:
            magic = f.read(len(MAGIC))
            if magic != MAGIC:
                raise ValueError(f"{path}: bad magic {magic!r}")
            (hlen,) = _U32.unpack(f.read(4))
            header = json.loads(f.read(hlen).decode())
            self._payload_start = len(MAGIC) + 4 + hlen
        self.records = {r["name"]: TensorRecord.from_json(r) for r in header["tensors"]}

    def names(self) -> list[str]:
        return list(self.records)

    def read(self, name: str) -> np.ndarray:
        rec = self.records[name]
        with open(self.path, "rb") as f:
            f.seek(self._payload_start + rec.offset)
            buf = f.read(rec.nbytes)
        if zlib.crc32(buf) != rec.crc32:
            raise IOError(f"{self.path}:{name}: crc mismatch (corrupt shard)")
        return _decode(buf, rec)

    def validate(self) -> None:
        for name in self.records:
            self.read(name)


def default_codec_for(name: str, arr: np.ndarray, *, compress: bool,
                      quantize_moments: bool) -> str:
    """Checkpoint codec policy.

    Optimizer moments (``opt_state/.../mu|nu``) may be int8-quantized — a
    beyond-paper optimization that shrinks termination checkpoints so they fit
    inside the eviction-notice window. Params and scalars stay exact.
    """
    is_moment = ("/mu/" in f"/{name}/" or name.endswith("/mu")
                 or "/nu/" in f"/{name}/" or name.endswith("/nu"))
    floaty = np.issubdtype(np.asarray(arr).dtype, np.floating) or \
        np.asarray(arr).dtype == np.dtype(ml_dtypes.bfloat16)
    if quantize_moments and is_moment and floaty and np.asarray(arr).ndim >= 1:
        return resolve_codec("int8+zstd") if compress else "int8"
    if compress and np.asarray(arr).nbytes >= 1024:
        if HAVE_ZSTD:
            return "zstd"
        # zlib runs ~20 MB/s on float payloads for a ~7% ratio — it would
        # dominate checkpoint time for no real size win, so large float
        # tensors stay raw; integer/bool payloads still compress well
        if np.asarray(arr).dtype.kind in "iub":
            return "zlib"
    return "raw"
