"""Tensor (de)serialization for Spot-on checkpoints.

A checkpoint shard file is a self-describing container:

    MAGIC | u32 header_len | header JSON (utf-8) | payload

The header lists every tensor stored in the file with its name (pytree key
path), dtype, local shape, global shape, the global index (slice) this piece
covers, byte offset/length into the payload, a crc32 checksum, and optional
codec ("zstd"/"zlib" per-tensor compression, "int8" absmax quantization for
optimizer moments).  Per-tensor compression keeps partial reads cheap: an
elastic restore that needs one tensor's bytes never decompresses the whole
file.  ``zstandard`` is an optional dependency: when it is not installed,
requested zstd codecs degrade to the stdlib ``zlib`` codec at encode time
(recorded as such in the header, so files stay self-describing), the default
codec policy compresses only payloads where zlib pays (integer/bool data —
on float tensors zlib's ~20 MB/s for a ~7% ratio would dominate checkpoint
time, so they stay raw), and reading a zstd-coded file raises a clear error
instead of an ImportError at import.

The encode path holds a **one-copy invariant**: a tensor's payload is
materialized on the host at most once (the staged array itself for raw
codecs, the int8 array for quantized ones). ``quantize`` returns a contiguous
*array*, not bytes, and everything downstream — chunking, hashing, crc,
compression, file writes — operates on ``memoryview`` windows over that
buffer. Decode is symmetric: ``ShardFileReader`` maps its container once and
decodes tensors from mmap slices straight into caller-preallocated
destination buffers (``read_into``).

bfloat16 (and other ml_dtypes extended types) round-trip via dtype-name lookup
rather than numpy's descr machinery, which cannot serialize custom dtypes.
"""

from __future__ import annotations

import io
import json
import os
import struct
import zlib
from dataclasses import dataclass, field
from typing import Any, Callable, Iterable, Sequence

import numpy as np

from ..faults import inject as faults
from .ioutil import array_bytes_view, mmap_view, release_view

try:  # optional: zstd beats zlib on ratio+speed, but zlib always exists
    import zstandard
    HAVE_ZSTD = True
except ImportError:  # pragma: no cover - environment-dependent
    zstandard = None
    HAVE_ZSTD = False

import jax
import jax.numpy as jnp
import ml_dtypes  # ships with jax

MAGIC = b"SPOTON1\n"
_U32 = struct.Struct("<I")

# dtype registry covering numpy natives + ml_dtypes extensions used by JAX.
_EXTENDED_DTYPES = {
    "bfloat16": ml_dtypes.bfloat16,
    "float8_e4m3fn": ml_dtypes.float8_e4m3fn,
    "float8_e5m2": ml_dtypes.float8_e5m2,
}


def dtype_to_name(dtype) -> str:
    return np.dtype(dtype).name


def name_to_dtype(name: str) -> np.dtype:
    if name in _EXTENDED_DTYPES:
        return np.dtype(_EXTENDED_DTYPES[name])
    return np.dtype(name)


@dataclass
class TensorRecord:
    """Metadata for one stored tensor piece."""

    name: str
    dtype: str                    # logical dtype (pre-quantization)
    shape: tuple[int, ...]        # local (stored piece) shape
    global_shape: tuple[int, ...]
    index: tuple[tuple[int, int], ...]  # [start, stop) per dim, global coords
    offset: int = 0
    nbytes: int = 0
    crc32: int = 0
    codec: str = "raw"            # raw | zstd | int8 | int8+zstd
    scale: float | None = None    # absmax scale for int8 codec

    def to_json(self) -> dict:
        d = {
            "name": self.name, "dtype": self.dtype, "shape": list(self.shape),
            "global_shape": list(self.global_shape),
            "index": [list(p) for p in self.index],
            "offset": self.offset, "nbytes": self.nbytes, "crc32": self.crc32,
            "codec": self.codec,
        }
        if self.scale is not None:
            d["scale"] = self.scale
        return d

    @staticmethod
    def from_json(d: dict) -> "TensorRecord":
        return TensorRecord(
            name=d["name"], dtype=d["dtype"], shape=tuple(d["shape"]),
            global_shape=tuple(d["global_shape"]),
            index=tuple(tuple(p) for p in d["index"]),
            offset=d["offset"], nbytes=d["nbytes"], crc32=d["crc32"],
            codec=d.get("codec", "raw"), scale=d.get("scale"),
        )


# ---------------------------------------------------------------------------
# pytree <-> named leaves
# ---------------------------------------------------------------------------

def _key_str(path) -> str:
    """Stable, filesystem-free name for a pytree key path."""
    parts = []
    for k in path:
        if isinstance(k, jax.tree_util.DictKey):
            parts.append(str(k.key))
        elif isinstance(k, jax.tree_util.SequenceKey):
            parts.append(str(k.idx))
        elif isinstance(k, jax.tree_util.GetAttrKey):
            parts.append(str(k.name))
        else:  # pragma: no cover - future key kinds
            parts.append(str(k))
    return "/".join(parts)


def flatten_state(tree) -> dict[str, Any]:
    """Flatten a pytree into {keypath: leaf}. Leaves may be jax/np arrays or scalars."""
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in leaves:
        name = _key_str(path)
        if name in out:
            raise ValueError(f"duplicate leaf name {name!r}")
        out[name] = leaf
    return out


def tree_structure_of(tree):
    return jax.tree_util.tree_structure(tree)


def unflatten_state(treedef, named: dict[str, Any], order: Sequence[str]):
    return jax.tree_util.tree_unflatten(treedef, [named[n] for n in order])


def to_host(leaf) -> np.ndarray:
    """Device/py leaf -> numpy array: the snapshot *freeze*.

    jax.Array leaves stay zero-copy views (np.asarray of an immutable
    buffer — on CPU backends not even a transfer). Caller-owned numpy leaves
    are **copied**: the encode path hashes and writes from memoryview windows
    over this buffer, so if it aliased live state a concurrent in-place
    mutation between digest and write would commit a chunk whose bytes match
    neither its content address nor its crc — an unrestorable checkpoint
    that was reported committed. The copy is the freeze the snapshot
    contract promises, and it is the save path's one materialization.
    """
    if isinstance(leaf, jax.Array):
        return np.asarray(leaf)
    if isinstance(leaf, np.ndarray):
        return leaf.copy()
    return np.asarray(leaf)


# ---------------------------------------------------------------------------
# codecs
# ---------------------------------------------------------------------------

def resolve_codec(codec: str) -> str:
    """Degrade zstd-suffixed codecs to zlib when zstandard is unavailable."""
    if codec.endswith("zstd") and not HAVE_ZSTD:
        return codec[:-len("zstd")] + "zlib"
    return codec


def split_codec(codec: str) -> tuple[str, str]:
    """Codec string -> (quantization, compression) halves.

    The delta write path applies the two halves at different granularities:
    quantization per tensor (the absmax scale is tensor-global), compression
    per chunk (so unchanged chunks can skip the compressor entirely).
    """
    quant = "int8" if codec.startswith("int8") else ""
    if codec.endswith("zstd"):
        comp = "zstd"
    elif codec.endswith("zlib"):
        comp = "zlib"
    else:
        comp = ""
    return quant, comp


def quantize(arr: np.ndarray, quant: str) -> tuple[np.ndarray, float | None]:
    """Tensor -> contiguous payload *array* (+ absmax scale for int8).

    Returns an array, not bytes: for the raw codec this is the input itself
    when already contiguous (zero-copy), so downstream hashing/compression
    can run on memoryview windows without a ``.tobytes()`` materialization.
    """
    if quant == "int8":
        absmax = np.float32(np.max(np.abs(arr.astype(np.float32)))) if arr.size \
            else np.float32(0.0)
        scale, inv = int8_scale_inv(absmax)
        # multiply-only elementwise step, float32 scalar arithmetic: this is
        # what keeps a host quantize bit-identical to the on-device kernel
        # (kernels/quantize) even under XLA's fast-math, which rewrites
        # division into reciprocal-multiply — identical payload bytes are what
        # let urgent (device-quantized) and periodic (host-quantized) saves of
        # the same state dedup to the same pool chunks
        q = np.clip(np.round(arr.astype(np.float32) * inv), -127, 127).astype(np.int8)
        return q, float(scale)
    return np.ascontiguousarray(arr), None


def int8_scale_inv(absmax) -> tuple[np.float32, np.float32]:
    """absmax -> (scale, 1/scale), both float32, computed with numpy scalar
    ops. Every quantize implementation (host, jnp oracle, Pallas kernel)
    funnels its reduce result through this one function so the scalar
    rounding sequence — and therefore the stored bytes — cannot diverge."""
    absmax = np.float32(absmax)
    scale = absmax / np.float32(127.0) if absmax > 0 else np.float32(1.0)
    return scale, np.float32(1.0) / scale


def compress_bytes(buf, comp: str) -> bytes:
    """Compress a bytes-like (bytes or memoryview window) payload."""
    if comp == "zstd":
        return zstandard.ZstdCompressor(level=3).compress(buf)
    if comp == "zlib":
        return zlib.compress(buf, 3)
    return buf


def decompress_bytes(buf, comp: str) -> bytes:
    if comp == "zstd":
        if not HAVE_ZSTD:
            raise IOError(
                "payload was written with the zstd codec but the 'zstandard' "
                "package is not installed (pip install zstandard)")
        return zstandard.ZstdDecompressor().decompress(buf)
    if comp == "zlib":
        return zlib.decompress(buf)
    return buf


def stored_dtype(dtype_name: str, quant: str) -> np.dtype:
    """Dtype of the raw (pre-compression) payload on disk."""
    return np.dtype(np.int8) if quant == "int8" else name_to_dtype(dtype_name)


def alloc_payload(dtype_name: str, shape, quant: str) -> np.ndarray:
    """Preallocated destination for a tensor's raw payload — decode fills
    this in place (one mmap-slice copy per chunk, no concatenation)."""
    return np.empty(tuple(shape), dtype=stored_dtype(dtype_name, quant))


def finish_payload(dst: np.ndarray, *, dtype_name: str, quant: str,
                   scale: float | None) -> np.ndarray:
    """Filled payload array -> logical tensor (dequantize if needed).

    The dequantize multiplies in float32 with a float32 scale — the exact
    arithmetic of the device dequant kernel (kernels/quantize), so host- and
    device-restored tensors are bit-identical. A float32 target multiplies
    straight into the output dtype (one allocation); other targets need the
    float32 intermediate before the final cast, but never a second astype
    when the cast is a no-op.
    """
    if quant == "int8":
        target = name_to_dtype(dtype_name)
        s = np.float32(scale)
        if target == np.float32:
            return np.multiply(dst, s, dtype=np.float32)
        return (dst.astype(np.float32) * s).astype(target)
    return dst


def payload_to_array(raw, *, dtype_name: str, shape, quant: str,
                     scale: float | None) -> np.ndarray:
    """Decoded (decompressed) raw payload bytes -> tensor (copies)."""
    shape = tuple(shape)
    dst = np.frombuffer(raw, dtype=stored_dtype(dtype_name, quant)).reshape(shape)
    if quant != "int8":
        dst = dst.copy()        # frombuffer views are read-only
    return finish_payload(dst, dtype_name=dtype_name, quant=quant, scale=scale)


def _encode(arr: np.ndarray, codec: str):
    quant, comp = split_codec(codec)
    raw, scale = quantize(arr, quant)
    view = array_bytes_view(raw)
    if comp:
        return compress_bytes(view, comp), scale
    return view, scale          # zero-copy: raw codec payload is the array


def _decode(buf, rec: TensorRecord) -> np.ndarray:
    quant, comp = split_codec(rec.codec)
    try:
        raw = decompress_bytes(buf, comp) if comp else buf
    except IOError as e:
        raise IOError(f"tensor {rec.name!r}: {e}") from None
    return payload_to_array(raw, dtype_name=rec.dtype, shape=rec.shape,
                            quant=quant, scale=rec.scale)


# ---------------------------------------------------------------------------
# shard file writer / reader
# ---------------------------------------------------------------------------

@dataclass
class PendingTensor:
    record: TensorRecord
    payload: Any               # bytes or memoryview over the staged array


def encode_tensor(
    name: str,
    arr: np.ndarray,
    *,
    global_shape: tuple[int, ...] | None = None,
    index: tuple[tuple[int, int], ...] | None = None,
    codec: str = "raw",
    prequant_scale: float | None = None,
    logical_dtype: str | None = None,
) -> PendingTensor:
    """Encode one tensor piece.

    ``prequant_scale`` marks ``arr`` as an already-quantized int8 payload
    (produced on-device before the host copy): the quantize half of ``codec``
    is skipped, ``logical_dtype`` records the original dtype, and the on-disk
    bytes are identical to a host-side quantize of the same values.
    """
    # `arr` is snapshot-owned: to_host froze (copied) it at the snapshot
    # boundary, so this asarray is a no-op normalization, not an alias of
    # live training state
    arr = np.asarray(arr)  # spotlint: ignore[SPOT021]
    codec = resolve_codec(codec)
    gshape = tuple(global_shape if global_shape is not None else arr.shape)
    idx = tuple(index if index is not None else tuple((0, s) for s in arr.shape))
    if prequant_scale is not None:
        _quant, comp = split_codec(codec)
        view = array_bytes_view(np.ascontiguousarray(arr))
        payload = compress_bytes(view, comp) if comp else view
        scale = prequant_scale
        dtype_name = logical_dtype or dtype_to_name(arr.dtype)
    else:
        payload, scale = _encode(arr, codec)
        dtype_name = dtype_to_name(arr.dtype)
    rec = TensorRecord(
        name=name, dtype=dtype_name, shape=tuple(arr.shape),
        global_shape=gshape, index=idx, nbytes=len(payload),
        crc32=zlib.crc32(payload), codec=codec, scale=scale,
    )
    return PendingTensor(rec, payload)


def write_shard_file(path, tensors: Iterable[PendingTensor]) -> list[TensorRecord]:
    """Write a shard container; returns finalized records (offsets filled)."""
    tensors = list(tensors)
    offset = 0
    records = []
    for t in tensors:
        t.record.offset = offset
        offset += t.record.nbytes
        records.append(t.record)
    header = json.dumps({"tensors": [r.to_json() for r in records]}).encode()
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(_U32.pack(len(header)))
        f.write(header)
        for t in tensors:
            faults.write_bytes(f, t.payload, op="shard.write", path=str(path))
        f.flush()
        os.fsync(f.fileno())
    return records


class ShardFileReader:
    """Random access into a shard container; validates crc per read.

    The container is mapped once (``mmap``) and every tensor read slices the
    mapping — no per-tensor ``open``/``read`` syscalls, and raw-codec tensors
    copy straight from the page cache into the destination buffer. Falls back
    to one buffered read of the whole file where mmap is unavailable.
    """

    def __init__(self, path: "str | os.PathLike[str]") -> None:
        self.path = path
        self._buf: memoryview | None = mmap_view(str(path))
        if bytes(self._buf[:len(MAGIC)]) != MAGIC:
            magic = bytes(self._buf[:len(MAGIC)])
            release_view(self._buf)
            raise ValueError(f"{path}: bad magic {magic!r}")
        (hlen,) = _U32.unpack(self._buf[len(MAGIC):len(MAGIC) + 4])
        self._payload_start = len(MAGIC) + 4 + hlen
        header = json.loads(bytes(self._buf[len(MAGIC) + 4:self._payload_start]))
        self.records = {r["name"]: TensorRecord.from_json(r) for r in header["tensors"]}

    def close(self) -> None:
        if self._buf is not None:
            release_view(self._buf)
            self._buf = None

    def names(self) -> list[str]:
        return list(self.records)

    def _payload_view(self, rec: TensorRecord) -> memoryview:
        if self._buf is None:
            raise ValueError(f"{self.path}: reader is closed")
        start = self._payload_start + rec.offset
        buf = self._buf[start:start + rec.nbytes]
        if zlib.crc32(buf) != rec.crc32:
            raise IOError(f"{self.path}:{rec.name}: crc mismatch (corrupt shard)")
        return buf

    def read(self, name: str) -> np.ndarray:
        return _decode(self._payload_view(self.records[name]),
                       self.records[name])

    def read_into(self, name: str, dst: np.ndarray) -> bool:
        """Decode ``name`` directly into preallocated ``dst`` when its dtype
        and shape match the stored payload; returns False (caller falls back
        to ``read``) otherwise. One copy: mmap slice -> dst."""
        rec = self.records[name]
        quant, _comp = split_codec(rec.codec)
        if quant:
            return False
        return self.read_payload_into(name, dst)

    def read_payload_view(self, name: str) -> memoryview | None:
        """crc-validated zero-copy view of an *uncompressed* tensor's stored
        payload (mmap slice — a device transfer can copy straight from the
        page cache). None for compressed records; the view's lifetime is
        tied to this reader's mapping."""
        rec = self.records[name]
        _quant, comp = split_codec(rec.codec)
        if comp:
            return None
        return self._payload_view(rec)

    def read_payload_into(self, name: str, dst: np.ndarray) -> bool:
        """Fill ``dst`` with the *stored* payload (post-decompress,
        pre-dequantize): for an int8-coded tensor ``dst`` must be int8 —
        this is what lets the streaming restore ship quantized payloads to
        the device at 1/4 width and widen them there."""
        rec = self.records[name]
        quant, comp = split_codec(rec.codec)
        if (tuple(dst.shape) != tuple(rec.shape)
                or dst.dtype != stored_dtype(rec.dtype, quant)
                or not dst.flags.c_contiguous):
            return False
        buf = self._payload_view(rec)
        out = array_bytes_view(dst)
        if comp:
            out[:] = decompress_bytes(buf, comp)
        else:
            out[:] = buf
        return True

    def validate(self) -> None:
        for name in self.records:
            self.read(name)


def is_float_dtype(dtype) -> bool:
    """True for float dtypes *including* ml_dtypes extended types, which
    numpy's issubdtype does not classify as inexact."""
    dt = np.dtype(dtype)
    return (np.issubdtype(dt, np.floating)
            or any(dt == np.dtype(t) for t in _EXTENDED_DTYPES.values()))


def is_moment_name(name: str) -> bool:
    """True for optimizer-moment leaves (``opt_state/.../mu|nu``)."""
    wrapped = f"/{name}/"
    return "/mu/" in wrapped or "/nu/" in wrapped


def default_codec_for(name: str, arr: np.ndarray, *, compress: bool,
                      quantize_moments: bool) -> str:
    """Checkpoint codec policy.

    Optimizer moments (``opt_state/.../mu|nu``) may be int8-quantized — a
    beyond-paper optimization that shrinks termination checkpoints so they fit
    inside the eviction-notice window. Params and scalars stay exact.
    """
    # metadata-only inspection (dtype/nbytes/ndim); the buffer is not
    # retained, so aliasing is harmless here
    arr = np.asarray(arr)  # spotlint: ignore[SPOT021]
    return codec_for_meta(name, arr.dtype, arr.nbytes, ndim=arr.ndim,
                          compress=compress, quantize_moments=quantize_moments)


def codec_for_meta(name: str, dtype, nbytes: int, *, ndim: int,
                   compress: bool, quantize_moments: bool) -> str:
    """``default_codec_for`` from metadata alone — the device-delta tracker
    must know a leaf's codec *before* any bytes reach the host (the codec
    decides whether the fingerprint path applies at all), so the policy is
    keyed on (name, dtype, nbytes, ndim), never on array content."""
    dtype = np.dtype(dtype)
    if (quantize_moments and is_moment_name(name) and is_float_dtype(dtype)
            and ndim >= 1):
        return resolve_codec("int8+zstd") if compress else "int8"
    if compress and nbytes >= 1024:
        if HAVE_ZSTD:
            return "zstd"
        # zlib runs ~20 MB/s on float payloads for a ~7% ratio — it would
        # dominate checkpoint time for no real size win, so large float
        # tensors stay raw; integer/bool payloads still compress well
        if dtype.kind in "iub":
            return "zlib"
    return "raw"
