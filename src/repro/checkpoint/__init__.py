"""Distributed checkpoint substrate: serialization, sharded save/restore,
atomic store with incremental (delta) chunk pool, async writer.
See DESIGN.md §3."""

from .async_ckpt import AsyncCheckpointer
from .chunkstore import ChunkPool, ChunkRef, DeltaIndex
from .device_delta import DeltaBlocks, DeviceDeltaTracker
from .sharded import (CheckpointReader, Snapshot, extract_snapshot, prestage,
                      restore_to_template, restore_to_template_streaming)
from .store import CheckpointInfo, CheckpointStore

__all__ = [
    "AsyncCheckpointer", "CheckpointInfo", "CheckpointReader", "CheckpointStore",
    "ChunkPool", "ChunkRef", "DeltaBlocks", "DeltaIndex", "DeviceDeltaTracker",
    "Snapshot", "extract_snapshot", "prestage", "restore_to_template",
    "restore_to_template_streaming",
]
