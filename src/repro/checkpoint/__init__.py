"""Distributed checkpoint substrate: serialization, sharded save/restore,
atomic store with incremental (delta) chunk pool, async writer, and the
priority codec scheduler that gives restore QoS over background encodes.
See DESIGN.md §3."""

from .async_ckpt import AsyncCheckpointer
from .chunkstore import ChunkPool, ChunkRef, DeltaIndex
from .codec_sched import (PERIODIC, RESTORE, URGENT, CodecLane,
                          CodecScheduler)
from .device_delta import DeltaBlocks, DeviceDeltaTracker
from .sharded import (CheckpointReader, Snapshot, extract_snapshot, prestage,
                      restore_to_template, restore_to_template_streaming)
from .store import CheckpointInfo, CheckpointStore

__all__ = [
    "AsyncCheckpointer", "CheckpointInfo", "CheckpointReader", "CheckpointStore",
    "ChunkPool", "ChunkRef", "CodecLane", "CodecScheduler", "DeltaBlocks",
    "DeltaIndex", "DeviceDeltaTracker", "PERIODIC", "RESTORE", "Snapshot",
    "URGENT", "extract_snapshot", "prestage", "restore_to_template",
    "restore_to_template_streaming",
]
