"""Content-addressed chunk pool — the substrate of incremental checkpoints.

Every tensor payload is split into fixed-size chunks; each chunk is stored
once in a pool shared by all checkpoints under the store root::

    <root>/chunks/<hh>/<hash>      # hh = first two hex chars (fan-out)

The address is a 160-bit content digest of the *stored* (post-quantize,
post-compress) bytes, so a pool file's content always equals its name's
preimage — self-verifying, and idempotent under concurrent writers: two
fleet members encoding the same state produce byte-identical chunks and race
benignly on an ``os.replace`` of identical content. The digest is SHA-1
(hardware-accelerated and GIL-releasing — measured 3-4x the throughput of
the blake2b it replaced, and digesting every chunk is the warm-save floor);
adversarial collisions are not in the threat model, the hash guards against
accidental aliasing exactly as git's object store does. Chunks addressed by
the old blake2b scheme stay readable — a manifest stores the address with
each reference, readers never recompute it — they just no longer dedup
against new saves.

Chunking itself is zero-copy: ``iter_chunks`` yields ``memoryview`` windows
over the staged tensor buffer, and hashing/compression/crc/file-writes all
consume the windows directly — no ``.tobytes()`` materialization, no sliced
``bytes`` per chunk.

Delta saves fall out of content addressing: a chunk whose bytes did not
change since the last committed step already exists in the pool, so ``write``
degenerates to an mtime touch and the save writes only dirty chunks. The
``DeltaIndex`` memo makes the common case cheap — it remembers the raw-bytes
digest of each (leaf, piece, chunk) position from the previous save, so an
unchanged chunk skips the compressor as well, not just the disk write. A memo
hit is trusted only after ``touch`` confirms the pool file still exists (the
chunk may have been swept since), so the memo can never dangle.

Sweeping the pool is refcount-aware by construction: the store's gc unions
the chunk references of every committed manifest (plus in-process pins for
saves in flight) and removes only unreferenced files older than an age gate —
the same staleness discipline the staging-dir sweep uses for writers on other
hosts of the shared volume. ``touch`` on reuse keeps a chunk's mtime fresh
while any writer still depends on it.

Compression runs per chunk on a process-wide worker pool (zlib/zstd and
blake2b release the GIL), so encode overlaps across tensors instead of
running single-threaded. The pool is the priority scheduler in
``codec_sched``: encode/decode jobs carry a lane (URGENT save > RESTORE >
PERIODIC save), restore jobs jump queued periodic encodes, and the chunk
loop below yields between chunks so an in-flight periodic save hands its
worker to a restore instead of holding it for a whole piece.
"""

from __future__ import annotations

import hashlib
import os
import threading
import uuid
import zlib
from concurrent.futures import wait as futures_wait
from dataclasses import dataclass
from typing import Callable, Iterator

import numpy as np

from . import codec_sched
from . import serialize as ser
from ..faults import inject as faults
from .codec_sched import CodecLane
from .ioutil import array_bytes_view, fsync_dir, mmap_view, release_view


def _retry():
    # Deferred: repro.core's package __init__ imports the coordinator, which
    # imports repro.checkpoint — a module-level import here would observe
    # either package half-initialized depending on which is imported first.
    from ..core import retry
    return retry

CHUNKS_DIRNAME = "chunks"
DEFAULT_CHUNK_SIZE = 1 << 20          # 1 MiB: dedup granularity vs. ref count


def codec_executor() -> CodecLane:
    """PERIODIC lane of the process-wide codec scheduler — background
    encode/compress work, preemptible between chunks."""
    return codec_sched.lane(codec_sched.PERIODIC)


def restore_executor() -> CodecLane:
    """RESTORE lane: decode/read jobs inside the MTTR window. These jump
    every queued periodic encode and are helped inline by yielding periodic
    workers, so restore throughput no longer collapses when a concurrent
    writer is saving into the same pool."""
    return codec_sched.lane(codec_sched.RESTORE)


def urgent_executor() -> CodecLane:
    """URGENT lane for termination checkpoints: an urgent save's encode jobs
    preempt everything queued — the eviction-notice window pays for every
    queued task. This used to be a second reserved ThreadPoolExecutor; as a
    lane of the single pool it no longer competes with the shared workers
    for the same physical cores."""
    return codec_sched.lane(codec_sched.URGENT)


def chunk_digest(data) -> str:
    """160-bit content address of a bytes-like chunk (see module docstring
    for the SHA-1 choice). Same hex width as the former blake2b-160, so the
    pool's on-disk fan-out layout is unchanged."""
    return hashlib.sha1(data).hexdigest()


def chunk_content_ok(ref: "ChunkRef", data, pool: "ChunkPool | None" = None
                     ) -> bool:
    """Integrity check of a chunk's stored bytes on the restore hot path.

    The sha1 content address doubles as the checksum — it already covers
    exactly the stored bytes, it is the stronger guarantee, and with SHA
    extensions it digests measurably faster than ``zlib.crc32`` (restore
    validation is a per-byte cost inside the MTTR window). Chunks written
    under the legacy blake2b addressing don't re-digest to their name, so
    they fall back to the recorded crc32 — and the first such hit flips the
    pool to crc-first validation, so a legacy pool pays the double digest
    once, not per chunk. (Every ref records a crc32, so crc alone is a
    complete check; sha1-first is the speed choice for modern pools.)
    """
    if pool is not None and pool.legacy_validate:
        return zlib.crc32(data) == ref.crc32
    if chunk_digest(data) == ref.hash:
        return True
    if zlib.crc32(data) == ref.crc32:
        if pool is not None:
            pool.legacy_validate = True
        return True
    return False


@dataclass(frozen=True)
class ChunkRef:
    """One chunk reference inside a manifest-v2 tensor record."""

    hash: str
    nbytes: int        # stored (encoded) length
    raw_len: int       # pre-compression length
    crc32: int         # of the stored bytes (fast validation)
    comp: str          # "raw" | "zlib" | "zstd" — how to decode

    def to_json(self) -> dict:
        return {"h": self.hash, "n": self.nbytes, "r": self.raw_len,
                "c": self.crc32, "k": self.comp}

    @staticmethod
    def from_json(d: dict) -> "ChunkRef":
        return ChunkRef(hash=d["h"], nbytes=d["n"], raw_len=d["r"],
                        crc32=d["c"], comp=d["k"])


class ChunkPool:
    #: True when this pool's directory tree IS the durable copy, so the
    #: save must fsync dirty fan-out dirs before its manifest commits.
    #: Cache-tier pools (``backend.BackendChunkPool``) flip this off — their
    #: durability bar is "every ref uploaded", not local rename durability.
    durable_dirs = True

    def __init__(self, root: str):
        self.root = root
        # flips True on the first blake2b-era chunk seen (sha1 re-digest
        # can't match its name): validation drops to crc-first so legacy
        # pools don't pay two digest passes per chunk on restore
        self.legacy_validate = False

    def path(self, h: str) -> str:
        return os.path.join(self.root, h[:2], h)

    def chunk_path(self, ref: ChunkRef) -> str:
        """Resolve the file holding ``ref``'s stored bytes. The base pool
        answers with its own content-addressed entry; overlay pools (the
        peer-exchange read-through pool, modeled cold-storage pools in the
        benchmarks) override this single hook to redirect *where bytes come
        from* while the decode/validation path stays untouched — content
        addressing makes any source interchangeable once the digest checks.
        """
        return self.path(ref.hash)

    def touch(self, h: str) -> bool:
        """Refresh mtime (protects the chunk from age-gated sweeps by other
        writers); False if the chunk is not in the pool."""
        try:
            os.utime(self.path(h))
            return True
        except OSError:
            return False

    def check(self, h: str, nbytes: int) -> bool:
        """Cheap dedup-reuse guard: the pooled file exists with the expected
        stored size (one stat — no content read on the hot path)."""
        try:
            return os.path.getsize(self.path(h)) == nbytes
        except OSError:
            return False

    def write(self, h: str, data, *, sync_dir: bool = True) -> int:
        """Idempotent put; returns bytes physically written (0 on dedup hit).

        A dedup hit is size-verified: an existing file with the wrong length
        (truncated by a crashed writer, damaged in place) is overwritten
        rather than reused, so a save never extends the blast radius of a
        bad pool entry it could have repaired for free. After the atomic
        rename the fan-out directory is fsynced: a chunk a manifest is about
        to reference must not be un-renamed by a crash. Callers writing many
        chunks pass ``sync_dir=False`` and sync the distinct dirty dirs once
        per save (see ``store_payload_chunks``) — the durability bar is only
        that every referenced chunk's rename is durable before the manifest
        commits, not one fsync per chunk."""
        path = self.path(h)
        if self.check(h, len(data)):
            self.touch(h)
            return 0
        dirpath = os.path.dirname(path)
        os.makedirs(dirpath, exist_ok=True)
        tmp = path + f".tmp-{uuid.uuid4().hex[:8]}"
        try:
            with open(tmp, "wb") as f:
                faults.write_bytes(f, data, op="chunk.write", path=tmp)
                f.flush()
                faults.fault_point("chunk.fsync", tmp)
                os.fsync(f.fileno())
            faults.fault_point("chunk.replace", path)
            os.replace(tmp, path)   # atomic: readers never see partial chunks
        except Exception:
            # Quarantine: a failed/short tmp must not survive to be mistaken
            # for progress — the retrying caller re-encodes from memory. A
            # SimulatedCrash is a BaseException and skips this on purpose:
            # a killed process leaves its debris for gc to reclaim.
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise
        faults.fault_point("chunk.replaced", path, rollback=(path, tmp))
        if sync_dir:
            fsync_dir(dirpath)      # durable: rename survives a crash
        return len(data)

    def read_view(self, ref: ChunkRef) -> memoryview:
        """crc-validated view of a chunk's stored bytes (mmap-backed when the
        platform allows — decode copies straight from the page cache).
        Release with ``ioutil.release_view`` when done."""
        path = self.chunk_path(ref)
        faults.fault_point("chunk.read", path)
        view = mmap_view(path)
        if not chunk_content_ok(ref, view, self):
            release_view(view)
            _heal_and_raise(path, ref, "content digest/crc mismatch")
        return view

    def read(self, ref: ChunkRef) -> bytes:
        view = self.read_view(ref)
        try:
            return bytes(view)
        finally:
            release_view(view)

    def entries(self) -> Iterator[tuple[str, str, bool]]:
        """One walk over the pool: yields (name, path, is_tmp). Tmp files are
        crashed mid-write leftovers — the gc sweeps them by age."""
        try:
            shards = os.listdir(self.root)
        except FileNotFoundError:
            return
        for hh in shards:
            sub = os.path.join(self.root, hh)
            try:
                names = os.listdir(sub)
            except (NotADirectoryError, FileNotFoundError):
                continue
            for name in names:
                yield name, os.path.join(sub, name), ".tmp-" in name

    def all_chunks(self) -> Iterator[tuple[str, str]]:
        """Yield (hash, path) for every committed pool entry."""
        for name, path, is_tmp in self.entries():
            if not is_tmp:
                yield name, path


@dataclass(frozen=True)
class _MemoEntry:
    raw_digest: str
    codec: str
    ref: ChunkRef


class DeltaIndex:
    """Per-store memo: last stored chunk per (leaf, piece, chunk) position.

    Purely an optimization — a miss (fresh process, other writer's step,
    swept chunk) just re-encodes; a stale hit is impossible because the key
    is the raw-content digest plus codec, and the pooled file is re-checked
    for existence on every reuse."""

    def __init__(self):
        self._map: dict[tuple, _MemoEntry] = {}
        self._lock = threading.Lock()

    def get(self, key: tuple) -> _MemoEntry | None:
        with self._lock:
            return self._map.get(key)

    def put(self, key: tuple, raw_digest: str, codec: str, ref: ChunkRef) -> None:
        with self._lock:
            self._map[key] = _MemoEntry(raw_digest, codec, ref)


def iter_chunks(raw, chunk_size: int) -> Iterator:
    """Fixed-size windows over a bytes-like payload. Slicing a memoryview
    yields zero-copy sub-views, so passing the staged array's buffer here
    never materializes per-chunk bytes."""
    for off in range(0, len(raw), chunk_size):
        yield raw[off:off + chunk_size]


def store_payload_chunks(
    pool: ChunkPool,
    key: tuple,
    raw,
    *,
    codec: str,
    comp: str,
    chunk_size: int = DEFAULT_CHUNK_SIZE,
    index: DeltaIndex | None = None,
    pin: Callable[[str], None] = lambda h: None,
    dirty_dirs: set | None = None,
) -> tuple[list[ChunkRef], int]:
    """Chunk one raw tensor payload (bytes-like) into the pool.

    Returns (refs, bytes_physically_written). ``pin`` is called with each
    referenced hash *before* the chunk is relied upon, so the store's gc can
    keep in-flight references alive until the manifest commits. When the
    caller passes ``dirty_dirs`` (a set, shared across a save's encode jobs;
    ``set.add`` is atomic under the GIL), per-chunk directory fsyncs are
    skipped and the dirty fan-out dirs are collected instead, so the save
    syncs each distinct dir once before its manifest commits.
    """
    if not isinstance(raw, (bytes, memoryview)):
        raw = memoryview(raw)
    refs: list[ChunkRef] = []
    written = 0
    for ci, raw_chunk in enumerate(iter_chunks(raw, chunk_size)):
        # preemption checkpoint: a periodic-save encode hands its worker to
        # any queued restore/urgent job here, bounding their queue delay to
        # one chunk's encode instead of one piece's
        codec_sched.maybe_yield()
        rd = chunk_digest(raw_chunk)
        memo = index.get((key, ci)) if index is not None else None
        if (memo is not None and memo.raw_digest == rd and memo.codec == codec
                and pool.check(memo.ref.hash, memo.ref.nbytes)):
            # still pooled at the expected size -> skip encode+write
            pin(memo.ref.hash)
            pool.touch(memo.ref.hash)
            refs.append(memo.ref)
            continue
        ref, n, _rd = store_chunk(pool, raw_chunk, comp=comp, pin=pin,
                                  dirty_dirs=dirty_dirs, raw_digest=rd)
        written += n
        if index is not None:
            index.put((key, ci), rd, codec, ref)
        refs.append(ref)
    return refs, written


def store_chunk(pool: ChunkPool, raw_chunk, *, comp: str,
                pin: Callable[[str], None] = lambda h: None,
                dirty_dirs: set | None = None,
                raw_digest: str | None = None) -> tuple[ChunkRef, int, str]:
    """Encode + store one raw chunk; returns (ref, bytes_written, raw sha1).

    The per-chunk body of ``store_payload_chunks``, shared with the
    device-delta write path (which brings its own skip decision — the
    fingerprint — and only reaches here for dirty blocks)."""
    rd = raw_digest if raw_digest is not None else chunk_digest(raw_chunk)
    enc = ser.compress_bytes(raw_chunk, comp)
    k = comp or "raw"
    if comp and len(enc) >= len(raw_chunk):
        enc, k = raw_chunk, "raw"             # compression didn't pay here
    # stored-raw chunks share the raw digest — don't hash 2x
    h = rd if enc is raw_chunk else chunk_digest(enc)
    pin(h)
    # Transient write faults (EIO-class) retry with backoff; pool.write
    # unlinks its quarantined tmp first, so each attempt re-lands the full
    # encoded payload. ENOSPC and friends are persistent and surface
    # immediately — the coordinator's degradation policy owns those.
    n = _retry().call_with_retry(
        lambda: pool.write(h, enc, sync_dir=dirty_dirs is None
                           and pool.durable_dirs),
        describe=f"chunk {h[:10]} write")
    if n and dirty_dirs is not None and pool.durable_dirs:
        dirty_dirs.add(os.path.dirname(pool.path(h)))
    ref = ChunkRef(hash=h, nbytes=len(enc), raw_len=len(raw_chunk),
                   crc32=zlib.crc32(enc), comp=k)
    return ref, n, rd


def _heal_and_raise(path: str, ref: ChunkRef, why: str) -> None:
    # self-heal: the file provably does not hold its address's content, so
    # removing it is always safe — the next save of the same content
    # rewrites it instead of dedup-reusing the damage
    try:
        os.remove(path)
    except OSError:
        pass
    raise IOError(f"chunk {ref.hash}: {why} (corrupt pool entry removed; "
                  "rewritten on next save)")


def _readinto_full(f, window: memoryview) -> int:
    got = 0
    while got < len(window):
        n = f.readinto(window[got:])
        if not n:
            break
        got += n
    return got


def _decode_chunk_into(pool: ChunkPool, ref: ChunkRef, window: memoryview) -> None:
    """Retrying wrapper around one chunk decode: a transient read fault
    (EIO on a flaky mount) re-reads with backoff; a content mismatch raises
    immediately (``_heal_and_raise``'s IOError carries no errno) because the
    bad entry has already been removed and only a re-save can help."""
    _retry().call_with_retry(
        lambda: _decode_chunk_into_once(pool, ref, window),
        describe=f"chunk {ref.hash[:10]} read")


def _decode_chunk_into_once(pool: ChunkPool, ref: ChunkRef,
                            window: memoryview) -> None:
    """One chunk: pool file -> (crc check, decompress) -> destination window.

    Raw chunks ``readinto`` the preallocated tensor buffer directly — one
    unbuffered pread from the page cache, then crc over the destination
    (the stored bytes *are* the raw bytes); everything data-sized releases
    the GIL, which is what makes chunk/tensor-parallel restore actually
    overlap. Compressed chunks read once and decompress into the window
    (the codec output is the only intermediate)."""
    path = pool.chunk_path(ref)
    faults.fault_point("chunk.read", path)
    with open(path, "rb", buffering=0) as f:
        if os.fstat(f.fileno()).st_size != ref.nbytes:
            _heal_and_raise(path, ref, "size mismatch")
        if ref.comp in ("", "raw"):     # stored bytes ARE the raw bytes
            if (_readinto_full(f, window) != len(window)
                    or not chunk_content_ok(ref, window, pool)):
                _heal_and_raise(path, ref, "content digest/crc mismatch")
        else:
            data = f.read()
            if not chunk_content_ok(ref, data, pool):
                _heal_and_raise(path, ref, "content digest/crc mismatch")
            window[:] = ser.decompress_bytes(data, ref.comp)


def read_payload_into(pool: ChunkPool, refs: list[dict], dst,
                      *, executor: CodecLane | None = None) -> None:
    """Reassemble a tensor's raw payload from its manifest chunk refs
    directly into ``dst`` (an ndarray or writable buffer) — no per-chunk
    ``bytes`` concatenation, no ``frombuffer(...).copy()``.

    With an ``executor``, chunks prefetch+decode in parallel (mmap reads,
    crc32 and the decompressors all release the GIL). Jobs must not submit
    sub-jobs on the same executor, so callers parallelizing at a coarser
    grain pass ``executor=None`` here.
    """
    mv = array_bytes_view(dst) if isinstance(dst, np.ndarray) else memoryview(dst)
    crefs = [ChunkRef.from_json(d) for d in refs]
    total = sum(r.raw_len for r in crefs)
    if total != len(mv):
        raise IOError(f"chunk refs cover {total} bytes but destination "
                      f"holds {len(mv)}")
    jobs = []
    off = 0
    for ref in crefs:
        window = mv[off:off + ref.raw_len]
        off += ref.raw_len
        if executor is None or len(crefs) == 1:
            _decode_chunk_into(pool, ref, window)
        else:
            jobs.append(executor.submit(_decode_chunk_into, pool, ref, window))
    if jobs:
        futures_wait(jobs)
        for j in jobs:            # propagate the first decode/crc failure
            j.result()


def _decode_boundary_chunk(pool: ChunkPool, ref: ChunkRef, window: memoryview,
                           cut_lo: int, cut_hi: int) -> None:
    # A chunk straddling the requested range's edge: the chunk is the unit
    # of storage (digest, crc, compression frame), so it must decode whole —
    # into a scratch buffer — and only the overlap is copied out. At most
    # two chunks per range pay this.
    scratch = bytearray(ref.raw_len)
    _decode_chunk_into(pool, ref, memoryview(scratch))
    window[:] = scratch[cut_lo:cut_hi]


def read_payload_range_into(pool: ChunkPool, refs: list[dict], dst,
                            *, byte_lo: int, base_off: int = 0,
                            executor: CodecLane | None = None
                            ) -> tuple[int, int]:
    """Decode only the chunks overlapping one byte range of a raw payload.

    The range-addressed sibling of ``read_payload_into``: ``dst`` receives
    bytes ``[byte_lo, byte_lo + len(dst))`` of the flattened raw payload,
    and chunks entirely outside that window are never opened — this is what
    makes a sharded restore read O(shard) instead of O(tensor). ``refs`` may
    be the record's full chunk list or a pre-selected contiguous slice of it
    (via the manifest's shard-span map); ``base_off`` is the flat byte
    offset where ``refs[0]`` begins.

    Chunks fully inside the window decode straight into their destination
    slice (same zero-copy path as the full read); the at-most-two boundary
    chunks decode to scratch and copy only the overlap. Returns
    ``(chunks_decoded, chunks_skipped)`` so callers can account the win.
    The serial path yields to higher codec lanes between chunks, matching
    the store path's preemption discipline.
    """
    mv = array_bytes_view(dst) if isinstance(dst, np.ndarray) else memoryview(dst)
    byte_hi = byte_lo + len(mv)
    crefs = [ChunkRef.from_json(d) for d in refs]
    if base_off + sum(r.raw_len for r in crefs) < byte_hi:
        raise IOError(
            f"chunk refs end at {base_off + sum(r.raw_len for r in crefs)} "
            f"but the requested range extends to {byte_hi}")
    jobs = []
    decoded = skipped = 0
    off = base_off
    for ref in crefs:
        lo, hi = off, off + ref.raw_len
        off = hi
        if hi <= byte_lo or lo >= byte_hi:
            skipped += 1
            continue
        decoded += 1
        w_lo, w_hi = max(lo, byte_lo), min(hi, byte_hi)
        window = mv[w_lo - byte_lo:w_hi - byte_lo]
        if w_lo == lo and w_hi == hi:
            fn, fargs = _decode_chunk_into, (pool, ref, window)
        else:
            fn, fargs = _decode_boundary_chunk, (
                pool, ref, window, w_lo - lo, w_hi - lo)
        if executor is None:
            codec_sched.maybe_yield()
            fn(*fargs)
        else:
            jobs.append(executor.submit(fn, *fargs))
    if jobs:
        futures_wait(jobs)
        for j in jobs:
            j.result()
    return decoded, skipped
