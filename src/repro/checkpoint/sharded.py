"""Sharded (per-host) checkpoint extraction, writing and elastic restore.

Save path, two phases (so the trainer only blocks on the cheap one):

  1. ``extract_snapshot(state)`` — device→host copy of every *addressable*
     shard with ``replica_id == 0`` plus its global index. O(local bytes),
     synchronous, step-boundary cost. This is the transparent-checkpoint
     "freeze" moment, the analogue of CRIU's stop-and-copy.
  2. ``write_snapshot(dir, snapshot)`` — encode + write shard container(s).
     Runs in the async writer thread (checkpoint/IO overlaps training).

Restore is **mesh-independent** ("elastic"): the manifest stores global shapes
and per-piece global indices, and ``restore_to_template`` re-slices saved
pieces into whatever sharding the *target* template carries. Saving on a
512-chip mesh and restoring on 256 chips (a lost pod) — or on one CPU device —
is the same code path. This generalizes the paper's "resume on a new instance"
to "resume on a different topology".

In a real multi-host deployment each process calls ``extract_snapshot`` /
``write_snapshot`` for its own shard file into the shared staging dir and
process 0 commits after a barrier (``jax.experimental.multihost_utils``); in
this single-process container process 0 owns every shard, same code path.
"""

from __future__ import annotations

import os
from concurrent.futures import wait as futures_wait
from dataclasses import dataclass, field
from typing import Any, Callable

import numpy as np

import jax

from . import chunkstore
from . import serialize as ser

Index = tuple[tuple[int, int], ...]


@dataclass
class LeafPieces:
    """All locally-owned pieces of one logical tensor."""

    global_shape: tuple[int, ...]
    dtype: str
    pieces: list[tuple[Index, np.ndarray]]
    is_scalar_py: bool = False     # python int/float leaf (restore casts back)
    py_type: str = ""


@dataclass
class Snapshot:
    """Host-side frozen training state, ready to be written."""

    step: int
    leaves: dict[str, LeafPieces]
    leaf_order: list[str]
    treedef_repr: str
    mesh: dict
    nbytes: int = 0


def _slices_to_index(slices, shape) -> Index:
    out = []
    for sl, dim in zip(slices, shape):
        start = 0 if sl.start is None else sl.start
        stop = dim if sl.stop is None else sl.stop
        out.append((int(start), int(stop)))
    return tuple(out)


def extract_snapshot(state, *, step: int, mesh_info: dict | None = None) -> Snapshot:
    """Freeze `state` to host memory; returns shard pieces per leaf."""
    named = ser.flatten_state(state)
    leaf_order = list(named)
    leaves: dict[str, LeafPieces] = {}
    nbytes = 0
    for name, leaf in named.items():
        is_scalar_py = isinstance(leaf, (int, float, bool)) and not isinstance(leaf, np.generic)
        if isinstance(leaf, jax.Array) and not leaf.is_fully_replicated:
            pieces = []
            for shard in leaf.addressable_shards:
                if shard.replica_id != 0:
                    continue
                arr = np.asarray(shard.data)
                pieces.append((_slices_to_index(shard.index, leaf.shape), arr))
                nbytes += arr.nbytes
            lp = LeafPieces(tuple(leaf.shape), ser.dtype_to_name(leaf.dtype), pieces)
        else:
            arr = ser.to_host(leaf)
            nbytes += arr.nbytes
            lp = LeafPieces(
                tuple(arr.shape), ser.dtype_to_name(arr.dtype),
                [(tuple((0, s) for s in arr.shape), arr)],
                is_scalar_py=is_scalar_py, py_type=type(leaf).__name__,
            )
        leaves[name] = lp
    treedef = jax.tree_util.tree_structure(state)
    return Snapshot(step=step, leaves=leaves, leaf_order=leaf_order,
                    treedef_repr=str(treedef), mesh=mesh_info or {}, nbytes=nbytes)


def write_snapshot(
    dirpath: str,
    snapshot: Snapshot,
    *,
    process_index: int = 0,
    compress: bool = True,
    quantize_moments: bool = False,
) -> list[dict]:
    """Write this process's shard container. Returns tensor records (+file)."""
    pending = []
    for name, lp in snapshot.leaves.items():
        for pi, (index, arr) in enumerate(lp.pieces):
            codec = ser.default_codec_for(name, arr, compress=compress,
                                          quantize_moments=quantize_moments)
            pending.append(ser.encode_tensor(
                f"{name}#{pi}", arr, global_shape=lp.global_shape,
                index=index, codec=codec))
    fname = f"shard_p{process_index:03d}.spot"
    records = ser.write_shard_file(os.path.join(dirpath, fname), pending)
    out = []
    for rec in records:
        d = rec.to_json()
        d["file"] = fname
        out.append(d)
    return out


def _delta_encode_piece(pool, key, arr, codec, chunk_size, index, pin):
    """Worker-pool task: quantize one piece, chunk it into the pool."""
    codec = ser.resolve_codec(codec)
    quant, comp = ser.split_codec(codec)
    raw, scale = ser.quantize(np.asarray(arr), quant)
    refs, written = chunkstore.store_payload_chunks(
        pool, key, raw, codec=codec, comp=comp, chunk_size=chunk_size,
        index=index, pin=pin)
    return codec, scale, refs, written, len(raw)


def write_snapshot_delta(
    snapshot: Snapshot,
    pool: chunkstore.ChunkPool,
    *,
    compress: bool = True,
    quantize_moments: bool = False,
    chunk_size: int = chunkstore.DEFAULT_CHUNK_SIZE,
    index: chunkstore.DeltaIndex | None = None,
    pin=lambda h: None,
    executor=None,
) -> tuple[list[dict], int]:
    """Incremental write: every piece chunked into the shared pool.

    Encode/compress runs on the shared codec executor so serialization
    overlaps across tensors. Returns (manifest tensor records, bytes
    physically written) — unchanged chunks cost a hash + an mtime touch, so
    the second number is the actual churn, not the state size.
    """
    ex = executor if executor is not None else chunkstore.codec_executor()
    jobs = []
    for name, lp in snapshot.leaves.items():
        for pi, (idx, arr) in enumerate(lp.pieces):
            codec = ser.default_codec_for(name, arr, compress=compress,
                                          quantize_moments=quantize_moments)
            fut = ex.submit(_delta_encode_piece, pool, (name, pi), arr, codec,
                            chunk_size, index, pin)
            jobs.append((name, pi, idx, lp, arr, fut))
    try:
        results = [fut.result() for *_rest, fut in jobs]
    except BaseException:
        # quiesce before propagating: a straggler task must not call pin()
        # after the caller has already unpinned this save's chunks
        for *_rest, fut in jobs:
            fut.cancel()
        futures_wait([fut for *_rest, fut in jobs])
        raise
    records = []
    new_bytes = 0
    for (name, pi, idx, lp, arr, fut), res in zip(jobs, results):
        codec, scale, refs, written, raw_len = res
        new_bytes += written
        rec = ser.TensorRecord(
            name=f"{name}#{pi}", dtype=ser.dtype_to_name(np.asarray(arr).dtype),
            shape=tuple(np.asarray(arr).shape), global_shape=lp.global_shape,
            index=idx, nbytes=sum(r.nbytes for r in refs), crc32=0,
            codec=codec, scale=scale)
        d = rec.to_json()
        d["chunks"] = [r.to_json() for r in refs]
        d["raw_nbytes"] = raw_len
        records.append(d)
    return records, new_bytes


# ---------------------------------------------------------------------------
# restore
# ---------------------------------------------------------------------------

class CheckpointReader:
    """Random access over a committed checkpoint's tensors.

    Reads both manifest formats: v1 records point into per-process shard
    container files inside the step dir; v2 (delta) records carry chunk
    references into the store's shared content-addressed pool."""

    def __init__(self, ckpt_dir: str, tensor_records: list[dict],
                 chunk_pool: chunkstore.ChunkPool | None = None):
        self.ckpt_dir = ckpt_dir
        self.chunk_pool = chunk_pool or chunkstore.ChunkPool(
            os.path.join(os.path.dirname(os.path.abspath(ckpt_dir)),
                         chunkstore.CHUNKS_DIRNAME))
        self._readers: dict[str, ser.ShardFileReader] = {}
        # name -> list of (record, file)
        self.by_name: dict[str, list[dict]] = {}
        for rec in tensor_records:
            base = rec["name"].rsplit("#", 1)[0]
            self.by_name.setdefault(base, []).append(rec)

    def _reader(self, fname: str) -> ser.ShardFileReader:
        if fname not in self._readers:
            self._readers[fname] = ser.ShardFileReader(os.path.join(self.ckpt_dir, fname))
        return self._readers[fname]

    def _read_piece(self, rec: dict) -> np.ndarray:
        if "chunks" in rec:
            raw = chunkstore.read_payload_chunks(self.chunk_pool, rec["chunks"])
            quant, _comp = ser.split_codec(rec.get("codec", "raw"))
            return ser.payload_to_array(
                raw, dtype_name=rec["dtype"], shape=rec["shape"],
                quant=quant, scale=rec.get("scale"))
        return self._reader(rec["file"]).read(rec["name"])

    def global_shape(self, name: str) -> tuple[int, ...]:
        return tuple(self.by_name[name][0]["global_shape"])

    def dtype(self, name: str) -> np.dtype:
        return ser.name_to_dtype(self.by_name[name][0]["dtype"])

    def names(self) -> list[str]:
        return list(self.by_name)

    def read_slice(self, name: str, index: Index | None = None) -> np.ndarray:
        """Assemble an arbitrary global slice of `name` from saved pieces."""
        gshape = self.global_shape(name)
        if index is None:
            index = tuple((0, s) for s in gshape)
        out_shape = tuple(stop - start for start, stop in index)
        out = np.empty(out_shape, dtype=self.dtype(name))
        filled = 0
        for rec in self.by_name[name]:
            pidx = tuple(tuple(p) for p in rec["index"])
            # intersection of requested region and piece region
            inter = tuple((max(a0, b0), min(a1, b1)) for (a0, a1), (b0, b1) in zip(index, pidx))
            if any(lo >= hi for lo, hi in inter):
                continue
            piece = self._read_piece(rec)
            src = tuple(slice(lo - b0, hi - b0) for (lo, hi), (b0, _) in zip(inter, pidx))
            dst = tuple(slice(lo - a0, hi - a0) for (lo, hi), (a0, _) in zip(inter, index))
            out[dst] = piece[src]
            filled += int(np.prod([hi - lo for lo, hi in inter]))
        if filled != int(np.prod(out_shape)):
            raise IOError(
                f"{name}: requested region not fully covered by saved pieces "
                f"({filled} of {int(np.prod(out_shape))} elements)")
        return out

    def validate(self) -> None:
        """Full-content crc validation of every piece (per-chunk for v2)."""
        for name, recs in self.by_name.items():
            for rec in recs:
                self._read_piece(rec)


def _idx_of_slices(slices, shape) -> Index:
    return _slices_to_index(slices, shape)


def restore_to_template(reader: CheckpointReader, template) -> Any:
    """Restore a pytree matching `template`'s structure, shapes and shardings.

    Template leaves may be jax.Arrays (their sharding is reproduced —
    elastic restore reads only the slices each device needs),
    jax.ShapeDtypeStruct with `.sharding`, numpy arrays, or python scalars.
    """
    named = ser.flatten_state(template)
    treedef = jax.tree_util.tree_structure(template)
    out = {}
    for name, leaf in named.items():
        if name not in reader.by_name:
            raise KeyError(f"checkpoint missing leaf {name!r}; has {sorted(reader.by_name)[:8]}...")
        if isinstance(leaf, (int, float, bool)) and not isinstance(leaf, np.generic):
            val = reader.read_slice(name).reshape(())[()]
            out[name] = type(leaf)(val)
            continue
        sharding = getattr(leaf, "sharding", None)
        shape = tuple(leaf.shape)
        dtype = leaf.dtype
        if reader.global_shape(name) != shape:
            raise ValueError(
                f"{name}: shape mismatch ckpt={reader.global_shape(name)} vs template={shape}")
        if sharding is not None and hasattr(sharding, "device_set"):
            def cb(idx, _name=name, _shape=shape, _dtype=dtype):
                region = _idx_of_slices(idx, _shape)
                return reader.read_slice(_name, region).astype(_dtype)
            out[name] = jax.make_array_from_callback(shape, sharding, cb)
        else:
            out[name] = reader.read_slice(name).astype(dtype)
    return jax.tree_util.tree_unflatten(treedef, [out[n] for n in named])
