"""Sharded (per-host) checkpoint extraction, writing and elastic restore.

Save path, two phases (so the trainer only blocks on the cheap one):

  1. ``extract_snapshot(state)`` — device→host copy of every *addressable*
     shard with ``replica_id == 0`` plus its global index. O(local bytes),
     synchronous, step-boundary cost. This is the transparent-checkpoint
     "freeze" moment, the analogue of CRIU's stop-and-copy. The copy itself
     is pipelined: ``copy_to_host_async`` is issued across *all* shards
     first, then a single gather pass materializes them — the device→host
     DMAs of different tensors overlap instead of serializing behind one
     blocking ``np.asarray`` per leaf. With ``on_device_quantize``, selected
     leaves (optimizer moments before an urgent save) are absmax-int8
     quantized *on device* first, so they cross the device→host link at 1/4
     width; the stored bytes are identical to a host-side quantize.
  2. ``write_snapshot(dir, snapshot)`` — encode + write shard container(s).
     Runs in the async writer thread (checkpoint/IO overlaps training).

Restore is pipelined too: tensors decode in parallel on the codec executor
(mmap reads, digest validation and decompression release the GIL) and each
tensor reassembles into a preallocated destination buffer — see
CheckpointReader. ``restore_to_template_streaming`` goes further for
device-destined restores: decode overlaps the host→device transfers, raw
single-chunk payloads stream from validated mmap views (page cache →
device, no intermediate host buffer), and int8-quantized payloads cross
the link at 1/4 width and widen on device — the restore mirror of the
on-device quantize below, and the heart of the fast-resume (MTTR) path.

Restore is **mesh-independent** ("elastic"): the manifest stores global shapes
and per-piece global indices, and ``restore_to_template`` re-slices saved
pieces into whatever sharding the *target* template carries. Saving on a
512-chip mesh and restoring on 256 chips (a lost pod) — or on one CPU device —
is the same code path. This generalizes the paper's "resume on a new instance"
to "resume on a different topology".

In a real multi-host deployment each process calls ``extract_snapshot`` /
``write_snapshot`` for its own shard file into the shared staging dir and
process 0 commits after a barrier (``jax.experimental.multihost_utils``); in
this single-process container process 0 owns every shard, same code path.
"""

from __future__ import annotations

import bisect
import os
import threading
import time
from concurrent.futures import Future
from concurrent.futures import wait as futures_wait
from dataclasses import dataclass, field
from typing import Any, Callable, Sequence

import numpy as np

import jax

from . import chunkstore
from . import manifest as mf
from . import serialize as ser
from ..distributed import multihost
from ..distributed.sharding import addressable_shard_spans
from .device_delta import DeltaBlocks, DeviceDeltaTracker, write_delta_blocks_piece
from .ioutil import fsync_dir

Index = tuple[tuple[int, int], ...]

# leaves below this stored size batch into one executor task on restore —
# per-task overhead beats decode cost for scalar/counter leaves, and configs
# can carry hundreds of them
SMALL_LEAF_BYTES = 4096


@dataclass
class LeafPieces:
    """All locally-owned pieces of one logical tensor. A piece payload is a
    dense host ndarray, or a ``device_delta.DeltaBlocks`` when the
    fingerprint path pruned the device→host copy to the dirty blocks."""

    global_shape: tuple[int, ...]
    dtype: str                     # logical dtype (pre-quantization)
    pieces: list[tuple[Index, Any]]     # ndarray | DeltaBlocks
    is_scalar_py: bool = False     # python int/float leaf (restore casts back)
    py_type: str = ""
    prequant: str = ""             # "int8": pieces hold on-device-quantized data
    scale: float | None = None     # absmax scale when prequant


@dataclass
class Snapshot:
    """Host-side frozen training state, ready to be written."""

    step: int
    leaves: dict[str, LeafPieces]
    leaf_order: list[str]
    treedef_repr: str
    mesh: dict
    nbytes: int = 0
    # D2H accounting (the save-path win the ledger reports): bytes that
    # actually crossed the device→host link vs. bytes the fingerprint path
    # proved unchanged and never transferred. stall_s is the wall time the
    # trainer was blocked inside extract — the save's step-boundary cost.
    d2h_bytes: int = 0
    d2h_skipped: int = 0
    stall_s: float = 0.0
    # invoked by the store with the final manifest records once this
    # snapshot's checkpoint is durably committed (device-delta bookkeeping)
    on_committed: Callable[[list[dict]], None] | None = None


def _slices_to_index(slices, shape) -> Index:
    out = []
    for sl, dim in zip(slices, shape):
        start = 0 if sl.start is None else sl.start
        stop = dim if sl.stop is None else sl.stop
        out.append((int(start), int(stop)))
    return tuple(out)


def _stage_async(leaf) -> None:
    """Issue the device→host DMA for one array without blocking. Best-effort:
    backends without async transfer simply block in the gather pass."""
    try:
        if leaf.is_fully_replicated:
            leaf.copy_to_host_async()
        else:
            for shard in leaf.addressable_shards:
                if shard.replica_id == 0:
                    shard.data.copy_to_host_async()
    except Exception:
        pass


def prestage(state, tracker: DeviceDeltaTracker | None = None):
    """Start device→host copies for every array leaf and return ``state``.

    The trainer hands this to the coordinator as the state supplier, so the
    moment a checkpoint decision is made the DMAs are already in flight —
    by the time ``extract_snapshot`` gathers, most bytes have landed.

    With a ``tracker`` (device-delta saves) the staging is double-buffered
    differently: fingerprint-eligible leaves dispatch their per-block digest
    + diff compute on device instead of a full-state DMA — only the dirty
    blocks will cross later, and the digest compute overlaps whatever the
    trainer does next (the gather of save N runs under step N+1's compute;
    every staged result is a fresh device buffer, so donation of the state
    into the next step can never alias it). Non-eligible leaves stage the
    ordinary way. Urgent saves never pass a tracker.
    """
    if tracker is not None:
        named = ser.flatten_state(state)
        for name, leaf in named.items():
            if not tracker.prestage_leaf(name, leaf):
                if isinstance(leaf, jax.Array):
                    _stage_async(leaf)
        return state
    for leaf in jax.tree_util.tree_leaves(state):
        if isinstance(leaf, jax.Array):
            _stage_async(leaf)
    return state


def extract_snapshot(state, *, step: int, mesh_info: dict | None = None,
                     on_device_quantize: Callable[[str], bool] | None = None,
                     tracker: DeviceDeltaTracker | None = None,
                     ) -> Snapshot:
    """Freeze `state` to host memory; returns shard pieces per leaf.

    Three passes: (0) optionally absmax-int8-quantize selected leaves on
    device (``on_device_quantize(name)`` — urgent saves pass the optimizer-
    moment predicate, shrinking the device→host transfer 4x); (1) issue
    ``copy_to_host_async`` across every staged array so the DMAs overlap;
    (2) gather each shard into host memory — the only blocking pass.

    With a ``tracker`` (periodic delta saves), leaves whose previous-save
    fingerprints are device-resident take the dirty-block path instead:
    digests compare on device, only changed blocks are gathered to host
    (``DeltaBlocks`` pieces), and unchanged blocks never cross the link.
    ``on_device_quantize`` and ``tracker`` are mutually exclusive by
    construction — urgent saves bypass fingerprinting entirely.
    """
    t_stall0 = time.perf_counter()
    named = ser.flatten_state(state)
    leaf_order = list(named)
    tracked: dict[str, Any] = {}
    commit_cb = None
    if tracker is not None and on_device_quantize is None:
        tracked, commit_cb = tracker.begin(named)
    prequant: dict[str, tuple[Any, Any]] = {}       # name -> (q_array, scale)
    if on_device_quantize is not None:
        from ..kernels.quantize import quantize_int8
        for name, leaf in named.items():
            if (isinstance(leaf, jax.Array) and leaf.ndim >= 1
                    and ser.is_float_dtype(leaf.dtype)
                    and on_device_quantize(name)):
                prequant[name] = quantize_int8(leaf)
    for name, leaf in named.items():                # phase 1: async staging
        if name in tracked:
            tracked[name].resolve()     # diff sync + dirty-block gather
            continue
        staged = prequant[name][0] if name in prequant else leaf
        if isinstance(staged, jax.Array):
            _stage_async(staged)
    leaves: dict[str, LeafPieces] = {}
    nbytes = 0
    d2h_bytes = 0
    d2h_skipped = 0
    for name, leaf in named.items():                # phase 2: gather
        if name in tracked:
            res = tracked[name].finish()
            if res is not None:
                db, leaf_d2h, leaf_skip = res
                leaves[name] = LeafPieces(
                    db.shape, db.dtype_name,
                    [(tuple((0, s) for s in db.shape), db)])
                nbytes += db.nbytes
                d2h_bytes += leaf_d2h
                d2h_skipped += leaf_skip
                continue
            # high-churn dense fallback: gathered below like any other leaf
        is_scalar_py = isinstance(leaf, (int, float, bool)) and not isinstance(leaf, np.generic)
        pq, scale = None, None
        if name in prequant:
            src, dev_scale = prequant[name]
            pq, scale = "int8", float(np.asarray(dev_scale))
        else:
            src = leaf
        if isinstance(src, jax.Array) and not src.is_fully_replicated:
            pieces = []
            for shard in src.addressable_shards:
                if shard.replica_id != 0:
                    continue
                arr = np.asarray(shard.data)
                pieces.append((_slices_to_index(shard.index, src.shape), arr))
                nbytes += arr.nbytes
                d2h_bytes += arr.nbytes
            lp = LeafPieces(tuple(src.shape), ser.dtype_to_name(leaf.dtype),
                            pieces, prequant=pq or "", scale=scale)
        else:
            arr = ser.to_host(src)
            nbytes += arr.nbytes
            d2h_bytes += arr.nbytes
            lp = LeafPieces(
                tuple(arr.shape), ser.dtype_to_name(leaf.dtype if pq
                                                    else arr.dtype),
                [(tuple((0, s) for s in arr.shape), arr)],
                is_scalar_py=is_scalar_py, py_type=type(leaf).__name__,
                prequant=pq or "", scale=scale,
            )
        leaves[name] = lp
    treedef = jax.tree_util.tree_structure(state)
    return Snapshot(step=step, leaves=leaves, leaf_order=leaf_order,
                    treedef_repr=str(treedef), mesh=mesh_info or {},
                    nbytes=nbytes, d2h_bytes=d2h_bytes,
                    d2h_skipped=d2h_skipped,
                    stall_s=time.perf_counter() - t_stall0,
                    on_committed=commit_cb)


def _piece_codec(name: str, lp: LeafPieces, arr: np.ndarray, *,
                 compress: bool, quantize_moments: bool) -> str:
    """Codec for one piece; a pre-quantized piece keeps its int8 half and
    only the compression half is policy-chosen (over the int8 payload)."""
    if lp.prequant:
        comp = ser.default_codec_for(name, arr, compress=compress,
                                     quantize_moments=False)
        return lp.prequant if comp == "raw" else f"{lp.prequant}+{comp}"
    return ser.default_codec_for(name, arr, compress=compress,
                                 quantize_moments=quantize_moments)


def write_snapshot(
    dirpath: str,
    snapshot: Snapshot,
    *,
    process_index: int = 0,
    compress: bool = True,
    quantize_moments: bool = False,
) -> list[dict]:
    """Write this process's shard container. Returns tensor records (+file)."""
    pending = []
    for name, lp in snapshot.leaves.items():
        for pi, (index, arr) in enumerate(lp.pieces):
            codec = _piece_codec(name, lp, arr, compress=compress,
                                 quantize_moments=quantize_moments)
            pending.append(ser.encode_tensor(
                f"{name}#{pi}", arr, global_shape=lp.global_shape,
                index=index, codec=codec,
                prequant_scale=lp.scale if lp.prequant else None,
                logical_dtype=lp.dtype if lp.prequant else None))
    fname = f"shard_p{process_index:03d}.spot"
    records = ser.write_shard_file(os.path.join(dirpath, fname), pending)
    out = []
    for rec in records:
        d = rec.to_json()
        d["file"] = fname
        out.append(d)
    return out


def _delta_encode_piece(pool, key, arr, codec, chunk_size, index, pin,
                        prequant_scale=None, dirty_dirs=None):
    """Worker-pool task: quantize one piece, chunk it into the pool.

    Hashing and compression consume memoryview windows over the staged (or
    quantized) array buffer — the piece is never re-materialized as bytes.
    """
    codec = ser.resolve_codec(codec)
    quant, comp = ser.split_codec(codec)
    if prequant_scale is not None:
        raw, scale = np.ascontiguousarray(arr), prequant_scale
    else:
        raw, scale = ser.quantize(arr, quant)
    nbytes = raw.nbytes
    refs, written = chunkstore.store_payload_chunks(
        pool, key, ser.array_bytes_view(raw), codec=codec, comp=comp,
        chunk_size=chunk_size, index=index, pin=pin, dirty_dirs=dirty_dirs)
    return codec, scale, refs, written, nbytes


def write_snapshot_delta(
    snapshot: Snapshot,
    pool: chunkstore.ChunkPool,
    *,
    compress: bool = True,
    quantize_moments: bool = False,
    chunk_size: int = chunkstore.DEFAULT_CHUNK_SIZE,
    index: chunkstore.DeltaIndex | None = None,
    pin=lambda h: None,
    executor=None,
) -> tuple[list[dict], int]:
    """Incremental write: every piece chunked into the shared pool.

    Encode/compress runs on the shared codec executor so serialization
    overlaps across tensors. Returns (manifest tensor records, bytes
    physically written) — unchanged chunks cost a hash + an mtime touch, so
    the second number is the actual churn, not the state size.

    Durability bar: every chunk a manifest references must be durable before
    the manifest commits. For a POSIX pool (``pool.durable_dirs``) that
    means the per-save dir-fsync barrier below; for a cache-tier pool
    (``backend.BackendChunkPool``, ``durable_dirs=False``) ``store_chunk``
    collects no dirty dirs — the pool pipelines backend uploads instead and
    the store's pre-commit ``flush_uploads`` barrier replaces the fsyncs.
    """
    ex = executor if executor is not None else chunkstore.codec_executor()
    jobs = []
    dirty_dirs: set[str] = set()    # fan-out dirs with new chunks this save
    for name, lp in snapshot.leaves.items():
        for pi, (idx, arr) in enumerate(lp.pieces):
            if isinstance(arr, DeltaBlocks):
                # fingerprint-pruned piece: only its dirty blocks reached
                # the host; clean blocks reuse the previous save's refs
                fut = ex.submit(write_delta_blocks_piece, pool, (name, pi),
                                arr, index, pin, dirty_dirs)
                jobs.append((name, pi, idx, lp, arr, fut))
                continue
            # snapshot pieces were frozen by to_host at extract time; this
            # normalizes scalars/0-d values, it does not alias live state
            arr = np.asarray(arr)  # spotlint: ignore[SPOT021]
            codec = _piece_codec(name, lp, arr, compress=compress,
                                 quantize_moments=quantize_moments)
            fut = ex.submit(_delta_encode_piece, pool, (name, pi), arr, codec,
                            chunk_size, index, pin,
                            lp.scale if lp.prequant else None, dirty_dirs)
            jobs.append((name, pi, idx, lp, arr, fut))
    try:
        results = [fut.result() for *_rest, fut in jobs]
    except BaseException:
        # quiesce before propagating: a straggler task must not call pin()
        # after the caller has already unpinned this save's chunks
        for *_rest, fut in jobs:
            fut.cancel()
        futures_wait([fut for *_rest, fut in jobs])
        raise
    if dirty_dirs:
        # one fsync per distinct dirty fan-out dir, overlapped on the
        # executor — every new chunk's rename is durable before the caller
        # commits a manifest that references it. The results must be
        # collected: an fsync that failed with a real IO error means a
        # referenced chunk's rename may not survive a crash, and committing
        # a manifest over it would claim durability the disk refused.
        sync_futs = [ex.submit(fsync_dir, d) for d in dirty_dirs]
        futures_wait(sync_futs)
        for sf in sync_futs:
            sf.result()
    records = []
    new_bytes = 0
    for (name, pi, idx, lp, arr, fut), res in zip(jobs, results):
        codec, scale, refs, written, raw_len = res
        new_bytes += written
        if isinstance(arr, DeltaBlocks):
            shape, dtype_name = arr.shape, arr.dtype_name
        else:
            shape = tuple(arr.shape)
            dtype_name = lp.dtype if lp.prequant else ser.dtype_to_name(arr.dtype)
        rec = ser.TensorRecord(
            name=f"{name}#{pi}", dtype=dtype_name,
            shape=shape, global_shape=lp.global_shape,
            index=idx, nbytes=sum(r.nbytes for r in refs), crc32=0,
            codec=codec, scale=scale)
        d = rec.to_json()
        d["chunks"] = [r.to_json() for r in refs]
        d["raw_nbytes"] = raw_len
        # optional shard->chunk-span map: the axis-0 row band each chunk
        # covers, so a restoring process can select exactly the chunks its
        # shards address (manifest.record_shard_spans documents the format)
        quant, _ = ser.split_codec(codec)
        if shape:
            row_bytes = (int(np.prod(shape[1:], dtype=np.int64))
                         * ser.stored_dtype(dtype_name, quant).itemsize)
            spans = mf.shard_span_map(shape, row_bytes,
                                      (r.raw_len for r in refs))
            if spans is not None:
                d["shard_spans"] = spans
        records.append(d)
    return records, new_bytes


# ---------------------------------------------------------------------------
# restore
# ---------------------------------------------------------------------------

def _submit_leaf_jobs(
    ex: Any,
    names: Sequence[str],
    size_of: Callable[[str], int],
    run_one: Callable[[str], Any],
) -> tuple[dict[str, Callable[[], Any]], list[Future]]:
    """One decode job per leaf, coalescing sub-4KiB leaves into one task
    (per-task executor overhead beats decode cost for scalar/counter
    leaves, and configs can carry hundreds). Returns ({name: resolver},
    submitted futures) — resolvers block on and return that leaf's result;
    the futures list is for cancel/quiesce on failure."""
    small = [n for n in names if size_of(n) < SMALL_LEAF_BYTES]
    resolve: dict[str, Callable[[], Any]] = {}
    futs: list[Future] = []
    if len(small) >= 2:
        small_fut = ex.submit(
            lambda ns=tuple(small): {n: run_one(n) for n in ns})
        futs.append(small_fut)
        for n in small:
            resolve[n] = (lambda n=n: small_fut.result()[n])
    for n in names:
        if n not in resolve:
            fut = ex.submit(run_one, n)
            futs.append(fut)
            resolve[n] = fut.result
    return resolve, futs


class CheckpointReader:
    """Random access over a committed checkpoint's tensors.

    Reads both manifest formats: v1 records point into per-process shard
    container files inside the step dir; v2 (delta) records carry chunk
    references into the store's shared content-addressed pool.

    The data path is zero-copy where the formats allow: shard containers and
    pool chunks are mmap'd (one mapping per file, reused across tensors),
    crc validation runs on the mapped views, and each tensor decodes into a
    preallocated destination buffer instead of per-chunk
    ``frombuffer(...).copy()`` concatenation. ``read_many`` decodes whole
    tensors in parallel on the codec executor; ``read_slice`` parallelizes
    across one tensor's chunks."""

    def __init__(self, ckpt_dir: str, tensor_records: list[dict],
                 chunk_pool: chunkstore.ChunkPool | None = None):
        self.ckpt_dir = ckpt_dir
        self.chunk_pool = chunk_pool or chunkstore.ChunkPool(
            os.path.join(os.path.dirname(os.path.abspath(ckpt_dir)),
                         chunkstore.CHUNKS_DIRNAME))
        self._readers: dict[str, ser.ShardFileReader] = {}
        self._readers_lock = threading.Lock()
        # shard-aware restore accounting: chunks decoded vs proven skippable
        # by range-addressed reads, plus regions that had to fall back to the
        # piece-assembly path (read_slice) — the bench and tests read these
        self.region_stats = {"region_reads": 0, "chunks_decoded": 0,
                             "chunks_skipped": 0, "fallback_reads": 0}
        self._stats_lock = threading.Lock()
        # name -> list of (record, file)
        self.by_name: dict[str, list[dict]] = {}
        for rec in tensor_records:
            base = rec["name"].rsplit("#", 1)[0]
            self.by_name.setdefault(base, []).append(rec)

    def close(self) -> None:
        with self._readers_lock:
            readers, self._readers = list(self._readers.values()), {}
        for r in readers:
            r.close()

    def _reader(self, fname: str) -> ser.ShardFileReader:
        with self._readers_lock:
            if fname not in self._readers:
                self._readers[fname] = ser.ShardFileReader(
                    os.path.join(self.ckpt_dir, fname))
            return self._readers[fname]

    def _read_piece_into(self, rec: dict, out: np.ndarray | None,
                         *, parallel: bool = True) -> np.ndarray:
        """Decode one piece; fills ``out`` in place when it matches the
        stored payload (raw codec, same dtype/shape, contiguous) and returns
        it, else returns a freshly decoded array in the logical dtype."""
        quant, _comp = ser.split_codec(rec.get("codec", "raw"))
        if "chunks" in rec:
            pdtype = ser.stored_dtype(rec["dtype"], quant)
            if (out is not None and not quant and out.dtype == pdtype
                    and tuple(out.shape) == tuple(rec["shape"])
                    and out.flags.c_contiguous):
                dst = out
            else:
                dst = ser.alloc_payload(rec["dtype"], rec["shape"], quant)
            chunkstore.read_payload_into(
                self.chunk_pool, rec["chunks"], dst,
                executor=chunkstore.restore_executor() if parallel else None)
            return ser.finish_payload(dst, dtype_name=rec["dtype"],
                                      quant=quant, scale=rec.get("scale"))
        reader = self._reader(rec["file"])
        if out is not None and reader.read_into(rec["name"], out):
            return out
        return reader.read(rec["name"])

    def _read_piece(self, rec: dict) -> np.ndarray:
        return self._read_piece_into(rec, None)

    def global_shape(self, name: str) -> tuple[int, ...]:
        return tuple(self.by_name[name][0]["global_shape"])

    def dtype(self, name: str) -> np.dtype:
        return ser.name_to_dtype(self.by_name[name][0]["dtype"])

    def names(self) -> list[str]:
        return list(self.by_name)

    def read_slice(self, name: str, index: Index | None = None,
                   *, parallel: bool = True) -> np.ndarray:
        """Assemble an arbitrary global slice of `name` from saved pieces.

        ``parallel`` spreads chunk decode over the codec executor; callers
        already running *on* that executor (``read_many`` jobs) pass False —
        a job must never block on sub-jobs queued behind it.
        """
        gshape = self.global_shape(name)
        full = tuple((0, int(s)) for s in gshape)
        if index is None:
            index = full
        # single-piece fast path: the decoded piece IS the result — no
        # destination buffer, no assembly copy. Quantized pieces in
        # particular would otherwise materialize at logical width twice
        # (dequantized piece, then a copy into ``out``).
        if tuple(tuple(int(x) for x in p) for p in index) == full:
            rec = self.single_piece_record(name)
            if rec is not None:
                return self._read_piece_into(rec, None, parallel=parallel)
        out_shape = tuple(stop - start for start, stop in index)
        out = np.empty(out_shape, dtype=self.dtype(name))
        filled = 0
        for rec in self.by_name[name]:
            pidx = tuple(tuple(p) for p in rec["index"])
            # intersection of requested region and piece region
            inter = tuple((max(a0, b0), min(a1, b1)) for (a0, a1), (b0, b1) in zip(index, pidx))
            if any(lo >= hi for lo, hi in inter):
                continue
            n_inter = int(np.prod([hi - lo for lo, hi in inter]))
            if inter == pidx == tuple(index):
                # piece exactly covers the request: decode straight into out
                piece = self._read_piece_into(rec, out, parallel=parallel)
                if piece is not out:
                    out[...] = piece
                filled += n_inter
                continue
            piece = self._read_piece_into(rec, None, parallel=parallel)
            src = tuple(slice(lo - b0, hi - b0) for (lo, hi), (b0, _) in zip(inter, pidx))
            dst = tuple(slice(lo - a0, hi - a0) for (lo, hi), (a0, _) in zip(inter, index))
            out[dst] = piece[src]
            filled += n_inter
        if filled != int(np.prod(out_shape)):
            raise IOError(
                f"{name}: requested region not fully covered by saved pieces "
                f"({filled} of {int(np.prod(out_shape))} elements)")
        return out

    def stored_nbytes(self, name: str) -> int:
        """Stored (encoded) bytes across all of ``name``'s pieces."""
        return sum(int(r.get("nbytes", 0)) for r in self.by_name[name])

    def single_piece_record(self, name: str) -> dict | None:
        """The one record covering the whole tensor, or None when the tensor
        was saved as multiple shard pieces (streaming whole-tensor reads and
        device-side dequant need a single payload with a single scale)."""
        recs = self.by_name[name]
        if len(recs) != 1:
            return None
        rec = recs[0]
        full = tuple((0, int(s)) for s in rec["global_shape"])
        if tuple(tuple(int(x) for x in p) for p in rec["index"]) != full:
            return None
        return rec

    def read_region_streaming(self, name: str, region: Index,
                              *, parallel: bool = True) -> np.ndarray | None:
        """Range-addressed decode of one contiguous global region of ``name``
        — only the chunks whose bytes the region touches are opened, so a
        sharded restore reads O(shard), not O(tensor).

        The stored layout must allow it: a v2 single-full-piece record whose
        flat C-order payload makes the region one contiguous byte range
        (i.e. only axis 0 sub-sliced; trailing axes full). Chunk selection
        goes through the manifest's shard-span map when the record carries
        one (``manifest.record_shard_spans``), else through ``raw_len``
        prefix sums — both pick the same chunks. Returns the region in the
        logical dtype, or None when the layout cannot be range-addressed
        (v1 container records, multi-piece saves, trailing-axis slices);
        callers fall back to ``read_slice``, which is always correct.
        Bit-identical to slicing the full-leaf read: raw chunks decode into
        the exact destination window, and int8 dequantization multiplies
        elementwise with the tensor-global scale, so restoring a region
        equals restoring the tensor and slicing it."""
        rec = self.single_piece_record(name)
        if rec is None or "chunks" not in rec:
            return None
        shape = tuple(int(s) for s in rec["shape"])
        region = tuple((int(a), int(b)) for a, b in region)
        if len(region) != len(shape):
            return None
        if any(not 0 <= a <= b <= s for (a, b), s in zip(region, shape)):
            return None
        full = tuple((0, s) for s in shape)
        if region == full:
            return self._read_piece_into(rec, None, parallel=parallel)
        if any((a, b) != (0, s) for (a, b), s in zip(region[1:], shape[1:])):
            return None          # trailing-axis sub-slice: not flat-contiguous
        quant, _comp = ser.split_codec(rec.get("codec", "raw"))
        pdtype = ser.stored_dtype(rec["dtype"], quant)
        row_bytes = int(np.prod(shape[1:], dtype=np.int64)) * pdtype.itemsize
        a, b = region[0]
        byte_lo, byte_hi = a * row_bytes, b * row_bytes
        refs = rec["chunks"]
        offs = mf.chunk_byte_offsets(rec)
        spans = mf.record_shard_spans(rec)
        if spans is not None:
            # chunks whose row band intersects [a, b)
            c0 = bisect.bisect_right([hi for _, hi in spans], a)
            c1 = bisect.bisect_left([lo for lo, _ in spans], b)
        else:
            c0 = bisect.bisect_right(offs, byte_lo) - 1
            c1 = bisect.bisect_left(offs, byte_hi)
        c0, c1 = max(c0, 0), min(c1, len(refs))
        if c1 <= c0:
            return None          # degenerate map/region: let read_slice decide
        out = np.empty(tuple(hi - lo for lo, hi in region), dtype=pdtype)
        decoded, skipped = chunkstore.read_payload_range_into(
            self.chunk_pool, refs[c0:c1], out,
            byte_lo=byte_lo, base_off=offs[c0],
            executor=chunkstore.restore_executor() if parallel else None)
        with self._stats_lock:
            st = self.region_stats
            st["region_reads"] += 1
            st["chunks_decoded"] += decoded
            st["chunks_skipped"] += skipped + (len(refs) - (c1 - c0))
        return ser.finish_payload(out, dtype_name=rec["dtype"], quant=quant,
                                  scale=rec.get("scale"))

    def read_region_for_restore(self, name: str, region: Index) -> np.ndarray:
        """One shard-region decode job on the RESTORE lane: range-addressed
        when the stored layout allows, ``read_slice`` fallback otherwise.
        Runs *on* the restore executor, so chunk work inside stays serial —
        a lane job must never block on sub-jobs queued behind it."""
        arr = self.read_region_streaming(name, region, parallel=False)
        if arr is not None:
            return arr
        with self._stats_lock:
            self.region_stats["fallback_reads"] += 1
        return self.read_slice(name, region, parallel=False)

    def read_payload(self, name: str, *, parallel: bool = True
                     ) -> tuple[np.ndarray, str, str, float | None]:
        """Stored (post-decompress, pre-dequantize) payload of a
        single-full-piece tensor: (payload, logical dtype name, quant,
        scale). An int8-coded record's payload comes back as int8 — the
        streaming restore ships it across the host→device link at 1/4 the
        logical width and widens it on device."""
        rec = self.single_piece_record(name)
        if rec is None:
            raise ValueError(f"{name}: not a single full-coverage piece")
        quant, _comp = ser.split_codec(rec.get("codec", "raw"))
        pdtype = ser.stored_dtype(rec["dtype"], quant)
        shape = tuple(rec["shape"])
        if "chunks" in rec:
            crefs = rec["chunks"]
            if len(crefs) == 1:
                ref = chunkstore.ChunkRef.from_json(crefs[0])
                if ref.comp in ("", "raw"):
                    # zero-copy: validated mmap view of the pool chunk —
                    # the device transfer copies straight from the page
                    # cache, no intermediate host buffer at all
                    # intentional escape: the view's lifetime is the
                    # returned array's (np.frombuffer holds the only
                    # reference); the pool chunk is immutable and
                    # committed, and device_put copies out of it
                    # before the restore returns
                    view = self.chunk_pool.read_view(ref)  # spotlint: ignore[SPOT020]
                    arr = np.frombuffer(view, dtype=pdtype).reshape(shape)
                    return arr, rec["dtype"], quant, rec.get("scale")
            dst = ser.alloc_payload(rec["dtype"], shape, quant)
            chunkstore.read_payload_into(
                self.chunk_pool, crefs, dst,
                executor=chunkstore.restore_executor() if parallel else None)
            return dst, rec["dtype"], quant, rec.get("scale")
        # intentional escape: lifetime transfers to the np.frombuffer
        # array; the backing reader mmap stays open until this
        # CheckpointReader is closed, after device transfer
        view = self._reader(rec["file"]).read_payload_view(rec["name"])  # spotlint: ignore[SPOT020]
        if view is not None:
            arr = np.frombuffer(view, dtype=pdtype).reshape(shape)
            return arr, rec["dtype"], quant, rec.get("scale")
        dst = ser.alloc_payload(rec["dtype"], shape, quant)
        if not self._reader(rec["file"]).read_payload_into(rec["name"], dst):
            raise IOError(f"{name}: container payload does not match its record")
        return dst, rec["dtype"], quant, rec.get("scale")

    def read_many(self, names: list[str]) -> dict[str, np.ndarray]:
        """Read whole tensors in parallel (one restore-lane job per leaf,
        sub-4KiB leaves coalesced — see ``_submit_leaf_jobs``; inside each
        job chunk decode is serial — no nested submission)."""
        resolve, futs = _submit_leaf_jobs(
            chunkstore.restore_executor(), names, self.stored_nbytes,
            lambda n: self.read_slice(n, None, parallel=False))
        try:
            return {n: resolve[n]() for n in names}
        except BaseException:
            for f in futs:
                f.cancel()
            futures_wait(futs)
            raise

    def validate(self) -> None:
        """Full-content crc validation of every piece (per-chunk for v2)."""
        for name, recs in self.by_name.items():
            for rec in recs:
                self._read_piece(rec)


def _idx_of_slices(slices, shape) -> Index:
    return _slices_to_index(slices, shape)


def _leaf_sharding(leaf):
    """The template leaf's device sharding, or None for a host leaf."""
    sharding = getattr(leaf, "sharding", None)
    if sharding is None or not hasattr(sharding, "device_set"):
        return None
    return sharding


def _check_template(reader: CheckpointReader, named: dict) -> None:
    for name, leaf in named.items():
        if name not in reader.by_name:
            raise KeyError(f"checkpoint missing leaf {name!r}; has {sorted(reader.by_name)[:8]}...")
        if hasattr(leaf, "shape") and reader.global_shape(name) != tuple(leaf.shape):
            raise ValueError(
                f"{name}: shape mismatch ckpt={reader.global_shape(name)} "
                f"vs template={tuple(leaf.shape)}")


def _host_leaf_value(name: str, leaf, host: dict):
    """Finalize one host-destined leaf from its decoded array (scalar cast
    back to its python type; arrays cast to the template dtype)."""
    if isinstance(leaf, (int, float, bool)) and not isinstance(leaf, np.generic):
        return type(leaf)(host[name].reshape(())[()])
    return host[name].astype(leaf.dtype, copy=False)


def restore_to_template(reader: CheckpointReader, template) -> Any:
    """Restore a pytree matching `template`'s structure, shapes and shardings.

    Template leaves may be jax.Arrays (their sharding is reproduced —
    elastic restore reads only the slices each device needs),
    jax.ShapeDtypeStruct with `.sharding`, numpy arrays, or python scalars.

    Host-destined leaves decode in parallel (``read_many``); device-sharded
    leaves decode per-device-slice with chunk-level parallelism inside each
    callback. Both paths are bit-identical to a serial restore — only the
    schedule differs. For restores that should land on device, see
    ``restore_to_template_streaming``, which additionally overlaps decode
    with the host→device transfers.
    """
    named = ser.flatten_state(template)
    treedef = jax.tree_util.tree_structure(template)
    _check_template(reader, named)
    host_names = [n for n, leaf in named.items() if _leaf_sharding(leaf) is None]
    host = reader.read_many(host_names)
    out = {}
    for name, leaf in named.items():
        if name in host:
            out[name] = _host_leaf_value(name, leaf, host)
            continue
        shape = tuple(leaf.shape)
        dtype = leaf.dtype

        def cb(idx, _name=name, _shape=shape, _dtype=dtype):
            region = _idx_of_slices(idx, _shape)
            return reader.read_slice(_name, region).astype(_dtype, copy=False)
        out[name] = jax.make_array_from_callback(shape, leaf.sharding, cb)
    return jax.tree_util.tree_unflatten(treedef, [out[n] for n in named])


def _whole_tensor_sharding(sharding, shape: tuple[int, ...]) -> bool:
    """True when every addressable device wants the full tensor (single
    device or fully replicated) — the whole-payload streaming fast path."""
    try:
        imap = sharding.devices_indices_map(shape)
    except Exception:
        return False
    full = tuple((0, s) for s in shape)
    return all(_slices_to_index(idx, shape) == full for idx in imap.values())


def restore_to_template_streaming(reader: CheckpointReader, template) -> Any:
    """Streaming disk→device restore: ``restore_to_template`` semantics with
    the read→decode→``jax.device_put`` stages pipelined.

    Every leaf's read/decode job is submitted to the scheduler's RESTORE
    lane up front (tiny leaves batched into one task, int8-quantized leaves
    queued first) — restore work jumps every queued periodic-save encode,
    and yielding periodic workers help it run (restore QoS); the main
    thread consumes completions and immediately issues the
    asynchronous host→device transfer — so disk IO, decompression and H2D
    DMA of different tensors overlap instead of serializing. int8-quantized
    payloads cross the link at stored (1/4) width and widen on device in a
    single batched dispatch (``kernels.quantize.dequantize_int8_many``)
    whose execution overlaps the remaining full-width decodes; sharded
    template leaves decode per-device-slice from prefetched regions;
    host-destined leaves (no device sharding on the template leaf) come out
    exactly as the serial path produces them. Bit-identical to
    ``restore_to_template`` — only the schedule differs.
    """
    from ..kernels.quantize import dequantize_int8_many

    named = ser.flatten_state(template)
    treedef = jax.tree_util.tree_structure(template)
    _check_template(reader, named)
    ex = chunkstore.restore_executor()
    all_futs: list = []

    # --- planning pass ----------------------------------------------------
    plans: dict[str, str] = {}
    regions: dict[str, dict[Index, Any]] = {}
    for name, leaf in named.items():
        sharding = _leaf_sharding(leaf)
        if sharding is None:
            plans[name] = "host"
        elif (_whole_tensor_sharding(sharding, tuple(leaf.shape))
                and reader.single_piece_record(name) is not None):
            rec = reader.single_piece_record(name)
            quant, _ = ser.split_codec(rec.get("codec", "raw"))
            plans[name] = "quantized" if quant == "int8" else "payload"
        else:
            plans[name] = "sharded"

    # --- submission pass: every leaf's decode work enters the executor.
    # Quantized payloads go first: they are the smallest bytes-on-disk per
    # logical byte, so their decode+H2D finishes early and the batched
    # on-device widen runs *under* the remaining full-width decodes.
    def _run_one(name: str):
        if plans[name] == "host":
            return reader.read_slice(name, None, parallel=False)
        return reader.read_payload(name, parallel=False)

    order = sorted((n for n, p in plans.items() if p != "sharded"),
                   key=lambda n: plans[n] != "quantized")
    resolve, job_futs = _submit_leaf_jobs(ex, order, reader.stored_nbytes,
                                          _run_one)
    all_futs.extend(job_futs)
    for name, leaf in named.items():
        if plans[name] != "sharded":
            continue
        # per-shard enqueue: decode jobs only for the regions some
        # *addressable* device of this process materializes — in a
        # multihost pod each process touches O(its shards) chunks, and the
        # range-addressed read inside skips every chunk outside the region
        per_region: dict[Index, Any] = {}
        for key in addressable_shard_spans(leaf.sharding, tuple(leaf.shape)):
            per_region[key] = ex.submit(reader.read_region_for_restore,
                                        name, key)
        regions[name] = per_region
        all_futs.extend(per_region.values())

    # --- consumption: transfers issue as decodes land ---------------------
    out = {}
    try:
        # quantized leaves first: 1/4-width H2D per payload as it lands,
        # then ONE batched widen/multiply/cast dispatch for all of them —
        # bit-identical to serialize.finish_payload
        qnames = [n for n in order if plans[n] == "quantized"]
        if qnames:
            payloads, q_scales, q_dtypes = [], [], []
            for name in qnames:
                payload, dtype_name, _quant, scale = resolve[name]()
                payloads.append(payload)
                q_scales.append(scale)
                q_dtypes.append(dtype_name)
            # one batched H2D for all quantized payloads (python-side
            # device_put overhead is per *call*, not per array)
            q_devs = jax.device_put(
                payloads, [named[n].sharding for n in qnames])
            for name, arr in zip(qnames, dequantize_int8_many(
                    q_devs, q_scales, q_dtypes)):
                if arr.dtype != np.dtype(named[name].dtype):
                    arr = arr.astype(named[name].dtype)
                out[name] = arr
        # full-width payloads: resolve in decode order, then one batched H2D
        # — per-call device_put python overhead holds the GIL the decode
        # threads still need, so fewer/larger transfer calls win
        pnames = [n for n in order if plans[n] == "payload"]
        if pnames:
            staged = []
            for name in pnames:
                payload, _dtype_name, _quant, _scale = resolve[name]()
                staged.append(payload.astype(named[name].dtype, copy=False))
            for name, arr in zip(pnames, jax.device_put(
                    staged, [named[n].sharding for n in pnames])):
                out[name] = arr
        for name, leaf in named.items():
            plan = plans[name]
            if plan in ("quantized", "payload"):
                continue
            if plan == "host":
                out[name] = _host_leaf_value(name, leaf, {name: resolve[name]()})
            else:
                shape = tuple(leaf.shape)
                dtype = leaf.dtype

                def cb(idx, _shape=shape, _dtype=dtype, _futs=regions[name]):
                    key = _idx_of_slices(idx, _shape)
                    return _futs[key].result().astype(_dtype, copy=False)
                out[name] = jax.make_array_from_callback(shape, leaf.sharding, cb)
    except BaseException:
        for f in all_futs:
            f.cancel()
        futures_wait(all_futs)
        raise
    # pod rendezvous: no participant takes its first post-restore step until
    # every participant has materialized its shards — multihost semantics
    # (jax.experimental.multihost_utils API), simulated in-process for CPU
    # CI via distributed.multihost.use_simulated_barrier. A lone process
    # with no barrier installed passes straight through.
    multihost.sync_global_devices("spoton:restore_streaming")
    return jax.tree_util.tree_unflatten(treedef, [out[n] for n in named])
