"""Asynchronous checkpointing — overlap checkpoint IO with training compute.

The trainer blocks only on ``extract_snapshot`` (device→host copy at a step
boundary); encoding + file IO run on a daemon writer thread. This is the
distributed-training analogue of CRIU's brief stop-the-world followed by
background page writeout, and it is what makes *frequent* transparent
checkpoints affordable (the paper's 10/15-minute cadence at near-zero overhead,
Table I rows 1–2).

Termination checkpoints (eviction notice received) use ``save_urgent``: the
pending queue is drained/discarded in favour of the newest state and the call
blocks until the checkpoint is durably committed — the best-effort window is
the eviction notice (≥30 s), so latency, not overlap, is the goal there.

With a delta-mode store both paths are incremental: a periodic save writes
only chunks dirtied since the last committed state, and an urgent save reuses
every unchanged chunk of the last snapshot already in the pool — the
notice-window write is the churn since the previous checkpoint, not the full
state. Completed writes are published via ``drain_completed`` so the
coordinator can account *physical* bytes (``CheckpointInfo.new_bytes``)
without blocking on the writer thread.
"""

from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Any, Callable

from . import serialize as ser
from . import sharded
from .store import CheckpointInfo, CheckpointStore


@dataclass
class _Job:
    snapshot: sharded.Snapshot
    kind: str
    extra: dict | None
    done: threading.Event
    result: CheckpointInfo | None = None
    error: BaseException | None = None


class AsyncCheckpointer:
    def __init__(self, store: CheckpointStore, *, max_pending: int = 2):
        self.store = store
        self._queue: queue.Queue[_Job | None] = queue.Queue(maxsize=max_pending)
        self._lock = threading.Lock()
        self._last_error: BaseException | None = None
        self._inflight: _Job | None = None
        self._completed: list[CheckpointInfo] = []
        self._thread = threading.Thread(target=self._worker, daemon=True,
                                        name="spoton-ckpt-writer")
        self._thread.start()

    # -- worker ----------------------------------------------------------------

    def _worker(self) -> None:
        while True:
            job = self._queue.get()
            if job is None:
                return
            with self._lock:
                self._inflight = job
            try:
                job.result = self.store.save_snapshot(
                    job.snapshot, kind=job.kind, extra=job.extra)
                with self._lock:
                    self._completed.append(job.result)
            except BaseException as e:  # surfaced on next call / wait
                job.error = e
                with self._lock:
                    self._last_error = e
            finally:
                with self._lock:
                    self._inflight = None
                job.done.set()
                self._queue.task_done()

    def _raise_pending_error(self) -> None:
        with self._lock:
            err, self._last_error = self._last_error, None
        if err is None:
            return
        if not isinstance(err, Exception):
            # a process-kill equivalent (torture harness SimulatedCrash,
            # KeyboardInterrupt) observed on the writer thread: re-raise
            # as itself — wrapping it in RuntimeError would downgrade a
            # crash into a recoverable periodic-save failure
            raise err
        raise RuntimeError("async checkpoint write failed") from err

    # -- API -------------------------------------------------------------------

    def save_async(self, step: int, state, *, kind: str = "transparent",
                   mesh_info: dict | None = None, extra: dict | None = None,
                   tracker=None) -> sharded.Snapshot:
        """Snapshot now (blocking, cheap), write in background (backpressured).

        With a ``tracker`` (device-delta, delta-mode stores) the extract leg
        moves only fingerprint-dirty blocks device→host; the tracker's
        commit bookkeeping runs on this writer thread once the store marks
        the checkpoint COMMITTED."""
        self._raise_pending_error()
        snap = sharded.extract_snapshot(
            state, step=step, mesh_info=mesh_info,
            tracker=tracker if self.store.mode == "delta" else None)
        job = _Job(snapshot=snap, kind=kind, extra=extra, done=threading.Event())
        self._queue.put(job)  # blocks if max_pending writes are outstanding
        return snap

    def save_urgent(self, step: int, state, *, kind: str = "termination",
                    mesh_info: dict | None = None, extra: dict | None = None,
                    timeout_s: float | None = None) -> CheckpointInfo:
        """Termination checkpoint: snapshot, drop queued (stale) jobs, write now.

        Blocks until durably committed (or `timeout_s`). Stale queued periodic
        snapshots are discarded — the termination snapshot supersedes them.

        On a quantize-moments store the optimizer moments are absmax-int8
        quantized *on device* before the host copy, so the extract leg of the
        notice window moves them at 1/4 width; the stored bytes are the same
        as a host-side quantize, so the chunks still dedup against periodic
        saves of the same state.

        Urgent saves never use the device-delta fingerprint path: the notice
        window cannot wait for a digest round-trip at a step boundary, and
        the delta-mode chunk pool already makes the *write* leg incremental
        via the raw-digest memo.

        The write runs on a dedicated transient thread, not the periodic
        writer thread: an inflight periodic save must not serialize the
        notice window. The store's commit protocol is multi-writer safe
        (idempotent pool puts, per-save stage dirs, commit lock), and at the
        codec level the urgent save's encode jobs enter the scheduler's
        URGENT lane — queued periodic encodes wait, and running ones yield
        their workers between chunks.
        """
        snap = sharded.extract_snapshot(
            state, step=step, mesh_info=mesh_info,
            on_device_quantize=(ser.is_moment_name
                                if self.store.quantize_moments else None))
        # discard queued-but-unstarted periodic jobs; they are older than `snap`
        try:
            while True:
                stale = self._queue.get_nowait()
                if stale is not None:
                    stale.error = RuntimeError("superseded by termination checkpoint")
                    stale.done.set()
                    self._queue.task_done()
        except queue.Empty:
            pass
        job = _Job(snapshot=snap, kind=kind, extra=extra, done=threading.Event())
        runner = threading.Thread(target=self._run_urgent, args=(job,),
                                  daemon=True, name="spoton-ckpt-urgent")
        runner.start()
        if not job.done.wait(timeout=timeout_s):
            raise TimeoutError(
                f"termination checkpoint at step {step} missed the notice window")
        if job.error is not None:
            if not isinstance(job.error, Exception):
                raise job.error  # process-kill equivalent: never downgrade
            raise RuntimeError("termination checkpoint failed") from job.error
        assert job.result is not None
        return job.result

    def _run_urgent(self, job: _Job) -> None:
        """Body of the transient urgent-save thread — same bookkeeping as
        the periodic worker, minus the queue."""
        try:
            job.result = self.store.save_snapshot(
                job.snapshot, kind=job.kind, extra=job.extra)
            with self._lock:
                self._completed.append(job.result)
        except BaseException as e:
            job.error = e
            with self._lock:
                self._last_error = e
        finally:
            job.done.set()

    def drain_completed(self) -> list[CheckpointInfo]:
        """Pop infos of writes finished since the last drain (all kinds,
        including urgent saves — callers that already accounted an urgent
        save's result should filter on ``kind``)."""
        with self._lock:
            done, self._completed = self._completed, []
        return done

    def wait_until_finished(self) -> None:
        self._queue.join()
        self._raise_pending_error()

    def close(self) -> None:
        try:
            self.wait_until_finished()
        finally:
            # always stop the worker, even when surfacing a pending error
            self._queue.put(None)
            self._thread.join(timeout=10)
