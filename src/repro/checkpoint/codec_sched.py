"""Priority-aware codec scheduler — restore QoS on one shared worker pool.

The checkpoint layer used to run two flat ``ThreadPoolExecutor``s: a shared
encode/decode pool and a reserved "urgent" pool for termination saves. That
layout had the right instinct (the eviction-notice window must not queue
behind periodic traffic) and the wrong mechanism everywhere else: restore —
the MTTR window, the reason the framework exists — was a fair-share peer of
background save encodes, and measured restore throughput collapsed ~7x the
moment a single concurrent writer was saving into the same pool
(``BENCH_resume.json``: 1.87 GB/s idle → 0.27 GB/s under one writer).

This module replaces both pools with **one** worker pool fed by a
strict-priority queue with three lanes::

    URGENT   (0)  termination-save encodes — the eviction notice pays for
                  every queued task, nothing may sit in front of them
    RESTORE  (1)  restore/decode jobs — the MTTR window
    PERIODIC (2)  periodic-save encodes — background work; yields between
                  chunks (below) so it can be preempted mid-piece

Two mechanisms give restore its QoS:

* **Queue jumping** — workers always pop the highest-priority job available
  (FIFO within a lane), so a restore submitted while periodic encodes are
  queued runs before all of them. One pool, not two: folding the old
  reserved urgent executor into the URGENT lane means an urgent save no
  longer competes with a second pool for the same physical cores.
* **Cooperative preemption** — queue jumping alone cannot reclaim workers
  already *inside* a long periodic encode. Encode jobs are chunk-granular
  loops (``store_payload_chunks``, ``write_delta_blocks_piece``), so between
  chunks they call ``maybe_yield()``: a worker running a PERIODIC job pops
  and executes queued higher-priority jobs inline until none remain, then
  resumes its encode. Preemption latency is bounded by one chunk's encode
  (~1 MiB hash+compress+write), not one piece's. URGENT and RESTORE jobs
  never yield — ``maybe_yield`` is a no-op unless the current job is
  PERIODIC — so the eviction window and the restore path keep their latency.

Scheduling is observable: per-lane counters (jobs, queue-wait seconds, exec
seconds — exec excludes time spent running helped jobs, so lane totals don't
double-count) plus a global yield count, snapshot via ``snapshot_stats``.
The coordinator folds these into ``CoordinatorStats``/``TimeLedger`` so a
slow restore is attributable: queue-wait says "starved scheduler", exec says
"slow disk".

Worker threads are daemon (interpreter exit can never hang on a stuck 9p
fsync) and the process-wide scheduler registers an ``atexit`` shutdown that
cancels queued work and joins briefly — the old module-global executors were
leaked, and their non-daemon workers could hang exit after a failed
benchmark run.
"""

from __future__ import annotations

import atexit
import heapq
import itertools
import os
import threading
import time
from concurrent.futures import Future

URGENT = 0
RESTORE = 1
PERIODIC = 2

LANE_NAMES = {URGENT: "urgent", RESTORE: "restore", PERIODIC: "periodic"}

# the scheduler currently executing a job on this thread (any instance, not
# just the process-wide one) — lets chunk loops call the module-level
# maybe_yield() without knowing which scheduler their job came from
_ACTIVE = threading.local()


class _Job:
    __slots__ = ("prio", "seq", "fn", "args", "kwargs", "future", "t_submit")

    def __init__(self, prio: int, seq: int, fn, args, kwargs):
        self.prio = prio
        self.seq = seq
        self.fn = fn
        self.args = args
        self.kwargs = kwargs
        self.future: Future = Future()
        self.t_submit = time.perf_counter()

    def __lt__(self, other: "_Job") -> bool:
        # strict priority, FIFO within a lane
        return (self.prio, self.seq) < (other.prio, other.seq)


class CodecScheduler:
    """One worker pool, three strict-priority lanes, cooperative yields."""

    def __init__(self, max_workers: int):
        self.max_workers = max_workers
        self._cond = threading.Condition()
        self._heap: list[_Job] = []
        self._seq = itertools.count()
        self._threads: list[threading.Thread] = []
        self._idle = 0
        self._shutdown = False
        self._tls = threading.local()
        self._stats = {name: {"submitted": 0, "completed": 0,
                              "queue_wait_s": 0.0, "exec_s": 0.0}
                       for name in LANE_NAMES.values()}
        self._yields = 0

    # -- submission ---------------------------------------------------------

    def submit(self, priority: int, fn, /, *args, **kwargs) -> Future:
        if priority not in LANE_NAMES:
            raise ValueError(f"unknown codec priority {priority!r}")
        with self._cond:
            if not self._shutdown:
                job = _Job(priority, next(self._seq), fn, args, kwargs)
                heapq.heappush(self._heap, job)
                self._stats[LANE_NAMES[priority]]["submitted"] += 1
                if self._idle > 0:
                    self._cond.notify()
                elif len(self._threads) < self.max_workers:
                    t = threading.Thread(
                        target=self._worker, daemon=True,
                        name=f"spoton-codec-{len(self._threads)}")
                    self._threads.append(t)
                    t.start()
                return job.future
        if priority != URGENT:
            raise RuntimeError("codec scheduler is shut down")
        # URGENT work is a termination save racing interpreter teardown:
        # the atexit hook has already shut the lane workers down, but the
        # eviction-notice checkpoint must still become durable. Run the job
        # inline on the submitter's thread and hand back a completed
        # future — the caller sees the same submit/result contract.
        with self._cond:
            self._stats[LANE_NAMES[URGENT]]["submitted"] += 1
        job = _Job(URGENT, next(self._seq), fn, args, kwargs)
        self._run(job)
        return job.future

    # -- workers ------------------------------------------------------------

    def _worker(self) -> None:
        while True:
            with self._cond:
                while not self._heap and not self._shutdown:
                    self._idle += 1
                    self._cond.wait()
                    self._idle -= 1
                if not self._heap:
                    return            # shutdown and nothing left to drain
                job = heapq.heappop(self._heap)
            self._run(job)

    def _run(self, job: _Job) -> None:
        if not job.future.set_running_or_notify_cancel():
            return                    # cancelled while queued
        t0 = time.perf_counter()
        prev_prio = getattr(self._tls, "prio", None)
        prev_sched = getattr(_ACTIVE, "sched", None)
        # child_s accumulates helped-job wall time so a yielding PERIODIC
        # job's own exec excludes the restores it ran inline
        prev_child = getattr(self._tls, "child_s", 0.0)
        self._tls.prio = job.prio
        self._tls.child_s = 0.0
        _ACTIVE.sched = self
        try:
            result = job.fn(*job.args, **job.kwargs)
        except BaseException as e:
            job.future.set_exception(e)
        else:
            job.future.set_result(result)
        finally:
            dt = time.perf_counter() - t0
            self_dt = dt - self._tls.child_s
            self._tls.prio = prev_prio
            self._tls.child_s = prev_child + dt
            _ACTIVE.sched = prev_sched
            with self._cond:
                st = self._stats[LANE_NAMES[job.prio]]
                st["completed"] += 1
                st["queue_wait_s"] += t0 - job.t_submit
                st["exec_s"] += self_dt

    # -- cooperative preemption ---------------------------------------------

    def maybe_yield(self, *, limit: int | None = None) -> int:
        """Chunk-granular preemption checkpoint for PERIODIC encode jobs.

        Called between chunks by the encode loops: if this thread is a
        worker running a PERIODIC job and higher-priority work is queued,
        pop and run it inline until the queue holds nothing more urgent
        than the caller. No-op (and free) on every other thread/priority —
        URGENT and RESTORE jobs never yield. Returns jobs helped.
        """
        cur = getattr(self._tls, "prio", None)
        if cur is None or cur <= RESTORE or not self._heap:
            return 0                  # racy heap peek: worst case we miss
        ran = 0                       # one yield window, caught next chunk
        while limit is None or ran < limit:
            with self._cond:
                if (self._shutdown or not self._heap
                        or self._heap[0].prio >= cur):
                    break
                job = heapq.heappop(self._heap)
            self._run(job)
            ran += 1
        if ran:
            with self._cond:
                self._yields += ran
        return ran

    # -- observability ------------------------------------------------------

    def snapshot_stats(self) -> dict:
        with self._cond:
            out: dict = {name: dict(st) for name, st in self._stats.items()}
            out["yields"] = self._yields
            out["queued"] = len(self._heap)
            return out

    # -- lifecycle ----------------------------------------------------------

    def shutdown(self, *, wait: bool = True, timeout: float | None = None,
                 cancel_pending: bool = False) -> None:
        with self._cond:
            self._shutdown = True
            pending: list[_Job] = []
            urgent: list[_Job] = []
            if cancel_pending:
                drained, self._heap = self._heap, []
                for job in drained:
                    (urgent if job.prio == URGENT else pending).append(job)
            self._cond.notify_all()
        # never cancel URGENT jobs: they are termination-save encodes, and a
        # save_urgent racing the atexit shutdown must still reach its
        # COMMITTED rename. Drain them inline (lane FIFO order) on this
        # thread; only periodic/restore work is discarded.
        for job in sorted(urgent, key=lambda j: j.seq):
            self._run(job)
        for job in pending:
            job.future.cancel()
        if wait:
            deadline = (None if timeout is None
                        else time.monotonic() + timeout)
            for t in self._threads:
                t.join(timeout=None if deadline is None
                       else max(0.0, deadline - time.monotonic()))


class CodecLane:
    """Executor-shaped view of one scheduler lane: ``submit`` binds the
    lane's priority, so every existing ``executor.submit(...)`` call site
    (and ``concurrent.futures.wait`` on the returned futures) works
    unchanged while the work lands in the right queue."""

    __slots__ = ("scheduler", "priority")

    def __init__(self, scheduler: CodecScheduler, priority: int):
        self.scheduler = scheduler
        self.priority = priority

    def submit(self, fn, /, *args, **kwargs) -> Future:
        return self.scheduler.submit(self.priority, fn, *args, **kwargs)


# ---------------------------------------------------------------------------
# process-wide scheduler
# ---------------------------------------------------------------------------

_sched: CodecScheduler | None = None
_sched_lock = threading.Lock()


def _default_workers() -> int:
    # cores + 2: codec jobs interleave GIL-releasing compute (hash/crc/
    # compress) with file IO, so slight oversubscription hides syscall
    # stalls without thrashing small boxes
    return min(8, (os.cpu_count() or 2) + 2)


def scheduler() -> CodecScheduler:
    """The process-wide codec scheduler, shared by every store. Lazily
    created; an ``atexit`` hook cancels queued work and joins the (daemon)
    workers so a failed run can never hang interpreter exit."""
    global _sched
    if _sched is None:
        with _sched_lock:
            if _sched is None:
                s = CodecScheduler(max_workers=_default_workers())
                atexit.register(s.shutdown, wait=True, timeout=10.0,
                                cancel_pending=True)
                _sched = s
    return _sched


def lane(priority: int) -> CodecLane:
    return CodecLane(scheduler(), priority)


def _reset_for_tests() -> None:
    """Tear down the process-wide scheduler so the next ``scheduler()``
    call builds a fresh one. Test-only: regression tests for the
    shutdown/teardown races need to shut the global instance down and then
    restore a working scheduler for the rest of the suite."""
    global _sched
    with _sched_lock:
        s, _sched = _sched, None
    if s is not None:
        atexit.unregister(s.shutdown)
        s.shutdown(wait=True, timeout=5.0, cancel_pending=True)


def maybe_yield() -> int:
    """Module-level preemption checkpoint: dispatches to whichever scheduler
    is executing a job on this thread (the process-wide one in production;
    a private instance under test). Free no-op everywhere else."""
    s = getattr(_ACTIVE, "sched", None)
    return 0 if s is None else s.maybe_yield()


_ZERO_LANE = {"submitted": 0, "completed": 0, "queue_wait_s": 0.0,
              "exec_s": 0.0}


def snapshot_stats() -> dict:
    """Stats snapshot without forcing the scheduler into existence (readers
    like the coordinator must not spin up worker state just to report 0)."""
    s = _sched
    if s is None:
        out: dict = {name: dict(_ZERO_LANE) for name in LANE_NAMES.values()}
        out["yields"] = 0
        out["queued"] = 0
        return out
    return s.snapshot_stats()
