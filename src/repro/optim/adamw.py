"""AdamW + linear-warmup cosine schedule + global-norm clipping.

Pure pytree implementation (no optax dependency). Moments are fp32 regardless
of param dtype; the update is computed in fp32 and cast back — bf16 params
with fp32 optimizer state, the layout the checkpoint size model assumes
(10 bytes/param: 2 + 4 + 4).
"""

from __future__ import annotations

import math
from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    peak_lr: float = 3e-4
    min_lr_frac: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    # Adafactor-style factored second moment for matrix params: nu becomes a
    # (row, col) outer-product estimate, cutting optimizer state from
    # 8 bytes/param to ~4 (mu fp32 + O(n+m) factors). At grok-314b scale the
    # fp32 moments are 9.8 GiB/device on 256 chips — this is the structural
    # fix, and it shrinks termination checkpoints by the same factor.
    factored_second_moment: bool = False


def lr_at(cfg: AdamWConfig, step):
    step = jnp.asarray(step, jnp.float32)
    warm = cfg.peak_lr * step / max(cfg.warmup_steps, 1)
    t = jnp.clip((step - cfg.warmup_steps)
                 / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.peak_lr * (cfg.min_lr_frac
                         + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(math.pi * t)))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def _factorable(shape) -> bool:
    """Factor the trailing two dims when both are >= 64 (matrix params;
    stacked-layer leading dims ride along)."""
    return len(shape) >= 2 and shape[-1] >= 64 and shape[-2] >= 64


def init_opt_state(params, *, factored: bool = False) -> dict:
    zeros32 = lambda p: jnp.zeros(p.shape, jnp.float32)

    def nu_init(p):
        if factored and _factorable(p.shape):
            return {"row": jnp.zeros(p.shape[:-1], jnp.float32),
                    "col": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
        return zeros32(p)

    return {
        "mu": jax.tree.map(zeros32, params),
        "nu": jax.tree.map(nu_init, params),
        "count": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(grads, opt_state, params, cfg: AdamWConfig):
    count = opt_state["count"] + 1
    lr = lr_at(cfg, count)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))

    b1c = 1 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** count.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g32
        if isinstance(v, dict):  # factored second moment (Adafactor)
            g2 = g32 * g32 + 1e-30
            row = cfg.b2 * v["row"] + (1 - cfg.b2) * jnp.mean(g2, axis=-1)
            col = cfg.b2 * v["col"] + (1 - cfg.b2) * jnp.mean(g2, axis=-2)
            v = {"row": row, "col": col}
            denom = jnp.mean(row, axis=-1, keepdims=True) + 1e-30
            vhat = (row[..., :, None] * col[..., None, :]
                    / denom[..., None]) / b2c
        else:
            v = cfg.b2 * v + (1 - cfg.b2) * g32 * g32
            vhat = v / b2c
        mhat = m / b1c
        step = mhat / (jnp.sqrt(vhat) + cfg.eps)
        p32 = p.astype(jnp.float32)
        new_p = p32 - lr * (step + cfg.weight_decay * p32)
        return new_p.astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(opt_state["mu"])
    # nu may contain dict leaves (factored); align by flattening against params
    flat_v = _flatten_nu(opt_state["nu"], treedef)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_mu = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_nu = jax.tree.unflatten(treedef, [o[2] for o in out])
    metrics = {"lr": lr, "grad_norm": gnorm}
    return new_params, {"mu": new_mu, "nu": new_nu, "count": count}, metrics


def _flatten_nu(nu, params_treedef):
    """Flatten nu to one leaf per param, keeping factored {row, col} dicts
    intact as single entries."""
    is_factored = lambda x: isinstance(x, dict) and set(x) == {"row", "col"}
    return jax.tree.flatten(nu, is_leaf=is_factored)[0]
