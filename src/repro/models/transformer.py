"""Decoder assembly for every assigned architecture.

Layers are grouped into **period segments**: the config's `block_pattern` is
one period (e.g. gemma3's 5×local+1×global, recurrentgemma's rglru,rglru,attn);
parameters are stacked over period repeats and the repeats are driven by
`jax.lax.scan`, so HLO size is ~independent of depth (critical for compiling
64-layer/314B configs with a 512-device SPMD partitioner on one CPU).
Heterogeneous layers live at different *positions inside* the period body,
where their kind — and hence window size, RoPE theta, cache structure — is
static. A leading dense-MLP prelude (DeepSeek-MoE) is its own segment.

Three entry points per model: `forward` (teacher-forced logits; training and
prefill), `prefill` (forward + cache construction), `decode_step` (one token).
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from . import layers as L
from . import moe as MOE
from . import rglru as RG
from . import ssm as SSM
from .config import ModelConfig, RGLRUConfig, SSMConfig
from ..distributed.sharding import shard_act


# ---------------------------------------------------------------------------
# segmentation plan
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Segment:
    pattern: tuple[str, ...]   # kinds at each position of one period
    n_repeats: int
    prelude: bool = False      # dense-MLP prelude layers (MoE models)


def plan_segments(cfg: ModelConfig) -> tuple[Segment, ...]:
    kinds = list(cfg.layer_kinds())
    segs: list[Segment] = []
    start = 0
    if cfg.moe is not None and cfg.moe.dense_prelude_layers:
        n = cfg.moe.dense_prelude_layers
        segs.append(Segment(tuple(kinds[:n]), 1, prelude=True))
        start = n
    rest = kinds[start:]
    p = len(cfg.block_pattern)
    n_full = len(rest) // p
    if n_full:
        segs.append(Segment(tuple(cfg.block_pattern), n_full))
    r = len(rest) % p
    if r:
        segs.append(Segment(tuple(rest[-r:]), 1))
    assert sum(len(s.pattern) * s.n_repeats for s in segs) == cfg.n_layers
    return tuple(segs)


def _kind_window(cfg: ModelConfig, kind: str) -> int:
    return cfg.window if kind == "local" else 0


def _kind_theta(cfg: ModelConfig, kind: str) -> float:
    if kind == "global" and cfg.rope_theta_global:
        return cfg.rope_theta_global
    return cfg.rope_theta


# ---------------------------------------------------------------------------
# parameter init
# ---------------------------------------------------------------------------

def _dense(key, shape, dtype, fan_in=None):
    fan_in = fan_in if fan_in is not None else shape[-2] if len(shape) >= 2 else shape[-1]
    return (jax.random.normal(key, shape, jnp.float32) / math.sqrt(fan_in)).astype(dtype)


def _mlp_params(key, d_in, d_ff, dtype, gated):
    ks = jax.random.split(key, 3)
    w = {"up": _dense(ks[0], (d_in, d_ff), dtype),
         "down": _dense(ks[1], (d_ff, d_in), dtype)}
    if gated:
        w["gate"] = _dense(ks[2], (d_in, d_ff), dtype)
    return w


def _attn_params(key, cfg: ModelConfig, dtype):
    ks = jax.random.split(key, 4)
    return {
        "wq": _dense(ks[0], (cfg.d_model, cfg.q_dim), dtype),
        "wk": _dense(ks[1], (cfg.d_model, cfg.kv_dim), dtype),
        "wv": _dense(ks[2], (cfg.d_model, cfg.kv_dim), dtype),
        "wo": _dense(ks[3], (cfg.q_dim, cfg.d_model), dtype),
    }


def _moe_params(key, cfg: ModelConfig, dtype):
    m = cfg.moe
    ks = jax.random.split(key, 5)
    E, D, F = m.n_experts, cfg.d_model, m.d_expert
    w = {
        "router": _dense(ks[0], (D, E), jnp.float32),
        "experts": {
            "up": _dense(ks[1], (E, D, F), dtype, fan_in=D),
            "down": _dense(ks[2], (E, F, D), dtype, fan_in=F),
            "gate": _dense(ks[3], (E, D, F), dtype, fan_in=D),
        },
    }
    if not _gated(cfg):
        del w["experts"]["gate"]
    if m.n_shared:
        w["shared"] = _mlp_params(ks[4], D, m.n_shared * F, dtype, _gated(cfg))
    return w


def _mamba_params(key, cfg: ModelConfig, dtype):
    s = cfg.ssm or SSMConfig()
    D = cfg.d_model
    DI = s.expand * D
    dt = s.resolved_dt_rank(D)
    ks = jax.random.split(key, 6)
    A = jnp.tile(jnp.arange(1, s.d_state + 1, dtype=jnp.float32), (DI, 1))
    return {
        "in_proj": _dense(ks[0], (D, 2 * DI), dtype),
        "conv": _dense(ks[1], (DI, s.d_conv), dtype, fan_in=s.d_conv),
        "x_proj": _dense(ks[2], (DI, dt + 2 * s.d_state), dtype),
        "dt_proj": _dense(ks[3], (dt, DI), dtype),
        "dt_bias": jnp.full((DI,), -4.6, dtype),  # softplus^-1(0.01)
        "A_log": jnp.log(A),
        "D": jnp.ones((DI,), jnp.float32),
        "out_proj": _dense(ks[4], (DI, D), dtype),
    }


def _rglru_params(key, cfg: ModelConfig, dtype):
    r = cfg.rglru or RGLRUConfig()
    D = cfg.d_model
    W = r.lru_width or D
    nb = r.n_blocks or cfg.n_heads
    bs = W // nb
    ks = jax.random.split(key, 7)
    # Λ init so a ∈ (0.9, 0.999) at r=0.5 (Griffin appendix)
    lam = jax.random.uniform(ks[5], (W,), jnp.float32, 0.3, 1.5)
    return {
        "in_x": _dense(ks[0], (D, W), dtype),
        "in_gate": _dense(ks[1], (D, W), dtype),
        "conv": _dense(ks[2], (W, r.d_conv), dtype, fan_in=r.d_conv),
        "lru": {
            "w_r": _dense(ks[3], (nb, bs, bs), jnp.float32, fan_in=bs),
            "w_i": _dense(ks[4], (nb, bs, bs), jnp.float32, fan_in=bs),
            "b_r": jnp.zeros((W,), jnp.float32),
            "b_i": jnp.zeros((W,), jnp.float32),
            "lam": lam,
        },
        "out": _dense(ks[6], (W, D), dtype),
    }


def _gated(cfg: ModelConfig) -> bool:
    return cfg.mlp_gated


def _layer_params(key, cfg: ModelConfig, kind: str, prelude: bool, dtype):
    ks = jax.random.split(key, 3)
    D = cfg.d_model
    w: dict[str, Any] = {"norm1": jnp.zeros((D,), jnp.float32)}
    if kind in ("global", "local"):
        w["attn"] = _attn_params(ks[0], cfg, dtype)
        w["norm2"] = jnp.zeros((D,), jnp.float32)
        if cfg.moe is not None and not prelude:
            w["moe"] = _moe_params(ks[1], cfg, dtype)
        else:
            d_ff = cfg.moe.d_ff_prelude if (cfg.moe and prelude) else cfg.d_ff
            w["mlp"] = _mlp_params(ks[1], D, d_ff, dtype, _gated(cfg))
    elif kind == "mamba":
        w["mamba"] = _mamba_params(ks[0], cfg, dtype)
    elif kind == "rglru":
        w["rec"] = _rglru_params(ks[0], cfg, dtype)
        w["norm2"] = jnp.zeros((D,), jnp.float32)
        w["mlp"] = _mlp_params(ks[1], D, cfg.d_ff, dtype, _gated(cfg))
    else:
        raise ValueError(kind)
    return w


def init_params(cfg: ModelConfig, key) -> dict:
    dtype = jnp.dtype(cfg.dtype)
    segs = plan_segments(cfg)
    params: dict[str, Any] = {"segments": []}
    for si, seg in enumerate(segs):
        seg_params = {}
        for pi, kind in enumerate(seg.pattern):
            def one(r, _pi=pi, _kind=kind, _seg=seg, _si=si):
                k = jax.random.fold_in(key, _si * 10007 + _pi * 101 + r)
                return _layer_params(k, cfg, _kind, _seg.prelude, dtype)
            stacked = jax.tree.map(lambda *xs: jnp.stack(xs),
                                   *[one(r) for r in range(seg.n_repeats)])
            seg_params[f"pos{pi}"] = stacked
        params["segments"].append(seg_params)
    if cfg.embed_inputs:
        params["embed"] = _dense(jax.random.fold_in(key, 999_983),
                                 (cfg.vocab_size, cfg.d_model), dtype,
                                 fan_in=cfg.d_model)
    if not (cfg.tie_embeddings and cfg.embed_inputs):
        params["lm_head"] = _dense(jax.random.fold_in(key, 999_979),
                                   (cfg.d_model, cfg.vocab_size), dtype)
    params["final_norm"] = jnp.zeros((cfg.d_model,), jnp.float32)
    return params


# ---------------------------------------------------------------------------
# block application — sequence path
# ---------------------------------------------------------------------------

def _channel_mix(x, w, cfg: ModelConfig, *, decode: bool = False):
    """MLP or MoE residual branch. Returns (delta, aux)."""
    if "moe" in w:
        if decode:  # exact dropless path (see moe.moe_block_dense)
            return MOE.moe_block_dense(x, w["moe"], cfg.moe, act=cfg.act,
                                       gated=_gated(cfg)), jnp.zeros((), jnp.float32)
        y, aux = MOE.moe_block(x, w["moe"], cfg.moe, act=cfg.act, gated=_gated(cfg))
        return y, aux
    y = L.mlp(x, w["mlp"], act=cfg.act, gated=_gated(cfg))
    return y, jnp.zeros((), jnp.float32)


def _attn_mix(x, w, cfg: ModelConfig, kind: str, positions, q_offset=0,
              kv=None, kv_valid_len=None):
    """Attention residual branch (sequence). Returns (delta, (k, v))."""
    B, S, D = x.shape
    q = (x @ w["wq"]).reshape(B, S, cfg.n_heads, cfg.head_dim)
    k = (x @ w["wk"]).reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
    v = (x @ w["wv"]).reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
    theta = _kind_theta(cfg, kind)
    q = L.rope(q, positions, theta=theta)
    k = L.rope(k, positions, theta=theta)
    q = shard_act(q, "heads")
    k = shard_act(k, "kv_heads")
    v = shard_act(v, "kv_heads")
    # flash-attention residency policy: save only q,k,v and the output;
    # score/softmax intermediates are recomputed in backward (otherwise any
    # remat-dots policy pins O(S·ctx) score matrices per layer).
    attn_fn = jax.checkpoint(
        lambda q_, k_, v_: L.attention(q_, k_, v_, q_offset=q_offset,
                                       window=_kind_window(cfg, kind),
                                       kv_valid_len=kv_valid_len))
    out = attn_fn(q, k, v)
    return out.reshape(B, S, cfg.q_dim) @ w["wo"], (k, v)


def _apply_block_seq(kind, w, x, cfg: ModelConfig, positions, *,
                     collect_cache: bool, cache_len: int | None = None):
    """One layer, full sequence. Returns (x, aux, cache_or_None)."""
    cache = None
    if kind in ("global", "local"):
        h = L.rms_norm(x, w["norm1"], eps=cfg.norm_eps)
        delta, (k, v) = _attn_mix(h, w["attn"], cfg, kind, positions)
        x = shard_act(x + delta, "residual")
        h2 = L.rms_norm(x, w["norm2"], eps=cfg.norm_eps)
        delta2, aux = _channel_mix(h2, w, cfg)
        x = shard_act(x + delta2, "residual")
        if collect_cache:
            if kind == "local" and cfg.window:
                cache = L.ring_fill_from_prefill(k, v, cfg.window)
            else:
                pad = (cache_len or k.shape[1]) - k.shape[1]
                if pad > 0:
                    k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
                    v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
                cache = {"k": k, "v": v}
    elif kind == "mamba":
        h = L.rms_norm(x, w["norm1"], eps=cfg.norm_eps)
        if collect_cache:
            delta, cache = _mamba_seq_with_cache(h, w["mamba"], cfg.ssm)
        else:
            delta = SSM.mamba_block(h, w["mamba"], cfg.ssm)
        x = shard_act(x + delta, "residual")
        aux = jnp.zeros((), jnp.float32)
    elif kind == "rglru":
        h = L.rms_norm(x, w["norm1"], eps=cfg.norm_eps)
        if collect_cache:
            delta, cache = _rglru_seq_with_cache(h, w["rec"], cfg.rglru or RGLRUConfig())
        else:
            delta = RG.recurrent_block(h, w["rec"], cfg.rglru or RGLRUConfig())
        x = shard_act(x + delta, "residual")
        h2 = L.rms_norm(x, w["norm2"], eps=cfg.norm_eps)
        x = shard_act(x + L.mlp(h2, w["mlp"], act=cfg.act, gated=_gated(cfg)), "residual")
        aux = jnp.zeros((), jnp.float32)
    else:
        raise ValueError(kind)
    return x, aux, cache


def _mamba_seq_with_cache(x, w, scfg: SSMConfig):
    A = -jnp.exp(w["A_log"].astype(jnp.float32))
    ug = x @ w["in_proj"]
    u_raw, gate = jnp.split(ug, 2, axis=-1)
    K = scfg.d_conv
    conv_state = u_raw[:, -(K - 1):] if x.shape[1] >= K - 1 else \
        jnp.pad(u_raw, ((0, 0), (K - 1 - x.shape[1], 0), (0, 0)))
    u = jax.nn.silu(L.causal_conv1d(u_raw, w["conv"]))
    dt_rank = scfg.resolved_dt_rank(x.shape[-1])
    xdbc = u @ w["x_proj"]
    dt, Bm, Cm = jnp.split(xdbc, [dt_rank, dt_rank + scfg.d_state], axis=-1)
    delta = jax.nn.softplus(dt @ w["dt_proj"] + w["dt_bias"])
    y, h_last = SSM.selective_scan(u, delta, A, Bm, Cm, w["D"])
    y = y * jax.nn.silu(gate)
    return y @ w["out_proj"], {"conv": conv_state, "ssm": h_last}


def _rglru_seq_with_cache(x, w, rcfg: RGLRUConfig):
    branch_raw = x @ w["in_x"]
    K = rcfg.d_conv
    conv_state = branch_raw[:, -(K - 1):] if x.shape[1] >= K - 1 else \
        jnp.pad(branch_raw, ((0, 0), (K - 1 - x.shape[1], 0), (0, 0)))
    branch = L.causal_conv1d(branch_raw, w["conv"])
    y, h_last = RG.rg_lru(branch, w["lru"])
    gate = jax.nn.gelu(x @ w["in_gate"])
    return (y * gate) @ w["out"], {"conv": conv_state, "h": h_last}


# ---------------------------------------------------------------------------
# full-model sequence forward
# ---------------------------------------------------------------------------

def _embed(params, cfg: ModelConfig, inputs):
    if cfg.embed_inputs:
        x = jnp.take(params["embed"], inputs, axis=0)
    else:
        x = inputs.astype(jnp.dtype(cfg.dtype))
    return shard_act(x, "residual")


def _unembed(params, cfg: ModelConfig, x):
    x = L.rms_norm(x, params["final_norm"], eps=cfg.norm_eps)
    head = params["embed"].T if (cfg.tie_embeddings and cfg.embed_inputs) \
        else params["lm_head"]
    return shard_act(x @ head, "logits")


def unembed_weights(params, cfg: ModelConfig):
    return params["embed"].T if (cfg.tie_embeddings and cfg.embed_inputs) \
        else params["lm_head"]


def forward(params, cfg: ModelConfig, inputs, *, remat: str = "none",
            collect_cache: bool = False, cache_len: int | None = None,
            return_hidden: bool = False):
    """Teacher-forced forward. inputs: tokens (B,S) int or embeds (B,S,D).
    Returns (logits (B,S,V), aux_loss, caches|None). `cache_len` sizes the
    full-attention KV caches (>= S) so decode can continue past prefill.
    With `return_hidden` the final norm output is returned instead of logits
    (the fused unembed+CE path owns the head matmul)."""
    x = _embed(params, cfg, inputs)
    S = x.shape[1]
    positions = jnp.arange(S)
    segs = plan_segments(cfg)
    aux_total = jnp.zeros((), jnp.float32)
    caches: list[Any] = []

    for seg, seg_params in zip(segs, params["segments"]):
        def period_body(x, layer_params, _seg=seg):
            aux_p = jnp.zeros((), jnp.float32)
            cache_p = {}
            for i, kind in enumerate(_seg.pattern):
                x, aux_i, cache_i = _apply_block_seq(
                    kind, layer_params[f"pos{i}"], x, cfg, positions,
                    collect_cache=collect_cache, cache_len=cache_len)
                aux_p = aux_p + aux_i
                if collect_cache:
                    cache_p[f"pos{i}"] = cache_i
            return x, (aux_p, cache_p)

        if remat != "none":
            policy = None if remat == "full" else \
                jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
            period_body = jax.checkpoint(period_body, policy=policy,
                                         static_argnums=())

        def scan_body(carry, layer_params):
            x = carry
            x, (aux_p, cache_p) = period_body(x, layer_params)
            return x, (aux_p, cache_p)

        x, (aux_seg, cache_seg) = jax.lax.scan(scan_body, x, seg_params)
        aux_total = aux_total + jnp.sum(aux_seg)
        caches.append(cache_seg)

    if return_hidden:
        x = L.rms_norm(x, params["final_norm"], eps=cfg.norm_eps)
        return x, aux_total, (caches if collect_cache else None)
    logits = _unembed(params, cfg, x)
    return logits, aux_total, (caches if collect_cache else None)


def prefill(params, cfg: ModelConfig, inputs, *, cache_len: int | None = None):
    """Returns (logits_last (B,V), caches, next_pos). Caches are stacked per
    segment/position exactly as decode_step consumes them; pass `cache_len`
    > S to leave room for decoded tokens."""
    logits, _, caches = forward(params, cfg, inputs, collect_cache=True,
                                cache_len=cache_len)
    S = inputs.shape[1]
    return logits[:, -1], caches, S


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int, *, dtype=None):
    """Empty caches shaped like prefill output (stacked per segment/pos)."""
    dtype = dtype or jnp.dtype(cfg.dtype)
    segs = plan_segments(cfg)
    caches = []
    for seg in segs:
        seg_cache = {}
        for pi, kind in enumerate(seg.pattern):
            if kind == "global":
                c = L.init_kv_cache(batch, max_len, cfg.n_kv_heads, cfg.head_dim, dtype)
            elif kind == "local":
                c = L.init_kv_cache(batch, min(cfg.window or max_len, max_len),
                                    cfg.n_kv_heads, cfg.head_dim, dtype)
            elif kind == "mamba":
                c = SSM.mamba_init_state(batch, cfg.d_model, cfg.ssm, dtype)
            elif kind == "rglru":
                r = cfg.rglru or RGLRUConfig()
                c = RG.recurrent_init_state(batch, r.lru_width or cfg.d_model, r, dtype)
            else:
                raise ValueError(kind)
            seg_cache[f"pos{pi}"] = jax.tree.map(
                lambda a, n=seg.n_repeats: jnp.broadcast_to(a, (n, *a.shape)), c)
        caches.append(seg_cache)
    return caches


def _apply_block_decode(kind, w, x, cache, pos, cfg: ModelConfig):
    """One layer, one token. x: (B,1,D). Returns (x, new_cache)."""
    if kind in ("global", "local"):
        h = L.rms_norm(x, w["norm1"], eps=cfg.norm_eps)
        B = x.shape[0]
        q = (h @ w["attn"]["wq"]).reshape(B, 1, cfg.n_heads, cfg.head_dim)
        k = (h @ w["attn"]["wk"]).reshape(B, 1, cfg.n_kv_heads, cfg.head_dim)
        v = (h @ w["attn"]["wv"]).reshape(B, 1, cfg.n_kv_heads, cfg.head_dim)
        theta = _kind_theta(cfg, kind)
        ppos = pos[None] if jnp.ndim(pos) == 0 else pos
        q = L.rope(q, ppos, theta=theta)
        k = L.rope(k, ppos, theta=theta)
        if kind == "local" and cfg.window:
            cache = L.cache_update_ring(cache, k, v, pos)
            out = L.decode_attention_ring(q, cache, pos, window=cfg.window)
        else:
            cache = L.cache_update_full(cache, k, v, pos)
            out = L.attention(q, cache["k"], cache["v"], q_offset=pos,
                              kv_valid_len=pos + 1)
        x = x + out.reshape(B, 1, cfg.q_dim) @ w["attn"]["wo"]
        h2 = L.rms_norm(x, w["norm2"], eps=cfg.norm_eps)
        delta2, _ = _channel_mix(h2, w, cfg, decode=True)
        x = x + delta2
    elif kind == "mamba":
        h = L.rms_norm(x, w["norm1"], eps=cfg.norm_eps)
        delta, cache = SSM.mamba_step(h, cache, w["mamba"], cfg.ssm)
        x = x + delta
    elif kind == "rglru":
        h = L.rms_norm(x, w["norm1"], eps=cfg.norm_eps)
        delta, cache = RG.recurrent_step(h, cache, w["rec"], cfg.rglru or RGLRUConfig())
        x = x + delta
        h2 = L.rms_norm(x, w["norm2"], eps=cfg.norm_eps)
        x = x + L.mlp(h2, w["mlp"], act=cfg.act, gated=_gated(cfg))
    else:
        raise ValueError(kind)
    return x, cache


def decode_step(params, cfg: ModelConfig, inputs, caches, pos):
    """One decode step. inputs: token ids (B,1) or embeds (B,1,D); `pos` is the
    global position being written. Returns (logits (B,V), new_caches)."""
    x = _embed(params, cfg, inputs)
    segs = plan_segments(cfg)
    new_caches = []
    for seg, seg_params, seg_cache in zip(segs, params["segments"], caches):
        def scan_body(x, xs, _seg=seg):
            layer_params, layer_cache = xs
            new_cache = {}
            for i, kind in enumerate(_seg.pattern):
                x, new_cache[f"pos{i}"] = _apply_block_decode(
                    kind, layer_params[f"pos{i}"], x, layer_cache[f"pos{i}"],
                    pos, cfg)
            return x, new_cache

        x, seg_new = jax.lax.scan(scan_body, x, (seg_params, seg_cache))
        new_caches.append(seg_new)
    logits = _unembed(params, cfg, x)
    return logits[:, 0], new_caches
