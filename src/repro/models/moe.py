"""Mixture-of-Experts layer: shared + routed experts, top-k gating with
capacity-bounded dispatch/combine (einsum formulation — maps onto TPU as
all-to-all-friendly matmuls under expert sharding).

Covers both assigned MoE archs:
  * deepseek-moe-16b — 64 fine-grained routed experts (top-6) + 2 shared;
    experts sharded over the "model" axis (EP), 4 experts/device on a 16-way axis.
  * grok-1-314b — 8 routed experts (top-2), no shared; experts replicated over
    the expert dim but tensor-parallel *within* each expert (d_expert sharded),
    since 8 experts cannot split a 16-way axis.
The sharding choice lives in distributed/sharding.py keyed on divisibility.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .config import MoEConfig
from .layers import activation


def router_capacity(n_tokens: int, cfg: MoEConfig) -> int:
    cap = int(math.ceil(cfg.top_k * n_tokens * cfg.capacity_factor / cfg.n_experts))
    return max(cap, 1)


def top_k_routing(logits, cfg: MoEConfig):
    """logits: (T, E) fp32. Returns (dispatch (T,E,C) bool-ish float,
    combine (T,E,C) float, aux_loss scalar). Deterministic, capacity-bounded;
    overflow tokens are dropped (standard Switch/GShard semantics)."""
    T, E = logits.shape
    C = router_capacity(T, cfg)
    probs = jax.nn.softmax(logits, axis=-1)
    topk_p, topk_idx = jax.lax.top_k(probs, cfg.top_k)          # (T,K)
    # renormalize the selected gates (DeepSeek-MoE style)
    topk_p = topk_p / jnp.clip(jnp.sum(topk_p, -1, keepdims=True), 1e-9)

    # expert one-hots per (token, k): (T,K,E)
    onehot = jax.nn.one_hot(topk_idx, E, dtype=jnp.float32)
    # position of each (t,k) in its expert's queue, priority by token order,
    # k-major within token (standard GShard ordering)
    flat = onehot.reshape(T * cfg.top_k, E)
    pos_in_expert = (jnp.cumsum(flat, axis=0) - flat)           # (T*K, E)
    pos = jnp.sum(pos_in_expert * flat, axis=-1).reshape(T, cfg.top_k)
    keep = pos < C
    gate = topk_p * keep                                        # (T,K)

    slot = jax.nn.one_hot(jnp.where(keep, pos, C).astype(jnp.int32), C + 1,
                          dtype=jnp.float32)[..., :C]           # (T,K,C)
    # (T,E,C) = sum_k onehot[t,k,e] * slot[t,k,c]
    dispatch = jnp.einsum("tke,tkc->tec", onehot * keep[..., None], slot)
    combine = jnp.einsum("tke,tkc->tec", (onehot * gate[..., None]), slot)

    # load-balancing auxiliary loss (Switch): E * sum_e f_e * p_e
    f = jnp.mean(jnp.sum(onehot, axis=1), axis=0)               # fraction routed
    p = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(f * p)
    return dispatch, combine, aux


def expert_ffn(xe, w, *, act: str, gated: bool):
    """xe: (E, C, D); w leaves shaped (E, D, F)/(E, F, D)."""
    up = jnp.einsum("ecd,edf->ecf", xe, w["up"])
    if gated:
        g = jnp.einsum("ecd,edf->ecf", xe, w["gate"])
        h = activation(g, act) * up
    else:
        h = activation(up, act)
    return jnp.einsum("ecf,efd->ecd", h, w["down"])


def moe_block_dense(x, w, cfg: MoEConfig, *, act: str, gated: bool):
    """Exact (dropless) MoE for decode: every expert evaluated on every token,
    combined with the (renormalized) top-k gates. For the small token counts of
    a decode step this is roofline-equivalent to routed dispatch — the cost is
    reading all expert weights either way — and it makes incremental decode
    bit-consistent regardless of load imbalance (no capacity drops)."""
    B, S, D = x.shape
    xt = x.reshape(B * S, D)
    logits = xt.astype(jnp.float32) @ w["router"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    topk_p, topk_idx = jax.lax.top_k(probs, cfg.top_k)
    topk_p = topk_p / jnp.clip(jnp.sum(topk_p, -1, keepdims=True), 1e-9)
    gates = jnp.sum(jax.nn.one_hot(topk_idx, cfg.n_experts, dtype=jnp.float32)
                    * topk_p[..., None], axis=1)                 # (T,E)
    up = jnp.einsum("td,edf->tef", xt, w["experts"]["up"])
    if gated:
        g = jnp.einsum("td,edf->tef", xt, w["experts"]["gate"])
        h = activation(g, act) * up
    else:
        h = activation(up, act)
    ye = jnp.einsum("tef,efd->ted", h, w["experts"]["down"])
    y = jnp.einsum("te,ted->td", gates, ye.astype(jnp.float32))
    if "shared" in w:
        sup = xt @ w["shared"]["up"]
        sh = activation(xt @ w["shared"]["gate"], act) * sup if gated \
            else activation(sup, act)
        y = y + (sh @ w["shared"]["down"]).astype(jnp.float32)
    return y.astype(x.dtype).reshape(B, S, D)


import os

GROUP_TOKENS_TARGET = int(os.environ.get("REPRO_MOE_GROUP_TOKENS", "4096"))


def _n_groups(total_tokens: int) -> int:
    """GShard local groups: tokens are routed within device-aligned groups so
    the dispatch tensor is (G, T/G, E, C_g) with per-group capacity — without
    grouping, C grows with the GLOBAL token count and the one-hot dispatch
    tensor explodes (measured: ~600 GiB/device for deepseek train_4k).

    The one-hot dispatch is O(T_g²) per group, so groups also target a fixed
    token count (~4096); G stays a multiple of the data-parallel degree so
    groups never straddle device shards (measured 8-30× FLOP inflation when
    they do)."""
    from ..distributed.sharding import current_rules
    r = current_rules()
    g = r.dp_size if r is not None else 1
    while total_tokens % g != 0 or total_tokens // g < 1:
        g //= 2
    g = max(g, 1)
    while (total_tokens // g > GROUP_TOKENS_TARGET
           and total_tokens % (g * 2) == 0):
        g *= 2
    return g


def moe_block(x, w, cfg: MoEConfig, *, act: str, gated: bool,
              n_groups: int | None = None):
    """x: (B,S,D). w: {"router": (D,E), "experts": {...}, ["shared": {...}]}.
    Returns (y (B,S,D), aux_loss). Routing/dispatch are per local group."""
    from ..distributed.sharding import shard_act
    B, S, D = x.shape
    T = B * S
    G = n_groups or _n_groups(T)
    xg = x.reshape(G, T // G, D)            # group-major == batch-major: the
    xg = shard_act(xg, "moe_groups")        # groups stay data-sharded
    logits = jnp.einsum("gtd,de->gte", xg.astype(jnp.float32),
                        w["router"].astype(jnp.float32))
    dispatch, combine, aux = jax.vmap(lambda l: top_k_routing(l, cfg))(logits)
    # dispatch tokens to per-group expert buffers: (G,E,C,D). The dispatch
    # mask is 0/1 — exact in bf16; running these einsums in the compute dtype
    # halves the largest MoE tensors' HBM traffic (combine keeps fp32 gates).
    xe = jnp.einsum("gtec,gtd->gecd", dispatch.astype(x.dtype), xg)
    xe = shard_act(xe, "moe_experts")
    up = jnp.einsum("gecd,edf->gecf", xe, w["experts"]["up"])
    if gated:
        gt = jnp.einsum("gecd,edf->gecf", xe, w["experts"]["gate"])
        h = activation(gt, act) * up
    else:
        h = activation(up, act)
    ye = jnp.einsum("gecf,efd->gecd", h, w["experts"]["down"])
    ye = shard_act(ye, "moe_experts")
    y = jnp.einsum("gtec,gecd->gtd", combine, ye.astype(jnp.float32))
    if "shared" in w:
        xt = x.reshape(T, D)
        sup = xt @ w["shared"]["up"]
        if gated:
            sh = activation(xt @ w["shared"]["gate"], act) * sup
        else:
            sh = activation(sup, act)
        y = y.reshape(T, D) + (sh @ w["shared"]["down"]).astype(jnp.float32)
    return y.astype(x.dtype).reshape(B, S, D), jnp.mean(aux)
