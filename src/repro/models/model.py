"""Model facade: shape templates (`input_specs`, `param_specs`, `cache_specs`)
used by the dry-run (ShapeDtypeStruct stand-ins, no allocation) and by
checkpoint restore templates."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from . import transformer as T


def input_specs(cfg: ModelConfig, *, kind: str, seq_len: int, batch: int) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of a step function.

    kind: "train" (tokens+labels), "prefill" (tokens), "decode" (one token +
    cache position). [audio]/[vlm] archs take precomputed frontend embeddings.
    """
    emb = jnp.dtype(cfg.dtype)
    if cfg.embed_inputs:
        def tok(b, s):
            return jax.ShapeDtypeStruct((b, s), jnp.int32)
    else:
        def tok(b, s):
            return jax.ShapeDtypeStruct((b, s, cfg.d_model), emb)
    if kind == "train":
        return {"inputs": tok(batch, seq_len),
                "labels": jax.ShapeDtypeStruct((batch, seq_len), jnp.int32)}
    if kind == "prefill":
        return {"inputs": tok(batch, seq_len)}
    if kind == "decode":
        return {"inputs": tok(batch, 1),
                "pos": jax.ShapeDtypeStruct((), jnp.int32)}
    raise ValueError(kind)


def param_specs(cfg: ModelConfig):
    """Parameter ShapeDtypeStructs without allocating (eval_shape over init)."""
    return jax.eval_shape(lambda: T.init_params(cfg, jax.random.key(0)))


def cache_specs(cfg: ModelConfig, batch: int, max_len: int):
    return jax.eval_shape(lambda: T.init_cache(cfg, batch, max_len))
