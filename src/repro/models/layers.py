"""Core neural layers: RMSNorm, RoPE, gated MLPs, and GQA attention with
full / sliding-window masking, chunked (flash-style) prefill, banded local
prefill, and single-token decode against KV or ring-buffer caches.

All softmax/normalization math accumulates in fp32 regardless of param dtype.
The XLA paths here are also the `ref` semantics the Pallas kernels must match.
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

NEG_INF = -2.0 ** 30  # large-but-finite; avoids NaNs from (-inf) - (-inf)


# ---------------------------------------------------------------------------
# norms / activations / mlp
# ---------------------------------------------------------------------------

def rms_norm(x, weight, *, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + weight.astype(jnp.float32))).astype(x.dtype)


def activation(x, kind: str):
    if kind == "silu":
        return jax.nn.silu(x)
    if kind == "gelu":
        return jax.nn.gelu(x)
    if kind == "relu":
        return jax.nn.relu(x)
    if kind == "relu2":
        r = jax.nn.relu(x)
        return r * r
    raise ValueError(f"unknown activation {kind!r}")


def mlp(x, w, *, act: str, gated: bool):
    """w: {"up": (D,F), "down": (F,D)[, "gate": (D,F)]}; x: (..., D)."""
    up = x @ w["up"]
    h = activation(x @ w["gate"], act) * up if gated else activation(up, act)
    return h @ w["down"]


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope(x, positions, *, theta: float):
    """x: (..., S, H, hd) rotated by `positions` (broadcastable to (..., S))."""
    hd = x.shape[-1]
    half = hd // 2
    freq = jnp.exp(-math.log(theta) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[..., :, None].astype(jnp.float32) * freq  # (..., S, half)
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    sin = sin[..., :, None, :]  # (..., S, 1, half) broadcast over heads
    cos = cos[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# attention cores
# ---------------------------------------------------------------------------

def _gqa_scores(q, k):
    """q: (B,C,KV,G,hd), k: (B,S,KV,hd) -> (B,KV,G,C,S) fp32."""
    return jnp.einsum("bckgh,bskh->bkgcs", q, k,
                      preferred_element_type=jnp.float32)


def _gqa_out(p, v):
    """p: (B,KV,G,C,S) fp32, v: (B,S,KV,hd) -> (B,C,KV,G,hd)."""
    return jnp.einsum("bkgcs,bskh->bckgh", p, v.astype(jnp.float32))


def _mask_bias(qpos, kpos, *, window: int, kv_valid_len=None):
    """(C,S) additive bias: causal + optional sliding window + cache validity."""
    m = kpos[None, :] <= qpos[:, None]
    if window:
        m &= kpos[None, :] > qpos[:, None] - window
    if kv_valid_len is not None:
        m &= kpos[None, :] < kv_valid_len
    return jnp.where(m, 0.0, NEG_INF).astype(jnp.float32)


def attention(q, k, v, *, q_offset=0, window: int = 0, q_chunk: int = 1024,
              kv_valid_len=None, scale: float | None = None):
    """Causal (optionally sliding-window) GQA attention.

    q: (B,Sq,H,hd); k,v: (B,Skv,KV,hd). H % KV == 0. `q_offset` is the global
    position of q[0] (prefill continuation / decode). Memory is bounded by
    chunking queries (flash-attention access pattern at the XLA level); for
    window layers the kv range per chunk is additionally sliced to the band,
    so local-attention prefill does O(S·window) work, not O(S²).
    """
    B, Sq, H, hd = q.shape
    _, Skv, KV, _ = k.shape
    G = H // KV
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    qg = (q * scale).reshape(B, Sq, KV, G, hd)

    if Sq == 1:  # decode fast path
        qpos = jnp.asarray([q_offset])
        bias = _mask_bias(qpos, jnp.arange(Skv), window=window,
                          kv_valid_len=kv_valid_len)
        s = _gqa_scores(qg, k) + bias
        p = jax.nn.softmax(s, axis=-1)
        return _gqa_out(p, v).reshape(B, Sq, H, hd).astype(q.dtype)

    q_chunk = min(q_chunk, Sq)
    if Sq % q_chunk != 0:
        q_chunk = math.gcd(Sq, q_chunk) or Sq
    n_chunks = Sq // q_chunk
    qs = qg.reshape(B, n_chunks, q_chunk, KV, G, hd).transpose(1, 0, 2, 3, 4, 5)
    starts = jnp.arange(n_chunks) * q_chunk

    banded = bool(window) and Skv > 2 * (window + q_chunk)
    if banded:
        band = window + q_chunk  # kv slice covering the chunk's reachable keys
        band = min(band, Skv)

    def body(_, xs):
        qc, start = xs
        qpos = q_offset + start + jnp.arange(q_chunk)
        if banded:
            lo = jnp.clip(start + q_offset - window + 1, 0, Skv - band)
            kc = jax.lax.dynamic_slice_in_dim(k, lo, band, axis=1)
            vc = jax.lax.dynamic_slice_in_dim(v, lo, band, axis=1)
            kpos = lo + jnp.arange(band)
        else:
            kc, vc, kpos = k, v, jnp.arange(Skv)
        bias = _mask_bias(qpos, kpos, window=window, kv_valid_len=kv_valid_len)
        s = _gqa_scores(qc, kc) + bias
        p = jax.nn.softmax(s, axis=-1)
        return None, _gqa_out(p, vc)

    _, out = jax.lax.scan(body, None, (qs, starts))
    out = out.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sq, H, hd)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# KV caches: full and ring-buffer (sliding window)
# ---------------------------------------------------------------------------

def init_kv_cache(batch, max_len, n_kv, hd, dtype):
    return {
        "k": jnp.zeros((batch, max_len, n_kv, hd), dtype),
        "v": jnp.zeros((batch, max_len, n_kv, hd), dtype),
    }


def cache_update_full(cache, k_new, v_new, pos):
    """Insert (B,S_new,KV,hd) at position `pos` (scalar)."""
    k = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new, pos, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new, pos, axis=1)
    return {"k": k, "v": v}


def ring_positions(pos, window):
    """Global position held by each ring slot when the newest token is at
    global position `pos`: slot i holds pos_i = pos - ((pos - i) mod window)."""
    i = jnp.arange(window)
    return pos - jnp.mod(pos - i, window)


def cache_update_ring(cache, k_new, v_new, pos):
    """Decode-time single-token ring insert at slot pos % window."""
    window = cache["k"].shape[1]
    slot = jnp.mod(pos, window)
    k = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new, slot, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new, slot, axis=1)
    return {"k": k, "v": v}


def ring_fill_from_prefill(k_full, v_full, window):
    """After prefilling S tokens, load the trailing `window` of them into ring
    slots (slot of global position p is p % window). Handles S < window by
    leaving future slots zeroed (masked out via ring_positions validity)."""
    B, S, KV, hd = k_full.shape
    if S < window:
        pad = window - S
        k = jnp.pad(k_full, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v_full, ((0, 0), (0, pad), (0, 0), (0, 0)))
        return {"k": k, "v": v}
    base = S - window
    perm = base + jnp.mod(jnp.arange(window) - base, window)
    return {"k": jnp.take(k_full, perm, axis=1), "v": jnp.take(v_full, perm, axis=1)}


def decode_attention_ring(q, cache, pos, *, window, scale=None):
    """Single-token attention against a ring-buffer cache."""
    B, _, H, hd = q.shape
    KV = cache["k"].shape[2]
    G = H // KV
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    kpos = ring_positions(pos, window)
    valid = (kpos >= 0) & (kpos > pos - window) & (kpos <= pos)
    bias = jnp.where(valid, 0.0, NEG_INF).astype(jnp.float32)
    qg = (q * scale).reshape(B, 1, KV, G, hd)
    s = _gqa_scores(qg, cache["k"]) + bias[None, :]
    p = jax.nn.softmax(s, axis=-1)
    return _gqa_out(p, cache["v"]).reshape(B, 1, H, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# depthwise causal conv (mamba / rglru)
# ---------------------------------------------------------------------------

def causal_conv1d(x, w):
    """x: (B,S,C), w: (C,K) depthwise causal conv, no bias."""
    K = w.shape[-1]
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(K):  # K is 4: unrolled shifted adds beat conv lowering
        shift = K - 1 - i
        xi = jnp.pad(x, ((0, 0), (shift, 0), (0, 0)))[:, :x.shape[1]]
        out = out + xi.astype(jnp.float32) * w[:, i].astype(jnp.float32)
    return out.astype(x.dtype)


def conv_state_update(state, x_new, w):
    """Streaming conv: state (B,K-1,C) holds the last K-1 inputs.
    x_new: (B,1,C). Returns (y (B,1,C), new_state)."""
    K = w.shape[-1]
    window = jnp.concatenate([state, x_new], axis=1)          # (B,K,C)
    y = jnp.einsum("bkc,ck->bc", window.astype(jnp.float32),
                   w.astype(jnp.float32))[:, None]
    return y.astype(x_new.dtype), window[:, 1:]
