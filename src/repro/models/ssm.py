"""Mamba-1 block (falcon-mamba-7b): gated selective state-space model.

Sequence path (train / prefill) uses an associative scan over the diagonal
recurrence h_t = A_t ⊙ h_{t-1} + B_t x_t — log-depth on TPU, and the semantics
the Pallas ssm_scan kernel reproduces with a chunked carried-state layout.
Decode keeps O(1) state: (conv window, ssm state), the property that makes the
arch eligible for the long_500k shape.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import SSMConfig
from .layers import causal_conv1d, conv_state_update


def _scan_op(l, r):
    a1, b1 = l
    a2, b2 = r
    return a2 * a1, a2 * b1 + b2


def selective_scan(u, delta, A, B, C, D, *, chunk: int = 128):
    """u: (B,S,DI); delta: (B,S,DI); A: (DI,N); B,C: (B,S,N); D: (DI,).
    Returns (y (B,S,DI), h_last (B,DI,N)). fp32 internally.

    Chunked: a sequential lax.scan over S/chunk chunks carries the (B,DI,N)
    state; the log-depth associative scan runs within each chunk. The naive
    whole-sequence associative scan materializes the (B,S,DI,N) recurrence
    tensor — ~120 GiB/device for falcon-mamba train_4k. Mirrors the Pallas
    ssm_scan kernel's carried-state layout.
    """
    Bb, S, DI = u.shape
    N = A.shape[1]
    c = min(chunk, S)
    while S % c != 0:
        c -= 1
    n = S // c

    def one_chunk(h0, xs):
        u_c, d_c, B_c, C_c = xs                         # (B, c, ...)
        dA = jnp.exp(d_c[..., None] * A[None, None])    # (B,c,DI,N)
        dBu = (d_c * u_c)[..., None] * B_c[:, :, None, :]
        acum, bcum = jax.lax.associative_scan(_scan_op, (dA, dBu), axis=1)
        hs = acum * h0[:, None] + bcum                  # (B,c,DI,N)
        y = jnp.einsum("bsdn,bsn->bsd", hs, C_c)
        return hs[:, -1], y

    u32, d32 = u.astype(jnp.float32), delta.astype(jnp.float32)
    B32, C32 = B.astype(jnp.float32), C.astype(jnp.float32)
    if n == 1:
        h_last, y = one_chunk(jnp.zeros((Bb, DI, N), jnp.float32),
                              (u32, d32, B32, C32))
    else:
        def to_chunks(x):
            return x.reshape(Bb, n, c, *x.shape[2:]).transpose(1, 0, 2, *range(3, x.ndim + 1))
        xs = tuple(to_chunks(x) for x in (u32, d32, B32, C32))
        h_last, ys = jax.lax.scan(one_chunk, jnp.zeros((Bb, DI, N), jnp.float32), xs)
        y = ys.transpose(1, 0, 2, 3).reshape(Bb, S, DI)
    y = (y + u32 * D[None, None]).astype(u.dtype)
    return y, h_last


def selective_scan_step(state, u_t, delta_t, A, B_t, C_t, D):
    """One recurrence step. state: (B,DI,N); u_t,delta_t: (B,DI);
    B_t,C_t: (B,N). Returns (y_t (B,DI), new_state)."""
    d32 = delta_t.astype(jnp.float32)
    dA = jnp.exp(d32[..., None] * A[None])                        # (B,DI,N)
    dBu = d32[..., None] * B_t[:, None, :].astype(jnp.float32) * \
        u_t.astype(jnp.float32)[..., None]
    new_state = dA * state + dBu
    y = jnp.einsum("bdn,bn->bd", new_state, C_t.astype(jnp.float32))
    return (y + u_t.astype(jnp.float32) * D[None]).astype(u_t.dtype), new_state


def _project(x, w, cfg: SSMConfig, d_model: int):
    """Shared input projections. x: (B,S,D) -> (u, gate, delta, B, C)."""
    d_inner = cfg.expand * d_model
    dt_rank = cfg.resolved_dt_rank(d_model)
    ug = x @ w["in_proj"]                                         # (B,S,2*DI)
    u, gate = jnp.split(ug, 2, axis=-1)
    u = causal_conv1d(u, w["conv"]) if x.shape[1] > 1 else u      # seq path conv
    u = jax.nn.silu(u)
    xdbc = u @ w["x_proj"]                                        # (B,S,dt+2N)
    dt, Bm, Cm = jnp.split(xdbc, [dt_rank, dt_rank + cfg.d_state], axis=-1)
    delta = jax.nn.softplus(dt @ w["dt_proj"] + w["dt_bias"])     # (B,S,DI)
    return u, gate, delta, Bm, Cm


def mamba_block(x, w, cfg: SSMConfig):
    """Full-sequence mamba block. x: (B,S,D) -> (B,S,D)."""
    A = -jnp.exp(w["A_log"].astype(jnp.float32))                  # (DI,N)
    u, gate, delta, Bm, Cm = _project(x, w, cfg, x.shape[-1])
    y, _ = selective_scan(u, delta, A, Bm, Cm, w["D"])
    y = y * jax.nn.silu(gate)
    return y @ w["out_proj"]


def mamba_init_state(batch, d_model, cfg: SSMConfig, dtype):
    d_inner = cfg.expand * d_model
    return {
        "conv": jnp.zeros((batch, cfg.d_conv - 1, d_inner), dtype),
        "ssm": jnp.zeros((batch, d_inner, cfg.d_state), jnp.float32),
    }


def mamba_step(x_t, state, w, cfg: SSMConfig):
    """Streaming decode. x_t: (B,1,D). Returns (y (B,1,D), new_state)."""
    A = -jnp.exp(w["A_log"].astype(jnp.float32))
    ug = x_t @ w["in_proj"]
    u, gate = jnp.split(ug, 2, axis=-1)                           # (B,1,DI)
    u_conv, conv_state = conv_state_update(state["conv"], u, w["conv"])
    u_act = jax.nn.silu(u_conv)[:, 0]                             # (B,DI)
    dt_rank = cfg.resolved_dt_rank(x_t.shape[-1])
    xdbc = u_act @ w["x_proj"]
    dt, Bm, Cm = jnp.split(xdbc, [dt_rank, dt_rank + cfg.d_state], axis=-1)
    delta = jax.nn.softplus(dt @ w["dt_proj"] + w["dt_bias"])     # (B,DI)
    y, ssm_state = selective_scan_step(state["ssm"], u_act, delta, A, Bm, Cm, w["D"])
    y = y[:, None] * jax.nn.silu(gate)
    return y @ w["out_proj"], {"conv": conv_state, "ssm": ssm_state}
