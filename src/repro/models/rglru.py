"""RG-LRU recurrent block (RecurrentGemma / Griffin).

Recurrence (per channel):
    r_t = sigmoid(W_r x_t + b_r)           (recurrence gate, block-diag W)
    i_t = sigmoid(W_i x_t + b_i)           (input gate, block-diag W)
    a_t = exp(-c * softplus(Λ) * r_t)      (c = 8.0)
    h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t²) ⊙ (i_t ⊙ x_t)

The full recurrent block is: x -> [linear -> conv1d -> RG-LRU] ⊙ gelu(linear)
-> linear, mirroring Griffin's temporal-mixing block. Sequence path uses an
associative scan; decode carries (conv window, h) — O(1) state.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import RGLRUConfig
from .layers import causal_conv1d, conv_state_update

_C = 8.0


def _block_diag_linear(x, w, b):
    """x: (...,W); w: (nb, W/nb, W/nb); b: (W,)."""
    nb, bs, _ = w.shape
    xs = x.reshape(*x.shape[:-1], nb, bs)
    y = jnp.einsum("...nb,nbc->...nc", xs, w)
    return y.reshape(*x.shape[:-1], nb * bs) + b


def _gates(x, w):
    """Returns (a_t, gated_input) for the recurrence, fp32."""
    xf = x.astype(jnp.float32)
    r = jax.nn.sigmoid(_block_diag_linear(xf, w["w_r"].astype(jnp.float32),
                                          w["b_r"].astype(jnp.float32)))
    i = jax.nn.sigmoid(_block_diag_linear(xf, w["w_i"].astype(jnp.float32),
                                          w["b_i"].astype(jnp.float32)))
    log_a = -_C * jax.nn.softplus(w["lam"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.clip(1.0 - jnp.exp(2.0 * log_a), 0.0, 1.0))
    return a, beta * (i * xf)


def rg_lru(x, w, h0=None):
    """Sequence RG-LRU. x: (B,S,W) -> (y (B,S,W), h_last (B,W))."""
    a, bx = _gates(x, w)

    def op(l, r):
        a1, b1 = l
        a2, b2 = r
        return a1 * a2, a2 * b1 + b2

    if h0 is not None:
        bx = bx.at[:, 0].add(a[:, 0] * h0.astype(jnp.float32))
    _, h = jax.lax.associative_scan(op, (a, bx), axis=1)
    return h.astype(x.dtype), h[:, -1]


def rg_lru_step(x_t, h, w):
    """x_t: (B,W); h: (B,W) fp32. Returns (y (B,W), h_new)."""
    a, bx = _gates(x_t, w)
    h_new = a * h + bx
    return h_new.astype(x_t.dtype), h_new


def recurrent_block(x, w, cfg: RGLRUConfig):
    """Griffin temporal-mixing block, sequence path. x: (B,S,D) -> (B,S,D)."""
    branch = x @ w["in_x"]                                   # (B,S,W)
    branch = causal_conv1d(branch, w["conv"])
    y, _ = rg_lru(branch, w["lru"])
    gate = jax.nn.gelu(x @ w["in_gate"])
    return (y * gate) @ w["out"]


def recurrent_init_state(batch, width, cfg: RGLRUConfig, dtype):
    return {
        "conv": jnp.zeros((batch, cfg.d_conv - 1, width), dtype),
        "h": jnp.zeros((batch, width), jnp.float32),
    }


def recurrent_step(x_t, state, w, cfg: RGLRUConfig):
    """x_t: (B,1,D). Returns (y (B,1,D), new_state)."""
    branch = x_t @ w["in_x"]                                 # (B,1,W)
    branch, conv_state = conv_state_update(state["conv"], branch, w["conv"])
    y, h = rg_lru_step(branch[:, 0], state["h"], w["lru"])
    gate = jax.nn.gelu(x_t @ w["in_gate"])
    out = (y[:, None] * gate) @ w["out"]
    return out, {"conv": conv_state, "h": h}
