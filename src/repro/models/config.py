"""Model configuration dataclasses for the assigned architecture pool."""

from __future__ import annotations

from dataclasses import dataclass, field, replace


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int                 # routed experts
    top_k: int
    d_expert: int                  # per-expert ffn hidden dim
    n_shared: int = 0              # always-on shared experts (same d_expert)
    capacity_factor: float = 1.25
    dense_prelude_layers: int = 0  # leading dense layers (DeepSeek-MoE layer 0)
    d_ff_prelude: int = 0
    router_jitter: float = 0.0
    aux_loss_weight: float = 0.01


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0               # 0 -> d_model // 16

    def resolved_dt_rank(self, d_model: int) -> int:
        return self.dt_rank or max(d_model // 16, 1)


@dataclass(frozen=True)
class RGLRUConfig:
    lru_width: int = 0             # 0 -> d_model
    d_conv: int = 4
    n_blocks: int = 0              # block-diagonal gate blocks (0 -> n_heads)


@dataclass(frozen=True)
class ModelConfig:
    """One architecture. `block_pattern` is cycled to n_layers; entries are
    "global" (full causal attn), "local" (sliding window), "mamba", "rglru"."""

    name: str
    family: str                    # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    block_pattern: tuple[str, ...] = ("global",)
    window: int = 0
    moe: MoEConfig | None = None
    ssm: SSMConfig | None = None
    rglru: RGLRUConfig | None = None
    rope_theta: float = 10_000.0
    rope_theta_global: float = 0.0  # per-layer theta for "global" layers (gemma3); 0 -> rope_theta
    norm_eps: float = 1e-6
    act: str = "silu"              # silu | gelu | relu | relu2
    mlp_gated: bool = True
    embed_inputs: bool = True      # False: modality frontend stub provides embeddings
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    notes: str = ""

    # -- derived ---------------------------------------------------------------

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    def layer_kinds(self) -> tuple[str, ...]:
        pat = self.block_pattern
        return tuple(pat[i % len(pat)] for i in range(self.n_layers))

    @property
    def is_attention_free(self) -> bool:
        return all(k == "mamba" for k in self.layer_kinds())

    @property
    def has_full_attention(self) -> bool:
        return any(k == "global" for k in self.layer_kinds())

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic archs eligible for the long_500k shape: SSM, hybrid
        recurrent, and local-attention-dominated (gemma3's 5:1 local:global —
        its decode cost is O(window) on 5/6 of layers)."""
        kinds = self.layer_kinds()
        n_full = sum(k == "global" for k in kinds)
        return n_full == 0 or (self.window > 0 and n_full / len(kinds) <= 0.25)

    def scaled(self, **overrides) -> "ModelConfig":
        """Reduced config of the same family (smoke tests)."""
        return replace(self, **overrides)

    def param_count(self) -> int:
        """Analytic parameter count (embedding included once if tied)."""
        D, F, V = self.d_model, self.d_ff, self.vocab_size
        n_mlp_mats = 3 if self.mlp_gated else 2
        total = 0
        for kind in self.layer_kinds():
            if kind in ("global", "local"):
                attn = D * self.q_dim + 2 * D * self.kv_dim + self.q_dim * D
                total += attn + 2 * D  # norms
                if self.moe is not None:
                    m = self.moe
                    total += D * m.n_experts
                    total += (m.n_experts + m.n_shared) * n_mlp_mats * D * m.d_expert
                else:
                    total += n_mlp_mats * D * F
            elif kind == "mamba":
                s = self.ssm or SSMConfig()
                di = s.expand * D
                dt = s.resolved_dt_rank(D)
                total += D * 2 * di + di * s.d_conv + di * (dt + 2 * s.d_state)
                total += dt * di + di * s.d_state + di + di * D + D
            elif kind == "rglru":
                r = self.rglru or RGLRUConfig()
                W = r.lru_width or D
                nb = r.n_blocks or self.n_heads
                total += 2 * D * W + W * r.d_conv + W * D + 2 * D
                total += 2 * nb * (W // nb) * (W // nb) + 3 * W  # gates + lambda + biases
                total += n_mlp_mats * D * F + D if F else 0
        total += D  # final norm
        if self.embed_inputs:
            total += V * D
        total += 0 if self.tie_embeddings and self.embed_inputs else V * D  # lm head
        return total
