from .config import ModelConfig, MoEConfig, RGLRUConfig, SSMConfig
from .transformer import (decode_step, forward, init_cache, init_params,
                          plan_segments, prefill)

__all__ = [
    "ModelConfig", "MoEConfig", "RGLRUConfig", "SSMConfig", "decode_step",
    "forward", "init_cache", "init_params", "plan_segments", "prefill",
]
