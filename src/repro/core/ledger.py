"""TimeModel + TimeLedger — one place where modeled durations meet the clock.

The coordinator and trainer both need to charge virtual time: checkpoint
extract/write/read costs, per-step compute. Before this module each charged
the clock ad hoc (``isinstance(clock, VirtualClock)`` checks sprinkled through
coordinator and trainer); the ledger centralizes the rule and keeps an audit
trail of what was charged per category, which the fleet coordinator uses to
attribute time across members sharing one clock.

Wall-clock mode: charges are no-ops — durations are physical, the clock moves
by itself. Virtual mode: ``charge`` advances the VirtualClock and records the
amount under its category.
"""

from __future__ import annotations

from dataclasses import dataclass, field

from .clock import Clock, VirtualClock


@dataclass(frozen=True)
class TimeModel:
    """Virtual-time cost of checkpoint operations, by bytes moved."""

    extract_bw: float = 10e9     # device->host snapshot bandwidth
    write_bw: float = 0.5e9      # shared-NFS write bandwidth
    read_bw: float = 1.0e9       # shared-NFS read bandwidth
    latency_s: float = 2.0       # per-op fixed cost (mount, metadata, commit)

    def extract_s(self, nbytes: int) -> float:
        return nbytes / self.extract_bw

    def write_s(self, nbytes: int) -> float:
        return self.latency_s + nbytes / self.write_bw

    def read_s(self, nbytes: int) -> float:
        return self.latency_s + nbytes / self.read_bw


@dataclass
class TimeLedger:
    """Charges modeled durations to a clock and accounts them by category.

    Besides *charges* (which advance a VirtualClock), the ledger keeps
    *observations*: measured windows — MTTR, the eviction→first-step-back
    span — whose time already elapsed on the clock and must not be charged
    again, but which belong in the same audit trail.
    """

    clock: Clock
    time_model: TimeModel | None = None
    charged: dict[str, float] = field(default_factory=dict)
    observed: dict[str, list[float]] = field(default_factory=dict)
    counted: dict[str, int] = field(default_factory=dict)

    @property
    def virtual(self) -> bool:
        return isinstance(self.clock, VirtualClock)

    # -- modeled costs (0.0 when no model is configured) ----------------------

    def extract_s(self, nbytes: int) -> float:
        return self.time_model.extract_s(nbytes) if self.time_model else 0.0

    def write_s(self, nbytes: int) -> float:
        return self.time_model.write_s(nbytes) if self.time_model else 0.0

    def read_s(self, nbytes: int) -> float:
        return self.time_model.read_s(nbytes) if self.time_model else 0.0

    # -- charging -------------------------------------------------------------

    def charge(self, seconds: float, *, category: str = "ckpt") -> float:
        """Advance a VirtualClock by a modeled duration; no-op on wall clocks
        or when no TimeModel is configured (physics charges those)."""
        if seconds <= 0.0 or self.time_model is None or not self.virtual:
            return 0.0
        self.clock.advance(seconds)
        self.charged[category] = self.charged.get(category, 0.0) + seconds
        return seconds

    def charge_measured(self, seconds: float, *, category: str) -> float:
        """Advance a VirtualClock by a *measured* wall duration.

        For work that physically executes even under a virtual clock —
        the restore decode really reads the disk and really contends with
        real writer threads. Charging the measured wall time instead of a
        byte-count model makes virtual-mode samples (MTTR above all)
        wall-clock-coupled: two restores that ran at different speeds land
        at different clock readings instead of collapsing onto the model's
        constant. Needs no TimeModel; no-op on wall clocks (the duration
        already elapsed there)."""
        if seconds <= 0.0 or not self.virtual:
            return 0.0
        self.clock.advance(seconds)
        self.charged[category] = self.charged.get(category, 0.0) + seconds
        return seconds

    def charge_step(self, step_time_s: float | None) -> float:
        """Charge one training step's modeled duration (virtual mode only).
        Unlike ``charge`` this needs no TimeModel — step cost is given."""
        if step_time_s is None or not self.virtual:
            return 0.0
        self.clock.advance(step_time_s)
        self.charged["step"] = self.charged.get("step", 0.0) + step_time_s
        return step_time_s

    # -- observations ---------------------------------------------------------

    def observe(self, category: str, seconds: float) -> None:
        """Record a measured window (e.g. one MTTR sample) without moving
        the clock — the duration already elapsed; charging it again would
        double-count it."""
        self.observed.setdefault(category, []).append(seconds)

    def observed_total(self, category: str) -> float:
        return sum(self.observed.get(category, ()))

    # -- counters -------------------------------------------------------------

    def count(self, category: str, amount: int) -> None:
        """Accumulate a unitless quantity (bytes moved, bytes skipped) in the
        audit trail. Counters never touch the clock — they exist so the
        save path's device→host traffic (``d2h_bytes`` vs
        ``d2h_bytes_skipped``) is visible in the same ledger that accounts
        its time."""
        self.counted[category] = self.counted.get(category, 0) + int(amount)

    def counted_total(self, category: str) -> int:
        return self.counted.get(category, 0)

    def total(self, category: str | None = None) -> float:
        if category is not None:
            return self.charged.get(category, 0.0)
        return sum(self.charged.values())
