"""The paper's primary contribution: the Spot-on checkpoint coordinator,
spot-instance simulation, multi-cloud provider backends, pricing, and elastic
restore. See DESIGN.md §1–2."""

from .clock import Clock, VirtualClock, WallClock
from .coordinator import (CoordinatorStats, Signal, SpotOnCoordinator,
                          StragglerDetector)
from .cost import (AWS_M5_2XLARGE, AZURE_D8S_V3, GCP_N2_STANDARD_8,
                   TPU_V5E_CHIP, CostAccountant, PriceSheet)
from .events import (DEFAULT_NOTICE_S, PREEMPT, ScheduledEvent,
                     SimulatedMetadataService, first_preempt)
from .fleet import FleetCoordinator, FleetReport, FleetSpec
from .ledger import TimeLedger, TimeModel
from .policy import CheckpointPolicy, Mode
from .providers import (AwsProvider, AzureProvider, CloudProvider, GcpProvider,
                        PreemptNotice, PROVIDERS, get_provider)
from .spot_sim import (AutoScalingGroup, EvictionSchedule, InstancePool,
                       ManagedInstanceGroup, NoEviction, PeriodicEviction,
                       PoissonEviction, ScaleSet, SpotInstance, TraceEviction)

__all__ = [
    "AWS_M5_2XLARGE", "AZURE_D8S_V3", "AutoScalingGroup", "AwsProvider",
    "AzureProvider", "Clock", "CheckpointPolicy", "CloudProvider",
    "CoordinatorStats", "CostAccountant", "DEFAULT_NOTICE_S",
    "EvictionSchedule", "FleetCoordinator", "FleetReport", "FleetSpec",
    "GCP_N2_STANDARD_8", "GcpProvider", "InstancePool",
    "ManagedInstanceGroup", "Mode", "NoEviction", "PREEMPT", "PROVIDERS",
    "PeriodicEviction", "PoissonEviction", "PreemptNotice", "PriceSheet",
    "ScaleSet", "ScheduledEvent", "Signal", "SimulatedMetadataService",
    "SpotInstance", "SpotOnCoordinator", "StragglerDetector", "TPU_V5E_CHIP",
    "TimeLedger", "TimeModel", "TraceEviction", "VirtualClock", "WallClock",
    "first_preempt", "get_provider",
]
