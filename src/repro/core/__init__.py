"""The paper's primary contribution: the Spot-on checkpoint coordinator,
spot-instance simulation, pricing, and elastic restore. See DESIGN.md §1–2."""

from .clock import Clock, VirtualClock, WallClock
from .coordinator import (CoordinatorStats, Signal, SpotOnCoordinator,
                          StragglerDetector, TimeModel)
from .cost import AZURE_D8S_V3, TPU_V5E_CHIP, CostAccountant, PriceSheet
from .events import (DEFAULT_NOTICE_S, PREEMPT, ScheduledEvent,
                     SimulatedMetadataService, first_preempt)
from .policy import CheckpointPolicy, Mode
from .spot_sim import (EvictionSchedule, NoEviction, PeriodicEviction,
                       PoissonEviction, ScaleSet, SpotInstance, TraceEviction)

__all__ = [
    "AZURE_D8S_V3", "TPU_V5E_CHIP", "Clock", "CheckpointPolicy",
    "CoordinatorStats", "CostAccountant", "DEFAULT_NOTICE_S",
    "EvictionSchedule", "Mode", "NoEviction", "PREEMPT", "PeriodicEviction",
    "PoissonEviction", "PriceSheet", "ScaleSet", "ScheduledEvent", "Signal",
    "SimulatedMetadataService", "SpotInstance", "SpotOnCoordinator",
    "StragglerDetector", "TimeModel", "TraceEviction", "VirtualClock",
    "WallClock", "first_preempt",
]
