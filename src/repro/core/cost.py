"""Pricing and cost accounting (paper Fig. 2/3).

Prices are per-instance-hour. The paper's case study uses Azure D8s_v3
(on-demand $0.38/hr, spot $0.076/hr — an 80% discount) and Azure Files NFS at
$16 per 100 GiB provisioned per month. We also ship a TPU-v5e-like sheet for
the framework's target hardware (public list prices, us-central, mid-2024:
~$1.20/chip-hr on-demand, ~$0.47 preemptible) plus size-comparable AWS/GCP
sheets (8 vCPU / 32 GiB; us-east list prices with typical spot discounts, and
EFS / Filestore standing in for the shared checkpoint volume) used by the
multi-cloud provider backends.
"""

from __future__ import annotations

from dataclasses import dataclass, field

GIB = 1024 ** 3
MONTH_S = 30 * 24 * 3600.0


@dataclass(frozen=True)
class PriceSheet:
    name: str
    ondemand_per_hr: float
    spot_per_hr: float
    storage_per_100gib_month: float = 16.0

    @property
    def spot_discount(self) -> float:
        return 1.0 - self.spot_per_hr / self.ondemand_per_hr


AZURE_D8S_V3 = PriceSheet("azure-d8s-v3", ondemand_per_hr=0.38, spot_per_hr=0.076)
TPU_V5E_CHIP = PriceSheet("tpu-v5e-chip", ondemand_per_hr=1.20, spot_per_hr=0.47)
AWS_M5_2XLARGE = PriceSheet("aws-m5-2xlarge", ondemand_per_hr=0.384,
                            spot_per_hr=0.134, storage_per_100gib_month=30.0)
GCP_N2_STANDARD_8 = PriceSheet("gcp-n2-standard-8", ondemand_per_hr=0.388,
                               spot_per_hr=0.097, storage_per_100gib_month=20.0)


@dataclass
class CostAccountant:
    """Integrates instance-seconds and provisioned storage into dollars."""

    prices: PriceSheet
    instance_seconds: dict[str, float] = field(default_factory=dict)  # kind -> s
    storage_gib_provisioned: float = 0.0
    storage_seconds: float = 0.0
    _storage_last_mark: float | None = None

    def record_instance(self, kind: str, seconds: float, count: int = 1) -> None:
        if kind not in ("spot", "ondemand"):
            raise ValueError(kind)
        self.instance_seconds[kind] = self.instance_seconds.get(kind, 0.0) + seconds * count

    def provision_storage(self, gib: float, now: float) -> None:
        self._flush_storage(now)
        self.storage_gib_provisioned = max(self.storage_gib_provisioned, gib)
        if self._storage_last_mark is None:
            self._storage_last_mark = now

    def _flush_storage(self, now: float) -> None:
        if self._storage_last_mark is not None:
            self.storage_seconds += (now - self._storage_last_mark) * self.storage_gib_provisioned
            self._storage_last_mark = now

    def compute_cost(self) -> dict[str, float]:
        spot_hr = self.instance_seconds.get("spot", 0.0) / 3600.0
        od_hr = self.instance_seconds.get("ondemand", 0.0) / 3600.0
        return {
            "spot_usd": spot_hr * self.prices.spot_per_hr,
            "ondemand_usd": od_hr * self.prices.ondemand_per_hr,
        }

    def storage_cost(self, now: float) -> float:
        self._flush_storage(now)
        gib_months = self.storage_seconds / MONTH_S
        return gib_months * (self.prices.storage_per_100gib_month / 100.0)

    def total_usd(self, now: float) -> float:
        c = self.compute_cost()
        return c["spot_usd"] + c["ondemand_usd"] + self.storage_cost(now)

    def summary(self, now: float) -> dict[str, float]:
        c = self.compute_cost()
        return {
            **c,
            "storage_usd": self.storage_cost(now),
            "total_usd": self.total_usd(now),
            "spot_hours": self.instance_seconds.get("spot", 0.0) / 3600.0,
            "ondemand_hours": self.instance_seconds.get("ondemand", 0.0) / 3600.0,
        }
