"""Elastic restore — resume a checkpoint on a different topology.

The paper restarts on "a new instance" of the same VM size. At pod scale the
replacement capacity may be *smaller* (a pod is gone) or differently shaped;
because manifests store global shapes and per-piece indices (checkpoint/
sharded.py), restoring under any mesh is just re-slicing. This module adds the
policy layer: pick a mesh for the devices that are left, rebuild the template
with the new shardings, and hand back a state the train step can jit against.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
from jax.sharding import Mesh

from ..checkpoint.store import CheckpointStore


@dataclass(frozen=True)
class MeshPlan:
    shape: tuple[int, ...]
    axes: tuple[str, ...]

    def build(self, devices=None) -> Mesh:
        devices = devices if devices is not None else jax.devices()
        n = 1
        for s in self.shape:
            n *= s
        if len(devices) < n:
            raise ValueError(f"need {n} devices, have {len(devices)}")
        import numpy as np
        return Mesh(np.asarray(devices[:n]).reshape(self.shape), self.axes)


def plan_mesh_for(n_devices: int, *, model_parallel: int, axes=("data", "model")) -> MeshPlan:
    """Largest (data, model) mesh for the surviving device count, preserving
    the model-parallel degree (param shards must still fit one instance)."""
    if n_devices % model_parallel != 0:
        raise ValueError(f"{n_devices} devices not divisible by model={model_parallel}")
    return MeshPlan((n_devices // model_parallel, model_parallel), tuple(axes))


def fleet_mesh_plan(n_instances: int, *, hosts_per_instance: int = 1,
                    model_parallel: int = 1,
                    axes=("data", "model")) -> MeshPlan:
    """Mesh plan for a fleet's surviving capacity (eviction-driven rescale).

    Each fleet instance contributes ``hosts_per_instance`` accounting units;
    the model-parallel degree is preserved across rescales so parameter
    shards keep fitting one instance. Raises ValueError when the surviving
    capacity cannot host the model-parallel degree — the fleet coordinator
    records that as a stall rather than a rescale.
    """
    if n_instances < 1:
        raise ValueError("fleet has no surviving instances")
    return plan_mesh_for(n_instances * hosts_per_instance,
                         model_parallel=model_parallel, axes=axes)


def member_addressable(plan: MeshPlan, member_index: int, *,
                       model_axis: str = "model"):
    """Byte-span ownership predicate for one fleet member under ``plan`` —
    the ``addressable`` argument of ``DeviceDeltaTracker.rescale`` /
    ``SpotOnCoordinator.rescale_topology``.

    Ownership model matches ``distributed.sharding.elastic_rules``: every
    axis except ``model_axis`` replicates parameters (data parallelism), so
    with ``model == 1`` each member addresses every span. A model-parallel
    degree ``m > 1`` partitions each leaf's flat byte extent into ``m``
    equal slices and the member owns the slice of its model coordinate
    (``member_index % m`` — members fill the model axis fastest, mirroring
    ``MeshPlan.build``'s row-major device placement). A fingerprint span
    survives the rescale iff it lies fully inside the member's slice.
    """
    m = 1
    for size, axis in zip(plan.shape, plan.axes):
        if axis == model_axis:
            m = int(size)
    if m <= 1:
        return lambda name, lo, hi, total: True
    coord = member_index % m

    def owns(name: str, lo: int, hi: int, total: int) -> bool:
        if total <= 0:
            return coord == 0
        slice_lo = (coord * total) // m
        slice_hi = ((coord + 1) * total) // m
        return slice_lo <= lo and hi <= slice_hi

    return owns


def elastic_restore(store: CheckpointStore, template_fn, mesh: Mesh):
    """Restore the latest valid checkpoint onto `mesh`.

    `template_fn(mesh) -> state-template` rebuilds ShapeDtypeStructs with the
    new mesh's shardings (global shapes are mesh-independent by construction).
    """
    template = template_fn(mesh)
    return store.restore(template)
