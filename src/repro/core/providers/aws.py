"""AWS backend — IMDS spot ``instance-action`` + rebalance recommendation.

Schema fidelity: the real IMDSv2 endpoints are

    GET /latest/meta-data/spot/instance-action
        -> 404 while safe, else {"action": "terminate"|"stop"|"hibernate",
                                 "time": "2026-07-26T12:00:00Z"}  (ISO-8601 UTC)
    GET /latest/meta-data/events/recommendations/rebalance
        -> 404 while safe, else {"noticeTime": "..."}

AWS issues the instance-action exactly two minutes before the interruption;
the rebalance recommendation can arrive arbitrarily earlier and means
"elevated interruption risk" — Spot-on uses it to take a proactive
checkpoint without stopping work. Simulated timestamps map the simulation
clock to the Unix epoch so the ISO strings round-trip exactly like the wire
format.
"""

from __future__ import annotations

from datetime import datetime, timezone
from typing import Any

from ..cost import AWS_M5_2XLARGE
from .base import (CloudProvider, PlatformEvent, PreemptNotice, PREEMPT_KIND,
                   REBALANCE_KIND)

DEFAULT_NOTICE_S = 120.0  # the two-minute warning


def ts_to_iso(ts: float) -> str:
    return datetime.fromtimestamp(ts, tz=timezone.utc).isoformat().replace(
        "+00:00", "Z")


def iso_to_ts(s: str) -> float:
    return datetime.fromisoformat(s.replace("Z", "+00:00")).timestamp()


class SimulatedIMDS:
    """Per-instance IMDS document set, driven by the simulator."""

    def __init__(self, clock, instance_name: str):
        self.clock = clock
        self.instance_name = instance_name
        self._instance_action: dict | None = None
        self._rebalance: dict | None = None

    # -- coordinator-facing (IMDS shapes; None plays the 404) -----------------

    def get_instance_action(self) -> dict | None:
        return self._instance_action

    def get_rebalance_recommendation(self) -> dict | None:
        return self._rebalance

    # -- platform-facing -------------------------------------------------------

    def schedule_preempt(self, *, notice_s: float = DEFAULT_NOTICE_S) -> PlatformEvent:
        not_before = self.clock.now() + max(notice_s, DEFAULT_NOTICE_S)
        self._instance_action = {"action": "terminate",
                                 "time": ts_to_iso(not_before)}
        return PlatformEvent(not_before)

    def announce_rebalance(self) -> None:
        """Idempotent: a recommendation, once issued, stays until the
        instance dies (matches IMDS: the doc persists once present)."""
        if self._rebalance is None:
            self._rebalance = {"noticeTime": ts_to_iso(self.clock.now())}

    def clear(self) -> None:
        self._instance_action = None
        self._rebalance = None


class AwsProvider(CloudProvider):
    name = "aws"
    notice_s = DEFAULT_NOTICE_S
    pool_kind = "auto-scaling-group"
    instance_prefix = "i-"
    prices = AWS_M5_2XLARGE
    rebalance_lead_s = 300.0           # hint ~5 min before the termination

    def make_metadata(self, clock, instance_name: str) -> SimulatedIMDS:
        return SimulatedIMDS(clock, instance_name)

    def make_pool(self, clock, schedule, accountant=None, **kwargs):
        from ..spot_sim import AutoScalingGroup
        kwargs.setdefault("notice_s", self.notice_s)
        kwargs.setdefault("rebalance_lead_s", self.rebalance_lead_s)
        return AutoScalingGroup(clock=clock, schedule=schedule,
                                accountant=accountant, provider=self, **kwargs)

    def poll(self, metadata, instance_name: str, now: float) -> list[PreemptNotice]:
        notices: list[PreemptNotice] = []
        act = metadata.get_instance_action()
        if act is not None:
            notices.append(PreemptNotice(
                event_id=f"aws-{act['action']}-{act['time']}",
                deadline=iso_to_ts(act["time"]), kind=PREEMPT_KIND, raw=act))
        reb = metadata.get_rebalance_recommendation()
        if reb is not None:
            notices.append(PreemptNotice(
                event_id=f"aws-rebalance-{reb['noticeTime']}",
                deadline=iso_to_ts(reb["noticeTime"]), kind=REBALANCE_KIND,
                raw=reb))
        return notices
