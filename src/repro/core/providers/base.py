"""CloudProvider — everything the coordinator must know about one cloud.

The paper claims Spot-on "is compatible with the major cloud vendors"; what
actually differs between vendors is bundled here:

* the **metadata-service schema** an instance polls (Azure Scheduled Events
  JSON, AWS IMDS ``spot/instance-action`` + rebalance recommendation, GCP's
  ``instance/preempted`` flag),
* the **notice semantics** — guaranteed minimum warning before the kill
  (Azure >=30 s, AWS 120 s, GCP ~30 s) and whether an advance *rebalance*
  hint exists (AWS only),
* the **pool-manager behavior** that replaces evicted capacity (Scale Set /
  Auto Scaling Group / Managed Instance Group),
* the **price sheet** for cost accounting.

``poll`` normalizes whatever the vendor document looks like into
``PreemptNotice`` records, so the coordinator never parses vendor JSON.
Adding a fourth backend = subclass ``CloudProvider``, implement the four
factory/parse methods, register it in ``PROVIDERS``.
"""

from __future__ import annotations

import abc
from dataclasses import dataclass, field
from typing import Any

from ..cost import PriceSheet

PREEMPT_KIND = "preempt"        # capacity will be taken: hard deadline
REBALANCE_KIND = "rebalance"    # elevated risk hint: checkpoint proactively


@dataclass(frozen=True)
class PlatformEvent:
    """What a simulated metadata service's ``schedule_preempt`` returns to
    the platform simulator: the actual kill time."""

    not_before: float


@dataclass(frozen=True)
class PreemptNotice:
    """Vendor-neutral eviction signal.

    ``event_id`` is stable across polls of the same underlying event (dedup
    key), ``deadline`` is a clock timestamp after which the instance may be
    destroyed. ``kind`` is PREEMPT_KIND or REBALANCE_KIND; a rebalance
    carries no kill guarantee — its deadline is informational.
    """

    event_id: str
    deadline: float
    kind: str = PREEMPT_KIND
    raw: dict = field(default_factory=dict)


class CloudProvider(abc.ABC):
    """One cloud vendor's spot semantics. Stateless where the vendor is
    stateless; providers that must synthesize deadlines (GCP) may keep
    per-instance poll state."""

    name: str = "abstract"
    notice_s: float = 30.0              # guaranteed minimum eviction notice
    pool_kind: str = "pool"             # human name of the pool manager
    instance_prefix: str = "vm-"
    prices: PriceSheet
    rebalance_lead_s: float = 0.0       # advance rebalance hint (AWS only)

    # -- factories -------------------------------------------------------------

    @abc.abstractmethod
    def make_metadata(self, clock, instance_name: str):
        """In-process simulator of this vendor's metadata endpoint."""

    @abc.abstractmethod
    def make_pool(self, clock, schedule, accountant=None, **kwargs):
        """Replacement-provisioning pool with this vendor's defaults."""

    # -- coordinator-facing ----------------------------------------------------

    @abc.abstractmethod
    def poll(self, metadata, instance_name: str, now: float) -> list[PreemptNotice]:
        """Read the metadata document(s) and normalize into notices.

        Preempt notices must precede rebalance notices in the returned list;
        the coordinator acts on the first unhandled one of each kind.
        """

    def poll_once(self, metadata, instance_name: str,
                  now: float) -> list[PreemptNotice]:
        """One fallible poll attempt: the coordinator's retry/degradation
        wrapper calls this, and the ``provider.poll`` fault point lets a
        FaultPlan stand in for a flaky metadata endpoint (a real endpoint
        surfaces as OSError/TimeoutError from the HTTP layer)."""
        from ... import faults

        faults.fault_point("provider.poll", instance_name or self.name)
        return self.poll(metadata, instance_name, now)

    def acknowledge(self, metadata, notice: PreemptNotice) -> None:
        """Vendor-specific ack (Azure StartRequests). Default: no-op."""

    # -- evaluation helpers ----------------------------------------------------

    def simulate_eviction(self, metadata) -> Any:
        """Trigger an eviction through the vendor's own mechanism (the paper
        uses ``az vmss simulate-eviction``; AWS/GCP analogues exist)."""
        return metadata.schedule_preempt(notice_s=self.notice_s)
