"""GCP backend — the ``instance/preempted`` metadata flag + MIG pool.

Schema fidelity: GCE exposes

    GET /computeMetadata/v1/instance/preempted -> "FALSE" | "TRUE"

and delivers an ACPI G2 soft-off at preemption start; the VM then has ~30 s
before the hard kill. Unlike Azure/AWS there is **no deadline in the
document** — an agent that observes the flag flip must synthesize its own
budget (observation time + 30 s). The provider therefore keeps per-instance
poll state so repeated polls of the same preemption return one stable notice
(same event id, same deadline) — exactly what a real guest agent does.
"""

from __future__ import annotations

import itertools

from ..cost import GCP_N2_STANDARD_8
from .base import CloudProvider, PlatformEvent, PreemptNotice, PREEMPT_KIND

DEFAULT_NOTICE_S = 30.0  # "Compute Engine gives you 30 seconds"


class SimulatedGceMetadata:
    """Per-instance GCE metadata server, driven by the simulator."""

    def __init__(self, clock, instance_name: str):
        self.clock = clock
        self.instance_name = instance_name
        self._preempted = False
        self._not_before: float | None = None

    # -- coordinator-facing ----------------------------------------------------

    def get_preempted(self) -> str:
        return "TRUE" if self._preempted else "FALSE"

    @property
    def preempt_not_before(self) -> float | None:
        """The platform's actual kill time. A real guest only learns this
        implicitly (ACPI G2 arrival); the simulator exposes it so a late
        poll cannot synthesize budget past the true deadline."""
        return self._not_before

    # -- platform-facing -------------------------------------------------------

    def schedule_preempt(self, *, notice_s: float = DEFAULT_NOTICE_S) -> PlatformEvent:
        self._preempted = True
        self._not_before = self.clock.now() + max(notice_s, DEFAULT_NOTICE_S)
        return PlatformEvent(self._not_before)

    def clear(self) -> None:
        self._preempted = False
        self._not_before = None


class GcpProvider(CloudProvider):
    name = "gcp"
    notice_s = DEFAULT_NOTICE_S
    pool_kind = "managed-instance-group"
    instance_prefix = "gce-"
    prices = GCP_N2_STANDARD_8

    def __init__(self):
        self._seq = itertools.count(1)
        # instance_name -> live notice (stable across polls of one preemption)
        self._active: dict[str, PreemptNotice] = {}

    def make_metadata(self, clock, instance_name: str) -> SimulatedGceMetadata:
        return SimulatedGceMetadata(clock, instance_name)

    def make_pool(self, clock, schedule, accountant=None, **kwargs):
        from ..spot_sim import ManagedInstanceGroup
        kwargs.setdefault("notice_s", self.notice_s)
        return ManagedInstanceGroup(clock=clock, schedule=schedule,
                                    accountant=accountant, provider=self,
                                    **kwargs)

    def poll(self, metadata, instance_name: str, now: float) -> list[PreemptNotice]:
        if metadata.get_preempted() != "TRUE":
            self._active.pop(instance_name, None)
            return []
        notice = self._active.get(instance_name)
        if notice is None:
            # first observation: the agent's budget starts counting NOW —
            # but never past the platform's actual kill time (a poll landing
            # late must not synthesize budget the VM doesn't have)
            deadline = now + self.notice_s
            not_before = getattr(metadata, "preempt_not_before", None)
            if not_before is not None:
                deadline = min(deadline, not_before)
            notice = PreemptNotice(
                event_id=f"gcp-preempt-{next(self._seq):06d}",
                deadline=deadline, kind=PREEMPT_KIND,
                raw={"preempted": "TRUE"})
            self._active[instance_name] = notice
        return [notice]
