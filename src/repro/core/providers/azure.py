"""Azure backend — Scheduled Events + Scale Set (the paper's setup).

The metadata schema lives in ``core/events.py`` (it predates the provider
abstraction and is kept there because the document shape is the paper's
ground truth); this module adapts it to the ``CloudProvider`` interface.
"""

from __future__ import annotations

from ..cost import AZURE_D8S_V3
from ..events import PREEMPT, SimulatedMetadataService
from .base import CloudProvider, PreemptNotice, PREEMPT_KIND


class AzureProvider(CloudProvider):
    name = "azure"
    notice_s = 30.0                    # Azure guarantees >=30 s
    pool_kind = "scale-set"
    instance_prefix = "vm-"
    prices = AZURE_D8S_V3

    def make_metadata(self, clock, instance_name: str) -> SimulatedMetadataService:
        return SimulatedMetadataService(clock, instance_name)

    def make_pool(self, clock, schedule, accountant=None, **kwargs):
        from ..spot_sim import ScaleSet
        kwargs.setdefault("notice_s", self.notice_s)
        return ScaleSet(clock=clock, schedule=schedule, accountant=accountant,
                        provider=self, **kwargs)

    def poll(self, metadata, instance_name: str, now: float) -> list[PreemptNotice]:
        doc = metadata.get_scheduled_events()
        notices = []
        for ev in doc.get("Events", ()):
            if ev.get("EventType") != PREEMPT:
                continue
            if instance_name is not None and instance_name not in ev.get("Resources", ()):
                continue
            notices.append(PreemptNotice(
                event_id=str(ev["EventId"]), deadline=float(ev["NotBefore"]),
                kind=PREEMPT_KIND, raw=ev))
        return notices

    def acknowledge(self, metadata, notice: PreemptNotice) -> None:
        # Azure: POST StartRequests expedites the event (paper §III-B).
        metadata.acknowledge_event(notice.event_id)
