"""Multi-cloud provider backends. See base.CloudProvider for the contract."""

from __future__ import annotations

from .base import (CloudProvider, PlatformEvent, PreemptNotice, PREEMPT_KIND,
                   REBALANCE_KIND)
from .azure import AzureProvider
from .aws import AwsProvider, SimulatedIMDS
from .gcp import GcpProvider, SimulatedGceMetadata

PROVIDERS: dict[str, type[CloudProvider]] = {
    "azure": AzureProvider,
    "aws": AwsProvider,
    "gcp": GcpProvider,
}


def get_provider(name_or_provider) -> CloudProvider:
    """Resolve a provider name (or pass a CloudProvider through)."""
    if isinstance(name_or_provider, CloudProvider):
        return name_or_provider
    try:
        return PROVIDERS[str(name_or_provider).lower()]()
    except KeyError:
        raise ValueError(
            f"unknown cloud provider {name_or_provider!r}; "
            f"known: {sorted(PROVIDERS)}") from None


__all__ = [
    "AwsProvider", "AzureProvider", "CloudProvider", "GcpProvider",
    "PREEMPT_KIND", "PROVIDERS", "PlatformEvent", "PreemptNotice",
    "REBALANCE_KIND", "SimulatedGceMetadata", "SimulatedIMDS", "get_provider",
]
