"""Checkpoint policies — the paper's two modes plus OFF.

The semantic split (paper §III-A) is *when* a checkpoint may be taken:

* TRANSPARENT — any step boundary. Periodic (every ``periodic_interval_s``)
  *and* on-demand (termination checkpoint inside the eviction notice).
* APPLICATION — only at application-defined **stage boundaries** (metaSPAdes'
  k-mer stages; for training, epoch/eval boundaries). "Compared to transparent
  checkpointing, application-specific checkpointing cannot be taken on
  demand" — so no termination checkpoints, and an eviction rolls the job back
  to the last completed stage.
* OFF — no protection (the paper's baseline rows).
"""

from __future__ import annotations

import enum
from dataclasses import dataclass


class Mode(enum.Enum):
    OFF = "off"
    APPLICATION = "application"
    TRANSPARENT = "transparent"


@dataclass(frozen=True)
class CheckpointPolicy:
    mode: Mode = Mode.TRANSPARENT
    periodic_interval_s: float = 900.0      # paper uses 15/30 min
    poll_interval_s: float = 1.0            # metadata poll cadence
    async_writes: bool = True               # overlap write IO with training
    checkpoint_on_rebalance: bool = True    # AWS rebalance hint -> proactive ckpt

    @property
    def supports_on_demand(self) -> bool:
        return self.mode is Mode.TRANSPARENT

    @property
    def periodic_enabled(self) -> bool:
        return self.mode is Mode.TRANSPARENT

    @property
    def stage_boundary_enabled(self) -> bool:
        return self.mode is Mode.APPLICATION

    @staticmethod
    def off() -> "CheckpointPolicy":
        return CheckpointPolicy(mode=Mode.OFF)

    @staticmethod
    def application() -> "CheckpointPolicy":
        return CheckpointPolicy(mode=Mode.APPLICATION)

    @staticmethod
    def transparent(periodic_interval_s: float = 900.0) -> "CheckpointPolicy":
        return CheckpointPolicy(mode=Mode.TRANSPARENT,
                                periodic_interval_s=periodic_interval_s)
