"""Time sources. Every component takes a Clock so the same code runs either in
real time (integration tests, scaled-interval benchmarks) or in virtual time
(replaying the paper's 60/90-minute eviction intervals in milliseconds)."""

from __future__ import annotations

import time


class Clock:
    def now(self) -> float:
        raise NotImplementedError

    def sleep(self, dt: float) -> None:
        raise NotImplementedError


class WallClock(Clock):
    def now(self) -> float:
        return time.monotonic()

    def sleep(self, dt: float) -> None:
        if dt > 0:
            time.sleep(dt)


class VirtualClock(Clock):
    """Deterministic simulated time; `sleep` advances instantly."""

    def __init__(self, start: float = 0.0):
        self._t = start

    def now(self) -> float:
        return self._t

    def sleep(self, dt: float) -> None:
        self.advance(dt)

    def advance(self, dt: float) -> None:
        if dt < 0:
            raise ValueError("time goes forward")
        self._t += dt
