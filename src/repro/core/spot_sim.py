"""Spot-instance lifecycle + pool-manager simulator (multi-provider).

Models the slice of a spot cloud the paper depends on:

* a **spot instance** that runs until the platform preempts it — preemption is
  announced through its provider-shaped metadata document with the provider's
  guaranteed notice, then the instance is destroyed at the deadline (all
  un-checkpointed work is lost);
* a **pool manager** that keeps target capacity by provisioning a replacement
  after an eviction (paper §III: "scale sets act as a VM pool manager ...
  capable of restarting new spot instances upon eviction"). ``InstancePool``
  is the generic machinery; ``ScaleSet`` (Azure), ``AutoScalingGroup`` (AWS,
  with advance rebalance recommendations) and ``ManagedInstanceGroup`` (GCP)
  carry per-vendor defaults;
* **eviction schedules** driving when preemptions happen: the paper uses
  fixed 60/90-minute intervals via ``simulate-eviction``; we add Poisson and
  trace-driven schedules for beyond-paper experiments.

Everything is clock-driven (virtual or wall), single-threaded and
deterministic: the workload loop calls ``pool.tick()`` between work quanta.
"""

from __future__ import annotations

import enum
import itertools
from dataclasses import dataclass, field
from typing import Any, Callable, Iterator, Protocol

import numpy as np

from .clock import Clock
from .cost import CostAccountant
from .events import DEFAULT_NOTICE_S, SimulatedMetadataService


class InstanceState(enum.Enum):
    PROVISIONING = "provisioning"
    RUNNING = "running"
    EVICTING = "evicting"      # preempt announced, NotBefore not yet reached
    TERMINATED = "terminated"


@dataclass
class SpotInstance:
    name: str
    clock: Clock
    kind: str = "spot"                      # "spot" | "ondemand"
    state: InstanceState = InstanceState.PROVISIONING
    created_at: float = 0.0
    running_since: float | None = None
    terminated_at: float | None = None
    eviction_not_before: float | None = None
    # provider metadata endpoint; any object with schedule_preempt(notice_s=)
    # returning an event carrying .not_before (Azure Scheduled Events by default)
    metadata: Any = None
    metadata_factory: Callable[[Clock, str], Any] | None = None

    def __post_init__(self):
        if self.metadata is None:
            factory = self.metadata_factory or SimulatedMetadataService
            self.metadata = factory(self.clock, self.name)

    # -- platform actions ------------------------------------------------------

    def boot(self) -> None:
        self.state = InstanceState.RUNNING
        self.running_since = self.clock.now()

    def announce_preemption(self, notice_s: float = DEFAULT_NOTICE_S) -> None:
        if self.state is not InstanceState.RUNNING:
            return
        ev = self.metadata.schedule_preempt(notice_s=notice_s)
        self.eviction_not_before = ev.not_before
        self.state = InstanceState.EVICTING

    def tick(self) -> None:
        """Advance lifecycle; destroys the VM once NotBefore is reached."""
        if (self.state is InstanceState.EVICTING
                and self.clock.now() >= self.eviction_not_before):
            self.terminate()

    def terminate(self) -> None:
        if self.state is InstanceState.TERMINATED:
            return
        self.state = InstanceState.TERMINATED
        self.terminated_at = self.clock.now()

    # -- workload-facing -------------------------------------------------------

    @property
    def alive(self) -> bool:
        return self.state in (InstanceState.RUNNING, InstanceState.EVICTING)

    def lifetime_s(self) -> float:
        if self.running_since is None:
            return 0.0
        end = self.terminated_at if self.terminated_at is not None else self.clock.now()
        return end - self.running_since


# ---------------------------------------------------------------------------
# eviction schedules
# ---------------------------------------------------------------------------

class EvictionSchedule(Protocol):
    def eviction_times(self, start: float) -> Iterator[float]: ...


@dataclass(frozen=True)
class NoEviction:
    def eviction_times(self, start: float) -> Iterator[float]:
        return iter(())


@dataclass(frozen=True)
class PeriodicEviction:
    """The paper's evaluation schedule: an eviction every `interval_s`."""

    interval_s: float

    def eviction_times(self, start: float) -> Iterator[float]:
        return (start + self.interval_s * k for k in itertools.count(1))


@dataclass(frozen=True)
class PoissonEviction:
    """Memoryless evictions with mean inter-arrival `mean_interval_s`."""

    mean_interval_s: float
    seed: int = 0

    def eviction_times(self, start: float) -> Iterator[float]:
        rng = np.random.default_rng(self.seed)
        t = start
        while True:
            t += float(rng.exponential(self.mean_interval_s))
            yield t


@dataclass(frozen=True)
class TraceEviction:
    """Replay explicit eviction timestamps (offsets from start)."""

    offsets_s: tuple[float, ...]

    def eviction_times(self, start: float) -> Iterator[float]:
        return (start + o for o in self.offsets_s)


# ---------------------------------------------------------------------------
# pool managers
# ---------------------------------------------------------------------------

@dataclass
class InstancePool:
    """Capacity-1 replacement pool (the paper's setup), provider-generic.

    `hosts_per_instance` models a pod slice: one logical "instance" may stand
    for N accounting units (e.g. 256 chips) so the cost model scales. When a
    ``provider`` (core.providers.CloudProvider) is given, its metadata schema,
    instance-name prefix and notice floor are used; without one the pool
    behaves exactly like the original Azure Scale Set.
    """

    clock: Clock
    schedule: EvictionSchedule
    accountant: CostAccountant | None = None
    kind: str = "spot"                    # instance kind provisioned
    provisioning_delay_s: float = 60.0    # VM create + boot + custom-data
    notice_s: float | None = None         # None -> provider floor (or Azure's)
    hosts_per_instance: int = 1
    provider: Any = None                  # core.providers.CloudProvider | None
    name_prefix: str | None = None        # None -> provider prefix (or "vm-")
    rebalance_lead_s: float = 0.0         # AWS: hint this long before the kill
    _names: Iterator[int] = field(default_factory=lambda: itertools.count(0))
    _eviction_iter: Iterator[float] | None = None
    _next_eviction: float | None = None
    current: SpotInstance | None = None
    evictions_announced: int = 0
    rebalance_recommendations: int = 0
    instances_created: int = 0
    _pending_ready_at: float | None = None

    def __post_init__(self):
        if self.notice_s is None:
            self.notice_s = (self.provider.notice_s if self.provider is not None
                             else DEFAULT_NOTICE_S)
        if self.name_prefix is None:
            self.name_prefix = (self.provider.instance_prefix
                                if self.provider is not None else "vm-")

    def start(self) -> None:
        self._eviction_iter = iter(self.schedule.eviction_times(self.clock.now()))
        self._next_eviction = next(self._eviction_iter, None)
        self._provision()

    def _provision(self) -> None:
        # first boot is immediate-ish; replacements pay provisioning_delay_s
        delay = 0.0 if self.instances_created == 0 else self.provisioning_delay_s
        self._pending_ready_at = self.clock.now() + delay

    def _metadata_factory(self) -> Callable[[Clock, str], Any] | None:
        if self.provider is None:
            return None
        return self.provider.make_metadata

    def tick(self) -> SpotInstance | None:
        """Drive platform events up to `clock.now()`. Returns running instance
        (or None while a replacement is provisioning)."""
        now = self.clock.now()
        # bring up pending instance
        if self.current is None and self._pending_ready_at is not None and now >= self._pending_ready_at:
            name = f"{self.name_prefix}{next(self._names):04d}"
            inst = SpotInstance(name=name, clock=self.clock, kind=self.kind,
                                created_at=now,
                                metadata_factory=self._metadata_factory())
            inst.boot()
            self.current = inst
            self.instances_created += 1
            self._pending_ready_at = None
        inst = self.current
        if inst is None:
            return None
        if self.kind == "spot":
            # advance rebalance hint (AWS): issued `rebalance_lead_s` before
            # the interruption, on metadata services that support it
            if (self.rebalance_lead_s > 0 and self._next_eviction is not None
                    and now >= self._next_eviction - self.rebalance_lead_s):
                announce = getattr(inst.metadata, "announce_rebalance", None)
                if announce is not None and \
                        getattr(inst.metadata, "get_rebalance_recommendation",
                                lambda: None)() is None:
                    announce()
                    self.rebalance_recommendations += 1
            # fire due evictions
            while self._next_eviction is not None and now >= self._next_eviction:
                inst.announce_preemption(notice_s=self.notice_s)
                self.evictions_announced += 1
                self._next_eviction = next(self._eviction_iter, None)
        inst.tick()
        if not inst.alive:
            self._account(inst)
            self.current = None
            self._provision()
            return None
        return inst

    def shutdown(self) -> None:
        """Workload finished: terminate and account the final instance."""
        if self.current is not None:
            self.current.terminate()
            self._account(self.current)
            self.current = None

    def _account(self, inst: SpotInstance) -> None:
        if self.accountant is not None:
            self.accountant.record_instance(inst.kind, inst.lifetime_s(),
                                            count=self.hosts_per_instance)

    # -- helpers ---------------------------------------------------------------

    def wait_for_instance(self) -> SpotInstance:
        """Advance the clock through the provisioning gap if needed."""
        inst = self.tick()
        while inst is None:
            target = self._pending_ready_at
            assert target is not None, "pool stopped without pending instance"
            self.clock.sleep(max(target - self.clock.now(), 0.0) + 1e-9)
            inst = self.tick()
        return inst


@dataclass
class ScaleSet(InstancePool):
    """Azure VM Scale Set — the paper's pool manager (and the default)."""


@dataclass
class AutoScalingGroup(InstancePool):
    """AWS Auto Scaling Group: 120 s instance-action notice plus an advance
    rebalance recommendation `rebalance_lead_s` before the interruption."""

    rebalance_lead_s: float = 300.0


@dataclass
class ManagedInstanceGroup(InstancePool):
    """GCP Managed Instance Group: ~30 s ACPI-G2 preemption notice."""
