"""FleetCoordinator — N per-instance coordinators, one shared store.

Scales the paper's single-instance workflow to a **heterogeneous fleet**: each
member is one spot instance on its own cloud provider (pool manager + metadata
schema + prices), all members mount the same ``CheckpointStore`` (the shared
NFS volume of the paper), and one ``SpotOnCoordinator`` runs beside each
member. The fleet models elastic data-parallel training:

* **replicated state** — every member holds the full training state, so the
  fleet only rolls back when *all* members are simultaneously dead (a full
  outage); a single eviction costs capacity, not progress;
* **single-writer periodic checkpoints** — the fleet owns the periodic
  cadence and asks the current leader (lowest-index alive member) to write,
  so N members don't save N copies. Termination checkpoints are written by
  whichever member receives the eviction notice, tagged with its provider;
* **eviction-driven elastic rescale** — when the alive count changes the
  fleet re-plans the device mesh (``core.elastic.fleet_mesh_plan``) and, when
  enough local devices exist to materialize it, rebuilds sharding rules
  through ``distributed.sharding.elastic_rules``. With fewer members the
  global batch is fixed, so per-step time stretches by ``size/alive``;
* **per-provider cost accounting** — one ``CostAccountant`` per provider
  aggregates instance-seconds at that provider's prices.

The run loop drives a synthetic replicated workload (a numpy state whose
tensor equals the step count — cheap, and bit-exact restores are checkable),
against the real checkpoint store: atomic commit, latest-valid search and
retention all execute for real. The trainer (train/trainer.py) remains the
single-instance path with real jitted steps; the fleet is the scale harness.
"""

from __future__ import annotations

import logging
import math
from dataclasses import dataclass, field, replace

import numpy as np

from ..checkpoint.store import CheckpointStore
from .clock import Clock
from .coordinator import Signal, SpotOnCoordinator
from .cost import CostAccountant
from .elastic import fleet_mesh_plan
from .ledger import TimeLedger, TimeModel
from .policy import CheckpointPolicy
from .providers import CloudProvider, get_provider
from .spot_sim import EvictionSchedule, InstancePool, NoEviction, SpotInstance

log = logging.getLogger("spoton.fleet")


@dataclass(frozen=True)
class FleetSpec:
    """One fleet member per entry: provider name (or instance) + its eviction
    schedule. ``hosts_per_instance``/``model_parallel`` shape the rescale
    planning; ``provisioning_delay_s`` applies to every member's pool."""

    providers: tuple = ("azure", "aws", "gcp")
    schedules: tuple | None = None          # None -> NoEviction() per member
    hosts_per_instance: int = 1
    model_parallel: int = 1
    provisioning_delay_s: float = 60.0


@dataclass
class _Member:
    index: int
    provider: CloudProvider
    pool: InstancePool
    coordinator: SpotOnCoordinator
    attached: str | None = None
    evictions_seen: int = 0


@dataclass
class FleetReport:
    completed: bool
    total_time_s: float
    steps_executed: int
    lost_steps: int
    restores: int
    full_outages: int
    final_state_consistent: bool
    rescale_events: list[dict] = field(default_factory=list)
    per_provider: dict[str, dict] = field(default_factory=dict)
    checkpoints: dict = field(default_factory=dict)
    total_usd: float = 0.0


class FleetCoordinator:
    def __init__(
        self,
        store: CheckpointStore,
        policy: CheckpointPolicy,
        clock: Clock,
        spec: FleetSpec,
        *,
        time_model: TimeModel | None = None,
        peer_exchange=None,
    ):
        self.store = store
        self.policy = policy
        self.clock = clock
        self.spec = spec
        # optional checkpoint.peer_exchange.FleetPeerExchange: when present,
        # an evictee seeds survivors' local pools during its notice window
        # and a cold member restores through its peer read-through pool
        self.peer_exchange = peer_exchange
        self.peer_seed_events: list[dict] = []
        self.ledger = TimeLedger(clock, time_model)
        # members never self-schedule periodic saves (the fleet owns the
        # cadence, below) but keep on-demand termination checkpoints
        member_policy = replace(policy, periodic_interval_s=math.inf)
        self._accountants: dict[str, CostAccountant] = {}
        self.members: list[_Member] = []
        schedules = spec.schedules or tuple(NoEviction() for _ in spec.providers)
        if len(schedules) != len(spec.providers):
            raise ValueError("one eviction schedule per provider required")
        for i, (prov_spec, sched) in enumerate(zip(spec.providers, schedules)):
            prov = get_provider(prov_spec)
            acct = self._accountants.setdefault(prov.name,
                                                CostAccountant(prov.prices))
            pool = prov.make_pool(
                clock, sched, acct,
                provisioning_delay_s=spec.provisioning_delay_s,
                hosts_per_instance=spec.hosts_per_instance,
                # distinct prefixes: N pools must not collide on instance names
                name_prefix=f"{prov.instance_prefix}m{i}-")
            coord = SpotOnCoordinator(store, member_policy, clock,
                                      provider=prov, ledger=self.ledger)
            self.members.append(_Member(index=i, provider=prov, pool=pool,
                                        coordinator=coord))
        self.size = len(self.members)
        self.rescale_events: list[dict] = []
        self._last_alive = -1

    # -- plumbing ---------------------------------------------------------------

    def _tick_member(self, m: _Member) -> SpotInstance | None:
        inst = m.pool.tick()
        if inst is None:
            if m.attached is not None:
                m.coordinator.detach()
                m.attached = None
            return None
        if inst.name != m.attached:
            m.coordinator.attach_instance(inst.metadata, inst.name)
            m.attached = inst.name
        return inst

    def _advance_to_next_capacity(self) -> None:
        """Nobody alive: jump the clock to the earliest pending replacement."""
        targets = [m.pool._pending_ready_at for m in self.members
                   if m.pool._pending_ready_at is not None]
        assert targets, "fleet stalled with no replacement provisioning"
        self.clock.sleep(max(min(targets) - self.clock.now(), 0.0) + 1e-9)

    def _record_rescale(self, n_alive: int) -> None:
        event = {"t": self.clock.now(), "alive": n_alive,
                 "capacity": n_alive * self.spec.hosts_per_instance}
        plan = None
        try:
            plan = fleet_mesh_plan(
                n_alive, hosts_per_instance=self.spec.hosts_per_instance,
                model_parallel=self.spec.model_parallel)
            event["mesh_shape"] = plan.shape
            event["mesh_axes"] = plan.axes
            try:
                # materialize only when this process has enough devices
                from ..distributed.sharding import elastic_rules
                rules = elastic_rules(plan.build())
                event["dp"], event["tp"] = rules.dp_size, rules.tp_size
            except ValueError:
                pass  # plan recorded; a real fleet builds it on its own chips
        except ValueError as e:
            event["error"] = str(e)  # capacity can't host the MP degree
        # rescale-stable fingerprints: each member remaps its device-delta
        # tracker onto the new plan instead of starting from scratch — the
        # D2H delta win survives the topology change (stable piece keys)
        if plan is not None:
            from .elastic import member_addressable
            kept = dropped = 0
            for m in self.members:
                res = m.coordinator.rescale_topology(
                    member_addressable(plan, m.index))
                kept += res["kept"]
                dropped += res["dropped"]
            event["fingerprints_kept"] = kept
            event["fingerprints_dropped"] = dropped
        self.rescale_events.append(event)
        log.info("elastic rescale: %s", event)

    def _seed_peers(self, m: _Member) -> None:
        """Eviction-notice move: the evictee pushes the latest committed
        checkpoint's hottest chunks into the survivors' local pools (AWS
        rebalance gives ≈120 s — the push budget is sized for it), so the
        replacement's restore finds them one NIC hop away."""
        if self.peer_exchange is None:
            return
        opened = self.store.latest_valid()
        if opened is None:
            return
        man, reader = opened
        reader.close()
        try:
            res = self.peer_exchange.seed_from(
                m.index, self.store.pool, sorted(man.chunk_hashes()))
        except OSError as e:            # seeding is best-effort by design
            log.warning("peer seed from member %d failed: %s", m.index, e)
            return
        self.peer_seed_events.append(
            {"t": self.clock.now(), "member": m.index, "step": man.step, **res})

    # -- the run loop -----------------------------------------------------------

    def run(self, *, total_steps: int, step_time_s: float,
            state_elems: int = 1024, max_iterations: int | None = None) -> FleetReport:
        spec = self.spec
        clock = self.clock
        t_start = clock.now()
        template = {"w": np.zeros((state_elems,), np.float32), "step": 0}
        state = {"w": np.zeros((state_elems,), np.float32), "step": 0}
        step = 0
        steps_executed = 0
        lost_steps = 0
        full_outages = 0
        cold = True          # fleet has no in-memory state yet
        last_periodic = clock.now()
        budget = max_iterations or (total_steps * 100 + 10_000)
        for m in self.members:
            m.pool.start()

        it = 0
        while step < total_steps:
            it += 1
            if it > budget:
                break
            alive = [m for m in self.members if self._tick_member(m) is not None]
            if not alive:
                if not cold:
                    # full outage: in-memory replicas gone, must restore
                    cold = True
                    full_outages += 1
                self._advance_to_next_capacity()
                continue
            if cold:
                # a replacement consults surviving peers before the shared
                # store when the fleet runs a peer exchange (read-through:
                # peer hit -> local pool -> decode; miss -> store fallback)
                rt_pool = (self.peer_exchange.read_through(
                               alive[0].index, self.store.pool)
                           if self.peer_exchange is not None else None)
                restored = alive[0].coordinator.restore_latest(
                    template, chunk_pool=rt_pool)
                if restored is not None:
                    state, _man = restored
                    state = {"w": np.asarray(state["w"]), "step": int(state["step"])}
                    lost_steps += max(0, step - state["step"])
                    step = state["step"]
                else:
                    lost_steps += step
                    step = 0
                    state = {"w": np.zeros((state_elems,), np.float32), "step": 0}
                cold = False
            n_alive = len(alive)
            if n_alive != self._last_alive:
                self._record_rescale(n_alive)
                self._last_alive = n_alive
            # elastic DP: fixed global batch -> step stretches with lost capacity
            dur = step_time_s * (self.size / n_alive)
            self.ledger.charge_step(dur)
            step += 1
            steps_executed += 1
            state = {"w": state["w"] + 1.0, "step": step}
            # fleet-owned periodic cadence, written by the current leader
            if (self.policy.periodic_enabled
                    and clock.now() - last_periodic >= self.policy.periodic_interval_s):
                alive[0].coordinator.save_periodic_now(step, state)
                last_periodic = clock.now()
            for m in alive:
                sig = m.coordinator.on_step_end(step, lambda s=state: s,
                                                step_duration_s=dur)
                if sig is Signal.PREEMPTING:
                    m.evictions_seen += 1
                    # the member rides out its notice; replacement provisioning
                    # begins when the platform destroys it (pool.tick above).
                    # Meanwhile the notice window pays for peer seeding: push
                    # the hottest committed chunks to the survivors
                    self._seed_peers(m)

        for m in self.members:
            m.coordinator.flush()
            m.pool.shutdown()
            m.coordinator.close()

        per_provider: dict[str, dict] = {}
        for name, acct in self._accountants.items():
            per_provider[name] = acct.summary(clock.now())
        for m in self.members:
            p = per_provider[m.provider.name]
            p["evictions"] = p.get("evictions", 0) + m.pool.evictions_announced
            p["instances"] = p.get("instances", 0) + m.pool.instances_created
            p["rebalance_recommendations"] = (
                p.get("rebalance_recommendations", 0)
                + m.pool.rebalance_recommendations)
        ckpt = {
            "periodic": sum(m.coordinator.stats.periodic_ckpts for m in self.members),
            "termination": sum(m.coordinator.stats.termination_ckpts for m in self.members),
            "termination_failures": sum(m.coordinator.stats.termination_failures
                                        for m in self.members),
            "periodic_failures": sum(m.coordinator.stats.periodic_failures
                                     for m in self.members),
            "rebalance": sum(m.coordinator.stats.rebalance_ckpts for m in self.members),
            # robustness counters: bounded retries burned on transient IO
            # faults, faults a torture plan injected (0 in clean runs), and
            # periodic saves skipped while a member's storage was degraded
            "io_retries": sum(m.coordinator.stats.io_retries
                              for m in self.members),
            "faults_injected": sum(m.coordinator.stats.faults_injected
                                   for m in self.members),
            "saves_degraded": sum(m.coordinator.stats.saves_degraded
                                  for m in self.members),
            # object-store backend robustness (zeros on a plain POSIX store)
            "backend_retries": sum(m.coordinator.stats.backend_retries
                                   for m in self.members),
            "backend_outages": sum(m.coordinator.stats.backend_outages
                                   for m in self.members),
            "spooled_bytes": sum(m.coordinator.stats.spooled_bytes
                                 for m in self.members),
            # physical bytes pushed to the shared volume: under a delta-mode
            # store this is dirty chunks only, far below N_saves x state size
            "bytes_written": sum(m.coordinator.stats.ckpt_bytes_written
                                 for m in self.members),
            "store_mode": self.store.mode,
            "store_total_bytes": self.store.total_bytes(),
            # peer-exchange accounting (zeros without an exchange fabric)
            "peer_seed_events": len(self.peer_seed_events),
            "peer_seeded_chunks": (self.peer_exchange.stats["seeded_chunks"]
                                   if self.peer_exchange else 0),
            "peer_seeded_bytes": (self.peer_exchange.stats["seeded_bytes"]
                                  if self.peer_exchange else 0),
            "by_provider": {
                name: {
                    "termination": sum(m.coordinator.stats.termination_ckpts
                                       for m in self.members
                                       if m.provider.name == name),
                    "periodic": sum(m.coordinator.stats.periodic_ckpts
                                    for m in self.members
                                    if m.provider.name == name),
                } for name in per_provider
            },
        }
        return FleetReport(
            completed=step >= total_steps,
            total_time_s=clock.now() - t_start,
            steps_executed=steps_executed,
            lost_steps=lost_steps,
            restores=sum(m.coordinator.stats.restores for m in self.members),
            full_outages=full_outages,
            final_state_consistent=bool(np.all(state["w"] == float(step))),
            rescale_events=self.rescale_events,
            per_provider=per_provider,
            checkpoints=ckpt,
            total_usd=sum(p["total_usd"] for p in per_provider.values()),
        )
