"""SpotOnCoordinator — the paper's checkpoint coordinator (Fig. 1).

Runs beside the workload (in-process here; a sidecar in the paper), and owns:

* scheduling **periodic checkpoints** (transparent mode),
* polling the cloud metadata service through its ``CloudProvider`` backend
  (Azure Scheduled Events / AWS IMDS / GCP preempted flag) and, on a
  normalized preempt notice, taking an opportunistic **termination
  checkpoint** (transparent mode only — the application-specific mode
  *cannot checkpoint on demand*, per the paper). Advance *rebalance*
  recommendations (AWS) trigger a proactive checkpoint without stopping,
* on restart, finding the **most recent valid checkpoint** and restoring,
* (beyond paper, needed at 1000-node scale) a **straggler policy** that turns a
  persistently slow instance into a voluntary eviction: checkpoint + replace.

Time accounting is delegated to a ``TimeLedger`` (core/ledger.py): when a
``TimeModel`` is configured (virtual-time benchmarks) the ledger charges
modeled durations to the clock — extract cost for async periodic saves (write
IO overlaps training), extract+write for blocking termination / stage
checkpoints, read cost for restores. In wall-clock mode durations are charged
by physics. With a delta-mode store (the default) write costs are charged on
``CheckpointInfo.new_bytes`` — the dirty chunks actually pushed to the shared
volume — not the logical state size; that is precisely why an urgent
termination checkpoint fits the eviction-notice window at low churn.
Periodic saves additionally run through the **device-delta tracker**
(``checkpoint.device_delta``): per-block fingerprints stay device-resident
between saves, so the extract leg moves only fingerprint-dirty blocks
device→host — the modeled extract cost is charged on ``Snapshot.d2h_bytes``
(the bytes that actually crossed the link), and
``CoordinatorStats``/``TimeLedger`` record ``d2h_bytes`` /
``d2h_bytes_skipped`` plus the extract stall so the saving is observable in
every run report. Urgent and stage saves bypass the tracker.
Checkpoints written through the coordinator carry ``{"provider", "instance"}``
tags in their manifest extras, so a fleet's shared store records which cloud
wrote each checkpoint.

The coordinator also owns **MTTR** (mean time to recovery — eviction to the
first training step completed on the replacement): ``detach`` starts the
window, the first ``on_step_end`` after it closes the window, and samples
accumulate in ``CoordinatorStats.mttr_samples`` plus the ledger's
observation trail (``TimeLedger.observe``). Restores default to the
streaming disk→device pipeline, which is what the fast-resume benchmark
(``benchmarks/resume_bench.py``) measures.
"""

from __future__ import annotations

import enum
import errno
import logging
import time as _time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable

from ..checkpoint import backend as chunk_backend
from ..checkpoint import codec_sched
from ..checkpoint.async_ckpt import AsyncCheckpointer
from ..checkpoint.sharded import Snapshot, extract_snapshot, prestage
from ..checkpoint.store import CheckpointStore
from ..faults import inject as fault_inject
from . import retry
from .clock import Clock, VirtualClock
from .ledger import TimeLedger, TimeModel  # noqa: F401  (TimeModel re-export)
from .policy import CheckpointPolicy, Mode
from .providers import (CloudProvider, PreemptNotice, PREEMPT_KIND,
                        REBALANCE_KIND, get_provider)

log = logging.getLogger("spoton")

# storage faults that describe a state (full/read-only/dead disk) rather
# than an event: a save failing with one of these enters the degradation
# window. EIO is included because the IO layer's bounded retries already
# ran — an EIO surfacing here is persistent by construction.
_STORAGE_FAULT_ERRNOS = frozenset(retry.PERSISTENT_ERRNOS) | {errno.EIO}


def _storage_fault(exc: BaseException | None) -> bool:
    """True when ``exc`` (or any chained cause — async failures arrive
    wrapped in RuntimeError) is a persistent storage-level fault."""
    seen = 0
    while exc is not None and seen < 8:
        if isinstance(exc, OSError) and exc.errno in _STORAGE_FAULT_ERRNOS:
            return True
        exc = exc.__cause__ or exc.__context__
        seen += 1
    return False


class Signal(enum.Enum):
    CONTINUE = "continue"
    PREEMPTING = "preempting"   # stop cleanly before NotBefore
    STRAGGLER = "straggler"     # ask the pool for a replacement


class StragglerDetector:
    """Flags an instance whose step time stays above factor×rolling-median.

    Firing re-arms the detector (window + streak cleared): the flag evicts the
    instance, so stale samples from it must not condemn the replacement — the
    detector needs ``min_samples`` fresh observations before it can fire again.
    """

    def __init__(self, factor: float = 2.0, window: int = 50,
                 min_samples: int = 20, patience: int = 5):
        self.factor = factor
        self.window: deque[float] = deque(maxlen=window)
        self.min_samples = min_samples
        self.patience = patience
        self._slow_streak = 0

    def observe(self, step_duration_s: float) -> bool:
        if len(self.window) >= self.min_samples:
            median = sorted(self.window)[len(self.window) // 2]
            if step_duration_s > self.factor * median:
                self._slow_streak += 1
            else:
                self._slow_streak = 0
        self.window.append(step_duration_s)
        if self._slow_streak >= self.patience:
            self.reset()
            return True
        return False

    def reset(self) -> None:
        self._slow_streak = 0
        self.window.clear()


@dataclass
class CoordinatorStats:
    periodic_ckpts: int = 0
    periodic_failures: int = 0
    termination_ckpts: int = 0
    termination_failures: int = 0
    rebalance_ckpts: int = 0
    stage_ckpts: int = 0
    restores: int = 0
    ckpt_bytes_written: int = 0
    ckpt_time_s: float = 0.0
    restore_time_s: float = 0.0
    # device→host traffic of the save path: bytes that crossed the link vs.
    # bytes the device fingerprint path proved unchanged and never staged,
    # and the cumulative wall time training was stalled inside extract
    d2h_bytes: int = 0
    d2h_bytes_skipped: int = 0
    save_stall_s: float = 0.0
    # restore-QoS scheduler split, from the codec scheduler's RESTORE lane:
    # queue-wait (job submitted → worker picked it up: a starved scheduler)
    # vs decode execution (worker busy on the bytes: a slow disk). Lane
    # counters are process-wide, so under concurrent restores from several
    # coordinators the split is a fleet aggregate, not per-member.
    restore_queue_wait_s: float = 0.0
    restore_decode_s: float = 0.0
    # times a periodic-save encode handed its worker to a higher-priority
    # job at a chunk boundary (cooperative preemption)
    save_yields: int = 0
    # robustness counters (process-wide deltas folded per coordinator, like
    # save_yields): bounded-retry attempts the IO layer burned on transient
    # faults, faults the torture layer injected (0 outside torture runs),
    # and periodic saves skipped-and-alerted while storage was degraded
    # (ENOSPC / persistent EIO) — urgent saves keep committing through it
    io_retries: int = 0
    faults_injected: int = 0
    saves_degraded: int = 0
    # object-store backend robustness (process-wide deltas, like io_retries):
    # bounded-retry attempts burned on backend.get/put/head ops, outage
    # windows the consecutive-failure detector entered, and bytes spooled to
    # the local cache while the store was unreachable (reconciled later)
    backend_retries: int = 0
    backend_outages: int = 0
    spooled_bytes: int = 0
    # consecutive-failure count of the metadata poll at its worst — how
    # close the coordinator came to assuming eviction blind
    poll_failures: int = 0
    # MTTR: eviction (detach) → first training step completed on the
    # replacement. Covers provisioning, restore, recompilation and data
    # fast-forward — the full window the fast-resume pipeline minimizes.
    mttr_samples: list[float] = field(default_factory=list)

    @property
    def mttr_mean_s(self) -> float:
        return (sum(self.mttr_samples) / len(self.mttr_samples)
                if self.mttr_samples else 0.0)


class SpotOnCoordinator:
    def __init__(
        self,
        store: CheckpointStore,
        policy: CheckpointPolicy,
        clock: Clock,
        *,
        provider: CloudProvider | str | None = None,
        mesh_info: dict | None = None,
        time_model: TimeModel | None = None,
        ledger: TimeLedger | None = None,
        straggler: StragglerDetector | None = None,
        device_delta: bool = True,
    ):
        self.store = store
        self.policy = policy
        self.clock = clock
        self.provider = get_provider(provider if provider is not None else "azure")
        self.mesh_info = mesh_info or {}
        self.ledger = ledger if ledger is not None else TimeLedger(clock, time_model)
        self.straggler = straggler
        self.stats = CoordinatorStats()
        self._async = AsyncCheckpointer(store) if policy.async_writes else None
        # device-resident delta detection for periodic saves (delta-mode
        # stores): fingerprints live on device between saves, so unchanged
        # blocks never cross the device→host link. Urgent/termination and
        # application (stage) saves always bypass it.
        self.delta_tracker = None
        if device_delta and store.mode == "delta":
            from ..checkpoint.device_delta import DeviceDeltaTracker
            self.delta_tracker = DeviceDeltaTracker(
                store.pool, chunk_size=store.chunk_size,
                compress=store.compress,
                quantize_moments=store.quantize_moments)
        self._metadata: Any = None
        self._instance_name: str | None = None
        self._last_periodic_at = clock.now()
        self._handled_notices: set[str] = set()
        self._last_poll_at = -float("inf")
        # MTTR bookkeeping: set at detach (the eviction moment), consumed by
        # the first completed step on the replacement instance
        self._evicted_at: float | None = None
        # last-seen global yield count (the scheduler counter is
        # process-wide and monotonic; we fold deltas)
        self._seen_yields = codec_sched.snapshot_stats()["yields"]
        # same delta-folding for the retry layer's and fault injector's
        # process-wide counters
        self._seen_io_retries = retry.snapshot_stats()["io_retries"]
        self._seen_faults = fault_inject.snapshot_stats()["faults_injected"]
        self._seen_backend = chunk_backend.snapshot_stats()
        # storage degradation: while set, periodic saves skip-and-alert
        # until the cooldown passes (urgent saves ignore it — the notice
        # window is always worth attempting). Capped so fleet members,
        # whose own periodic cadence is disabled (interval=inf, the fleet
        # drives saves), still re-probe storage eventually.
        self.degraded_cooldown_s = min(2.0 * policy.periodic_interval_s, 300.0)
        self._degraded_until: float | None = None
        # metadata-poll degradation: after this many consecutive failed
        # polls (each already retried with backoff), assume the instance is
        # evictable and checkpoint proactively instead of flying blind
        self.assume_evictable_after = 3
        self._poll_fail_streak = 0

    @property
    def time_model(self) -> TimeModel | None:
        return self.ledger.time_model

    # -- lifecycle --------------------------------------------------------------

    def attach_instance(self, metadata: Any, name: str) -> None:
        """Bind to the (new) instance's metadata endpoint after (re)start."""
        self._metadata = metadata
        self._instance_name = name
        self._last_periodic_at = self.clock.now()
        if self.straggler is not None:
            self.straggler.reset()

    def detach(self) -> None:
        """Unbind from a dying instance; starts the MTTR clock."""
        self._metadata = None
        self._instance_name = None
        self._evicted_at = self.clock.now()

    # -- checkpoint actions --------------------------------------------------------

    def _tags(self, **extra) -> dict:
        """Provider/instance provenance recorded in each manifest's extras."""
        tags = {"provider": self.provider.name}
        if self._instance_name is not None:
            tags["instance"] = self._instance_name
        tags.update(extra)
        return tags

    def save_periodic_now(self, step: int, state) -> bool:
        """Take one periodic-style checkpoint immediately (used by the fleet
        coordinator, which owns the cadence across members)."""
        return self._save_periodic(step, state)

    def _account_extract(self, snap: Snapshot | None = None, *,
                         d2h_bytes: int = 0, d2h_skipped: int = 0,
                         stall_s: float = 0.0) -> None:
        """Fold one extract's device→host traffic + stall into stats and the
        ledger's audit trail (observations/counters, never clock charges —
        the modeled extract cost is charged separately by the save paths).
        Pass a Snapshot, or the raw numbers (the urgent path only has a
        CheckpointInfo)."""
        if snap is not None:
            d2h_bytes, d2h_skipped, stall_s = (snap.d2h_bytes,
                                               snap.d2h_skipped, snap.stall_s)
        self.stats.d2h_bytes += d2h_bytes
        self.stats.d2h_bytes_skipped += d2h_skipped
        self.stats.save_stall_s += stall_s
        self.ledger.observe("save_stall", stall_s)
        self.ledger.count("d2h_bytes", d2h_bytes)
        self.ledger.count("d2h_bytes_skipped", d2h_skipped)

    def _drain_async_stats(self) -> None:
        """Fold finished background writes into the stats. Periodic/rebalance
        saves account their *physical* bytes here (delta saves write only
        dirty chunks); urgent saves were accounted synchronously. Also folds
        the codec scheduler's cooperative-yield counter (process-wide) so
        run reports show how often background encodes ceded their worker."""
        yields = codec_sched.snapshot_stats()["yields"]
        delta = yields - self._seen_yields
        if delta > 0:
            self._seen_yields = yields
            self.stats.save_yields += delta
            self.ledger.count("save_yields", delta)
        io_retries = retry.snapshot_stats()["io_retries"]
        delta = io_retries - self._seen_io_retries
        if delta > 0:
            self._seen_io_retries = io_retries
            self.stats.io_retries += delta
            self.ledger.count("io_retries", delta)
        injected = fault_inject.snapshot_stats()["faults_injected"]
        delta = injected - self._seen_faults
        if delta > 0:
            self._seen_faults = injected
            self.stats.faults_injected += delta
            self.ledger.count("faults_injected", delta)
        bstats = chunk_backend.snapshot_stats()
        for key in ("backend_retries", "backend_outages", "spooled_bytes"):
            delta = bstats[key] - self._seen_backend[key]
            if delta > 0:
                self._seen_backend[key] = bstats[key]
                setattr(self.stats, key, getattr(self.stats, key) + delta)
                self.ledger.count(key, delta)
        if self._async is None:
            return
        for info in self._async.drain_completed():
            if info.kind != "termination":
                self.stats.ckpt_bytes_written += info.new_bytes
            if getattr(info, "spooled", False):
                # the save is parked in the outage spool, not committed:
                # enter the same skip-and-alert window a storage fault does
                # (reconcile commits the backlog once the store returns)
                self._mark_degraded(RuntimeError(
                    "object store outage: save spooled locally"))

    def _mark_degraded(self, e: BaseException) -> None:
        self.stats.saves_degraded += 1
        self.ledger.count("saves_degraded", 1)
        self._degraded_until = self.clock.now() + self.degraded_cooldown_s
        log.warning(
            "storage degraded (%s): periodic checkpoints skip-and-alert "
            "for %.0fs; urgent saves still attempt", e,
            self.degraded_cooldown_s)

    def _save_periodic(self, step: int, state, *, stat: str = "periodic") -> bool:
        t0 = self.clock.now()
        if self._degraded_until is not None:
            if t0 < self._degraded_until:
                # skip-and-alert: storage said "full/broken" recently enough
                # that re-encoding the full state would only burn compute.
                # The committed history is intact; count the skip so run
                # reports surface the degradation window.
                self.stats.saves_degraded += 1
                self.ledger.count("saves_degraded", 1)
                self._last_periodic_at = t0
                return False
            self._degraded_until = None  # cooldown over: probe storage again
        # prestage at decision time: with the tracker, fingerprint + diff
        # kernels dispatch now (dirty-block gather instead of full DMAs);
        # without it, the device→host copies start before extract gathers
        state = prestage(state, tracker=(self.delta_tracker
                                         if self.store.mode == "delta"
                                         else None))
        try:
            if self._async is not None:
                snap = self._async.save_async(step, state, kind="transparent",
                                              mesh_info=self.mesh_info,
                                              extra=self._tags(),
                                              tracker=self.delta_tracker)
            else:
                snap = extract_snapshot(
                    state, step=step, mesh_info=self.mesh_info,
                    tracker=(self.delta_tracker
                             if self.store.mode == "delta" else None))
                info = self.store.save_snapshot(snap, kind="transparent",
                                                extra=self._tags())
                self.stats.ckpt_bytes_written += info.new_bytes
                if info.spooled:
                    self._mark_degraded(RuntimeError(
                        "object store outage: save spooled locally"))
        except (RuntimeError, OSError) as e:
            # a failed periodic save must not kill training: the committed
            # history is untouched (atomic commit) and the next cadence
            # retries with fresher state
            log.warning("periodic checkpoint failed: %s", e)
            self.stats.periodic_failures += 1
            self._last_periodic_at = self.clock.now()
            if _storage_fault(e):
                # ENOSPC/EDQUOT/EROFS, or EIO that already exhausted the IO
                # layer's bounded retries: a *state*, not an event — enter
                # the skip-and-alert window instead of re-failing each tick
                self._mark_degraded(e)
            return False
        self._account_extract(snap)
        # the extract leg is charged on the bytes that actually crossed the
        # link (the fingerprint path makes this ≪ state size at low churn);
        # only the write leg is conditional — async overlaps it with
        # training, sync pays it for the dirty chunks (info.new_bytes)
        cost = self.ledger.extract_s(snap.d2h_bytes) + (
            0.0 if self._async is not None
            else self.ledger.write_s(info.new_bytes))
        self.ledger.charge(cost, category="ckpt")
        if stat == "rebalance":
            self.stats.rebalance_ckpts += 1
        else:
            self.stats.periodic_ckpts += 1
        self.stats.ckpt_time_s += (self.clock.now() - t0)
        self._last_periodic_at = self.clock.now()
        return True

    def _save_termination(self, step: int, state, deadline: float) -> bool:
        """Opportunistic: returns False if the notice window was missed."""
        t0 = self.clock.now()
        budget = deadline - t0
        if budget <= 0:
            self.stats.termination_failures += 1
            return False
        # urgent saves bypass the device-delta tracker entirely — the notice
        # window cannot pay digest kernels whose results extract would then
        # discard — so the prestage is the plain full-state DMA kick
        state = prestage(state)
        try:
            if self._async is not None:
                info = self._async.save_urgent(step, state, mesh_info=self.mesh_info,
                                               extra=self._tags(),
                                               timeout_s=max(budget, 0.1))
            else:
                snap = extract_snapshot(state, step=step, mesh_info=self.mesh_info)
                info = self.store.save_snapshot(snap, kind="termination",
                                                extra=self._tags())
        except (TimeoutError, RuntimeError, OSError) as e:
            log.warning("termination checkpoint failed: %s", e)
            self.stats.termination_failures += 1
            return False
        self._account_extract(d2h_bytes=info.d2h_bytes,
                              d2h_skipped=info.d2h_bytes_skipped,
                              stall_s=info.save_stall_ms / 1e3)
        # extract covers the bytes that crossed the device→host link (the
        # full state for urgent saves — at 1/4 width for on-device-quantized
        # moments); the write leg is only the chunks the urgent save
        # actually pushed — unchanged chunks of the last snapshot are reused
        # from the pool, which is what keeps the notice-window write minimal
        # under delta mode
        cost = self.ledger.extract_s(info.d2h_bytes) + self.ledger.write_s(info.new_bytes)
        if self.ledger.time_model is not None and cost > budget:
            # virtual-time world: the write would not have finished in time
            self.ledger.charge(budget, category="ckpt")
            self.stats.termination_failures += 1
            return False
        self.ledger.charge(cost, category="ckpt")
        self.stats.termination_ckpts += 1
        self.stats.ckpt_bytes_written += info.new_bytes
        self.stats.ckpt_time_s += (self.clock.now() - t0)
        return True

    def on_stage_end(self, stage: int, step: int, state) -> None:
        """Application-specific checkpoint point (k-mer stage boundary)."""
        if not self.policy.stage_boundary_enabled:
            return
        t0 = self.clock.now()
        snap = extract_snapshot(state, step=step, mesh_info=self.mesh_info)
        info = self.store.save_snapshot(snap, kind="application",
                                        extra=self._tags(stage=stage))
        self._account_extract(snap)
        # app-specific saves are synchronous in the app's critical path; the
        # write leg is physical bytes so the APPLICATION-vs-TRANSPARENT
        # comparison stays symmetric under a delta-mode store
        self.ledger.charge(self.ledger.extract_s(snap.nbytes)
                           + self.ledger.write_s(info.new_bytes), category="ckpt")
        self.stats.stage_ckpts += 1
        self.stats.ckpt_bytes_written += info.new_bytes
        self.stats.ckpt_time_s += (self.clock.now() - t0)

    # -- the per-step hook ----------------------------------------------------------

    def _poll_notices(self, now: float) -> tuple[PreemptNotice | None,
                                                 PreemptNotice | None]:
        """Provider-normalized poll. Returns (preempt, rebalance) — each the
        first not-yet-handled notice of its kind, or None."""
        if self._metadata is None or now - self._last_poll_at < self.policy.poll_interval_s:
            return None, None
        self._last_poll_at = now
        try:
            # bounded retry with jittered backoff around the endpoint read;
            # clock.sleep keeps the backoff fake-clock-testable (and charged
            # in virtual-time worlds, where waiting is never free)
            notices = retry.call_with_retry(
                lambda: self.provider.poll_once(
                    self._metadata, self._instance_name or "", now),
                policy=retry.POLL_RETRY,
                classify=lambda e: (retry.is_transient(e)
                                    or isinstance(e, TimeoutError)),
                sleep=self.clock.sleep,
                describe=f"{self.provider.name} metadata poll")
        except Exception as e:
            # a notice endpoint that stays down is indistinguishable from an
            # eviction about to happen: degrade conservatively rather than
            # crash the coordinator or fly blind
            self._poll_fail_streak += 1
            self.stats.poll_failures = max(self.stats.poll_failures,
                                           self._poll_fail_streak)
            self.ledger.count("poll_failures", 1)
            log.warning("metadata poll failed (%d consecutive): %s",
                        self._poll_fail_streak, e)
            if self._poll_fail_streak % self.assume_evictable_after == 0:
                synthetic = PreemptNotice(
                    event_id=f"assume-evictable-{self._poll_fail_streak}",
                    deadline=now + self.provider.notice_s,
                    kind=REBALANCE_KIND,
                    raw={"reason": "metadata endpoint unreachable"})
                log.warning("assuming evictable after %d failed polls: "
                            "proactive checkpoint", self._poll_fail_streak)
                return None, synthetic
            return None, None
        self._poll_fail_streak = 0
        preempt = rebalance = None
        for n in notices:
            if n.event_id in self._handled_notices:
                continue
            if n.kind == PREEMPT_KIND and preempt is None:
                preempt = n
            elif n.kind == REBALANCE_KIND and rebalance is None:
                rebalance = n
        return preempt, rebalance

    def on_step_end(self, step: int, state_provider: Callable[[], Any],
                    step_duration_s: float | None = None) -> Signal:
        now = self.clock.now()
        if self._evicted_at is not None:
            # first step completed since the eviction: close the MTTR window
            mttr = now - self._evicted_at
            self.stats.mttr_samples.append(mttr)
            self.ledger.observe("mttr", mttr)
            self._evicted_at = None
        self._drain_async_stats()
        # 1. metadata poll (rate-limited like the paper's curl loop)
        preempt, rebalance = self._poll_notices(now)
        # 2. eviction imminent
        if preempt is not None:
            self._handled_notices.add(preempt.event_id)
            log.info("[%s] preempt notice for %s (deadline=%.1f)",
                     self.provider.name, self._instance_name, preempt.deadline)
            if self.policy.supports_on_demand:
                self._save_termination(step, state_provider(),
                                       deadline=preempt.deadline)
            # app-specific mode cannot act (paper semantics) — work since the
            # last stage boundary will be lost.
            self.provider.acknowledge(self._metadata, preempt)
            return Signal.PREEMPTING
        # 2b. rebalance recommendation (AWS): checkpoint proactively, keep going
        if rebalance is not None:
            self._handled_notices.add(rebalance.event_id)
            if (self.policy.supports_on_demand
                    and self.policy.checkpoint_on_rebalance):
                log.info("[%s] rebalance recommendation for %s: proactive ckpt",
                         self.provider.name, self._instance_name)
                self._save_periodic(step, state_provider(), stat="rebalance")
        # 3. periodic checkpoint
        if (self.policy.periodic_enabled
                and now - self._last_periodic_at >= self.policy.periodic_interval_s):
            self._save_periodic(step, state_provider())
        # 4. straggler policy
        if (self.straggler is not None and step_duration_s is not None
                and self.straggler.observe(step_duration_s)):
            log.warning("instance %s flagged as straggler", self._instance_name)
            if self.policy.supports_on_demand:
                self._save_termination(step, state_provider(),
                                       deadline=self.clock.now() + 3600.0)
            return Signal.STRAGGLER
        return Signal.CONTINUE

    # -- restart ----------------------------------------------------------------------

    def rescale_topology(self, addressable=None) -> dict[str, int]:
        """Elastic topology change: remap the device-delta tracker's
        fingerprints instead of invalidating them (see
        ``DeviceDeltaTracker.rescale``). ``addressable(name, lo, hi,
        total)`` says whether this process still owns a global byte span
        under the new mesh; None = fully-replicated DP, everything
        survives. No-op without a tracker."""
        if self.delta_tracker is None:
            return {"kept": 0, "dropped": 0}
        return self.delta_tracker.rescale(addressable)

    def restore_latest(self, template, *, streaming: bool = True,
                       chunk_pool=None):
        """Most-recent-valid restore; returns (state, manifest) or None.

        ``streaming`` (default) pipelines disk→decode→device transfers —
        bit-identical state, shorter resume leg of the MTTR window. The
        modeled read cost is charged under the ``restore`` category either
        way (the schedule changes, the bytes moved do not); on top of it
        the *measured* wall time of the decode is charged under
        ``restore_wall`` — the restore physically executes even in virtual
        mode, so two restores that contended differently land at different
        clock readings instead of collapsing onto the model's constant.
        The RESTORE-lane scheduler deltas across the call split that wall
        time into queue-wait (starved scheduler) vs decode (slow disk) on
        both ``CoordinatorStats`` and the ledger's observation trail."""
        t0 = self.clock.now()
        sched0 = codec_sched.snapshot_stats()["restore"]
        w0 = _time.perf_counter()
        try:
            state, man = self.store.restore(template, streaming=streaming,
                                            chunk_pool=chunk_pool)
        except FileNotFoundError:
            return None
        wall = _time.perf_counter() - w0
        sched1 = codec_sched.snapshot_stats()["restore"]
        queue_wait = sched1["queue_wait_s"] - sched0["queue_wait_s"]
        decode = sched1["exec_s"] - sched0["exec_s"]
        self.stats.restore_queue_wait_s += queue_wait
        self.stats.restore_decode_s += decode
        self.ledger.observe("restore_queue_wait", queue_wait)
        self.ledger.observe("restore_decode", decode)
        nbytes = sum(t["nbytes"] for t in man.tensors)
        self.ledger.charge(self.ledger.read_s(nbytes), category="restore")
        self.ledger.charge_measured(wall, category="restore_wall")
        self.stats.restores += 1
        self.stats.restore_time_s += (self.clock.now() - t0)
        return state, man

    def flush(self) -> None:
        if self._async is not None:
            try:
                self._async.wait_until_finished()
            except RuntimeError as e:
                log.warning("async checkpoint write failed at flush: %s", e)
                self.stats.periodic_failures += 1
                if _storage_fault(e):
                    self._mark_degraded(e)
            self._drain_async_stats()

    def close(self) -> None:
        if self._async is not None:
            try:
                self._async.close()
            except RuntimeError as e:
                log.warning("async checkpoint write failed at close: %s", e)
                self.stats.periodic_failures += 1
            self._drain_async_stats()
            self._async = None
